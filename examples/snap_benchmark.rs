//! End-to-end driver (the repository's headline validation run):
//! regenerate the paper's full evaluation — Table 1 (runtimes + the
//! `cat` bound), the §4.4 memory comparison, and Table 2 (F1 + NMI) —
//! on the six SNAP-shaped workloads.
//!
//!     cargo run --release --example snap_benchmark           # scale 0.1
//!     SCALE=0.05 cargo run --release --example snap_benchmark
//!
//! Results for the recorded run live in EXPERIMENTS.md.

use streamcom::bench::memory::{edge_list_bytes, fmt_bytes, sketch_bytes};
use streamcom::bench::report::Table;
use streamcom::bench::table1::{self, Table1Config};
use streamcom::bench::table2::{self, Table2Config};
use streamcom::bench::workloads;

fn main() {
    let scale: f64 = std::env::var("SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(workloads::DEFAULT_SCALE);
    println!("# snap_benchmark at scale {scale} (of the DESIGN.md §3 stand-in sizes)\n");

    // --- Table 1: execution times + readonly bound --------------------
    let (t1, rows1) = table1::run(&Table1Config { scale, ..Default::default() });
    println!("{}", t1.render());
    for r in &rows1 {
        if let Some(s) = table1::speedup_vs_fastest_baseline(r) {
            println!(
                "  {:<16} STR speedup vs fastest baseline {s:>6.1}x; STR/read {:.1}x",
                r.name,
                r.str_secs / r.readonly_secs.max(1e-12)
            );
        }
    }
    println!();

    // --- Memory (§4.4) -------------------------------------------------
    let graphs = workloads::load_all(scale, None, true);
    let mut tm = Table::new(
        "Memory (§4.4)",
        &["dataset", "edge list", "STR sketch", "ratio"],
    );
    for g in &graphs {
        let el = edge_list_bytes(g.m() as u64);
        let sk = sketch_bytes(g.n() as u64);
        tm.push_row(vec![
            g.name.clone(),
            fmt_bytes(el),
            fmt_bytes(sk),
            format!("{:.1}x", el as f64 / sk as f64),
        ]);
    }
    println!("{}", tm.render());

    // --- Table 2: detection quality ------------------------------------
    let (t2, rows2) = table2::run(&Table2Config { scale, ..Default::default() });
    println!("{}", t2.render());

    // --- headline summary ----------------------------------------------
    println!("headline checks:");
    let all_speedups_over_10x = rows1
        .iter()
        .filter_map(table1::speedup_vs_fastest_baseline)
        .all(|s| s > 10.0);
    println!("  STR >10x faster than every baseline on every row: {all_speedups_over_10x}");
    let mut str_wins = 0;
    let mut louvain_rows = 0;
    for r in rows2.iter().filter(|r| {
        matches!(r.name.as_str(), "youtube-s" | "livejournal-s" | "orkut-s")
    }) {
        if let Some((lf1, _)) = r.baseline_scores[1] {
            louvain_rows += 1;
            if r.str_scores.0 > lf1 {
                str_wins += 1;
            }
        }
    }
    println!("  STR beats Louvain on large rows: {str_wins}/{louvain_rows}");
}
