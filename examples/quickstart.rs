//! Quickstart: generate a small planted-partition graph, stream-cluster
//! it with the paper's algorithm, and score against ground truth.
//!
//!     cargo run --release --example quickstart

use streamcom::coordinator::algorithm::{StrConfig, StreamingClusterer};
use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::metrics::{f1, labels_to_communities, modularity, nmi};

fn main() {
    // 10 communities of 100 nodes; intra edges 10x more likely than inter
    let g = sbm::generate(&SbmConfig::equal(10, 100, 0.10, 0.001, 42));
    println!("graph: n={} m={} (planted 10 communities)", g.n(), g.m());

    // one pass over the edge stream, three integers per node
    let mut clusterer = StreamingClusterer::new(g.n(), StrConfig::new(1024));
    let t0 = std::time::Instant::now();
    clusterer.process_chunk(&g.edges.edges);
    let elapsed = t0.elapsed();

    let labels = clusterer.labels();
    let truth = g.truth.to_labels(g.n());
    println!(
        "clustered {} edges in {:?} ({:.1} Medges/s)",
        g.m(),
        elapsed,
        g.m() as f64 / elapsed.as_secs_f64() / 1e6
    );
    println!(
        "found {} communities (stats: {} joins, {} same-community, {} threshold rejects)",
        labels_to_communities(&labels).len(),
        clusterer.stats.joins,
        clusterer.stats.same_community,
        clusterer.stats.threshold_rejects,
    );
    println!(
        "scores: F1={:.3}  NMI={:.3}  modularity={:.3}",
        f1::average_f1_labels(&labels, &truth),
        nmi::nmi_labels(&labels, &truth),
        modularity::modularity(g.n(), &g.edges.edges, &labels),
    );
    println!(
        "sketch memory: {} bytes = 16 B/node (the paper's three integers)",
        clusterer.state.memory_bytes()
    );
}
