//! Dynamic-graph scenario (the paper's §5 future work): an evolving
//! network processed as an insert+delete event stream. A sliding-window
//! workload is synthesised from planted partitions whose *structure
//! rotates* between epochs: communities dissolve and re-form, old edges
//! expire, new ones arrive.
//!
//! Two trackers are compared per epoch:
//! * **dynamic** — the §5 insert+delete sketch maintained continuously.
//!   Deletions reverse the volume/degree updates but (by design — the
//!   3-int sketch has no edge memory) never split communities, so
//!   quality goes *stale* as structure rotates.
//! * **re-stream** — a fresh one-pass run over the current live window:
//!   the cheap repair the paper's O(m) cost makes affordable.
//!
//!     cargo run --release --example dynamic_graph

use streamcom::coordinator::algorithm::{cluster_edges, StrConfig};
use streamcom::coordinator::dynamic::{DynamicClusterer, Event};
use streamcom::graph::edge::Edge;
use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::metrics::{f1::average_f1_labels, nmi::nmi_labels};
use streamcom::util::rng::Xoshiro256;

fn main() {
    let epochs = 4;
    let window = 8_000; // live-edge budget (sliding window)
    let v_max = 96;
    let mut rng = Xoshiro256::new(2017);
    let mut d = DynamicClusterer::new(0, StrConfig::new(v_max));
    let mut live: std::collections::VecDeque<Edge> = Default::default();

    println!("dynamic stream: {epochs} epochs, sliding window of {window} edges\n");
    println!(
        "{:<8} {:>8} {:>9}   {:>12} {:>12}   {:>14}",
        "epoch", "+edges", "ms", "dynamic F1", "dynamic NMI", "re-stream F1"
    );
    for epoch in 0..epochs {
        // each epoch has a different planted structure over the same nodes
        let g = sbm::generate(&SbmConfig::equal(12, 80, 0.18, 0.002, 1000 + epoch));
        let truth = g.truth.to_labels(g.n());

        let t0 = std::time::Instant::now();
        for &e in &g.edges.edges {
            d.apply(Event::Insert(e)).unwrap();
            live.push_back(e);
            if live.len() > window {
                let old = live.pop_front().unwrap();
                d.apply(Event::Delete(old)).unwrap();
            }
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;

        let labels = d.labels();
        let n = truth.len().min(labels.len());
        let window_edges: Vec<Edge> = live.iter().copied().collect();
        let fresh = cluster_edges(n, &window_edges, v_max);

        println!(
            "{:<8} {:>8} {:>8.1}   {:>12.3} {:>12.3}   {:>14.3}",
            epoch,
            g.m(),
            ms,
            average_f1_labels(&labels[..n], &truth[..n]),
            nmi_labels(&labels[..n], &truth[..n]),
            average_f1_labels(&fresh[..n], &truth[..n]),
        );
        // invariant check after every epoch
        assert_eq!(d.state().total_volume(), 2 * d.live_edges());
    }
    println!(
        "\n(the dynamic sketch goes stale as structure rotates — deletions\n\
         cannot split communities without edge memory; the one-pass\n\
         re-stream of the live window is the affordable repair)"
    );

    // churn test: random deletions of live edges never break the sketch
    let mut deleted = 0;
    while deleted < 5_000 && !live.is_empty() {
        let idx = rng.range(0, live.len());
        let e = live[idx];
        live.remove(idx);
        d.apply(Event::Delete(e)).unwrap();
        deleted += 1;
    }
    assert_eq!(d.state().total_volume(), 2 * d.live_edges());
    println!(
        "\nafter {deleted} random deletions: live={} Σvol={} (= 2·live ✓)",
        d.live_edges(),
        d.state().total_volume()
    );
}
