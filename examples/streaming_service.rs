//! Streaming-service scenario: edges arrive continuously at a
//! [`ClusterService`] — N shard workers behind bounded mailboxes, with
//! periodic cross-edge drains — while a *concurrent* query thread keeps
//! asking for point lookups (`community_of`), top-k community
//! summaries, and operational stats. Exactly the "graphs are
//! fundamentally dynamic and edges naturally arrive in a streaming
//! fashion" deployment the paper's introduction motivates, now as a
//! long-lived subsystem instead of a batch run.
//!
//! At the end the service's partition is scored against ground truth
//! and against the batch parallel coordinator on the same stream — the
//! two are the same algorithm (deferred cross-edge resolution), so the
//! quality must match.
//!
//!     cargo run --release --example streaming_service

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use streamcom::coordinator::parallel::{run_parallel, ParallelConfig};
use streamcom::graph::generators::presets::SNAP_PRESETS;
use streamcom::metrics::f1::average_f1_labels;
use streamcom::metrics::nmi::nmi_labels;
use streamcom::service::{ClusterService, ServiceConfig};
use streamcom::stream::source::OwnedMemorySource;

fn main() {
    // livejournal-shaped workload arriving as a live stream
    let g = streamcom::bench::workloads::load_preset(&SNAP_PRESETS[3], 0.2, true);
    let truth = g.truth.to_labels(g.n());
    let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let v_max = (2 * g.m() / g.n()).max(4) as u64 * 8;
    println!(
        "service: streaming {} (n={} m={}) across {shards} shards, v_max={v_max}",
        g.name,
        g.n(),
        g.m()
    );

    let mut config = ServiceConfig::new(shards, v_max);
    config.drain_every = (g.m() as u64 / 20).max(4_096);
    let mut service = ClusterService::start(config);
    let queries = service.handle();

    // concurrent read traffic: sample a point lookup + stats 20×/s
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let n = g.n() as u32;
    let reader = std::thread::spawn(move || {
        let mut probes = 0u64;
        while !stop2.load(Ordering::Relaxed) {
            let s = queries.stats();
            let node = (probes * 7919) as u32 % n.max(1);
            let comm = queries.community_of(node);
            if probes % 10 == 0 {
                println!(
                    "  [query] t={:>9} edges  {:>6.2} Medges/s  lag={:>7}  \
                     node {node} → {comm}  queues={:?}",
                    s.edges_ingested,
                    s.edges_per_sec / 1e6,
                    s.edges_ingested.saturating_sub(s.snapshot_edges),
                    s.queue_depths,
                );
            }
            probes += 1;
            std::thread::sleep(Duration::from_millis(50));
        }
        probes
    });

    // ingest the full stream (push blocks on hot shards: backpressure)
    let mut source = OwnedMemorySource::new(g.edges.edges.clone());
    service.ingest(&mut source, 8_192);
    let result = service.finish();
    stop.store(true, Ordering::Relaxed);
    let probes = reader.join().expect("query thread panicked");

    let labels = result.snapshot.labels_padded(g.n());
    println!(
        "\nfinal: {} edges ({} cross) in {:.2}s ({:.2} Medges/s) with {probes} live probes",
        result.edges_ingested,
        result.cross_edges,
        result.elapsed.as_secs_f64(),
        result.edges_ingested as f64 / result.elapsed.as_secs_f64().max(1e-12) / 1e6,
    );
    println!(
        "service : F1={:.3} NMI={:.3}",
        average_f1_labels(&labels, &truth),
        nmi_labels(&labels, &truth)
    );

    // parity: the batch coordinator on the same stream
    let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(shards, v_max));
    let par_labels = par.labels();
    println!(
        "batch   : F1={:.3} NMI={:.3} (same sharding, run offline)",
        average_f1_labels(&par_labels, &truth),
        nmi_labels(&par_labels, &truth)
    );
}
