//! Streaming-service scenario: edges arrive continuously through the
//! backpressured pipeline while the coordinator maintains the
//! multi-parameter sketch; every `report_every` edges the §2.5
//! selection runs (through the PJRT metric engine when artifacts are
//! built, else the native engine) and the service reports the current
//! best clustering — exactly the "graphs are fundamentally dynamic and
//! edges naturally arrive in a streaming fashion" deployment the
//! paper's introduction motivates.
//!
//!     cargo run --release --example streaming_service

use streamcom::coordinator::selection::{select, MetricEngine, NativeEngine, SelectionRule};
use streamcom::coordinator::sweep::MultiSweep;
use streamcom::graph::generators::presets::SNAP_PRESETS;
use streamcom::metrics::f1::average_f1_labels;
use streamcom::runtime::PjrtEngine;
use streamcom::stream::chunk::{ChunkConfig, ChunkStream};
use streamcom::stream::meter::Meter;
use streamcom::stream::source::OwnedMemorySource;

fn main() {
    // livejournal-shaped workload arriving as a live stream
    let g = streamcom::bench::workloads::load_preset(&SNAP_PRESETS[3], 0.25, true);
    let truth = g.truth.to_labels(g.n());
    println!("service: streaming {} (n={} m={})", g.name, g.n(), g.m());

    let mut pjrt = PjrtEngine::load_default().ok();
    println!(
        "metric engine: {}",
        if pjrt.is_some() { "pjrt (AOT JAX/Pallas artifacts)" } else { "native fallback" }
    );

    let avg_deg = (2 * g.m() / g.n()).max(4) as u64;
    let ladder = MultiSweep::geometric_ladder(avg_deg, 8);
    let mut sweep = MultiSweep::new(0, ladder.clone());

    let source = OwnedMemorySource::new(g.edges.edges.clone());
    let stream = ChunkStream::spawn(source, ChunkConfig { chunk_size: 16_384, depth: 4 });

    let report_every = (g.m() / 5).max(1) as u64;
    let mut next_report = report_every;
    let mut meter = Meter::start();
    let mut selection_time = std::time::Duration::ZERO;

    while let Some(chunk) = stream.next_chunk() {
        sweep.process_chunk(&chunk);
        meter.add_edges(chunk.len() as u64);

        if sweep.edges_processed >= next_report {
            next_report += report_every;
            let t0 = std::time::Instant::now();
            let engine: &mut dyn MetricEngine = match &mut pjrt {
                Some(e) => e,
                None => &mut NativeEngine,
            };
            let (winner, scores) = select(&sweep, engine, SelectionRule::DensityScore);
            selection_time += t0.elapsed();
            let snap = meter.snapshot();
            println!(
                "t={:>9} edges  {:>6.1} Medges/s  selected v_max={:<6} ncomms={:<7.0} H={:.2}",
                sweep.edges_processed,
                snap.edges_per_sec() / 1e6,
                ladder[winner],
                scores[winner].ncomms,
                scores[winner].entropy,
            );
        }
    }

    let report = meter.finish();
    let engine: &mut dyn MetricEngine = match &mut pjrt {
        Some(e) => e,
        None => &mut NativeEngine,
    };
    let (winner, _) = select(&sweep, engine, SelectionRule::DensityScore);
    let labels = sweep.labels(winner);
    println!(
        "\nfinal: v_max={} F1={:.3} | stream {:.2}s total, selection {:.1}ms total ({:.2}% of stream time)",
        ladder[winner],
        average_f1_labels(&labels, &truth),
        report.elapsed.as_secs_f64(),
        selection_time.as_secs_f64() * 1e3,
        100.0 * selection_time.as_secs_f64() / report.elapsed.as_secs_f64(),
    );
}
