"""AOT pipeline tests: HLO-text artifacts are produced and well-formed.

These validate the Python half of the interchange contract; the Rust
integration test (`rust/tests/runtime_integration.rs`) validates the
other half by loading and executing the same artifacts via PJRT.
"""

from __future__ import annotations

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    return aot.build_artifacts(str(out)), str(out)


def test_all_artifacts_written(artifacts):
    written, out = artifacts
    assert set(written) == set(model.example_args())
    for path in written.values():
        assert os.path.getsize(path) > 100


def test_hlo_text_is_parseable_hlo(artifacts):
    """Artifacts must be HLO text modules with an ENTRY computation and
    no custom-calls (a Mosaic custom-call would be unloadable on CPU
    PJRT — the interpret=True contract)."""
    written, _ = artifacts
    for name, path in written.items():
        text = open(path).read()
        assert text.lstrip().startswith("HloModule"), name
        assert "ENTRY" in text, name
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_entry_shapes_in_hlo(artifacts):
    """The ENTRY signature must carry the DESIGN.md §7 shapes."""
    written, _ = artifacts
    sweep = open(written["sweep_metrics"]).read()
    assert "f32[8,4096]" in sweep
    assert "f32[8,6]" in sweep
    mod = open(written["modularity"]).read()
    assert "s32[4096]" in mod
    assert "f32[2]" in mod
    nmi = open(written["nmi"]).read()
    assert "f32[256,256]" in nmi
    assert "f32[3]" in nmi


def test_manifest_lists_every_artifact(artifacts):
    written, out = artifacts
    manifest = open(os.path.join(out, "manifest.txt")).read()
    for name in written:
        assert name in manifest


def test_outputs_are_tuples(artifacts):
    """Lowered with return_tuple=True: ENTRY root must be a tuple —
    the Rust side unwraps with to_tuple1()."""
    written, _ = artifacts
    for name, path in written.items():
        text = open(path).read()
        # The entry computation's ROOT should produce a tuple type like (f32[8,6])
        assert "ROOT" in text, name
