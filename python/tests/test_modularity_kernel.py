"""Kernel-vs-oracle tests for the modularity-partials Pallas kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.modularity_kernel import B_TILE, modularity_partials

B, K = ref.EDGE_BLOCK, ref.VOLUME_BUCKETS


def _check(ci, cj, mask, vols, rtol=2e-5):
    got = np.asarray(
        modularity_partials(jnp.array(ci), jnp.array(cj), jnp.array(mask), jnp.array(vols))
    )
    exp = np.asarray(
        ref.modularity_partials_ref(jnp.array(ci), jnp.array(cj), jnp.array(mask), jnp.array(vols))
    )
    np.testing.assert_allclose(got, exp, rtol=rtol, atol=1e-4)
    return got


def _block(rng, ncomm=64, density=0.9):
    ci = rng.integers(0, ncomm, B).astype(np.int32)
    cj = rng.integers(0, ncomm, B).astype(np.int32)
    mask = (rng.random(B) < density).astype(np.float32)
    vols = (rng.random(K) * 50).astype(np.float32)
    return ci, cj, mask, vols


def test_random_blocks():
    for seed in range(5):
        _check(*_block(np.random.default_rng(seed)))


def test_all_intra():
    """ci == cj everywhere → intra equals the mask sum."""
    rng = np.random.default_rng(3)
    ci = rng.integers(0, 10, B).astype(np.int32)
    mask = (rng.random(B) < 0.8).astype(np.float32)
    vols = np.zeros(K, np.float32)
    out = _check(ci, ci.copy(), mask, vols)
    np.testing.assert_allclose(out[0], mask.sum(), rtol=1e-6)
    assert out[1] == 0.0


def test_all_inter():
    """Disjoint label ranges → zero intra edges."""
    ci = np.zeros(B, np.int32)
    cj = np.ones(B, np.int32)
    mask = np.ones(B, np.float32)
    vols = np.ones(K, np.float32)
    out = _check(ci, cj, mask, vols)
    assert out[0] == 0.0
    np.testing.assert_allclose(out[1], float(K), rtol=1e-6)


def test_mask_zero_ignores_everything():
    rng = np.random.default_rng(5)
    ci, cj, _, vols = _block(rng)
    out = _check(ci, cj, np.zeros(B, np.float32), vols)
    assert out[0] == 0.0


def test_volsq_known_value():
    vols = np.zeros(K, np.float32)
    vols[:4] = np.array([1.0, 2.0, 3.0, 4.0])
    out = _check(
        np.zeros(B, np.int32), np.zeros(B, np.int32), np.zeros(B, np.float32), vols
    )
    np.testing.assert_allclose(out[1], 30.0, rtol=1e-6)


def test_b_tile_divides_block():
    assert B % B_TILE == 0


def test_modularity_composition():
    """End-to-end: combining partials reproduces direct modularity.

    Q = intra/m - volsq/(2m)^2 for a small planted two-community graph,
    cross-checked against a direct O(n^2) computation.
    """
    rng = np.random.default_rng(11)
    n, ncomm = 64, 2
    labels = np.arange(n) % ncomm
    # planted partition: p_in = 0.5, p_out = 0.05
    edges = []
    for i in range(n):
        for j in range(i + 1, n):
            p = 0.5 if labels[i] == labels[j] else 0.05
            if rng.random() < p:
                edges.append((i, j))
    m = len(edges)
    deg = np.zeros(n)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    w = 2.0 * m
    # direct modularity
    q_direct = 0.0
    adj = set(edges)
    for i in range(n):
        for j in range(n):
            wij = 1.0 if ((i, j) in adj or (j, i) in adj) else 0.0
            if labels[i] == labels[j]:
                q_direct += wij - deg[i] * deg[j] / w
    q_direct /= w

    # kernel path
    ci = np.full(B, -1, np.int32)
    cj = np.full(B, -2, np.int32)
    mask = np.zeros(B, np.float32)
    for b, (i, j) in enumerate(edges):
        ci[b], cj[b], mask[b] = labels[i], labels[j], 1.0
    vols = np.zeros(K, np.float32)
    for c in range(ncomm):
        vols[c] = deg[labels == c].sum()
    out = _check(ci, cj, mask, vols)
    q_kernel = out[0] / m - out[1] / (w * w)
    np.testing.assert_allclose(q_kernel, q_direct, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    ncomm=st.integers(1, 4096),
    density=st.floats(0.0, 1.0),
)
def test_hypothesis_blocks(seed, ncomm, density):
    rng = np.random.default_rng(seed)
    _check(*_block(rng, ncomm=ncomm, density=density))
