"""Kernel-vs-oracle tests for the sweep-metrics Pallas kernel.

The CORE correctness signal for L1: the kernel must agree with the
pure-jnp oracle on every input the Rust runtime can feed it, including
the degenerate sketches the coordinator actually produces (all
singletons, one giant community, empty rows, zero weight).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.metrics_kernel import K_TILE, sweep_metrics

A, K = ref.NUM_SWEEPS, ref.VOLUME_BUCKETS


def _check(vols, sizes, w, rtol=2e-5, atol=1e-5):
    got = np.asarray(sweep_metrics(jnp.array(vols), jnp.array(sizes), jnp.array(w)))
    exp = np.asarray(ref.sweep_metrics_ref(jnp.array(vols), jnp.array(sizes), jnp.array(w)))
    np.testing.assert_allclose(got, exp, rtol=rtol, atol=atol)
    return got


def _sketch(rng, max_size=6, max_mult=5):
    sizes = rng.integers(0, max_size, (A, K)).astype(np.float32)
    vols = (sizes * rng.integers(1, max_mult, (A, K))).astype(np.float32)
    w = np.maximum(vols.sum(axis=1), 1.0).astype(np.float32)
    return vols, sizes, w


def test_shapes_and_dtype():
    vols, sizes, w = _sketch(np.random.default_rng(1))
    out = _check(vols, sizes, w)
    assert out.shape == (A, 4)
    assert out.dtype == np.float32


def test_random_sketches_match_oracle():
    for seed in range(5):
        _check(*_sketch(np.random.default_rng(seed)))


def test_all_zero_sketch():
    z = np.zeros((A, K), np.float32)
    out = _check(z, z, np.zeros(A, np.float32))
    np.testing.assert_array_equal(out, np.zeros((A, 4), np.float32))


def test_single_giant_community():
    """All mass in bucket 0: H = 0, ncomms = 1."""
    vols = np.zeros((A, K), np.float32)
    sizes = np.zeros((A, K), np.float32)
    vols[:, 0] = 1000.0
    sizes[:, 0] = 100.0
    w = np.full(A, 1000.0, np.float32)
    out = _check(vols, sizes, w)
    np.testing.assert_allclose(out[:, 0], 0.0, atol=1e-6)  # entropy
    np.testing.assert_allclose(out[:, 3], 1.0)             # ncomms
    np.testing.assert_allclose(out[:, 2], 1.0, rtol=1e-6)  # balance = 1


def test_all_singletons():
    """Every node its own community: density contributions are all zero."""
    vols = np.ones((A, K), np.float32)
    sizes = np.ones((A, K), np.float32)
    w = vols.sum(axis=1).astype(np.float32)
    out = _check(vols, sizes, w)
    np.testing.assert_allclose(out[:, 1], 0.0, atol=1e-7)   # density
    np.testing.assert_allclose(out[:, 3], float(K))          # ncomms
    # uniform distribution: H = log K
    np.testing.assert_allclose(out[:, 0], np.log(K), rtol=1e-5)


def test_uniform_k_communities_entropy():
    """k equal communities → H = log k, balance = 1/k."""
    for k in (2, 16, 256):
        vols = np.zeros((A, K), np.float32)
        sizes = np.zeros((A, K), np.float32)
        vols[:, :k] = 10.0
        sizes[:, :k] = 4.0
        w = np.full(A, 10.0 * k, np.float32)
        out = _check(vols, sizes, w)
        np.testing.assert_allclose(out[:, 0], np.log(k), rtol=1e-5)
        np.testing.assert_allclose(out[:, 2], 1.0 / k, rtol=1e-5)


def test_density_two_node_communities():
    """|C| = 2, v = 2 → per-community density 2/(2·1) = 1, so D = 1."""
    vols = np.zeros((A, K), np.float32)
    sizes = np.zeros((A, K), np.float32)
    vols[:, :8] = 2.0
    sizes[:, :8] = 2.0
    w = np.full(A, 16.0, np.float32)
    out = _check(vols, sizes, w)
    np.testing.assert_allclose(out[:, 1], 1.0, rtol=1e-6)


def test_rows_are_independent():
    """Permuting sweep rows permutes the output rows identically."""
    vols, sizes, w = _sketch(np.random.default_rng(7))
    base = np.asarray(sweep_metrics(jnp.array(vols), jnp.array(sizes), jnp.array(w)))
    perm = np.random.default_rng(8).permutation(A)
    permed = np.asarray(
        sweep_metrics(jnp.array(vols[perm]), jnp.array(sizes[perm]), jnp.array(w[perm]))
    )
    np.testing.assert_allclose(permed, base[perm], rtol=1e-6)


def test_k_tile_divides_buckets():
    assert K % K_TILE == 0


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.5, 1e4),
    fill=st.floats(0.01, 1.0),
)
def test_hypothesis_value_sweep(seed, scale, fill):
    """Property: oracle agreement holds across magnitudes and sparsity."""
    rng = np.random.default_rng(seed)
    mask = (rng.random((A, K)) < fill).astype(np.float32)
    sizes = mask * rng.integers(1, 8, (A, K)).astype(np.float32)
    vols = sizes * rng.random((A, K)).astype(np.float32) * scale
    w = np.maximum(vols.sum(axis=1), 1e-3).astype(np.float32)
    _check(vols, sizes, w, rtol=5e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_scale_invariance_of_entropy(seed):
    """H and balance depend only on v/w — scaling both is a no-op."""
    rng = np.random.default_rng(seed)
    vols, sizes, w = _sketch(rng)
    a = np.asarray(sweep_metrics(jnp.array(vols), jnp.array(sizes), jnp.array(w)))
    b = np.asarray(sweep_metrics(jnp.array(vols * 4), jnp.array(sizes), jnp.array(w * 4)))
    np.testing.assert_allclose(a[:, 0], b[:, 0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(a[:, 2], b[:, 2], rtol=1e-4, atol=1e-6)
