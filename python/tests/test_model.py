"""L2 model tests: wrapper shapes, selection-score semantics."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

A, K = ref.NUM_SWEEPS, ref.VOLUME_BUCKETS


def _sketch_with(k_communities, comm_size, vol_per):
    """A sweep row with `k_communities` equal communities."""
    vols = np.zeros(K, np.float32)
    sizes = np.zeros(K, np.float32)
    vols[:k_communities] = vol_per
    sizes[:k_communities] = comm_size
    return vols, sizes, vols.sum()


def test_sweep_model_output_shape():
    vols = np.random.default_rng(0).random((A, K)).astype(np.float32)
    sizes = np.ones((A, K), np.float32)
    w = vols.sum(axis=1).astype(np.float32)
    out = np.asarray(model.sweep_metrics_model(jnp.array(vols), jnp.array(sizes), jnp.array(w)))
    assert out.shape == (A, 6)


def test_density_score_prefers_good_partition_over_singletons():
    """The selector must rank a dense clustered sketch above the
    all-singletons degenerate sketch (the failure mode of naive density).
    """
    vols = np.zeros((A, K), np.float32)
    sizes = np.zeros((A, K), np.float32)
    w = np.zeros(A, np.float32)
    # row 0: 32 dense communities of 8 nodes, vol 40 each
    v, s, tot = _sketch_with(32, 8.0, 40.0)
    vols[0], sizes[0], w[0] = v, s, tot
    # row 1: all singletons (v = 1 each)
    vols[1] = 1.0
    sizes[1] = 1.0
    w[1] = float(K)
    out = np.asarray(
        model.sweep_metrics_model(jnp.array(vols), jnp.array(sizes), jnp.array(w))
    )
    density_score = out[:, 4]
    assert density_score[0] > density_score[1]


def test_model_matches_kernel_metrics_columns():
    rng = np.random.default_rng(4)
    sizes = rng.integers(0, 6, (A, K)).astype(np.float32)
    vols = sizes * 3.0
    w = np.maximum(vols.sum(axis=1), 1.0).astype(np.float32)
    out = np.asarray(model.sweep_metrics_model(jnp.array(vols), jnp.array(sizes), jnp.array(w)))
    exp = np.asarray(ref.sweep_metrics_ref(jnp.array(vols), jnp.array(sizes), jnp.array(w)))
    np.testing.assert_allclose(out[:, :4], exp, rtol=2e-4, atol=1e-5)
    # derived columns
    np.testing.assert_allclose(out[:, 4], exp[:, 1] * np.log1p(exp[:, 3]), rtol=1e-4)
    np.testing.assert_allclose(out[:, 5], exp[:, 0] - exp[:, 2], rtol=1e-4, atol=1e-5)


def test_example_args_cover_all_artifacts():
    names = set(model.example_args().keys())
    assert names == {"sweep_metrics", "modularity", "nmi"}


def test_example_args_shapes_match_design():
    ea = model.example_args()
    sm_args = ea["sweep_metrics"][1]
    assert sm_args[0].shape == (A, K)
    mod_args = ea["modularity"][1]
    assert mod_args[0].shape == (ref.EDGE_BLOCK,)
    assert mod_args[3].shape == (K,)
    nmi_args = ea["nmi"][1]
    assert nmi_args[0].shape == (ref.CONTINGENCY, ref.CONTINGENCY)
