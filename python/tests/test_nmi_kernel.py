"""Kernel-vs-oracle tests for the NMI contingency Pallas kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.nmi_kernel import nmi_terms

C = ref.CONTINGENCY


def _check(cont, rtol=2e-4):
    got = np.asarray(nmi_terms(jnp.array(cont)))
    exp = np.asarray(ref.nmi_terms_ref(jnp.array(cont)))
    np.testing.assert_allclose(got, exp, rtol=rtol, atol=1e-5)
    return got


def test_random_tables():
    for seed in range(5):
        cont = np.random.default_rng(seed).integers(0, 30, (C, C)).astype(np.float32)
        _check(cont)


def test_perfect_match_diagonal():
    """Identity contingency → I = H_U = H_V (NMI = 1)."""
    cont = np.zeros((C, C), np.float32)
    k = 16
    for i in range(k):
        cont[i, i] = 10.0
    out = _check(cont)
    mi, hu, hv = out
    np.testing.assert_allclose(mi, hu, rtol=1e-5)
    np.testing.assert_allclose(mi, hv, rtol=1e-5)
    np.testing.assert_allclose(mi, np.log(k), rtol=1e-5)


def test_independent_partitions():
    """Rank-one table (outer product of marginals) → I = 0."""
    rng = np.random.default_rng(2)
    a = rng.random(C).astype(np.float32)
    b = rng.random(C).astype(np.float32)
    cont = np.outer(a, b).astype(np.float32) * 100
    out = _check(cont)
    np.testing.assert_allclose(out[0], 0.0, atol=1e-3)


def test_empty_table():
    out = _check(np.zeros((C, C), np.float32))
    np.testing.assert_array_equal(out, np.zeros(3, np.float32))


def test_symmetry():
    """I(U;V) = I(V;U); H swaps."""
    cont = np.random.default_rng(9).integers(0, 10, (C, C)).astype(np.float32)
    a = _check(cont)
    b = _check(cont.T.copy())
    np.testing.assert_allclose(a[0], b[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(a[1], b[2], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(a[2], b[1], rtol=1e-4, atol=1e-5)


def test_mi_bounded_by_entropies():
    for seed in range(3):
        cont = np.random.default_rng(seed).integers(0, 50, (C, C)).astype(np.float32)
        mi, hu, hv = _check(cont)
        assert mi <= min(hu, hv) + 1e-3
        assert mi >= -1e-4


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), sparsity=st.floats(0.001, 1.0))
def test_hypothesis_sparse_tables(seed, sparsity):
    rng = np.random.default_rng(seed)
    cont = rng.integers(0, 100, (C, C)).astype(np.float32)
    cont *= (rng.random((C, C)) < sparsity).astype(np.float32)
    _check(cont)
