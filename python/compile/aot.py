"""AOT pipeline: lower every L2 entry point to HLO *text* artifacts.

HLO text — NOT ``lowered.compile()`` or serialised ``HloModuleProto`` — is
the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
which the ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage (from ``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts

Emits ``<name>.hlo.txt`` per artifact plus ``manifest.txt`` recording the
input/output shapes the Rust runtime validates against.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_str(s) -> str:
    return f"{s.dtype}[{','.join(str(d) for d in s.shape)}]"


def build_artifacts(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    written = {}
    for name, (fn, args) in model.example_args().items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *args)
        out_desc = (
            _shape_str(out_shapes)
            if hasattr(out_shapes, "shape")
            else ";".join(_shape_str(s) for s in out_shapes)
        )
        in_desc = ";".join(_shape_str(s) for s in args)
        manifest_lines.append(f"{name} in={in_desc} out={out_desc}")
        written[name] = path
        print(f"wrote {path} ({len(text)} chars)  in={in_desc} out={out_desc}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return written


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--out", default=None, help="(compat) ignored if --out-dir given")
    args = p.parse_args()
    out_dir = args.out_dir
    if args.out and not args.out_dir:
        out_dir = os.path.dirname(args.out) or "."
    build_artifacts(out_dir)


if __name__ == "__main__":
    main()
