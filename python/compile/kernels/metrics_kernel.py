"""L1 Pallas kernel: sweep-sketch scoring (entropy / density / balance).

This is the §2.5 selection hot-spot: the multi-parameter run keeps ``A``
concurrent ``(c, v)`` sketches and must score each of them *without the
graph*, using only the community volume/size tables.

TPU mapping (DESIGN.md §6): the ``(A, K)`` tables are tiled ``(1, K_TILE)``
into VMEM via ``BlockSpec``; each grid step computes the partial row
reductions on the VPU and accumulates into the ``(1, 4)`` output block,
which stays resident across the K-tile loop (output index map ignores the
K grid axis). ``K_TILE = 512`` → 2 inputs × 512 × 4 B = 4 KiB live VMEM per
step, leaving room for double buffering of the HBM→VMEM pipeline.

Runs with ``interpret=True`` everywhere in this repo: the CPU PJRT client
cannot execute Mosaic custom-calls, and interpret mode lowers to plain HLO
so the AOT artifact is executable from Rust.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

K_TILE = 512


def _sweep_metrics_kernel(vols_ref, sizes_ref, w_ref, out_ref):
    """Grid = (A, K // K_TILE). Accumulates the four row statistics."""
    kt = pl.program_id(1)

    vols = vols_ref[...]          # (1, K_TILE)
    sizes = sizes_ref[...]        # (1, K_TILE)
    w = w_ref[...]                # (1,)

    w_safe = jnp.where(w > 0.0, w, 1.0)[0]
    p = jnp.where(w[0] > 0.0, vols / w_safe, 0.0)

    # entropy partial: -sum p log p  (0 log 0 := 0)
    logp = jnp.log(jnp.where(p > 0.0, p, 1.0))
    h_part = -jnp.sum(jnp.where(p > 0.0, p * logp, 0.0))

    # density numerator partial: sum over |C_k| > 1 of v_k / (s_k (s_k - 1))
    denom = sizes * (sizes - 1.0)
    d_part = jnp.sum(
        jnp.where(sizes > 1.0, vols / jnp.where(denom > 0.0, denom, 1.0), 0.0)
    )

    # balance partial: sum p^2
    b_part = jnp.sum(p * p)

    # non-empty community count partial
    n_part = jnp.sum((sizes > 0.0).astype(vols.dtype))

    partial = jnp.stack([h_part, d_part, b_part, n_part])[None, :]  # (1, 4)

    @pl.when(kt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=())
def sweep_metrics_raw(vols, sizes, w):
    """Accumulated [H, D_num, balance, ncomms] per sweep row, f32[A, 4].

    ``D_num`` is the *unnormalised* density sum; `sweep_metrics` divides by
    ``ncomms`` afterwards (the division needs the full row, so it lives
    outside the tile loop).
    """
    a, k = vols.shape
    assert k % K_TILE == 0, f"K={k} must be a multiple of K_TILE={K_TILE}"
    grid = (a, k // K_TILE)
    return pl.pallas_call(
        _sweep_metrics_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((1, K_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a, 4), vols.dtype),
        interpret=True,
    )(vols, sizes, w)


def sweep_metrics(vols, sizes, w):
    """Kernel-backed equivalent of :func:`ref.sweep_metrics_ref`."""
    raw = sweep_metrics_raw(vols, sizes, w)
    h, d_num, bal, ncomms = raw[:, 0], raw[:, 1], raw[:, 2], raw[:, 3]
    density = jnp.where(ncomms > 0.0, d_num / jnp.where(ncomms > 0.0, ncomms, 1.0), 0.0)
    return jnp.stack([h, density, bal, ncomms], axis=1)
