"""L1 Pallas kernel: block-streamed modularity partial sums.

Modularity (paper §3.1):

    Q = (1/w) [ sum_ij w_ij δ(i,j) - sum_C Vol(C)^2 / w ]

The Rust coordinator evaluates Q periodically without storing the stream:
it replays buffered *blocks* of edges (a bounded sample) through this
kernel together with the current community-volume table, and combines the
partial sums. The kernel computes, per call:

    out[0] = sum_b mask_b · 1{ci_b == cj_b}   (intra-community edges)
    out[1] = sum_k vols_k^2                   (squared volume mass)

TPU mapping: edge labels are tiled ``(B_TILE,)`` into VMEM; the
volume table is a single ``(K,)`` block (4096 · 4 B = 16 KiB) folded in on
the first grid step only. Equality + masked sum are VPU ops; the kernel is
bandwidth-bound, so ``B_TILE = 1024`` keeps the HBM→VMEM pipeline full.

interpret=True as everywhere (see metrics_kernel.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

B_TILE = 1024


def _modularity_kernel(ci_ref, cj_ref, mask_ref, vols_ref, out_ref):
    """Grid = (B // B_TILE,). out = f32[2] accumulated across tiles."""
    bt = pl.program_id(0)

    ci = ci_ref[...]
    cj = cj_ref[...]
    mask = mask_ref[...]

    intra = jnp.sum(mask * (ci == cj).astype(mask.dtype))

    @pl.when(bt == 0)
    def _init():
        vols = vols_ref[...]
        out_ref[0] = 0.0
        out_ref[1] = jnp.sum(vols * vols)

    out_ref[0] += intra


@jax.jit
def modularity_partials(ci, cj, mask, vols):
    """Kernel-backed equivalent of :func:`ref.modularity_partials_ref`.

    Args:
      ci, cj: i32[B] endpoint community labels (B multiple of B_TILE).
      mask:   f32[B] edge validity mask.
      vols:   f32[K] current community volumes.

    Returns:
      f32[2] = [intra_edges, sum vols^2].
    """
    (b,) = ci.shape
    assert b % B_TILE == 0, f"B={b} must be a multiple of B_TILE={B_TILE}"
    grid = (b // B_TILE,)
    return pl.pallas_call(
        _modularity_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B_TILE,), lambda i: (i,)),
            pl.BlockSpec((B_TILE,), lambda i: (i,)),
            pl.BlockSpec((B_TILE,), lambda i: (i,)),
            pl.BlockSpec(vols.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), mask.dtype),
        interpret=True,
    )(ci, cj, mask, vols)
