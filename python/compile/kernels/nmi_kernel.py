"""L1 Pallas kernel: NMI contingency reduction.

Computes the three information terms of a padded ``(C, C)`` contingency
table between detected communities and ground truth:

    out = [ I(U;V), H(U), H(V) ]   (nats)

The Rust scorer builds the table (top-C classes per side + tail bucket,
see ``rust/src/metrics/nmi.rs``) and normalises the result
(NMI_max or NMI_avg).

TPU mapping: C = 256 → the whole table is one 256 KiB VMEM block; row and
column marginals plus the log-ratio sum are VPU reductions over a single
tile, so no grid is needed. For larger C this would tile rows
``(C_TILE, C)`` with marginal accumulation; at C = 256 single-block is
both simplest and fastest.

interpret=True as everywhere (see metrics_kernel.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xlogx(p):
    return jnp.where(p > 0.0, p * jnp.log(jnp.where(p > 0.0, p, 1.0)), 0.0)


def _nmi_kernel(cont_ref, out_ref):
    cont = cont_ref[...]
    total = jnp.sum(cont)
    n = jnp.where(total > 0.0, total, 1.0)
    pij = cont / n
    pi = jnp.sum(pij, axis=1)
    pj = jnp.sum(pij, axis=0)
    outer = pi[:, None] * pj[None, :]
    ratio = jnp.where(
        (pij > 0.0) & (outer > 0.0),
        pij / jnp.where(outer > 0.0, outer, 1.0),
        1.0,
    )
    mi = jnp.sum(jnp.where(pij > 0.0, pij * jnp.log(ratio), 0.0))
    h_u = -jnp.sum(_xlogx(pi))
    h_v = -jnp.sum(_xlogx(pj))
    out_ref[...] = jnp.stack([mi, h_u, h_v])


@jax.jit
def nmi_terms(cont):
    """Kernel-backed equivalent of :func:`ref.nmi_terms_ref`."""
    return pl.pallas_call(
        _nmi_kernel,
        out_shape=jax.ShapeDtypeStruct((3,), cont.dtype),
        interpret=True,
    )(cont)
