"""Pure-jnp reference oracles for the Pallas kernels.

These are the *correctness ground truth* for the L1 kernels: every Pallas
kernel in this package must match its `*_ref` twin to float32 tolerance.
pytest (``python/tests/``) enforces this, including hypothesis sweeps over
shapes and value ranges.

The three computations are the quality-metric engine of the paper
(Hollocou et al. 2017):

* ``sweep_metrics_ref`` — §2.5 sketch-only selection scores: for each of
  the ``A`` concurrent ``v_max`` sweeps, compute entropy ``H(v)``, average
  density ``D(c, v)``, a volume-balance score, and the number of non-empty
  communities, from the padded ``(A, K)`` community volume/size tables.
* ``modularity_partials_ref`` — the two streaming partial sums needed to
  evaluate modularity over an edge block: the intra-community edge count
  and the squared-volume sum (Rust combines blocks and normalises).
* ``nmi_terms_ref`` — mutual information and marginal entropies of a
  detected-vs-ground-truth contingency matrix (Rust normalises).
"""

from __future__ import annotations

import jax.numpy as jnp

# Fixed AOT shapes — must stay in sync with DESIGN.md §7 and
# rust/src/runtime/artifacts.rs.
NUM_SWEEPS = 8          # A — concurrent v_max values in the sweep
VOLUME_BUCKETS = 4096   # K — padded community buckets per sweep
EDGE_BLOCK = 4096       # B — edges per modularity block
CONTINGENCY = 256       # C — padded classes per side of the NMI table


def _safe_xlogx(p):
    """x * log(x) with the 0·log(0) = 0 convention, elementwise."""
    return jnp.where(p > 0.0, p * jnp.log(jnp.where(p > 0.0, p, 1.0)), 0.0)


def sweep_metrics_ref(vols, sizes, w):
    """Score each sweep row from its community-volume sketch.

    Args:
      vols:  f32[A, K] community volumes (padded with zeros).
      sizes: f32[A, K] community sizes in nodes (padded with zeros).
      w:     f32[A]    total graph weight (2m) per sweep row.

    Returns:
      f32[A, 4] with columns:
        0: entropy      H(v)   = -sum_k (v_k/w) log(v_k/w)   over v_k > 0
        1: avg density  D(c,v) = (1/|P|) sum_{k: |C_k|>1} v_k/(|C_k|(|C_k|-1))
        2: balance      sum_k (v_k/w)^2  (inverse-Simpson concentration)
        3: ncomms       |P| = #{k : |C_k| > 0}
    """
    w_col = w[:, None]
    p = jnp.where(w_col > 0.0, vols / jnp.where(w_col > 0.0, w_col, 1.0), 0.0)
    entropy = -jnp.sum(_safe_xlogx(p), axis=1)

    nonempty = (sizes > 0.0).astype(vols.dtype)
    ncomms = jnp.sum(nonempty, axis=1)
    denom = sizes * (sizes - 1.0)
    dens_term = jnp.where(sizes > 1.0, vols / jnp.where(denom > 0.0, denom, 1.0), 0.0)
    density = jnp.where(
        ncomms > 0.0,
        jnp.sum(dens_term, axis=1) / jnp.where(ncomms > 0.0, ncomms, 1.0),
        0.0,
    )

    balance = jnp.sum(p * p, axis=1)
    return jnp.stack([entropy, density, balance, ncomms], axis=1)


def modularity_partials_ref(ci, cj, mask, vols):
    """Partial sums for block-streamed modularity evaluation.

    Args:
      ci, cj: i32[B] community labels of the two endpoints of each edge.
      mask:   f32[B] 1.0 for valid edges, 0.0 for padding.
      vols:   f32[K] community volumes of the *current* partition.

    Returns:
      f32[2]: [ sum_b mask_b * 1{ci_b == cj_b},  sum_k vols_k^2 ].

    Rust combines blocks: Q = intra_total/m - volsq/(2m)^2.
    """
    intra = jnp.sum(mask * (ci == cj).astype(mask.dtype))
    volsq = jnp.sum(vols * vols)
    return jnp.stack([intra, volsq])


def nmi_terms_ref(cont):
    """Mutual information + marginal entropies of a contingency table.

    Args:
      cont: f32[C, C] joint counts n_{uv} (detected u, truth v), padded
            with zeros.

    Returns:
      f32[3]: [ I(U;V), H(U), H(V) ] in nats. NMI_max = I / max(H_U, H_V),
      NMI_avg = 2I / (H_U + H_V); normalisation is done by the caller.
    """
    total = jnp.sum(cont)
    n = jnp.where(total > 0.0, total, 1.0)
    pij = cont / n
    pi = jnp.sum(pij, axis=1)
    pj = jnp.sum(pij, axis=0)
    outer = pi[:, None] * pj[None, :]
    ratio = jnp.where((pij > 0.0) & (outer > 0.0), pij / jnp.where(outer > 0.0, outer, 1.0), 1.0)
    mi = jnp.sum(jnp.where(pij > 0.0, pij * jnp.log(ratio), 0.0))
    h_u = -jnp.sum(_safe_xlogx(pi))
    h_v = -jnp.sum(_safe_xlogx(pj))
    return jnp.stack([mi, h_u, h_v])
