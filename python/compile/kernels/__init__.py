"""L1 Pallas kernels + pure-jnp reference oracles.

Kernel modules (each with an interpret-mode Pallas implementation):

* :mod:`.metrics_kernel`    — sweep-sketch scoring (entropy/density/balance)
* :mod:`.modularity_kernel` — block-streamed modularity partial sums
* :mod:`.nmi_kernel`        — NMI contingency reduction

:mod:`.ref` holds the oracles and the fixed AOT shape constants.
"""

from . import metrics_kernel, modularity_kernel, nmi_kernel, ref

__all__ = ["metrics_kernel", "modularity_kernel", "nmi_kernel", "ref"]
