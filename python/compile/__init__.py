"""Build-time compile package: L2 model + L1 kernels + AOT pipeline.

Never imported at runtime — the Rust binary consumes only the HLO-text
artifacts this package emits via ``python -m compile.aot``.
"""
