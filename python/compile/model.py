"""L2 — the JAX compute graph over the L1 Pallas kernels.

Three jittable entry points, each AOT-lowered to an HLO-text artifact by
:mod:`compile.aot` and executed from the Rust runtime
(``rust/src/runtime/``). Shapes are fixed at lowering time
(DESIGN.md §7); Rust pads its inputs.

Python never runs at serving/streaming time — these functions exist only
to be traced, lowered and serialised.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import metrics_kernel, modularity_kernel, nmi_kernel
from .kernels.ref import CONTINGENCY, EDGE_BLOCK, NUM_SWEEPS, VOLUME_BUCKETS


def sweep_metrics_model(vols, sizes, w):
    """Score the A sweep sketches and rank them.

    Returns f32[A, 6]: the four kernel metrics plus two derived selection
    scores used by ``coordinator/selection.rs``:

      col 4: density_score = D · log(1 + ncomms)   (the §2.5 selector —
             prefers dense partitions but penalises the all-singletons
             degenerate answer which has |P| = n)
      col 5: balance_score = H - balance           (entropy-driven
             alternative selector)
    """
    m = metrics_kernel.sweep_metrics(vols, sizes, w)
    h, d, bal, ncomms = m[:, 0], m[:, 1], m[:, 2], m[:, 3]
    density_score = d * jnp.log1p(ncomms)
    balance_score = h - bal
    return jnp.concatenate(
        [m, density_score[:, None], balance_score[:, None]], axis=1
    )


def modularity_model(ci, cj, mask, vols):
    """Block modularity partials; see modularity_kernel for the contract."""
    return modularity_kernel.modularity_partials(ci, cj, mask, vols)


def nmi_model(cont):
    """NMI information terms; see nmi_kernel for the contract."""
    return nmi_kernel.nmi_terms(cont)


def example_args():
    """ShapeDtypeStructs for AOT lowering, keyed by artifact name."""
    f32 = jnp.float32
    i32 = jnp.int32
    return {
        "sweep_metrics": (
            sweep_metrics_model,
            (
                jax.ShapeDtypeStruct((NUM_SWEEPS, VOLUME_BUCKETS), f32),
                jax.ShapeDtypeStruct((NUM_SWEEPS, VOLUME_BUCKETS), f32),
                jax.ShapeDtypeStruct((NUM_SWEEPS,), f32),
            ),
        ),
        "modularity": (
            modularity_model,
            (
                jax.ShapeDtypeStruct((EDGE_BLOCK,), i32),
                jax.ShapeDtypeStruct((EDGE_BLOCK,), i32),
                jax.ShapeDtypeStruct((EDGE_BLOCK,), f32),
                jax.ShapeDtypeStruct((VOLUME_BUCKETS,), f32),
            ),
        ),
        "nmi": (
            nmi_model,
            (jax.ShapeDtypeStruct((CONTINGENCY, CONTINGENCY), f32),),
        ),
    }
