//! OSLOM-lite — local statistical significance optimisation
//! (Lancichinetti et al. 2011) — the paper's baseline **O**.
//!
//! The original OSLOM scores a community by the order statistics of its
//! members' connection significance under the configuration null model,
//! adding/removing nodes until the community is locally optimal. This
//! implementation keeps that core loop with the standard simplification
//! (documented in DESIGN.md §3):
//!
//! * **Seeding** — communities from a Louvain pass (OSLOM's documented
//!   "cleanup mode" analyses and refines partitions produced by other
//!   methods; the original also self-seeds from singleton expansion).
//! * **Significance** — a node with degree `d` and `k_in` edges into a
//!   community of volume `vol` is scored by the binomial tail
//!   `P[Bin(d, vol/2m) ≥ k_in]`; members above `p_threshold` are pruned
//!   and border nodes below it are absorbed, iterating to a fixed
//!   point. This is OSLOM's single-node significance test without the
//!   order-statistics correction — the correction changes the threshold
//!   calibration, not the qualitative behaviour.
//!
//! Like the original, the refinement is the expensive part; Table 1's
//! blank cells for OSLOM beyond DBLP are mirrored by `practical_for`.

use std::collections::HashMap;

use crate::graph::csr::Csr;
use crate::util::rng::Xoshiro256;

use super::louvain::Louvain;
use super::CommunityDetector;

/// OSLOM-style significance-based baseline (lite).
pub struct OslomLite {
    /// RNG seed.
    pub seed: u64,
    /// Significance threshold for *moving into* a community (p-value).
    pub p_threshold: f64,
    /// Laxer threshold for *staying*: a member is evicted to a singleton
    /// only when even its own community looks random (p > this). The
    /// asymmetry replaces OSLOM's order-statistics correction, which
    /// similarly protects existing members on small communities.
    pub evict_threshold: f64,
    /// Refinement iteration cap.
    pub max_iters: usize,
}

impl OslomLite {
    /// Reference thresholds (p=0.1, evict=0.5, 6 iterations).
    pub fn new(seed: u64) -> Self {
        Self { seed, p_threshold: 0.1, evict_threshold: 0.5, max_iters: 6 }
    }

    /// Upper binomial tail P[Bin(n, p) >= k], computed stably in log
    /// space (exact summation, n is a node degree so small).
    fn binom_tail(n: u64, p: f64, k: u64) -> f64 {
        if k == 0 {
            return 1.0;
        }
        if p <= 0.0 {
            return 0.0;
        }
        if p >= 1.0 {
            return 1.0;
        }
        let ln_p = p.ln();
        let ln_q = (1.0 - p).ln();
        // log C(n, i) built incrementally
        let mut ln_c = 0.0f64; // C(n, 0)
        let mut tail = 0.0f64;
        for i in 0..=n {
            if i >= k {
                tail += (ln_c + i as f64 * ln_p + (n - i) as f64 * ln_q).exp();
            }
            if i < n {
                ln_c += ((n - i) as f64).ln() - ((i + 1) as f64).ln();
            }
        }
        tail.min(1.0)
    }

    /// Detect communities; returns per-node labels.
    pub fn run(&self, g: &Csr) -> Vec<u32> {
        let n = g.n;
        let two_m = g.total_weight() as f64;
        if two_m == 0.0 {
            return (0..n as u32).collect();
        }
        // seed (OSLOM cleanup mode: refine a Louvain partition)
        let mut labels = Louvain::new(self.seed ^ 0xBEEF).run(g);
        let mut rng = Xoshiro256::new(self.seed);

        for _ in 0..self.max_iters {
            // aggregates: community volume
            let mut vol: HashMap<u32, u64> = HashMap::new();
            for u in 0..n as u32 {
                *vol.entry(labels[u as usize]).or_insert(0) += g.degree(u) as u64;
            }

            let mut order: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut order);
            let mut changed = 0usize;
            let mut k_in: HashMap<u32, u64> = HashMap::new();
            for &u in &order {
                let d = g.degree(u) as u64;
                if d == 0 {
                    continue;
                }
                let cu = labels[u as usize];
                k_in.clear();
                for &v in g.neighbors(u) {
                    *k_in.entry(labels[v as usize]).or_insert(0) += 1;
                }
                // significance of u in each candidate community
                let score = |c: u32, k: u64, vol: &HashMap<u32, u64>| -> f64 {
                    let vc = vol.get(&c).copied().unwrap_or(0) as f64;
                    // exclude u's own degree from the community volume
                    let vc = if c == cu { (vc - d as f64).max(0.0) } else { vc };
                    let p = (vc / two_m).min(1.0);
                    Self::binom_tail(d, p, k)
                };
                let p_stay = score(cu, k_in.get(&cu).copied().unwrap_or(0), &vol);
                let (mut best_c, mut best_p) = (cu, p_stay);
                // sorted iteration: HashMap order is per-process random,
                // and ties must resolve identically across runs
                let mut cands: Vec<(u32, u64)> = k_in.iter().map(|(&c, &k)| (c, k)).collect();
                cands.sort_unstable_by_key(|&(c, _)| c);
                for (c, k) in cands {
                    if c == cu {
                        continue;
                    }
                    let pv = score(c, k, &vol);
                    if pv < best_p {
                        best_p = pv;
                        best_c = c;
                    }
                }
                // prune: move only on significance; evict to a singleton
                // only when even the current community looks random
                let target = if best_c != cu && best_p <= self.p_threshold {
                    best_c
                } else if p_stay > self.evict_threshold {
                    u
                } else {
                    cu
                };
                if target != cu {
                    *vol.entry(cu).or_insert(0) -= d.min(*vol.get(&cu).unwrap_or(&0));
                    *vol.entry(target).or_insert(0) += d;
                    labels[u as usize] = target;
                    changed += 1;
                }
            }
            if changed == 0 {
                break;
            }
        }
        super::normalize_labels(&mut labels);
        labels
    }
}

impl CommunityDetector for OslomLite {
    fn tag(&self) -> &'static str {
        "O"
    }

    fn name(&self) -> &'static str {
        "OSLOM-lite"
    }

    fn detect(&mut self, graph: &Csr) -> Vec<u32> {
        self.run(graph)
    }

    fn practical_for(&self, _n: usize, m: usize) -> bool {
        // mirrors Table 1: OSLOM ran only on Amazon/DBLP
        m <= 2_000_000
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::{Edge, EdgeList};
    use crate::graph::generators::sbm::{self, SbmConfig};
    use crate::metrics::nmi::nmi_labels;

    #[test]
    fn binom_tail_edge_cases() {
        assert_eq!(OslomLite::binom_tail(10, 0.5, 0), 1.0);
        assert!((OslomLite::binom_tail(10, 0.5, 11) - 0.0).abs() < 1e-12);
        // P[Bin(2, 0.5) >= 1] = 0.75
        assert!((OslomLite::binom_tail(2, 0.5, 1) - 0.75).abs() < 1e-12);
        // P[Bin(4, 0.25) >= 4] = (1/4)^4
        assert!((OslomLite::binom_tail(4, 0.25, 4) - 0.25f64.powi(4)).abs() < 1e-12);
    }

    #[test]
    fn binom_tail_monotone_in_k() {
        let mut prev = 1.0;
        for k in 0..=12 {
            let t = OslomLite::binom_tail(12, 0.3, k);
            assert!(t <= prev + 1e-15, "not monotone at k={k}");
            prev = t;
        }
    }

    #[test]
    fn splits_two_triangles() {
        let edges = vec![
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(0, 2),
            Edge::new(3, 4),
            Edge::new(4, 5),
            Edge::new(3, 5),
            Edge::new(2, 3),
        ];
        let csr = Csr::from_edge_list(&EdgeList::new(6, edges));
        let labels = OslomLite::new(1).run(&csr);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn recovers_sbm_partition() {
        let g = sbm::generate(&SbmConfig::equal(5, 40, 0.4, 0.005, 12));
        let csr = Csr::from_edge_list(&g.edges);
        let labels = OslomLite::new(2).run(&csr);
        let truth = g.truth.to_labels(g.n());
        let nmi = nmi_labels(&labels, &truth);
        assert!(nmi > 0.7, "nmi={nmi}");
    }
}
