//! The paper's five comparison algorithms, implemented from scratch
//! (the authors used the original C++ releases; DESIGN.md §3 documents
//! the substitution), plus label propagation as a sanity baseline.
//!
//! | paper tag | module      | approach                                   |
//! |-----------|-------------|--------------------------------------------|
//! | S         | [`scd`]     | WCC / triangle-based partitioning          |
//! | L         | [`louvain`] | modularity optimisation                    |
//! | I         | [`infomap`] | map-equation compression of random walks   |
//! | W         | [`walktrap`]| random-walk distances + agglomeration      |
//! | O         | [`oslom`]   | local statistical significance (lite)      |
//! | —         | [`labelprop`]| asynchronous label propagation            |
//!
//! Every algorithm implements [`CommunityDetector`] over a [`Csr`]
//! (the non-streaming algorithms legitimately need the whole graph in
//! memory — that contrast *is* the paper's Table 1 memory argument).

pub mod infomap;
pub mod labelprop;
pub mod louvain;
pub mod oslom;
pub mod scd;
pub mod walktrap;

use crate::graph::csr::Csr;

/// A whole-graph community-detection algorithm.
pub trait CommunityDetector {
    /// Short tag used in the report tables (`S`, `L`, `I`, `W`, `O`, …).
    fn tag(&self) -> &'static str;
    fn name(&self) -> &'static str;
    /// Detect communities; returns one label per node.
    fn detect(&mut self, graph: &Csr) -> Vec<u32>;
    /// Whether the algorithm is practical at the given size (mirrors the
    /// paper's blank Table-1 cells: Walktrap/OSLOM/Infomap time out on
    /// the large graphs).
    fn practical_for(&self, n: usize, m: usize) -> bool {
        let _ = (n, m);
        true
    }
}

/// Instantiate the full paper benchmark suite (in Table-1 column order).
pub fn paper_suite(seed: u64) -> Vec<Box<dyn CommunityDetector>> {
    vec![
        Box::new(scd::Scd::new(seed)),
        Box::new(louvain::Louvain::new(seed)),
        Box::new(infomap::Infomap::new(seed)),
        Box::new(walktrap::Walktrap::new(4)),
        Box::new(oslom::OslomLite::new(seed)),
    ]
}

/// Renumber labels to dense 0..k (stable by first appearance).
pub fn normalize_labels(labels: &mut [u32]) {
    use std::collections::HashMap;
    let mut map: HashMap<u32, u32> = HashMap::new();
    let mut next = 0u32;
    for l in labels.iter_mut() {
        let e = map.entry(*l).or_insert_with(|| {
            let v = next;
            next += 1;
            v
        });
        *l = *e;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_labels_dense_stable() {
        let mut l = vec![7, 7, 3, 9, 3];
        normalize_labels(&mut l);
        assert_eq!(l, vec![0, 0, 1, 2, 1]);
    }

    #[test]
    fn paper_suite_has_five_algorithms() {
        let suite = paper_suite(0);
        let tags: Vec<&str> = suite.iter().map(|a| a.tag()).collect();
        assert_eq!(tags, vec!["S", "L", "I", "W", "O"]);
    }
}
