//! Infomap — two-level map-equation optimisation (Rosvall & Bergstrom
//! 2008) — the paper's baseline **I**.
//!
//! For an undirected graph the random walk's stationary distribution is
//! degree-proportional, so the two-level map equation reduces to the
//! closed form over modules `m`:
//!
//!   L(M) = plogp(Σ_m q_m)  −  2 Σ_m plogp(q_m)
//!          −  Σ_α plogp(p_α)  +  Σ_m plogp(p_m + q_m)
//!
//! with `p_α = deg(α)/2w` the node visit rates, `p_m` the sum over the
//! module's nodes, `q_m = cut(m)/2w` the module exit probability, and
//! `plogp(x) = x·log₂(x)`. (Standard formulation; the node-rate term is
//! constant and kept only so L matches the published values.)
//!
//! Optimisation mirrors the reference implementation's core loop:
//! Louvain-style local moving on ΔL with module aggregation between
//! levels, seeded from singletons.

use std::collections::HashMap;

use crate::graph::csr::Csr;
use crate::util::rng::Xoshiro256;

use super::CommunityDetector;

#[inline]
fn plogp(x: f64) -> f64 {
    if x > 0.0 {
        x * x.log2()
    } else {
        0.0
    }
}

/// Weighted graph view reused across aggregation levels.
struct WGraph {
    adj: Vec<Vec<(u32, f64)>>,
    wdeg: Vec<f64>,
    total: f64, // 2w
}

impl WGraph {
    fn from_csr(g: &Csr) -> Self {
        let mut adj = Vec::with_capacity(g.n);
        let mut wdeg = vec![0.0; g.n];
        for u in 0..g.n as u32 {
            let mut run: Vec<(u32, f64)> = Vec::new();
            for &v in g.neighbors(u) {
                if let Some(last) = run.last_mut() {
                    if last.0 == v {
                        last.1 += 1.0;
                        continue;
                    }
                }
                run.push((v, 1.0));
            }
            wdeg[u as usize] = run.iter().map(|&(_, w)| w).sum();
            adj.push(run);
        }
        let total = wdeg.iter().sum();
        WGraph { adj, wdeg, total }
    }

    fn n(&self) -> usize {
        self.adj.len()
    }
}

/// Module statistics for the map equation.
#[derive(Debug, Clone, Default)]
struct Modules {
    /// p_m — visit-rate mass per module.
    p: Vec<f64>,
    /// q_m — exit probability per module.
    q: Vec<f64>,
}

impl Modules {
    /// Map-equation value over current statistics (node term omitted as
    /// a constant offset; relative comparisons are what the moves need,
    /// `codelength` adds it back for reporting).
    fn l_value(&self) -> f64 {
        let sum_q: f64 = self.q.iter().sum();
        let mut l = plogp(sum_q);
        for m in 0..self.p.len() {
            l -= 2.0 * plogp(self.q[m]);
            l += plogp(self.p[m] + self.q[m]);
        }
        l
    }
}

fn build_modules(g: &WGraph, comm: &[u32], k: usize) -> Modules {
    let mut p = vec![0.0; k];
    let mut cut = vec![0.0; k];
    for u in 0..g.n() {
        let cu = comm[u] as usize;
        p[cu] += g.wdeg[u] / g.total;
        for &(v, w) in &g.adj[u] {
            if comm[v as usize] != comm[u] {
                cut[cu] += w;
            }
        }
    }
    let q = cut.iter().map(|&c| c / g.total).collect();
    Modules { p, q }
}

fn local_moving(g: &WGraph, rng: &mut Xoshiro256) -> (Vec<u32>, bool) {
    let n = g.n();
    let mut comm: Vec<u32> = (0..n as u32).collect();
    let mut modules = build_modules(g, &comm, n);
    let mut sum_q: f64 = modules.q.iter().sum();
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);

    let mut improved_any = false;
    let mut neigh_w: HashMap<u32, f64> = HashMap::new();
    for _pass in 0..16 {
        let mut moved = 0usize;
        for &u in &order {
            let ui = u as usize;
            let cu = comm[ui];
            neigh_w.clear();
            for &(v, w) in &g.adj[ui] {
                if v == u {
                    continue;
                }
                *neigh_w.entry(comm[v as usize]).or_insert(0.0) += w;
            }
            if neigh_w.is_empty() {
                continue;
            }
            let deg_u = g.wdeg[ui];
            let p_u = deg_u / g.total;
            let w_to_cu = neigh_w.get(&cu).copied().unwrap_or(0.0);

            // Moving u (cu → c) flips its w_to_cu internal edges into
            // cut of cu and removes its (deg_u − w_to_cu) former cut
            // contribution; the target symmetrically. Only the plogp
            // terms of cu, c and Σq change, so ΔL is O(1):
            //   L = plogp(Σq) − 2 Σ plogp(q_m) + Σ plogp(p_m + q_m)
            let (p_cu, q_cu) = (modules.p[cu as usize], modules.q[cu as usize]);
            let q_cu_new = q_cu + (w_to_cu - (deg_u - w_to_cu)) / g.total;
            let old_terms_cu = -2.0 * plogp(q_cu) + plogp(p_cu + q_cu);
            let new_terms_cu = -2.0 * plogp(q_cu_new) + plogp(p_cu - p_u + q_cu_new);

            let mut best_c = cu;
            let mut best_delta = 0.0;
            let mut best_q_c_new = 0.0;
            // sorted iteration for run-to-run determinism on ties
            let mut cands: Vec<(u32, f64)> = neigh_w.iter().map(|(&c, &w)| (c, w)).collect();
            cands.sort_unstable_by_key(|&(c, _)| c);
            for (c, w_to_c) in cands {
                if c == cu {
                    continue;
                }
                let (p_c, q_c) = (modules.p[c as usize], modules.q[c as usize]);
                let q_c_new = q_c + ((deg_u - w_to_c) - w_to_c) / g.total;
                let sum_q_new = sum_q - q_cu - q_c + q_cu_new + q_c_new;
                let delta = plogp(sum_q_new) - plogp(sum_q)
                    + new_terms_cu - old_terms_cu
                    + (-2.0 * plogp(q_c_new) + plogp(p_c + p_u + q_c_new))
                    - (-2.0 * plogp(q_c) + plogp(p_c + q_c));
                if delta < best_delta - 1e-12 {
                    best_delta = delta;
                    best_c = c;
                    best_q_c_new = q_c_new;
                }
            }
            if best_c != cu {
                let c = best_c as usize;
                sum_q += q_cu_new - q_cu + best_q_c_new - modules.q[c];
                modules.p[cu as usize] -= p_u;
                modules.p[c] += p_u;
                modules.q[cu as usize] = q_cu_new;
                modules.q[c] = best_q_c_new;
                comm[ui] = best_c;
                moved += 1;
                improved_any = true;
            }
        }
        if moved == 0 {
            break;
        }
    }
    (comm, improved_any)
}

fn aggregate(g: &WGraph, comm: &[u32]) -> (WGraph, Vec<u32>) {
    let mut remap: HashMap<u32, u32> = HashMap::new();
    let mut node_of = vec![0u32; g.n()];
    for (u, &c) in comm.iter().enumerate() {
        let next = remap.len() as u32;
        node_of[u] = *remap.entry(c).or_insert(next);
    }
    let k = remap.len();
    let mut maps: Vec<HashMap<u32, f64>> = vec![HashMap::new(); k];
    for u in 0..g.n() {
        for &(v, w) in &g.adj[u] {
            *maps[node_of[u] as usize]
                .entry(node_of[v as usize])
                .or_insert(0.0) += w;
        }
    }
    let mut adj = Vec::with_capacity(k);
    let mut wdeg = vec![0.0; k];
    for (u, map) in maps.into_iter().enumerate() {
        let mut run: Vec<(u32, f64)> = map.into_iter().collect();
        run.sort_unstable_by_key(|&(v, _)| v);
        wdeg[u] = run.iter().map(|&(_, w)| w).sum();
        adj.push(run);
    }
    let total = wdeg.iter().sum();
    (WGraph { adj, wdeg, total }, node_of)
}

/// The paper's baseline **I**.
pub struct Infomap {
    /// RNG seed.
    pub seed: u64,
    /// Cap on aggregation levels.
    pub max_levels: usize,
}

impl Infomap {
    /// Defaults: 16 aggregation levels.
    pub fn new(seed: u64) -> Self {
        Self { seed, max_levels: 16 }
    }

    /// Detect communities; returns per-node labels.
    pub fn run(&self, g: &Csr) -> Vec<u32> {
        let mut rng = Xoshiro256::new(self.seed);
        let mut graph = WGraph::from_csr(g);
        let mut labels: Vec<u32> = (0..g.n as u32).collect();
        for _ in 0..self.max_levels {
            let (comm, improved) = local_moving(&graph, &mut rng);
            if !improved {
                break;
            }
            let (next, node_of) = aggregate(&graph, &comm);
            for l in labels.iter_mut() {
                *l = node_of[*l as usize];
            }
            if next.n() == graph.n() {
                break;
            }
            graph = next;
        }
        super::normalize_labels(&mut labels);
        labels
    }

    /// Full two-level codelength (bits/step) of a partition — for
    /// reporting and the unit tests.
    pub fn codelength(g: &Csr, labels: &[u32]) -> f64 {
        let wg = WGraph::from_csr(g);
        let k = labels.iter().copied().max().map(|x| x as usize + 1).unwrap_or(0);
        let modules = build_modules(&wg, labels, k);
        let node_term: f64 = (0..wg.n())
            .map(|u| plogp(wg.wdeg[u] / wg.total))
            .sum();
        modules.l_value() - node_term
    }
}

impl CommunityDetector for Infomap {
    fn tag(&self) -> &'static str {
        "I"
    }

    fn name(&self) -> &'static str {
        "Infomap"
    }

    fn detect(&mut self, graph: &Csr) -> Vec<u32> {
        self.run(graph)
    }

    fn practical_for(&self, _n: usize, m: usize) -> bool {
        // mirrors Table 1: Infomap ran up to YouTube (~3M edges)
        m <= 4_000_000
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::{Edge, EdgeList};
    use crate::graph::generators::sbm::{self, SbmConfig};
    use crate::metrics::nmi::nmi_labels;

    fn two_triangles_csr() -> Csr {
        Csr::from_edge_list(&EdgeList::new(6, vec![
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(0, 2),
            Edge::new(3, 4),
            Edge::new(4, 5),
            Edge::new(3, 5),
            Edge::new(2, 3),
        ]))
    }

    #[test]
    fn splits_two_triangles() {
        let g = two_triangles_csr();
        let labels = Infomap::new(1).run(&g);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_eq!(labels[4], labels[5]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn good_partition_has_lower_codelength() {
        let g = two_triangles_csr();
        let good = vec![0, 0, 0, 1, 1, 1];
        let all_one = vec![0; 6];
        let singletons: Vec<u32> = (0..6).collect();
        let l_good = Infomap::codelength(&g, &good);
        let l_one = Infomap::codelength(&g, &all_one);
        let l_single = Infomap::codelength(&g, &singletons);
        assert!(l_good < l_one, "{l_good} !< {l_one}");
        assert!(l_good < l_single, "{l_good} !< {l_single}");
    }

    #[test]
    fn recovers_sbm_partition() {
        let g = sbm::generate(&SbmConfig::equal(6, 40, 0.4, 0.005, 20));
        let csr = Csr::from_edge_list(&g.edges);
        let labels = Infomap::new(2).run(&csr);
        let truth = g.truth.to_labels(g.n());
        let nmi = nmi_labels(&labels, &truth);
        assert!(nmi > 0.8, "nmi={nmi}");
    }

    #[test]
    fn codelength_of_found_partition_beats_trivial() {
        let g = sbm::generate(&SbmConfig::equal(5, 30, 0.4, 0.01, 21));
        let csr = Csr::from_edge_list(&g.edges);
        let labels = Infomap::new(1).run(&csr);
        let l_found = Infomap::codelength(&csr, &labels);
        let l_one = Infomap::codelength(&csr, &vec![0; csr.n]);
        assert!(l_found < l_one);
    }
}
