//! Walktrap — random-walk distances + agglomerative merging (Pons &
//! Latapy 2005) — the paper's baseline **W**.
//!
//! Node similarity is the L2 distance between t-step transition
//! probability vectors, degree-normalised:
//!
//!   r_ij² = Σ_k (P^t_ik − P^t_jk)² / d(k)
//!
//! Communities are merged bottom-up, Ward-style: at each step merge the
//! *adjacent* pair minimising Δσ = |A||B|/(|A|+|B|) · r_AB²; the cut of
//! the merge path maximising modularity is returned (the reference
//! implementation's default output).
//!
//! Implementation notes: candidate pairs live in a lazy binary heap
//! keyed by Δσ with per-community version stamps (stale entries are
//! recomputed on pop — the classic lazy-deletion pattern the original
//! also uses); community adjacency and the modularity partials
//! (intra-edge count, Σ Vol²) are maintained incrementally so a merge
//! costs O(deg · n) for the mean-vector update rather than a full
//! edge rescan.
//!
//! Memory is Θ(n²) for the probability vectors, like the original —
//! which is exactly why Table 1 shows Walktrap timing out beyond DBLP;
//! `practical_for` mirrors that cut-off.

use std::collections::{BinaryHeap, HashMap, HashSet};

use crate::graph::csr::Csr;

use super::CommunityDetector;

/// Heap entry: minimal Δσ first (BinaryHeap is a max-heap → reverse).
struct Cand {
    dsigma: f32,
    a: u32,
    b: u32,
    stamp_a: u32,
    stamp_b: u32,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.dsigma == other.dsigma
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: smaller dsigma = greater priority; ties broken by
        // (a, b) so heap order is independent of insertion order
        other
            .dsigma
            .total_cmp(&self.dsigma)
            .then(other.a.cmp(&self.a))
            .then(other.b.cmp(&self.b))
    }
}

/// Walktrap-style agglomerative baseline.
pub struct Walktrap {
    /// Walk length t (the reference default is 4).
    pub t: usize,
}

impl Walktrap {
    /// Walktrap with walk length `t`.
    pub fn new(t: usize) -> Self {
        Self { t }
    }

    /// P^t rows for all nodes (dense; n² floats).
    fn walk_probabilities(g: &Csr, t: usize) -> Vec<Vec<f32>> {
        let n = g.n;
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut cur = vec![0f32; n];
        let mut next = vec![0f32; n];
        for s in 0..n as u32 {
            cur.iter_mut().for_each(|x| *x = 0.0);
            cur[s as usize] = 1.0;
            for _ in 0..t {
                next.iter_mut().for_each(|x| *x = 0.0);
                for u in 0..n as u32 {
                    let p = cur[u as usize];
                    if p == 0.0 {
                        continue;
                    }
                    let d = g.degree(u);
                    if d == 0 {
                        next[u as usize] += p; // stay on isolated nodes
                        continue;
                    }
                    let share = p / d as f32;
                    for &v in g.neighbors(u) {
                        next[v as usize] += share;
                    }
                }
                std::mem::swap(&mut cur, &mut next);
            }
            rows.push(cur.clone());
        }
        rows
    }

    /// Detect communities; returns per-node labels.
    pub fn run(&self, g: &Csr) -> Vec<u32> {
        let n = g.n;
        if n == 0 {
            return Vec::new();
        }
        let m = g.m as u64;
        let inv_deg: Vec<f32> = (0..n as u32)
            .map(|u| {
                let d = g.degree(u);
                if d == 0 {
                    0.0
                } else {
                    1.0 / d as f32
                }
            })
            .collect();

        // community state
        let mut mean = Self::walk_probabilities(g, self.t);
        let mut size: Vec<f32> = vec![1.0; n];
        let mut alive = vec![true; n];
        let mut stamp = vec![0u32; n];
        let mut comm_of: Vec<u32> = (0..n as u32).collect();
        let mut members: Vec<Vec<u32>> = (0..n as u32).map(|u| vec![u]).collect();

        // community adjacency: neighbor sets + inter-edge weights
        let mut nbrs: Vec<HashSet<u32>> = vec![HashSet::new(); n];
        let mut between: HashMap<(u32, u32), u64> = HashMap::new();
        let key = |a: u32, b: u32| if a < b { (a, b) } else { (b, a) };
        let mut intra_edges = 0u64; // self-loop-free CSR ⇒ starts 0
        let mut volume: Vec<u64> = (0..n as u32).map(|u| g.degree(u) as u64).collect();
        for u in 0..n as u32 {
            for &v in g.neighbors(u) {
                if v > u {
                    nbrs[u as usize].insert(v);
                    nbrs[v as usize].insert(u);
                    *between.entry(key(u, v)).or_insert(0) += 1;
                }
            }
        }

        let dist2 = |a: &[f32], b: &[f32]| -> f32 {
            a.iter()
                .zip(b)
                .zip(&inv_deg)
                .map(|((&x, &y), &w)| (x - y) * (x - y) * w)
                .sum()
        };
        let dsig = |sa: f32, sb: f32, d2: f32| sa * sb / (sa + sb) * d2;

        // modularity tracking: Q = intra/m − Σ vol² / (4 m²)
        let mut volsq: f64 = volume.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let q_of = |intra: u64, volsq: f64| -> f64 {
            if m == 0 {
                0.0
            } else {
                intra as f64 / m as f64 - volsq / (4.0 * (m as f64) * (m as f64))
            }
        };

        let mut heap: BinaryHeap<Cand> = BinaryHeap::new();
        for (&(a, b), _) in &between {
            let d2 = dist2(&mean[a as usize], &mean[b as usize]);
            heap.push(Cand {
                dsigma: dsig(size[a as usize], size[b as usize], d2),
                a,
                b,
                stamp_a: 0,
                stamp_b: 0,
            });
        }

        let mut best_q = q_of(intra_edges, volsq);
        let mut best_labels = comm_of.clone();

        while let Some(c) = heap.pop() {
            let (a, b) = (c.a, c.b);
            if !alive[a as usize] || !alive[b as usize] {
                continue;
            }
            if !nbrs[a as usize].contains(&b) {
                continue;
            }
            if c.stamp_a != stamp[a as usize] || c.stamp_b != stamp[b as usize] {
                // stale: recompute and re-push
                let d2 = dist2(&mean[a as usize], &mean[b as usize]);
                heap.push(Cand {
                    dsigma: dsig(size[a as usize], size[b as usize], d2),
                    a,
                    b,
                    stamp_a: stamp[a as usize],
                    stamp_b: stamp[b as usize],
                });
                continue;
            }

            // merge b into a
            let (sa, sb) = (size[a as usize], size[b as usize]);
            {
                let (pa, pb) = if a < b {
                    let (head, tail) = mean.split_at_mut(b as usize);
                    (&mut head[a as usize], &tail[0])
                } else {
                    let (head, tail) = mean.split_at_mut(a as usize);
                    (&mut tail[0], &head[b as usize])
                };
                for k in 0..n {
                    pa[k] = (sa * pa[k] + sb * pb[k]) / (sa + sb);
                }
            }
            size[a as usize] += sb;
            alive[b as usize] = false;
            stamp[a as usize] += 1;
            let moved = std::mem::take(&mut members[b as usize]);
            for &node in &moved {
                comm_of[node as usize] = a;
            }
            members[a as usize].extend(moved);

            // modularity partials
            let e_ab = between.remove(&key(a, b)).unwrap_or(0);
            intra_edges += e_ab;
            let (va, vb) = (volume[a as usize], volume[b as usize]);
            volsq += 2.0 * va as f64 * vb as f64; // (va+vb)² − va² − vb²
            volume[a as usize] += vb;
            volume[b as usize] = 0;

            // adjacency rewiring: b's neighbours become a's
            let bn: Vec<u32> = nbrs[b as usize].drain().collect();
            nbrs[a as usize].remove(&b);
            for x in bn {
                if x == a {
                    continue;
                }
                nbrs[x as usize].remove(&b);
                let w = between.remove(&key(b, x)).unwrap_or(0);
                if w > 0 {
                    *between.entry(key(a, x)).or_insert(0) += w;
                    nbrs[a as usize].insert(x);
                    nbrs[x as usize].insert(a);
                }
            }

            // push fresh candidates for a's neighbourhood
            for &x in &nbrs[a as usize] {
                if !alive[x as usize] {
                    continue;
                }
                let d2 = dist2(&mean[a as usize], &mean[x as usize]);
                heap.push(Cand {
                    dsigma: dsig(size[a as usize], size[x as usize], d2),
                    a,
                    b: x,
                    stamp_a: stamp[a as usize],
                    stamp_b: stamp[x as usize],
                });
            }

            let q = q_of(intra_edges, volsq);
            if q > best_q {
                best_q = q;
                best_labels = comm_of.clone();
            }
        }
        super::normalize_labels(&mut best_labels);
        best_labels
    }
}

impl CommunityDetector for Walktrap {
    fn tag(&self) -> &'static str {
        "W"
    }

    fn name(&self) -> &'static str {
        "Walktrap"
    }

    fn detect(&mut self, graph: &Csr) -> Vec<u32> {
        self.run(graph)
    }

    fn practical_for(&self, n: usize, _m: usize) -> bool {
        // n² probability vectors: mirror the paper's Amazon/DBLP-only rows
        n <= 2_000
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::{Edge, EdgeList};
    use crate::graph::generators::sbm::{self, SbmConfig};
    use crate::metrics::nmi::nmi_labels;

    #[test]
    fn splits_two_triangles() {
        let edges = vec![
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(0, 2),
            Edge::new(3, 4),
            Edge::new(4, 5),
            Edge::new(3, 5),
            Edge::new(2, 3),
        ];
        let csr = Csr::from_edge_list(&EdgeList::new(6, edges));
        let labels = Walktrap::new(3).run(&csr);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_eq!(labels[4], labels[5]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn walk_probabilities_are_stochastic() {
        let edges = vec![Edge::new(0, 1), Edge::new(1, 2), Edge::new(2, 0)];
        let csr = Csr::from_edge_list(&EdgeList::new(3, edges));
        let probs = Walktrap::walk_probabilities(&csr, 4);
        for row in &probs {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row sums to {s}");
        }
    }

    #[test]
    fn recovers_small_sbm() {
        let g = sbm::generate(&SbmConfig::equal(4, 25, 0.5, 0.01, 30));
        let csr = Csr::from_edge_list(&g.edges);
        let labels = Walktrap::new(4).run(&csr);
        let truth = g.truth.to_labels(g.n());
        let nmi = nmi_labels(&labels, &truth);
        assert!(nmi > 0.7, "nmi={nmi}");
    }

    #[test]
    fn practical_cutoff_mirrors_paper() {
        let w = Walktrap::new(4);
        assert!(w.practical_for(1_500, 100_000));
        assert!(!w.practical_for(100_000, 1_000_000));
    }

    #[test]
    fn runs_in_reasonable_time_at_cutoff_scale() {
        // guard against accidental O(n·m·n) regressions: ~1.4k nodes
        // must finish in seconds even in debug builds
        let g = sbm::generate(&SbmConfig::equal(14, 100, 0.12, 0.002, 31));
        let csr = Csr::from_edge_list(&g.edges);
        let t0 = std::time::Instant::now();
        let labels = Walktrap::new(3).run(&csr);
        assert!(labels.len() == g.n());
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(60),
            "walktrap too slow: {:?}",
            t0.elapsed()
        );
    }
}
