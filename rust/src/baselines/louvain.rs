//! Louvain modularity optimisation (Blondel et al. 2008) — the paper's
//! baseline **L**.
//!
//! Standard two-phase loop: (1) local moving — each node greedily moves
//! to the neighbouring community with the best modularity gain until no
//! move improves; (2) aggregation — communities collapse into
//! super-nodes (weighted multigraph, self-loops carry internal weight)
//! and the process repeats on the smaller graph. Terminates when a full
//! level yields no modularity improvement.
//!
//! ΔQ for moving node `i` (degree k_i) into community `C`:
//!   ΔQ = k_{i,C}/m − k_i · Σ_tot(C) / (2 m²)
//! (comparing against leaving `i` isolated; the implementation uses the
//! standard remove-then-best-insert formulation).

use std::collections::HashMap;

use crate::graph::csr::Csr;
use crate::util::rng::Xoshiro256;

use super::CommunityDetector;

/// Weighted adjacency used across aggregation levels.
struct WGraph {
    /// adj[u] = (v, weight); self-loop (u, u) holds internal weight ×2.
    adj: Vec<Vec<(u32, f64)>>,
    /// Weighted degree incl. self-loop weight.
    wdeg: Vec<f64>,
    /// Total edge weight m (sum of wdeg / 2).
    m: f64,
}

impl WGraph {
    fn from_csr(g: &Csr) -> Self {
        let mut adj = Vec::with_capacity(g.n);
        let mut wdeg = vec![0.0; g.n];
        for u in 0..g.n as u32 {
            // collapse parallel edges into weights
            let mut run: Vec<(u32, f64)> = Vec::new();
            for &v in g.neighbors(u) {
                if let Some(last) = run.last_mut() {
                    if last.0 == v {
                        last.1 += 1.0;
                        continue;
                    }
                }
                run.push((v, 1.0));
            }
            wdeg[u as usize] = run.iter().map(|&(_, w)| w).sum();
            adj.push(run);
        }
        let m = wdeg.iter().sum::<f64>() / 2.0;
        WGraph { adj, wdeg, m }
    }

    fn n(&self) -> usize {
        self.adj.len()
    }
}

/// One level of local moving; returns (labels, improved?).
fn local_moving(g: &WGraph, rng: &mut Xoshiro256, min_gain: f64) -> (Vec<u32>, bool) {
    let n = g.n();
    let mut comm: Vec<u32> = (0..n as u32).collect();
    // Σ_tot per community (sum of weighted degrees of members)
    let mut tot: Vec<f64> = g.wdeg.clone();
    let two_m = 2.0 * g.m;
    if two_m == 0.0 {
        return (comm, false);
    }

    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);

    let mut improved_any = false;
    let mut neigh_w: HashMap<u32, f64> = HashMap::new();
    loop {
        let mut moved = 0usize;
        for &u in &order {
            let ui = u as usize;
            let cu = comm[ui];
            // weights to neighbouring communities (excluding self-loop)
            neigh_w.clear();
            for &(v, w) in &g.adj[ui] {
                if v == u {
                    continue;
                }
                *neigh_w.entry(comm[v as usize]).or_insert(0.0) += w;
            }
            // remove u from its community
            tot[cu as usize] -= g.wdeg[ui];
            let k_u = g.wdeg[ui];
            let base = neigh_w.get(&cu).copied().unwrap_or(0.0);
            let mut best_c = cu;
            let mut best_gain = base - tot[cu as usize] * k_u / two_m;
            // sorted iteration for run-to-run determinism on ties
            let mut cands: Vec<(u32, f64)> = neigh_w.iter().map(|(&c, &w)| (c, w)).collect();
            cands.sort_unstable_by_key(|&(c, _)| c);
            for (c, k_uc) in cands {
                if c == cu {
                    continue;
                }
                let gain = k_uc - tot[c as usize] * k_u / two_m;
                if gain > best_gain + min_gain {
                    best_gain = gain;
                    best_c = c;
                }
            }
            tot[best_c as usize] += g.wdeg[ui];
            if best_c != cu {
                comm[ui] = best_c;
                moved += 1;
                improved_any = true;
            }
        }
        if moved == 0 {
            break;
        }
    }
    (comm, improved_any)
}

/// Aggregate: communities become nodes; returns (new graph, mapping
/// old-node → new-node).
fn aggregate(g: &WGraph, comm: &[u32]) -> (WGraph, Vec<u32>) {
    let mut remap: HashMap<u32, u32> = HashMap::new();
    let mut node_of: Vec<u32> = vec![0; g.n()];
    for (u, &c) in comm.iter().enumerate() {
        let next = remap.len() as u32;
        let id = *remap.entry(c).or_insert(next);
        node_of[u] = id;
    }
    let k = remap.len();
    let mut maps: Vec<HashMap<u32, f64>> = vec![HashMap::new(); k];
    for u in 0..g.n() {
        let cu = node_of[u];
        for &(v, w) in &g.adj[u] {
            let cv = node_of[v as usize];
            *maps[cu as usize].entry(cv).or_insert(0.0) += w;
        }
    }
    let mut adj = Vec::with_capacity(k);
    let mut wdeg = vec![0.0; k];
    for (u, map) in maps.into_iter().enumerate() {
        let mut run: Vec<(u32, f64)> = map.into_iter().collect();
        run.sort_unstable_by_key(|&(v, _)| v);
        wdeg[u] = run.iter().map(|&(_, w)| w).sum();
        adj.push(run);
    }
    let m = wdeg.iter().sum::<f64>() / 2.0;
    (WGraph { adj, wdeg, m }, node_of)
}

/// The paper's baseline **L**.
pub struct Louvain {
    /// RNG seed.
    pub seed: u64,
    /// Minimum per-move gain to accept (protects against float noise).
    pub min_gain: f64,
    /// Cap on aggregation levels.
    pub max_levels: usize,
}

impl Louvain {
    /// Defaults: 1e-9 gain cutoff, 32 levels.
    pub fn new(seed: u64) -> Self {
        Self { seed, min_gain: 1e-9, max_levels: 32 }
    }

    /// Run and return final labels.
    pub fn run(&self, g: &Csr) -> Vec<u32> {
        let mut rng = Xoshiro256::new(self.seed);
        let mut graph = WGraph::from_csr(g);
        // labels[u] = community of original node u, refined per level
        let mut labels: Vec<u32> = (0..g.n as u32).collect();
        for _level in 0..self.max_levels {
            let (comm, improved) = local_moving(&graph, &mut rng, self.min_gain);
            if !improved {
                break;
            }
            let (next, node_of) = aggregate(&graph, &comm);
            for l in labels.iter_mut() {
                *l = node_of[*l as usize];
            }
            if next.n() == graph.n() {
                break;
            }
            graph = next;
        }
        let mut out = labels;
        super::normalize_labels(&mut out);
        out
    }
}

/// Louvain over an explicit weighted adjacency (used by the two-pass
/// streaming refinement in `coordinator::refine`, which clusters the
/// *coarse community graph* rather than a node graph).
///
/// `adj[u]` lists `(v, w)` pairs; both directions must be present and a
/// self-loop `(u, u)` carries 2× the internal weight, matching the
/// aggregation convention above.
pub fn cluster_weighted(adj: Vec<Vec<(u32, f64)>>, seed: u64) -> Vec<u32> {
    let n = adj.len();
    let mut wdeg = vec![0.0; n];
    for (u, run) in adj.iter().enumerate() {
        wdeg[u] = run.iter().map(|&(_, w)| w).sum();
    }
    let m = wdeg.iter().sum::<f64>() / 2.0;
    let mut graph = WGraph { adj, wdeg, m };
    let mut rng = Xoshiro256::new(seed);
    let mut labels: Vec<u32> = (0..n as u32).collect();
    for _ in 0..32 {
        let (comm, improved) = local_moving(&graph, &mut rng, 1e-9);
        if !improved {
            break;
        }
        let (next, node_of) = aggregate(&graph, &comm);
        for l in labels.iter_mut() {
            *l = node_of[*l as usize];
        }
        if next.n() == graph.n() {
            break;
        }
        graph = next;
    }
    super::normalize_labels(&mut labels);
    labels
}

impl CommunityDetector for Louvain {
    fn tag(&self) -> &'static str {
        "L"
    }

    fn name(&self) -> &'static str {
        "Louvain"
    }

    fn detect(&mut self, graph: &Csr) -> Vec<u32> {
        self.run(graph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::{Edge, EdgeList};
    use crate::graph::generators::sbm::{self, SbmConfig};
    use crate::metrics::{modularity::modularity, nmi::nmi_labels};

    fn two_triangles_csr() -> (Csr, Vec<Edge>) {
        let edges = vec![
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(0, 2),
            Edge::new(3, 4),
            Edge::new(4, 5),
            Edge::new(3, 5),
            Edge::new(2, 3),
        ];
        (Csr::from_edge_list(&EdgeList::new(6, edges.clone())), edges)
    }

    #[test]
    fn finds_two_triangles() {
        let (g, _) = two_triangles_csr();
        let labels = Louvain::new(1).run(&g);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_eq!(labels[4], labels[5]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn achieves_near_optimal_modularity_on_toy() {
        let (g, edges) = two_triangles_csr();
        let labels = Louvain::new(2).run(&g);
        let q = modularity(6, &edges, &labels);
        assert!((q - 5.0 / 14.0).abs() < 1e-9, "q={q}");
    }

    #[test]
    fn recovers_sbm_partition() {
        let g = sbm::generate(&SbmConfig::equal(8, 50, 0.3, 0.005, 33));
        let csr = Csr::from_edge_list(&g.edges);
        let labels = Louvain::new(3).run(&csr);
        let truth = g.truth.to_labels(g.n());
        let nmi = nmi_labels(&labels, &truth);
        assert!(nmi > 0.9, "nmi={nmi}");
    }

    #[test]
    fn modularity_beats_streaming_on_small_graph() {
        // the paper's Table 2 shape: Louvain wins on small graphs
        let g = sbm::generate(&SbmConfig::equal(6, 40, 0.3, 0.01, 44));
        let csr = Csr::from_edge_list(&g.edges);
        let lv = Louvain::new(1).run(&csr);
        let st = crate::coordinator::algorithm::cluster_edges(g.n(), &g.edges.edges, 64);
        let q_lv = modularity(g.n(), &g.edges.edges, &lv);
        let q_st = modularity(g.n(), &g.edges.edges, &st);
        assert!(q_lv >= q_st, "louvain {q_lv} < streaming {q_st}");
    }

    #[test]
    fn empty_graph_is_fine() {
        let csr = Csr::from_edge_list(&EdgeList::new(4, vec![]));
        let labels = Louvain::new(1).run(&csr);
        assert_eq!(labels.len(), 4);
    }

    #[test]
    fn deterministic_per_seed() {
        let g = sbm::generate(&SbmConfig::equal(4, 30, 0.3, 0.01, 5));
        let csr = Csr::from_edge_list(&g.edges);
        assert_eq!(Louvain::new(9).run(&csr), Louvain::new(9).run(&csr));
    }
}
