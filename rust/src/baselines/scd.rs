//! SCD — triangle/WCC-based partitioning (Prat-Pérez et al., WWW 2014)
//! — the paper's baseline **S**.
//!
//! Faithful two-phase structure of the original:
//!
//! 1. **Seeding** — nodes sorted by clustering coefficient (triangles /
//!    possible pairs) descending; each unassigned node in that order
//!    founds a community containing itself and its unassigned
//!    neighbours (exactly SCD's "initial partition" heuristic).
//! 2. **Refinement** — hill-climbing on an approximate per-node WCC
//!    gain: each node evaluates leave / stay / move-to-neighbouring
//!    community using the WCC approximation from the SCD paper driven by
//!    per-community internal-degree statistics, iterating until no move
//!    improves or `max_iters` passes.
//!
//! Simplification vs. the original (documented per DESIGN.md §3): the
//! WCC gain uses the triangle-density approximation with per-community
//! aggregates rather than exact per-move triangle recount — the same
//! approximation family the SCD paper itself introduces for speed. The
//! complexity stays O(m · \bar{d}) per refinement pass.

use std::collections::HashMap;

use crate::graph::csr::Csr;
use crate::util::rng::Xoshiro256;

use super::CommunityDetector;

/// SCD-style baseline: triangle-seeded greedy refinement.
pub struct Scd {
    /// RNG seed.
    pub seed: u64,
    /// Refinement iteration cap.
    pub max_iters: usize,
}

impl Scd {
    /// Defaults: 8 refinement iterations.
    pub fn new(seed: u64) -> Self {
        Self { seed, max_iters: 8 }
    }

    /// Clustering coefficient per node: 2·T(u) / (d(u)(d(u)−1)).
    fn clustering_coefficients(g: &Csr) -> Vec<f64> {
        let mut cc = vec![0.0; g.n];
        for u in 0..g.n as u32 {
            let d = g.degree(u);
            if d < 2 {
                continue;
            }
            let mut tri = 0usize;
            for &v in g.neighbors(u) {
                if v > u {
                    tri += g.common_neighbors(u, v);
                }
            }
            // each triangle at u counted once per (u, v>u) pair with the
            // third vertex anywhere — over all v>u this counts each
            // triangle containing u either once or twice; good enough as
            // a ranking heuristic and exact up to constant for the sort.
            cc[u as usize] = 2.0 * tri as f64 / (d as f64 * (d as f64 - 1.0));
        }
        cc
    }

    /// Phase 1: seed communities greedily by clustering coefficient.
    fn seed_partition(g: &Csr, cc: &[f64], rng: &mut Xoshiro256) -> Vec<u32> {
        let n = g.n;
        let mut order: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut order); // tie-break noise below the sort
        order.sort_by(|&a, &b| {
            cc[b as usize]
                .partial_cmp(&cc[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut labels = vec![u32::MAX; n];
        for &u in &order {
            if labels[u as usize] != u32::MAX {
                continue;
            }
            labels[u as usize] = u;
            for &v in g.neighbors(u) {
                if labels[v as usize] == u32::MAX {
                    labels[v as usize] = u;
                }
            }
        }
        labels
    }

    /// Approximate WCC score of placing a node with `k_in` internal
    /// neighbours into a community with `size` nodes and internal edge
    /// density `delta`: the SCD paper's closed form, reduced to the
    /// node-level cohesion ratio.
    #[inline]
    fn wcc_gain(k_in: f64, size: f64, delta: f64, degree: f64) -> f64 {
        if size <= 0.0 || degree <= 0.0 {
            return 0.0;
        }
        // expected triangles through the node inside C ≈ k_in·(k_in−1)·δ
        let t_in = k_in * (k_in - 1.0).max(0.0) * delta;
        let t_all = degree * (degree - 1.0).max(0.0) * 0.05 + t_in; // smoothed
        if t_all <= 0.0 {
            return 0.0;
        }
        (t_in / t_all) * (k_in / degree)
    }

    /// Detect communities; returns per-node labels.
    pub fn run(&self, g: &Csr) -> Vec<u32> {
        let mut rng = Xoshiro256::new(self.seed);
        let cc = Self::clustering_coefficients(g);
        let mut labels = Self::seed_partition(g, &cc, &mut rng);

        // per-community aggregates: size, internal edge count
        let recompute = |labels: &[u32]| -> (HashMap<u32, (f64, f64)>, ()) {
            let mut agg: HashMap<u32, (f64, f64)> = HashMap::new();
            for u in 0..g.n as u32 {
                agg.entry(labels[u as usize]).or_insert((0.0, 0.0)).0 += 1.0;
            }
            for u in 0..g.n as u32 {
                for &v in g.neighbors(u) {
                    if v > u && labels[u as usize] == labels[v as usize] {
                        agg.get_mut(&labels[u as usize]).unwrap().1 += 1.0;
                    }
                }
            }
            (agg, ())
        };

        let mut neigh: HashMap<u32, f64> = HashMap::new();
        for _ in 0..self.max_iters {
            let (mut agg, ()) = recompute(&labels);
            let mut moved = 0usize;
            for u in 0..g.n as u32 {
                let d = g.degree(u);
                if d == 0 {
                    continue;
                }
                let cu = labels[u as usize];
                neigh.clear();
                for &v in g.neighbors(u) {
                    *neigh.entry(labels[v as usize]).or_insert(0.0) += 1.0;
                }
                let delta_of = |c: u32, agg: &HashMap<u32, (f64, f64)>| -> f64 {
                    let &(s, e) = agg.get(&c).unwrap_or(&(0.0, 0.0));
                    if s < 2.0 {
                        0.0
                    } else {
                        (2.0 * e / (s * (s - 1.0))).min(1.0)
                    }
                };
                let stay = Self::wcc_gain(
                    neigh.get(&cu).copied().unwrap_or(0.0),
                    agg.get(&cu).map(|a| a.0).unwrap_or(0.0),
                    delta_of(cu, &agg),
                    d as f64,
                );
                let mut best_c = cu;
                let mut best = stay;
                // sorted iteration for run-to-run determinism on ties
                let mut cands: Vec<(u32, f64)> = neigh.iter().map(|(&c, &k)| (c, k)).collect();
                cands.sort_unstable_by_key(|&(c, _)| c);
                for (c, k_in) in cands {
                    if c == cu {
                        continue;
                    }
                    let gain = Self::wcc_gain(
                        k_in,
                        agg.get(&c).map(|a| a.0).unwrap_or(0.0) + 1.0,
                        delta_of(c, &agg),
                        d as f64,
                    );
                    if gain > best + 1e-12 {
                        best = gain;
                        best_c = c;
                    }
                }
                if best_c != cu {
                    // update aggregates incrementally (sizes + internal
                    // edges via neighbour counts)
                    let k_old = neigh.get(&cu).copied().unwrap_or(0.0);
                    let k_new = neigh.get(&best_c).copied().unwrap_or(0.0);
                    if let Some(a) = agg.get_mut(&cu) {
                        a.0 -= 1.0;
                        a.1 -= k_old;
                    }
                    let a = agg.entry(best_c).or_insert((0.0, 0.0));
                    a.0 += 1.0;
                    a.1 += k_new;
                    labels[u as usize] = best_c;
                    moved += 1;
                }
            }
            if moved == 0 {
                break;
            }
        }
        super::normalize_labels(&mut labels);
        labels
    }
}

impl CommunityDetector for Scd {
    fn tag(&self) -> &'static str {
        "S"
    }

    fn name(&self) -> &'static str {
        "SCD"
    }

    fn detect(&mut self, graph: &Csr) -> Vec<u32> {
        self.run(graph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::{Edge, EdgeList};
    use crate::graph::generators::sbm::{self, SbmConfig};
    use crate::metrics::nmi::nmi_labels;

    #[test]
    fn two_triangles_split() {
        let edges = vec![
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(0, 2),
            Edge::new(3, 4),
            Edge::new(4, 5),
            Edge::new(3, 5),
            Edge::new(2, 3),
        ];
        let csr = Csr::from_edge_list(&EdgeList::new(6, edges));
        let labels = Scd::new(1).run(&csr);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_eq!(labels[4], labels[5]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn recovers_sbm_partition_reasonably() {
        let g = sbm::generate(&SbmConfig::equal(6, 50, 0.35, 0.004, 10));
        let csr = Csr::from_edge_list(&g.edges);
        let labels = Scd::new(2).run(&csr);
        let truth = g.truth.to_labels(g.n());
        let nmi = nmi_labels(&labels, &truth);
        assert!(nmi > 0.6, "nmi={nmi}");
    }

    #[test]
    fn clustering_coefficient_triangle_vs_path() {
        // triangle node has cc > path-center node
        let edges = vec![
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(0, 2), // triangle 0-1-2
            Edge::new(3, 4),
            Edge::new(4, 5), // path 3-4-5
        ];
        let csr = Csr::from_edge_list(&EdgeList::new(6, edges));
        let cc = Scd::clustering_coefficients(&csr);
        assert!(cc[0] > 0.0);
        assert_eq!(cc[4], 0.0);
    }

    #[test]
    fn handles_star_graph() {
        // star: no triangles anywhere — should not crash, hub groups leaves
        let edges: Vec<Edge> = (1..20u32).map(|i| Edge::new(0, i)).collect();
        let csr = Csr::from_edge_list(&EdgeList::new(20, edges));
        let labels = Scd::new(3).run(&csr);
        assert_eq!(labels.len(), 20);
    }
}
