//! Asynchronous label propagation (Raghavan et al. 2007).
//!
//! Not in the paper's table, but the standard near-linear sanity
//! baseline: every node repeatedly adopts the majority label among its
//! neighbours (ties broken randomly), in random asynchronous order,
//! until labels stabilise or `max_iters` passes.

use std::collections::HashMap;

use crate::graph::csr::Csr;
use crate::util::rng::Xoshiro256;

use super::CommunityDetector;

/// Asynchronous label-propagation baseline.
pub struct LabelProp {
    /// RNG seed.
    pub seed: u64,
    /// Propagation iteration cap.
    pub max_iters: usize,
}

impl LabelProp {
    /// Defaults: 50 propagation iterations.
    pub fn new(seed: u64) -> Self {
        Self { seed, max_iters: 50 }
    }

    /// Detect communities; returns per-node labels.
    pub fn run(&self, g: &Csr) -> Vec<u32> {
        let n = g.n;
        let mut labels: Vec<u32> = (0..n as u32).collect();
        let mut rng = Xoshiro256::new(self.seed);
        let mut order: Vec<u32> = (0..n as u32).collect();
        let mut counts: HashMap<u32, u32> = HashMap::new();
        for _ in 0..self.max_iters {
            rng.shuffle(&mut order);
            let mut changed = 0usize;
            for &u in &order {
                let neigh = g.neighbors(u);
                if neigh.is_empty() {
                    continue;
                }
                counts.clear();
                for &v in neigh {
                    *counts.entry(labels[v as usize]).or_insert(0) += 1;
                }
                let best = counts.values().copied().max().unwrap();
                // collect argmax set (sorted — HashMap order is random
                // per process), pick randomly among ties via our rng
                let mut winners: Vec<u32> = counts
                    .iter()
                    .filter(|&(_, &c)| c == best)
                    .map(|(&l, _)| l)
                    .collect();
                winners.sort_unstable();
                let new = if winners.len() == 1 {
                    winners[0]
                } else {
                    winners[rng.range(0, winners.len())]
                };
                if new != labels[u as usize] {
                    labels[u as usize] = new;
                    changed += 1;
                }
            }
            if changed == 0 {
                break;
            }
        }
        super::normalize_labels(&mut labels);
        labels
    }
}

impl CommunityDetector for LabelProp {
    fn tag(&self) -> &'static str {
        "LP"
    }

    fn name(&self) -> &'static str {
        "LabelProp"
    }

    fn detect(&mut self, graph: &Csr) -> Vec<u32> {
        self.run(graph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::{Edge, EdgeList};
    use crate::graph::generators::sbm::{self, SbmConfig};
    use crate::metrics::nmi::nmi_labels;

    #[test]
    fn separates_clear_communities() {
        let g = sbm::generate(&SbmConfig::equal(4, 40, 0.5, 0.002, 8));
        let csr = Csr::from_edge_list(&g.edges);
        let labels = LabelProp::new(1).run(&csr);
        let truth = g.truth.to_labels(g.n());
        let nmi = nmi_labels(&labels, &truth);
        assert!(nmi > 0.8, "nmi={nmi}");
    }

    #[test]
    fn isolated_nodes_keep_own_label() {
        let csr = Csr::from_edge_list(&EdgeList::new(3, vec![Edge::new(0, 1)]));
        let labels = LabelProp::new(2).run(&csr);
        assert_eq!(labels[0], labels[1]);
        assert_ne!(labels[2], labels[0]);
    }

    #[test]
    fn terminates_on_cycle_graphs() {
        // even cycles can oscillate in synchronous LPA; async must stop
        let edges: Vec<Edge> = (0..100u32).map(|i| Edge::new(i, (i + 1) % 100)).collect();
        let csr = Csr::from_edge_list(&EdgeList::new(100, edges));
        let labels = LabelProp::new(3).run(&csr);
        assert_eq!(labels.len(), 100);
    }
}
