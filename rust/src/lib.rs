//! # streamcom — streaming graph clustering
//!
//! Production-grade reproduction of Hollocou, Maudet, Bonald & Lelarge,
//! *"A Streaming Algorithm for Graph Clustering"* (2017), as a
//! three-layer Rust + JAX/Pallas system:
//!
//! * **L3 (this crate)** — the streaming coordinator: the paper's
//!   Algorithm 1 ([`coordinator`]), the edge-stream substrate
//!   ([`stream`]), the long-lived sharded clustering service
//!   ([`service`]), all five comparison baselines ([`baselines`]), the
//!   scoring metrics ([`metrics`]), SNAP-shaped workload generators
//!   ([`graph::generators`]) and the benchmark framework ([`bench`]).
//! * **L2/L1 (python/compile, build-time only)** — the sketch-scoring
//!   metric engine as JAX + Pallas kernels, AOT-lowered to HLO text and
//!   executed from [`runtime`] via PJRT. Python never runs on the
//!   streaming path. The default build is offline and dependency-free;
//!   the PJRT loader is gated behind the `pjrt` feature and stubs out
//!   to the native engine otherwise.
//!
//! ## Quickstart
//!
//! ```no_run
//! use streamcom::coordinator::algorithm::cluster_edges;
//! use streamcom::graph::generators::sbm::{self, SbmConfig};
//!
//! let g = sbm::generate(&SbmConfig::equal(10, 100, 0.1, 0.001, 42));
//! let labels = cluster_edges(g.n(), &g.edges.edges, 64);
//! println!("{} communities", streamcom::metrics::labels_to_communities(&labels).len());
//! ```
//!
//! For the online form — ingest while answering queries — see
//! [`service::ClusterService`]. See `examples/` for end-to-end drivers
//! and `docs/ARCHITECTURE.md` for the paper-to-module map and the
//! service dataflow.

#![warn(missing_docs)]

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod graph;
pub mod metrics;
pub mod runtime;
pub mod service;
pub mod stream;
pub mod util;
