//! Graph substrate: edge types, CSR adjacency, IO, ground truth,
//! generators.
//!
//! The streaming algorithm itself never needs adjacency — it touches an
//! edge once and forgets it. Everything *around* it does: the baselines
//! (Louvain, SCD, …) operate on a [`csr::Csr`]; the scorers need
//! [`ground_truth::GroundTruth`]; the experiments need the
//! [`generators`] that produce SNAP-shaped workloads.

pub mod binfmt;
pub mod csr;
pub mod edge;
pub mod generators;
pub mod ground_truth;
pub mod io;

pub use csr::Csr;
pub use edge::{Edge, EdgeList};
pub use ground_truth::GroundTruth;
