//! Compressed sparse row adjacency.
//!
//! The non-streaming baselines (Louvain, SCD, Infomap, Walktrap, OSLOM)
//! need random access to neighbourhoods; this is the classic CSR built
//! once from an [`EdgeList`] by counting sort — O(n + m), no per-node
//! allocation. Neighbour lists are sorted, enabling the O(d_u + d_v)
//! sorted-merge triangle counting SCD relies on.

use super::edge::{Edge, EdgeList};

/// Immutable CSR adjacency for an undirected graph (both directions
/// stored). Parallel edges are preserved (the paper streams multigraphs).
#[derive(Debug, Clone)]
pub struct Csr {
    /// offsets[i]..offsets[i+1] indexes `neighbors` for node i.
    pub offsets: Vec<u64>,
    /// Flattened neighbor array.
    pub neighbors: Vec<u32>,
    /// Node count.
    pub n: usize,
    /// Edge count.
    pub m: usize,
}

impl Csr {
    /// Build adjacency from an edge list.
    pub fn from_edge_list(el: &EdgeList) -> Self {
        Self::from_edges(el.n, &el.edges)
    }

    /// Build adjacency from raw edges over `n` nodes.
    pub fn from_edges(n: usize, edges: &[Edge]) -> Self {
        let mut deg = vec![0u64; n + 1];
        for e in edges {
            deg[e.u as usize + 1] += 1;
            deg[e.v as usize + 1] += 1;
        }
        let mut offsets = deg;
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let mut cursor = offsets.clone();
        let mut neighbors = vec![0u32; 2 * edges.len()];
        for e in edges {
            neighbors[cursor[e.u as usize] as usize] = e.v;
            cursor[e.u as usize] += 1;
            neighbors[cursor[e.v as usize] as usize] = e.u;
            cursor[e.v as usize] += 1;
        }
        // sort each adjacency run for merge-based triangle counting
        for i in 0..n {
            let (a, b) = (offsets[i] as usize, offsets[i + 1] as usize);
            neighbors[a..b].sort_unstable();
        }
        Csr { offsets, neighbors, n, m: edges.len() }
    }

    #[inline]
    /// Neighbors of `u` as a slice.
    pub fn neighbors(&self, u: u32) -> &[u32] {
        let (a, b) = (
            self.offsets[u as usize] as usize,
            self.offsets[u as usize + 1] as usize,
        );
        &self.neighbors[a..b]
    }

    #[inline]
    /// Degree of `u`.
    pub fn degree(&self, u: u32) -> usize {
        (self.offsets[u as usize + 1] - self.offsets[u as usize]) as usize
    }

    /// Total weight w = 2m.
    pub fn total_weight(&self) -> u64 {
        self.neighbors.len() as u64
    }

    /// Count triangles incident to edge (u, v) by sorted-merge of the
    /// two adjacency lists. O(d_u + d_v).
    pub fn common_neighbors(&self, u: u32, v: u32) -> usize {
        let (mut a, mut b) = (self.neighbors(u), self.neighbors(v));
        let mut count = 0;
        while let (Some(&x), Some(&y)) = (a.first(), b.first()) {
            match x.cmp(&y) {
                std::cmp::Ordering::Less => a = &a[1..],
                std::cmp::Ordering::Greater => b = &b[1..],
                std::cmp::Ordering::Equal => {
                    if x != u && x != v {
                        count += 1;
                    }
                    a = &a[1..];
                    b = &b[1..];
                }
            }
        }
        count
    }

    /// Iterate each undirected edge once (u <= v by construction order:
    /// emits (u, v) for every v in adj(u) with v >= u; parallel edges
    /// appear once per copy; self-loops never stored).
    pub fn edges(&self) -> impl Iterator<Item = Edge> + '_ {
        (0..self.n as u32).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .filter(move |&&v| v >= u)
                .map(move |&v| Edge::new(u, v))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_tail() -> Csr {
        // 0-1, 1-2, 0-2 (triangle), 2-3 (tail)
        let el = EdgeList::new(4, vec![
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(0, 2),
            Edge::new(2, 3),
        ]);
        Csr::from_edge_list(&el)
    }

    #[test]
    fn degrees_and_neighbors() {
        let g = triangle_plus_tail();
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
        assert_eq!(g.total_weight(), 8);
    }

    #[test]
    fn common_neighbors_counts_triangles() {
        let g = triangle_plus_tail();
        assert_eq!(g.common_neighbors(0, 1), 1); // node 2
        assert_eq!(g.common_neighbors(2, 3), 0);
    }

    #[test]
    fn edges_iterator_roundtrips() {
        let g = triangle_plus_tail();
        let mut es: Vec<Edge> = g.edges().map(Edge::canonical).collect();
        es.sort_unstable_by_key(|e| (e.u, e.v));
        assert_eq!(es, vec![
            Edge::new(0, 1),
            Edge::new(0, 2),
            Edge::new(1, 2),
            Edge::new(2, 3),
        ]);
    }

    #[test]
    fn parallel_edges_preserved() {
        let el = EdgeList::new(2, vec![Edge::new(0, 1), Edge::new(0, 1)]);
        let g = Csr::from_edge_list(&el);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.neighbors(0), &[1, 1]);
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edge_list(&EdgeList::new(3, vec![]));
        assert_eq!(g.degree(1), 0);
        assert_eq!(g.edges().count(), 0);
    }
}
