//! LFR-style benchmark generator (Lancichinetti–Fortunato–Radicchi).
//!
//! Produces graphs with the two heavy tails real SNAP graphs have —
//! power-law node degrees (exponent `gamma`) and power-law community
//! sizes (exponent `beta`) — plus a mixing parameter `mu`: each node
//! spends a fraction `1 - mu` of its degree on intra-community edges and
//! `mu` on inter-community edges.
//!
//! Realisation is by configuration-model stub matching, separately for
//! the intra stubs of each community and globally for inter stubs (with
//! same-community rejection + bounded retries). The result is a
//! *multigraph* with occasional parallel edges — which is exactly the
//! paper's input model (§2.1 streams multi-edges independently), so no
//! dedup pass is applied.

use crate::graph::edge::{Edge, EdgeList};
use crate::graph::ground_truth::GroundTruth;
use crate::util::rng::Xoshiro256;

use super::GeneratedGraph;

/// LFR-style configuration.
#[derive(Debug, Clone)]
pub struct LfrConfig {
    /// Node count.
    pub n: usize,
    /// Mean target degree.
    pub avg_deg: f64,
    /// Degree cap.
    pub max_deg: usize,
    /// Degree power-law exponent (2 < gamma <= 3 typical).
    pub gamma: f64,
    /// Community-size power-law exponent (1 < beta <= 2 typical).
    pub beta: f64,
    /// Smallest community size.
    pub min_comm: usize,
    /// Largest community size.
    pub max_comm: usize,
    /// Mixing: fraction of each node's edges leaving its community.
    pub mu: f64,
    /// RNG seed.
    pub seed: u64,
    /// Graph name for reports.
    pub name: String,
}

impl LfrConfig {
    /// LFR config with reference exponents (γ=2.5, β=1.5) and a display name.
    pub fn named(name: &str, n: usize, avg_deg: f64, mu: f64, seed: u64) -> Self {
        Self {
            n,
            avg_deg,
            max_deg: ((n as f64).sqrt() as usize).max(16),
            gamma: 2.5,
            beta: 1.5,
            min_comm: 8,
            max_comm: (n / 10).max(16),
            mu,
            seed,
            name: name.to_string(),
        }
    }
}

/// Sample a power-law degree sequence with the requested mean by
/// adjusting xmin (bisection — the standard LFR trick).
fn degree_sequence(cfg: &LfrConfig, rng: &mut Xoshiro256) -> Vec<usize> {
    let sample_mean = |xmin: f64, rng: &mut Xoshiro256| -> f64 {
        let mut s = 0.0;
        let probes = 2000.min(cfg.n);
        let mut r = rng.fork();
        for _ in 0..probes {
            s += r.power_law(xmin, cfg.max_deg as f64, cfg.gamma);
        }
        s / probes as f64
    };
    let (mut lo, mut hi) = (1.0f64, cfg.max_deg as f64 / 2.0);
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if sample_mean(mid, rng) < cfg.avg_deg {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let xmin = 0.5 * (lo + hi);
    (0..cfg.n)
        .map(|_| {
            (rng.power_law(xmin, cfg.max_deg as f64, cfg.gamma).round() as usize)
                .clamp(1, cfg.max_deg)
        })
        .collect()
}

/// Sample community sizes (power law in [min_comm, max_comm]) until they
/// cover n nodes; the last community absorbs the remainder.
fn community_sizes(cfg: &LfrConfig, rng: &mut Xoshiro256) -> Vec<usize> {
    let mut sizes = Vec::new();
    let mut covered = 0usize;
    while covered < cfg.n {
        let s = rng
            .power_law(cfg.min_comm as f64, cfg.max_comm as f64, cfg.beta)
            .round() as usize;
        let s = s.clamp(cfg.min_comm, cfg.max_comm).min(cfg.n - covered);
        if cfg.n - covered - s > 0 && cfg.n - covered - s < cfg.min_comm {
            // avoid a tiny trailing community
            sizes.push(cfg.n - covered);
            covered = cfg.n;
        } else {
            sizes.push(s);
            covered += s;
        }
    }
    sizes
}

/// Match stubs into edges: shuffle, pair consecutively, reject
/// self-loops by re-shuffling the tail a bounded number of times.
fn match_stubs(stubs: &mut Vec<u32>, rng: &mut Xoshiro256, edges: &mut Vec<Edge>) {
    if stubs.len() % 2 == 1 {
        stubs.pop(); // drop one stub to make the count even
    }
    rng.shuffle(stubs);
    let mut i = 0;
    while i + 1 < stubs.len() {
        let (a, b) = (stubs[i], stubs[i + 1]);
        if a == b {
            // swap with a random later stub; bounded retries, else drop
            let mut fixed = false;
            for _ in 0..8 {
                let j = rng.range(i + 1, stubs.len());
                if stubs[j] != a {
                    stubs.swap(i + 1, j);
                    fixed = true;
                    break;
                }
            }
            if !fixed {
                i += 2;
                continue;
            }
        }
        edges.push(Edge::new(stubs[i], stubs[i + 1]));
        i += 2;
    }
}

/// Generate an LFR-style graph with ground truth.
pub fn generate(cfg: &LfrConfig) -> GeneratedGraph {
    let mut rng = Xoshiro256::new(cfg.seed);
    let degrees = degree_sequence(cfg, &mut rng);
    let sizes = community_sizes(cfg, &mut rng);

    // assign nodes to communities; nodes with large intra-degree must fit:
    // sort nodes by degree descending, place round-robin into communities
    // with remaining capacity >= intra degree where possible.
    let ncomm = sizes.len();
    let mut order: Vec<u32> = (0..cfg.n as u32).collect();
    rng.shuffle(&mut order);
    order.sort_by_key(|&i| std::cmp::Reverse(degrees[i as usize]));
    let mut remaining = sizes.clone();
    let mut labels = vec![0u32; cfg.n];
    let mut cursor = 0usize;
    for &node in &order {
        let intra_deg =
            ((1.0 - cfg.mu) * degrees[node as usize] as f64).round() as usize;
        // first community with room and size > intra_deg; fall back to
        // any community with room
        let mut placed = false;
        for off in 0..ncomm {
            let k = (cursor + off) % ncomm;
            if remaining[k] > 0 && sizes[k] > intra_deg {
                labels[node as usize] = k as u32;
                remaining[k] -= 1;
                cursor = (k + 1) % ncomm;
                placed = true;
                break;
            }
        }
        if !placed {
            let k = remaining
                .iter()
                .position(|&r| r > 0)
                .expect("sizes cover n");
            labels[node as usize] = k as u32;
            remaining[k] -= 1;
        }
    }

    // build intra and inter stub lists
    let mut intra_stubs: Vec<Vec<u32>> = vec![Vec::new(); ncomm];
    let mut inter_stubs: Vec<u32> = Vec::new();
    for i in 0..cfg.n {
        let d = degrees[i];
        let intra = ((1.0 - cfg.mu) * d as f64).round() as usize;
        let intra = intra.min(d);
        for _ in 0..intra {
            intra_stubs[labels[i] as usize].push(i as u32);
        }
        for _ in 0..(d - intra) {
            inter_stubs.push(i as u32);
        }
    }

    let mut edges = Vec::new();
    for stubs in &mut intra_stubs {
        match_stubs(stubs, &mut rng, &mut edges);
    }
    // inter stubs: match globally, reject same-community pairs with
    // bounded retries (rejected pairs are dropped — slight mu distortion,
    // acceptable for benchmark-shaped workloads)
    {
        let stubs = &mut inter_stubs;
        if stubs.len() % 2 == 1 {
            stubs.pop();
        }
        rng.shuffle(stubs);
        let mut i = 0;
        while i + 1 < stubs.len() {
            let a = stubs[i];
            let mut ok = labels[a as usize] != labels[stubs[i + 1] as usize]
                && a != stubs[i + 1];
            if !ok {
                for _ in 0..8 {
                    let j = rng.range(i + 1, stubs.len());
                    if labels[a as usize] != labels[stubs[j] as usize] {
                        stubs.swap(i + 1, j);
                        ok = true;
                        break;
                    }
                }
            }
            if ok {
                edges.push(Edge::new(stubs[i], stubs[i + 1]));
            }
            i += 2;
        }
    }

    let mut g = GeneratedGraph {
        name: cfg.name.clone(),
        edges: EdgeList::new(cfg.n, edges),
        truth: GroundTruth::from_labels(&labels),
    };
    g.shuffle_stream(rng.next_u64());
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(mu: f64, seed: u64) -> LfrConfig {
        LfrConfig::named("test", 2000, 10.0, mu, seed)
    }

    #[test]
    fn node_and_edge_counts_sane() {
        let g = generate(&small_cfg(0.2, 1));
        assert_eq!(g.n(), 2000);
        let m = g.m() as f64;
        // mean degree 10 → m ≈ 10_000, stub dropping loses a little
        assert!((6_000.0..13_000.0).contains(&m), "m={m}");
    }

    #[test]
    fn mixing_parameter_controls_intra_fraction() {
        let frac = |mu: f64| {
            let g = generate(&small_cfg(mu, 2));
            let labels = g.truth.to_labels(g.n());
            let intra = g
                .edges
                .edges
                .iter()
                .filter(|e| labels[e.u as usize] == labels[e.v as usize])
                .count();
            intra as f64 / g.m() as f64
        };
        let f_low = frac(0.1);
        let f_high = frac(0.6);
        assert!(f_low > 0.8, "f_low={f_low}");
        assert!(f_high < f_low, "f_high={f_high} f_low={f_low}");
    }

    #[test]
    fn community_sizes_cover_n_and_respect_bounds() {
        let cfg = small_cfg(0.3, 3);
        let mut rng = Xoshiro256::new(cfg.seed);
        let sizes = community_sizes(&cfg, &mut rng);
        assert_eq!(sizes.iter().sum::<usize>(), cfg.n);
        for &s in &sizes {
            assert!(s >= cfg.min_comm, "size {s} < min {}", cfg.min_comm);
        }
    }

    #[test]
    fn degree_sequence_hits_target_mean() {
        let cfg = small_cfg(0.2, 4);
        let mut rng = Xoshiro256::new(cfg.seed);
        let degs = degree_sequence(&cfg, &mut rng);
        let mean = degs.iter().sum::<usize>() as f64 / degs.len() as f64;
        assert!((mean - cfg.avg_deg).abs() < 2.5, "mean={mean}");
        assert!(*degs.iter().max().unwrap() <= cfg.max_deg);
    }

    #[test]
    fn no_self_loops() {
        let g = generate(&small_cfg(0.3, 5));
        assert!(g.edges.edges.iter().all(|e| !e.is_self_loop()));
    }

    #[test]
    fn deterministic() {
        let a = generate(&small_cfg(0.25, 6));
        let b = generate(&small_cfg(0.25, 6));
        assert_eq!(a.edges.edges, b.edges.edges);
    }

    #[test]
    fn degree_distribution_is_heavy_tailed() {
        let g = generate(&LfrConfig::named("ht", 5000, 8.0, 0.2, 7));
        let degs = g.edges.degrees();
        let max = *degs.iter().max().unwrap() as f64;
        let mean = degs.iter().map(|&d| d as f64).sum::<f64>() / degs.len() as f64;
        assert!(max > 4.0 * mean, "max={max} mean={mean}");
    }
}
