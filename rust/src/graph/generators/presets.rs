//! SNAP-shaped workload presets for the Table 1 / Table 2 experiments.
//!
//! Each preset mirrors one dataset row of the paper's Table 1, scaled to
//! this testbed (DESIGN.md §3 records the substitution). `scale = 1.0`
//! gives the default sizes below; the bench harness exposes `--scale` to
//! shrink or grow them. Degree and mixing parameters are tuned so the
//! *qualitative* evaluation shape holds: the small co-purchase/co-author
//! graphs have strong, small communities (low μ); the large social
//! graphs have weaker, larger communities (higher μ) — which is where
//! the paper's STR shows its advantage.

use super::lfr::LfrConfig;

/// One Table-1 row: the paper's dataset and our scaled stand-in.
#[derive(Debug, Clone)]
pub struct SnapPreset {
    /// Paper dataset name.
    pub paper_name: &'static str,
    /// Our generated stand-in name.
    pub name: &'static str,
    /// Paper |V|, |E| (for the report).
    pub paper_nodes: u64,
    /// Edge count of the real SNAP graph.
    pub paper_edges: u64,
    /// Stand-in node count at scale 1.
    pub nodes: usize,
    /// Target mean degree (sets |E| ≈ nodes · avg_deg / 2).
    pub avg_deg: f64,
    /// Mixing parameter.
    pub mu: f64,
    /// Ground-truth community size band. SNAP's functional communities
    /// stay *small* even on the billion-edge graphs (user groups,
    /// product categories) — exactly the regime where Louvain's
    /// resolution limit bites and the paper's STR pulls ahead; the
    /// large-graph presets mirror that.
    pub min_comm: usize,
    /// Largest community size.
    pub max_comm: usize,
    /// Which baselines the paper's Table 1 reports on this dataset
    /// (the rest hit the 6-hour timeout or crashed): subset of "SLIWO".
    pub available: &'static str,
}

/// The six SNAP rows of Table 1, in paper order. Stand-in sizes keep the
/// relative ordering and roughly the paper's m/n ratio per graph while
/// scaling the absolute size ~10–100× down so the full 6-algorithm grid
/// (including the O(n²)-ish baselines on small rows only, as in the
/// paper) completes on one machine.
pub const SNAP_PRESETS: [SnapPreset; 6] = [
    SnapPreset {
        paper_name: "Amazon",
        name: "amazon-s",
        paper_nodes: 334_863,
        paper_edges: 925_872,
        nodes: 33_000,
        avg_deg: 5.6, // m/n ≈ 2.8
        mu: 0.30,
        min_comm: 8,
        max_comm: 100,
        available: "SLIWO",
    },
    SnapPreset {
        paper_name: "DBLP",
        name: "dblp-s",
        paper_nodes: 317_080,
        paper_edges: 1_049_866,
        nodes: 32_000,
        avg_deg: 6.6, // m/n ≈ 3.3
        mu: 0.35,
        min_comm: 8,
        max_comm: 120,
        available: "SLIWO",
    },
    SnapPreset {
        paper_name: "YouTube",
        name: "youtube-s",
        paper_nodes: 1_134_890,
        paper_edges: 2_987_624,
        nodes: 113_000,
        avg_deg: 5.3, // m/n ≈ 2.6
        mu: 0.55,
        min_comm: 5,
        max_comm: 60,
        available: "SLI",
    },
    SnapPreset {
        paper_name: "LiveJournal",
        name: "livejournal-s",
        paper_nodes: 3_997_962,
        paper_edges: 34_681_189,
        nodes: 400_000,
        avg_deg: 17.3, // m/n ≈ 8.7
        mu: 0.72,
        min_comm: 5,
        max_comm: 40,
        available: "SL",
    },
    SnapPreset {
        paper_name: "Orkut",
        name: "orkut-s",
        paper_nodes: 3_072_441,
        paper_edges: 117_185_083,
        nodes: 307_000,
        avg_deg: 76.0, // m/n ≈ 38
        mu: 0.75,
        min_comm: 5,
        max_comm: 30,
        available: "SL",
    },
    SnapPreset {
        paper_name: "Friendster",
        name: "friendster-s",
        paper_nodes: 65_608_366,
        paper_edges: 1_806_067_135,
        nodes: 1_300_000,
        avg_deg: 55.0, // m/n ≈ 27.5 (paper also has ~27.5)
        mu: 0.75,
        min_comm: 5,
        max_comm: 25,
        available: "S",
    },
];

impl SnapPreset {
    /// Instantiate the LFR config at the given scale (nodes multiplied,
    /// degrees kept — so edges scale linearly with nodes).
    pub fn config(&self, scale: f64, seed: u64) -> LfrConfig {
        let n = ((self.nodes as f64 * scale) as usize).max(256);
        let mut cfg = LfrConfig::named(self.name, n, self.avg_deg, self.mu, seed);
        cfg.max_deg = ((n as f64).sqrt() as usize * 2).clamp(32, 2048);
        cfg.min_comm = self.min_comm;
        // keep the truth-community band, but never above n/4
        cfg.max_comm = self.max_comm.min((n / 4).max(self.min_comm + 1));
        cfg
    }
}

/// Look up a preset by stand-in name (`amazon-s`, …) or paper name.
pub fn find(name: &str) -> Option<&'static SnapPreset> {
    SNAP_PRESETS
        .iter()
        .find(|p| p.name == name || p.paper_name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::lfr;

    #[test]
    fn all_presets_findable() {
        for p in &SNAP_PRESETS {
            assert!(find(p.name).is_some());
            assert!(find(p.paper_name).is_some());
        }
        assert!(find("nope").is_none());
    }

    #[test]
    fn preset_ordering_matches_paper_scale_ordering() {
        // edge counts must be strictly increasing like Table 1
        for w in SNAP_PRESETS.windows(2) {
            let m0 = w[0].nodes as f64 * w[0].avg_deg;
            let m1 = w[1].nodes as f64 * w[1].avg_deg;
            assert!(m1 > m0, "{} !> {}", w[1].name, w[0].name);
        }
    }

    #[test]
    fn smallest_preset_generates_at_tiny_scale() {
        let cfg = SNAP_PRESETS[0].config(0.05, 42);
        let g = lfr::generate(&cfg);
        assert!(g.n() >= 256);
        assert!(g.m() > g.n()); // avg degree > 2
        assert!(g.truth.len() > 2);
    }

    #[test]
    fn scale_changes_node_count() {
        let a = SNAP_PRESETS[0].config(1.0, 1);
        let b = SNAP_PRESETS[0].config(0.1, 1);
        assert_eq!(a.n, 33_000);
        assert_eq!(b.n, 3_300);
    }
}
