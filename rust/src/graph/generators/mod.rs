//! Synthetic graph generators with ground-truth communities.
//!
//! These replace the SNAP datasets of the paper's evaluation (no network
//! access in this environment — substitution documented in DESIGN.md §3).
//! Two families:
//!
//! * [`sbm`] — planted partition / stochastic block model, sampled in
//!   O(m) with Batagelj–Brandes geometric skipping. The cleanest
//!   controlled workload: `p_in`/`p_out` directly set the
//!   intra/inter-community edge ratio that drives the paper's Theorem 1
//!   intuition.
//! * [`lfr`] — LFR-style benchmark: power-law degrees, power-law
//!   community sizes, mixing parameter μ, realised by a configuration
//!   model (multigraph — exactly what the paper's streaming setting
//!   expects: parallel edges streamed independently).
//!
//! [`presets`] instantiates LFR configs shaped like each SNAP dataset of
//! Table 1, scaled to this testbed.

pub mod lfr;
pub mod presets;
pub mod sbm;

use crate::graph::edge::EdgeList;
use crate::graph::ground_truth::GroundTruth;
use crate::util::rng::Xoshiro256;

/// A generated workload: graph + ground truth + provenance.
#[derive(Debug, Clone)]
pub struct GeneratedGraph {
    /// Workload name.
    pub name: String,
    /// The edge list.
    pub edges: EdgeList,
    /// Planted ground truth.
    pub truth: GroundTruth,
}

impl GeneratedGraph {
    /// Shuffle the edge arrival order (the paper's streaming model
    /// assumes edges arrive in random order).
    pub fn shuffle_stream(&mut self, seed: u64) {
        let mut rng = Xoshiro256::new(seed);
        rng.shuffle(&mut self.edges.edges);
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.edges.n
    }

    /// Edge count.
    pub fn m(&self) -> usize {
        self.edges.m()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::Edge;

    #[test]
    fn shuffle_preserves_multiset() {
        let mut g = GeneratedGraph {
            name: "t".into(),
            edges: EdgeList::new(4, vec![
                Edge::new(0, 1),
                Edge::new(1, 2),
                Edge::new(2, 3),
                Edge::new(0, 3),
            ]),
            truth: GroundTruth::default(),
        };
        let before: std::collections::HashSet<_> =
            g.edges.edges.iter().map(|e| e.canonical()).collect();
        g.shuffle_stream(99);
        let after: std::collections::HashSet<_> =
            g.edges.edges.iter().map(|e| e.canonical()).collect();
        assert_eq!(before, after);
    }
}
