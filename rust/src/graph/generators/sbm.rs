//! Planted-partition / stochastic block model sampled in O(m).
//!
//! Intra-community edges: per community, Erdős–Rényi over the
//! `s·(s-1)/2` pairs with probability `p_in`, enumerated with geometric
//! skipping (Batagelj & Brandes 2005) so cost is proportional to the
//! number of *realised* edges. Inter-community edges: geometric skipping
//! over the full pair space with `p_out`, rejecting same-community
//! pairs (exact, since intra pairs drawn this way are discarded).

use crate::graph::edge::{Edge, EdgeList};
use crate::graph::ground_truth::GroundTruth;
use crate::util::rng::Xoshiro256;

use super::GeneratedGraph;

/// Planted-partition configuration.
#[derive(Debug, Clone)]
pub struct SbmConfig {
    /// Community sizes (sum = n).
    pub sizes: Vec<usize>,
    /// Intra-community edge probability.
    pub p_in: f64,
    /// Inter-community edge probability.
    pub p_out: f64,
    /// RNG seed.
    pub seed: u64,
}

impl SbmConfig {
    /// `k` equal communities of `size` nodes each.
    pub fn equal(k: usize, size: usize, p_in: f64, p_out: f64, seed: u64) -> Self {
        Self { sizes: vec![size; k], p_in, p_out, seed }
    }

    /// Total node count (sum of community sizes).
    pub fn n(&self) -> usize {
        self.sizes.iter().sum()
    }
}

/// Enumerate pairs `(a, b)` with `a < b < len` by linear index, with
/// geometric skipping at probability `p`; call `emit(a, b)` per hit.
fn skip_pairs(
    rng: &mut Xoshiro256,
    len: u64,
    p: f64,
    mut emit: impl FnMut(u64, u64),
) {
    if len < 2 || p <= 0.0 {
        return;
    }
    let total = len * (len - 1) / 2;
    let mut idx: u64 = 0;
    loop {
        let skip = rng.geometric(p);
        if skip >= total - idx {
            break;
        }
        idx += skip;
        // invert linear index -> (a, b), a < b, row-major over a
        // idx = a*len - a*(a+1)/2 + (b - a - 1)
        let a = {
            // solve smallest a with cum(a+1) > idx where
            // cum(a) = a*len - a*(a+1)/2
            let mut lo = 0u64;
            let mut hi = len - 1;
            while lo < hi {
                let mid = (lo + hi) / 2;
                let cum = (mid + 1) * len - (mid + 1) * (mid + 2) / 2;
                if cum > idx {
                    hi = mid;
                } else {
                    lo = mid + 1;
                }
            }
            lo
        };
        let cum_a = a * len - a * (a + 1) / 2;
        let b = a + 1 + (idx - cum_a);
        emit(a, b);
        idx += 1;
        if idx >= total {
            break;
        }
    }
}

/// Generate a planted-partition graph with ground truth.
pub fn generate(config: &SbmConfig) -> GeneratedGraph {
    let n = config.n();
    let mut rng = Xoshiro256::new(config.seed);

    // node -> community labels; communities get contiguous id ranges and
    // node ids are then permuted so block structure isn't positional.
    let mut labels = vec![0u32; n];
    let mut starts = Vec::with_capacity(config.sizes.len());
    {
        let mut cursor = 0usize;
        for (k, &s) in config.sizes.iter().enumerate() {
            starts.push(cursor);
            for i in cursor..cursor + s {
                labels[i] = k as u32;
            }
            cursor += s;
        }
    }
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);

    let mut edges = Vec::new();

    // intra edges per community
    for (k, &s) in config.sizes.iter().enumerate() {
        let base = starts[k] as u64;
        skip_pairs(&mut rng, s as u64, config.p_in, |a, b| {
            edges.push(Edge::new(perm[(base + a) as usize], perm[(base + b) as usize]));
        });
    }

    // inter edges: skip over the full pair space, keep only cross pairs
    skip_pairs(&mut rng, n as u64, config.p_out, |a, b| {
        if labels[a as usize] != labels[b as usize] {
            edges.push(Edge::new(perm[a as usize], perm[b as usize]));
        }
    });

    // ground truth in permuted id space
    let mut truth_labels = vec![0u32; n];
    for i in 0..n {
        truth_labels[perm[i] as usize] = labels[i];
    }

    let mut g = GeneratedGraph {
        name: format!("sbm-k{}-n{}", config.sizes.len(), n),
        edges: EdgeList::new(n, edges),
        truth: GroundTruth::from_labels(&truth_labels),
    };
    g.shuffle_stream(rng.next_u64());
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_counts_match_expectation() {
        let cfg = SbmConfig::equal(10, 100, 0.1, 0.001, 42);
        let g = generate(&cfg);
        assert_eq!(g.n(), 1000);
        // expected intra: 10 * C(100,2) * 0.1 = 4950; inter:
        // (C(1000,2) - 10*C(100,2)) * 0.001 ≈ 450
        let m = g.m() as f64;
        assert!((4800.0..6200.0).contains(&m), "m={m}");
        assert_eq!(g.truth.len(), 10);
    }

    #[test]
    fn intra_fraction_dominates_for_assortative_params() {
        let cfg = SbmConfig::equal(8, 64, 0.2, 0.002, 7);
        let g = generate(&cfg);
        let labels = g.truth.to_labels(g.n());
        let intra = g
            .edges
            .edges
            .iter()
            .filter(|e| labels[e.u as usize] == labels[e.v as usize])
            .count();
        let frac = intra as f64 / g.m() as f64;
        assert!(frac > 0.7, "intra fraction {frac}");
    }

    #[test]
    fn no_self_loops_or_out_of_range() {
        let g = generate(&SbmConfig::equal(4, 50, 0.15, 0.01, 3));
        for e in &g.edges.edges {
            assert!(!e.is_self_loop());
            assert!((e.u as usize) < g.n() && (e.v as usize) < g.n());
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let a = generate(&SbmConfig::equal(4, 40, 0.2, 0.01, 11));
        let b = generate(&SbmConfig::equal(4, 40, 0.2, 0.01, 11));
        assert_eq!(a.edges.edges, b.edges.edges);
        let c = generate(&SbmConfig::equal(4, 40, 0.2, 0.01, 12));
        assert_ne!(a.edges.edges, c.edges.edges);
    }

    #[test]
    fn skip_pairs_exhaustive_at_p1() {
        let mut rng = Xoshiro256::new(1);
        let mut got = Vec::new();
        skip_pairs(&mut rng, 5, 1.0, |a, b| got.push((a, b)));
        assert_eq!(got.len(), 10);
        // all distinct ordered pairs a < b
        let set: std::collections::HashSet<_> = got.iter().collect();
        assert_eq!(set.len(), 10);
        assert!(got.iter().all(|&(a, b)| a < b && b < 5));
    }

    #[test]
    fn skip_pairs_rate_close_to_p() {
        let mut rng = Xoshiro256::new(2);
        let mut count = 0u64;
        skip_pairs(&mut rng, 1000, 0.01, |_, _| count += 1);
        let total = 1000u64 * 999 / 2;
        let expected = total as f64 * 0.01;
        assert!(
            (count as f64 - expected).abs() < expected * 0.15,
            "count={count} expected≈{expected}"
        );
    }

    #[test]
    fn unequal_sizes_respected() {
        let cfg = SbmConfig { sizes: vec![10, 200, 30], p_in: 0.3, p_out: 0.0, seed: 5 };
        let g = generate(&cfg);
        let mut sizes: Vec<usize> = g.truth.communities.iter().map(|c| c.len()).collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![10, 30, 200]);
    }
}
