//! Ground-truth community storage, mirroring SNAP's `cmty` files:
//! a list of node sets (possibly overlapping; our generators emit
//! disjoint ones, but the scorers accept overlap like the paper's
//! F1 scorer does).

use std::collections::HashMap;

/// Ground-truth communities over nodes `0..n`.
#[derive(Debug, Clone, Default)]
pub struct GroundTruth {
    /// communities[k] = sorted node ids of community k
    pub communities: Vec<Vec<u32>>,
}

impl GroundTruth {
    /// Ground truth from explicit community memberships.
    pub fn new(mut communities: Vec<Vec<u32>>) -> Self {
        for c in &mut communities {
            c.sort_unstable();
            c.dedup();
        }
        communities.retain(|c| !c.is_empty());
        Self { communities }
    }

    /// Build from a disjoint label vector (label per node).
    pub fn from_labels(labels: &[u32]) -> Self {
        let mut map: HashMap<u32, Vec<u32>> = HashMap::new();
        for (i, &l) in labels.iter().enumerate() {
            map.entry(l).or_default().push(i as u32);
        }
        let mut communities: Vec<Vec<u32>> = map.into_values().collect();
        communities.sort_unstable_by_key(|c| c[0]);
        Self { communities }
    }

    /// Disjoint label vector (last community wins on overlap).
    pub fn to_labels(&self, n: usize) -> Vec<u32> {
        let mut labels = vec![u32::MAX; n];
        for (k, c) in self.communities.iter().enumerate() {
            for &i in c {
                labels[i as usize] = k as u32;
            }
        }
        // unassigned nodes become singletons with fresh labels
        let mut next = self.communities.len() as u32;
        for l in &mut labels {
            if *l == u32::MAX {
                *l = next;
                next += 1;
            }
        }
        labels
    }

    /// Number of communities.
    pub fn len(&self) -> usize {
        self.communities.len()
    }

    /// True when no communities are recorded.
    pub fn is_empty(&self) -> bool {
        self.communities.is_empty()
    }

    /// Mean community size (nodes).
    pub fn mean_size(&self) -> f64 {
        if self.communities.is_empty() {
            return 0.0;
        }
        self.communities.iter().map(|c| c.len()).sum::<usize>() as f64
            / self.communities.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_labels_roundtrip() {
        let labels = vec![0, 0, 1, 1, 2];
        let gt = GroundTruth::from_labels(&labels);
        assert_eq!(gt.len(), 3);
        let back = gt.to_labels(5);
        // same partition up to renaming
        assert_eq!(back[0], back[1]);
        assert_eq!(back[2], back[3]);
        assert_ne!(back[0], back[2]);
        assert_ne!(back[0], back[4]);
    }

    #[test]
    fn new_sorts_dedups_drops_empty() {
        let gt = GroundTruth::new(vec![vec![3, 1, 3], vec![], vec![2]]);
        assert_eq!(gt.len(), 2);
        assert_eq!(gt.communities[0], vec![1, 3]);
    }

    #[test]
    fn unassigned_nodes_become_singletons() {
        let gt = GroundTruth::new(vec![vec![0, 1]]);
        let labels = gt.to_labels(4);
        assert_eq!(labels[0], labels[1]);
        assert_ne!(labels[2], labels[3]);
        assert_ne!(labels[2], labels[0]);
    }

    #[test]
    fn mean_size() {
        let gt = GroundTruth::new(vec![vec![0, 1], vec![2, 3, 4, 5]]);
        assert!((gt.mean_size() - 3.0).abs() < 1e-12);
    }
}
