//! Segmented binary edge format (`.bin`, version 2): checksummed,
//! fixed-width, independently scannable.
//!
//! Layout (all little-endian):
//!
//! ```text
//! header, 48 B:
//!   [ 0.. 4)  magic  "SSEG"
//!   [ 4.. 8)  version      u32  (= 2)
//!   [ 8..16)  n            u64  node-count header (≤ 2^32: ids are u32)
//!   [16..24)  m            u64  total edge records
//!   [24..32)  seg_records  u64  records per full segment (≥ 1)
//!   [32..40)  seg_count    u64  ⌈m / seg_records⌉
//!   [40..48)  fnv1a-64 over bytes [0..40)
//! segment i of seg_count, at 48 + i·(16 + seg_records·8):
//!   [0..8)            records in this segment u64 (= seg_records,
//!                     except possibly the last)
//!   [8..8+records·8)  records: [u u32][v u32] …
//!   trailing 8 B      fnv1a-64 over the count + record bytes
//! ```
//!
//! Every segment except the last holds exactly `seg_records` records,
//! so segment offsets are *computable*: the `(seg_records, seg_count)`
//! pair in the header **is** the segment table, with no explicit offset
//! list to keep in sync — the same fixed-width trick as the WAL's 24 B
//! records (`service::wal`). That is what makes the file independently
//! scannable: a reader that owns segments `[a, b)` seeks straight to
//! [`SegHeader::seg_offset`]`(a)` without touching the rest of the
//! file (`stream::pscan` does exactly this).
//!
//! Hostile-input stance: the header checksum catches corruption, and
//! [`SegHeader::validate_file_len`] cross-checks every header-derived
//! size against the real file length with checked arithmetic *before*
//! any allocation — a crafted header claiming m = 2^61 fails there; it
//! never sizes a buffer. Each segment then redundantly carries its own
//! record count and trailing checksum, so a bit flip anywhere in the
//! payload is a hard [`std::io::ErrorKind::InvalidData`], never a
//! silently wrong edge.

use std::io;

use super::edge::Edge;

/// File magic, first four bytes of the header.
pub const MAGIC: [u8; 4] = *b"SSEG";

/// Format version. Version 1 was the ad-hoc `[magic u32, n u32, m u64]`
/// header with no checksums; readers reject it with a bad-magic error.
pub const VERSION: u32 = 2;

/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 48;

/// Bytes one edge record occupies (`[u u32][v u32]`).
pub const RECORD_BYTES: u64 = 8;

/// Per-segment overhead: 8 B leading record count + 8 B trailing checksum.
pub const SEG_OVERHEAD_BYTES: u64 = 16;

/// Default records per segment (512 KiB of payload): large enough to
/// amortise the 16 B overhead and a seek per segment, small enough that
/// a parallel scan gets useful work splits on medium files.
pub const DEFAULT_SEG_RECORDS: u64 = 65_536;

/// Largest admissible node-count header: records store `u32` ids, so a
/// larger `n` cannot be represented and is rejected at write time
/// (instead of the silent `as u32` truncation the v1 writer performed).
pub const MAX_NODE_COUNT: u64 = 1 << 32;

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// 64-bit FNV-1a over `bytes` — the same whole-buffer checksum the WAL
/// checkpoint files use; dependency-free and good enough to catch the
/// corruption classes a storage layer sees (bit flips, truncation,
/// doubled writes).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Decoded, validated file header. The `(seg_records, seg_count)` pair
/// doubles as the segment table (offsets are computable — see the
/// module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegHeader {
    /// Node-count header (≤ [`MAX_NODE_COUNT`]).
    pub n: u64,
    /// Total edge records in the file.
    pub m: u64,
    /// Records per full segment (≥ 1).
    pub seg_records: u64,
    /// Number of segments: ⌈m / seg_records⌉ (0 iff m = 0).
    pub seg_count: u64,
}

impl SegHeader {
    /// Header for writing `m` records with `n` nodes in segments of
    /// `seg_records`. Errors (`InvalidInput`) when `n` exceeds the u32
    /// id space — the hard-error replacement for the v1 writer's silent
    /// `n as u32` truncation — or when `seg_records` is 0.
    pub fn new(n: usize, m: u64, seg_records: u64) -> io::Result<Self> {
        if n as u64 > MAX_NODE_COUNT {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "node count {n} exceeds the binary format's u32 id space \
                     (max {MAX_NODE_COUNT}); refusing to write a truncated header"
                ),
            ));
        }
        if seg_records == 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "seg_records must be ≥ 1".to_string(),
            ));
        }
        Ok(Self { n: n as u64, m, seg_records, seg_count: m.div_ceil(seg_records) })
    }

    /// Serialise to the fixed 48 B wire form (trailing checksum included).
    pub fn encode(&self) -> [u8; HEADER_BYTES] {
        let mut out = [0u8; HEADER_BYTES];
        out[0..4].copy_from_slice(&MAGIC);
        out[4..8].copy_from_slice(&VERSION.to_le_bytes());
        out[8..16].copy_from_slice(&self.n.to_le_bytes());
        out[16..24].copy_from_slice(&self.m.to_le_bytes());
        out[24..32].copy_from_slice(&self.seg_records.to_le_bytes());
        out[32..40].copy_from_slice(&self.seg_count.to_le_bytes());
        let check = fnv1a(&out[0..40]);
        out[40..48].copy_from_slice(&check.to_le_bytes());
        out
    }

    /// Decode and validate a 48 B header: magic, version, checksum, the
    /// node-count cap, and internal consistency (`seg_count` must equal
    /// ⌈m / seg_records⌉). Byte-level corruption fails the checksum; a
    /// *consistent but hostile* header is caught later by
    /// [`validate_file_len`](Self::validate_file_len).
    pub fn decode(bytes: &[u8; HEADER_BYTES]) -> io::Result<Self> {
        if bytes[0..4] != MAGIC {
            return Err(invalid(format!(
                "bad magic {:02x?} (expected {:02x?} — not a segmented edge file, \
                 or a pre-v2 file that needs regenerating)",
                &bytes[0..4],
                MAGIC
            )));
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(invalid(format!(
                "unsupported format version {version} (expected {VERSION})"
            )));
        }
        let stored = u64::from_le_bytes(bytes[40..48].try_into().unwrap());
        let computed = fnv1a(&bytes[0..40]);
        if stored != computed {
            return Err(invalid(format!(
                "header checksum mismatch (stored {stored:#018x}, computed {computed:#018x})"
            )));
        }
        let h = Self {
            n: u64::from_le_bytes(bytes[8..16].try_into().unwrap()),
            m: u64::from_le_bytes(bytes[16..24].try_into().unwrap()),
            seg_records: u64::from_le_bytes(bytes[24..32].try_into().unwrap()),
            seg_count: u64::from_le_bytes(bytes[32..40].try_into().unwrap()),
        };
        if h.n > MAX_NODE_COUNT {
            return Err(invalid(format!(
                "header n={} exceeds the u32 id space (max {MAX_NODE_COUNT})",
                h.n
            )));
        }
        if h.seg_records == 0 {
            return Err(invalid("header seg_records is 0".to_string()));
        }
        let want_segs = h.m.div_ceil(h.seg_records);
        if h.seg_count != want_segs {
            return Err(invalid(format!(
                "header seg_count={} inconsistent with m={} / seg_records={} (expected {want_segs})",
                h.seg_count, h.m, h.seg_records
            )));
        }
        Ok(h)
    }

    /// Records in segment `seg` (callers keep `seg < seg_count`; only
    /// the last segment may run short).
    pub fn records_in(&self, seg: u64) -> u64 {
        debug_assert!(seg < self.seg_count);
        if seg + 1 == self.seg_count {
            self.m - seg * self.seg_records
        } else {
            self.seg_records
        }
    }

    /// On-disk size of segment `seg` including its count + checksum.
    pub fn seg_bytes(&self, seg: u64) -> u64 {
        SEG_OVERHEAD_BYTES + self.records_in(seg) * RECORD_BYTES
    }

    /// Byte offset of segment `seg` (checked: `None` on arithmetic
    /// overflow, which only a hostile header can produce).
    pub fn seg_offset(&self, seg: u64) -> Option<u64> {
        let full = self.seg_records.checked_mul(RECORD_BYTES)?.checked_add(SEG_OVERHEAD_BYTES)?;
        (HEADER_BYTES as u64).checked_add(seg.checked_mul(full)?)
    }

    /// Total file size the header implies (checked: `None` on overflow).
    pub fn file_len(&self) -> Option<u64> {
        if self.seg_count == 0 {
            return Some(HEADER_BYTES as u64);
        }
        let last = self.seg_bytes(self.seg_count - 1);
        self.seg_offset(self.seg_count - 1)?.checked_add(last)
    }

    /// The hostile-header gate: every size the header implies must match
    /// the *actual* file length before any reader allocates — a crafted
    /// `m = 2^61` fails here (overflow or mismatch), it never sizes a
    /// buffer.
    pub fn validate_file_len(&self, actual: u64) -> io::Result<()> {
        match self.file_len() {
            None => Err(invalid(format!(
                "header implies a file size beyond u64 (m={}, seg_records={}) — corrupt or hostile",
                self.m, self.seg_records
            ))),
            Some(want) if want != actual => Err(invalid(format!(
                "file length {actual} B does not match the header (m={}, seg_records={}, \
                 seg_count={} ⇒ {want} B) — truncated, overlong, or hostile",
                self.m, self.seg_records, self.seg_count
            ))),
            Some(_) => Ok(()),
        }
    }
}

/// Encode one segment (count + records + trailing checksum) into `out`
/// (cleared first; the buffer is reusable across segments).
pub fn encode_segment(out: &mut Vec<u8>, edges: &[Edge]) {
    out.clear();
    out.extend_from_slice(&(edges.len() as u64).to_le_bytes());
    for e in edges {
        out.extend_from_slice(&e.u.to_le_bytes());
        out.extend_from_slice(&e.v.to_le_bytes());
    }
    let check = fnv1a(out);
    out.extend_from_slice(&check.to_le_bytes());
}

/// Decode and length-validate the header of a file that is fully
/// resident in memory — a memory-mapped file, or a `Vec<u8>` on the
/// non-unix fallback. Same gate order as the streaming open:
/// magic/version/checksum/consistency via [`SegHeader::decode`], then
/// [`SegHeader::validate_file_len`] against the *real* byte count.
///
/// On success every `seg < seg_count` satisfies
/// `seg_offset(seg) + seg_bytes(seg) ≤ bytes.len()` (segments are
/// contiguous and the last one ends exactly at `file_len`), so borrowed
/// [`SegView`]s can be carved out of `bytes` with plain slicing — a
/// short map is an `InvalidData` error here, never a fault later.
pub fn parse_mapped(bytes: &[u8]) -> io::Result<SegHeader> {
    if bytes.len() < HEADER_BYTES {
        return Err(invalid(format!(
            "file is {} B — too short for the {HEADER_BYTES} B v2 header",
            bytes.len()
        )));
    }
    let head: &[u8; HEADER_BYTES] = bytes[..HEADER_BYTES].try_into().unwrap();
    let header = SegHeader::decode(head)?;
    header.validate_file_len(bytes.len() as u64)?;
    Ok(header)
}

/// Shared validation core for [`decode_segment`] and [`SegView::parse`]:
/// checks the stored record count against the header-derived `expected`,
/// then the trailing checksum, and returns the raw record payload
/// (`expected ·`[`RECORD_BYTES`] bytes of `[u u32][v u32]` pairs).
fn validate_segment(block: &[u8], expected: u64, seg: u64) -> io::Result<&[u8]> {
    let want_len = SEG_OVERHEAD_BYTES + expected * RECORD_BYTES;
    if block.len() as u64 != want_len {
        return Err(invalid(format!(
            "segment {seg}: block is {} B, expected {want_len} B — truncated file",
            block.len()
        )));
    }
    let count = u64::from_le_bytes(block[0..8].try_into().unwrap());
    if count != expected {
        return Err(invalid(format!(
            "segment {seg}: stored record count {count} does not match the header's {expected}"
        )));
    }
    let payload_end = block.len() - 8;
    let computed = fnv1a(&block[..payload_end]);
    let stored = u64::from_le_bytes(block[payload_end..].try_into().unwrap());
    if stored != computed {
        return Err(invalid(format!(
            "segment {seg}: checksum mismatch (stored {stored:#018x}, computed {computed:#018x})"
        )));
    }
    Ok(&block[8..payload_end])
}

/// A borrowed, checksum-verified view of one segment's records — the
/// zero-copy counterpart of [`decode_segment`]. [`parse`](Self::parse)
/// validates in place (count, then trailing FNV-1a) with the exact
/// error contract of the streaming reader, and afterwards the records
/// are readable straight out of the underlying bytes: [`raw`](Self::raw)
/// for the `&[u8]` payload, [`edges`](Self::edges) for a decoding
/// cursor, [`extend_into`](Self::extend_into) to materialise. No
/// edge-sized allocation happens anywhere in this type.
#[derive(Debug, Clone, Copy)]
pub struct SegView<'a> {
    /// Verified record payload: `count ·`[`RECORD_BYTES`] bytes.
    records: &'a [u8],
    count: u64,
}

impl<'a> SegView<'a> {
    /// Validate `block` (count + records + checksum, exactly
    /// [`SEG_OVERHEAD_BYTES`]` + expected·`[`RECORD_BYTES`] bytes —
    /// callers slice it out of a
    /// [`validate_file_len`](SegHeader::validate_file_len)-checked
    /// file) and return a view of its records. `seg` only labels
    /// error messages.
    pub fn parse(block: &'a [u8], expected: u64, seg: u64) -> io::Result<Self> {
        let records = validate_segment(block, expected, seg)?;
        Ok(Self { records, count: expected })
    }

    /// Verified record count.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// `true` when the segment holds no records.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The raw little-endian `[u u32][v u32]` payload, borrowed from
    /// the underlying file bytes.
    pub fn raw(&self) -> &'a [u8] {
        self.records
    }

    /// Zero-copy decoding cursor over the records.
    pub fn edges(&self) -> SegCursor<'a> {
        SegCursor { chunks: self.records.chunks_exact(RECORD_BYTES as usize) }
    }

    /// Append every record to `out` (one reserve, then straight-line
    /// decode — the materialising path the pooled-chunk readers use).
    pub fn extend_into(&self, out: &mut Vec<Edge>) {
        out.reserve(self.count as usize);
        for e in self.edges() {
            out.push(e);
        }
    }
}

/// Iterator over a [`SegView`]'s records, decoding each 8 B chunk to an
/// [`Edge`] on the fly (a concrete type so it can be stored/named).
pub struct SegCursor<'a> {
    chunks: std::slice::ChunksExact<'a, u8>,
}

impl Iterator for SegCursor<'_> {
    type Item = Edge;

    #[inline]
    fn next(&mut self) -> Option<Edge> {
        self.chunks.next().map(|c| {
            Edge::new(
                u32::from_le_bytes(c[0..4].try_into().unwrap()),
                u32::from_le_bytes(c[4..8].try_into().unwrap()),
            )
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.chunks.size_hint()
    }
}

impl ExactSizeIterator for SegCursor<'_> {}

/// Decode one segment block (count + records + checksum, exactly
/// [`SEG_OVERHEAD_BYTES`]` + expected·`[`RECORD_BYTES`] bytes — callers
/// size it from a [`validate_file_len`](SegHeader::validate_file_len)-
/// checked header) and append its records to `out`. The stored record
/// count must match the header-derived `expected`, and the trailing
/// checksum must verify; `seg` only labels error messages.
pub fn decode_segment(
    block: &[u8],
    expected: u64,
    seg: u64,
    out: &mut Vec<Edge>,
) -> io::Result<()> {
    SegView::parse(block, expected, seg)?.extend_into(out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrips_through_the_wire_form() {
        let h = SegHeader::new(1000, 123_456, 4096).unwrap();
        assert_eq!(h.seg_count, 31); // ⌈123456/4096⌉
        let got = SegHeader::decode(&h.encode()).unwrap();
        assert_eq!(got, h);
    }

    #[test]
    fn header_rejects_bad_magic_version_and_checksum() {
        let h = SegHeader::new(10, 100, 8).unwrap();
        let good = h.encode();

        let mut bad = good;
        bad[0] = b'X';
        assert!(SegHeader::decode(&bad).unwrap_err().to_string().contains("magic"));

        let mut bad = good;
        bad[4] = 9;
        // version is covered by the checksum too; flip both to isolate
        let check = fnv1a(&bad[0..40]);
        bad[40..48].copy_from_slice(&check.to_le_bytes());
        assert!(SegHeader::decode(&bad).unwrap_err().to_string().contains("version"));

        let mut bad = good;
        bad[20] ^= 0xff; // corrupt m without fixing the checksum
        assert!(SegHeader::decode(&bad).unwrap_err().to_string().contains("checksum"));
    }

    #[test]
    fn header_rejects_inconsistent_segment_table() {
        let mut h = SegHeader::new(10, 100, 8).unwrap();
        h.seg_count += 1; // lie about the segment count, re-checksum
        let bytes = h.encode();
        let err = SegHeader::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("seg_count"), "{err}");
    }

    #[test]
    fn writer_hard_errors_on_n_beyond_u32_space() {
        // the v1 writer silently truncated `n as u32`; now a hard error
        let err = SegHeader::new((1usize << 32) + 1, 4, 8).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("u32 id space"), "{err}");
        // exactly 2^32 nodes (ids 0..=u32::MAX) is representable
        assert!(SegHeader::new(1usize << 32, 4, 8).is_ok());
        assert!(SegHeader::new(4, 4, 0).is_err(), "zero seg_records");
    }

    #[test]
    fn hostile_sizes_fail_checked_arithmetic_not_allocation() {
        // a consistent header claiming m = 2^61: file_len overflows u64
        let h = SegHeader::new(8, 1u64 << 61, DEFAULT_SEG_RECORDS).unwrap();
        assert_eq!(h.file_len(), None);
        let err = h.validate_file_len(48).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // a merely-wrong (not overflowing) m reports the mismatch
        let h = SegHeader::new(8, 1 << 20, DEFAULT_SEG_RECORDS).unwrap();
        let err = h.validate_file_len(48).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err}");
    }

    #[test]
    fn segment_math_covers_the_file_exactly() {
        let h = SegHeader::new(10, 10, 4).unwrap(); // segments: 4, 4, 2
        assert_eq!(h.seg_count, 3);
        assert_eq!(h.records_in(0), 4);
        assert_eq!(h.records_in(2), 2);
        assert_eq!(h.seg_offset(0), Some(48));
        assert_eq!(h.seg_offset(1), Some(48 + 16 + 32));
        let want = 48 + 2 * (16 + 32) + (16 + 16);
        assert_eq!(h.file_len(), Some(want));
        // empty file: header only
        let h = SegHeader::new(0, 0, 4).unwrap();
        assert_eq!(h.seg_count, 0);
        assert_eq!(h.file_len(), Some(HEADER_BYTES as u64));
    }

    #[test]
    fn segment_roundtrips_and_detects_corruption() {
        let edges: Vec<Edge> = (0..100u32).map(|i| Edge::new(i, i + 1)).collect();
        let mut block = Vec::new();
        encode_segment(&mut block, &edges);
        assert_eq!(block.len() as u64, SEG_OVERHEAD_BYTES + 100 * RECORD_BYTES);

        let mut out = Vec::new();
        decode_segment(&block, 100, 0, &mut out).unwrap();
        assert_eq!(out, edges);

        // a count field that disagrees with the header's expectation is
        // its own error (it fires before the checksum is even computed
        // on a mismatched count, and the message names the segment)
        let mut lied = block.clone();
        lied[0..8].copy_from_slice(&99u64.to_le_bytes());
        // keep the block internally checksummed so only the count lies
        let payload_end = lied.len() - 8;
        let check = fnv1a(&lied[..payload_end]);
        lied[payload_end..].copy_from_slice(&check.to_le_bytes());
        let err = decode_segment(&lied, 100, 7, &mut Vec::new()).unwrap_err();
        assert!(err.to_string().contains("record count"), "{err}");
        assert!(err.to_string().contains("segment 7"), "{err}");

        // single bit flip in the payload → checksum error
        let mut flipped = block.clone();
        flipped[20] ^= 1;
        let err = decode_segment(&flipped, 100, 3, &mut Vec::new()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        assert!(err.to_string().contains("segment 3"), "{err}");
    }

    /// Build a full in-memory file: header + segments.
    fn encode_file(edges: &[Edge], n: usize, seg_records: u64) -> Vec<u8> {
        let h = SegHeader::new(n, edges.len() as u64, seg_records).unwrap();
        let mut out = h.encode().to_vec();
        let mut block = Vec::new();
        for chunk in edges.chunks(seg_records as usize) {
            encode_segment(&mut block, chunk);
            out.extend_from_slice(&block);
        }
        out
    }

    #[test]
    fn seg_view_is_a_zero_copy_cursor_over_verified_records() {
        let edges: Vec<Edge> = (0..37u32).map(|i| Edge::new(i, 2 * i)).collect();
        let mut block = Vec::new();
        encode_segment(&mut block, &edges);

        let view = SegView::parse(&block, 37, 0).unwrap();
        assert_eq!(view.count(), 37);
        assert!(!view.is_empty());
        // raw() borrows the original bytes — no copy happened
        assert_eq!(view.raw().as_ptr(), block[8..].as_ptr());
        assert_eq!(view.raw().len() as u64, 37 * RECORD_BYTES);
        // the cursor decodes on the fly and is exact-sized
        let cursor = view.edges();
        assert_eq!(cursor.len(), 37);
        assert_eq!(cursor.collect::<Vec<_>>(), edges);
        let mut out = Vec::new();
        view.extend_into(&mut out);
        assert_eq!(out, edges);
    }

    #[test]
    fn seg_view_shares_the_streaming_error_contract() {
        let edges: Vec<Edge> = (0..16u32).map(|i| Edge::new(i, i)).collect();
        let mut block = Vec::new();
        encode_segment(&mut block, &edges);

        // flipped bit → checksum error naming the segment
        let mut flipped = block.clone();
        flipped[30] ^= 0x10;
        let err = SegView::parse(&flipped, 16, 5).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("segment 5"), "{err}");
        assert!(err.to_string().contains("checksum"), "{err}");

        // short block → truncation error, still InvalidData
        let err = SegView::parse(&block[..block.len() - 1], 16, 2).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("segment 2"), "{err}");
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn parse_mapped_validates_header_and_length_before_any_view() {
        let edges: Vec<Edge> = (0..10u32).map(|i| Edge::new(i, i + 1)).collect();
        let file = encode_file(&edges, 11, 4);

        let h = parse_mapped(&file).unwrap();
        assert_eq!((h.n, h.m, h.seg_count), (11, 10, 3));
        // every segment is in bounds after parse_mapped succeeds
        let mut got = Vec::new();
        for seg in 0..h.seg_count {
            let off = h.seg_offset(seg).unwrap() as usize;
            let len = h.seg_bytes(seg) as usize;
            SegView::parse(&file[off..off + len], h.records_in(seg), seg)
                .unwrap()
                .extend_into(&mut got);
        }
        assert_eq!(got, edges);

        // shorter than a header → InvalidData, not a slice panic
        let err = parse_mapped(&file[..20]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("too short"), "{err}");

        // valid header, truncated payload → the length gate fires
        let err = parse_mapped(&file[..file.len() - 8]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("does not match the header"), "{err}");

        // header-only empty file is valid
        let empty = encode_file(&[], 0, 4);
        let h = parse_mapped(&empty).unwrap();
        assert_eq!((h.m, h.seg_count), (0, 0));
    }
}
