//! Edge-list IO: SNAP-style text, compact binary, ground-truth files.
//!
//! Text format is the SNAP convention the paper's datasets use: one
//! `u <whitespace> v` pair per line, `#`-prefixed comment lines.
//! Arbitrary (sparse) node ids are remapped to dense `u32` on ingest and
//! the mapping is returned so results can be translated back.
//!
//! Binary format (`.bin`): the versioned, checksummed, segmented
//! layout defined in [`super::binfmt`] — a fixed 48 B header
//! (magic/version/n/m + the computed segment table) followed by
//! independently scannable, individually checksummed segments of
//! fixed-width `u32` pairs. This is what the Table-1 benches stream
//! from — it removes the text-parsing confound when comparing against
//! the `cat` lower bound — and what the parallel source scan
//! (`stream::pscan`) splits segment-aligned across reader threads.
//! `streamcom convert` moves between the two formats with round-trip
//! verification. Binary reads come in two transports: the buffered
//! copy loop ([`read_binary_edges`]) and a zero-copy memory-mapped
//! path ([`read_binary_edges_mmap`]) that verifies segments in place
//! and decodes straight out of the mapping.

use std::collections::HashMap;
use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::binfmt;
use super::edge::{Edge, EdgeList};
use super::ground_truth::GroundTruth;

/// Parse one text line as an edge; `None` for comments/blank lines.
/// Thin `&str` wrapper over the byte scanner (`parse_edge_bytes`) so
/// there is exactly one line-classification implementation in the repo.
#[inline]
pub fn parse_edge_line(line: &str) -> Option<(u64, u64)> {
    match parse_edge_bytes(line.as_bytes()) {
        LineParse::Edge(u, v) => Some((u, v)),
        _ => None,
    }
}

/// Classification of one text line by the shared byte-level edge
/// scanner (`parse_edge_bytes`). The split matters because the two
/// consumers disagree on what a bad target means: the strict batch
/// reader ([`read_text_edges`]) hard-errors (a half-numeric line is a
/// corrupt file), while the lenient streaming transport
/// (`stream::source::TextFileSource`) skips.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum LineParse<'a> {
    /// Comment (`#`/`%`), blank, or non-numeric-source line — always
    /// skipped, by both consumers.
    Skip,
    /// A well-formed `u <ws> v` pair (64-bit ids, no narrowing here —
    /// the consumer decides whether an id beyond `u32` is remappable).
    Edge(u64, u64),
    /// The source id parsed but the target is missing (`None`) or
    /// malformed/overflowing (the offending token bytes).
    BadTarget(u64, Option<&'a [u8]>),
}

/// ASCII whitespace (the set `u8::is_ascii_whitespace` covers: space,
/// tab, CR, LF, form feed — plus vertical tab, which
/// `str::split_whitespace` also split on).
#[inline]
fn is_line_space(b: u8) -> bool {
    b.is_ascii_whitespace() || b == 0x0B
}

/// Scan the whitespace-delimited token starting at `line[*i..]` as a
/// decimal `u64`. Returns `None` — with the cursor still advanced past
/// the token — when the token is empty, contains a non-digit, or
/// overflows `u64`; an optional leading `+` is accepted, exactly like
/// `str::parse::<u64>`. The overflow check is what keeps a 20-digit id
/// from silently wrapping into a *wrong but plausible* value.
#[inline]
fn scan_token(line: &[u8], i: &mut usize) -> Option<u64> {
    let n = line.len();
    if *i < n && line[*i] == b'+' && *i + 1 < n && line[*i + 1].is_ascii_digit() {
        *i += 1; // "+42" parses like "42"; a bare "+" stays non-numeric
    }
    let start = *i;
    let mut x: u64 = 0;
    let mut ok = true;
    while *i < n && !is_line_space(line[*i]) {
        let b = line[*i];
        if ok && b.is_ascii_digit() {
            match x.checked_mul(10).and_then(|x| x.checked_add((b - b'0') as u64)) {
                Some(next) => x = next,
                None => ok = false,
            }
        } else {
            ok = false;
        }
        *i += 1;
    }
    (ok && *i > start).then_some(x)
}

/// Byte-level scan of one text line as two decimal ids — the shared
/// core of [`read_text_edges`] and the streaming
/// `stream::source::TextFileSource` (no UTF-8 validation, no per-line
/// `String`, hand-rolled decimal scan; see EXPERIMENTS.md §Perf for
/// why this matters on the streaming path). Classification matches the
/// old `&str` reader token for token on ASCII input: a token is
/// numeric only when it is *entirely* ASCII digits (optionally
/// `+`-prefixed, like `str::parse::<u64>`) and fits in `u64` — so
/// `12ab` is a non-numeric source (skip), and `1 2ab` or a 20-digit
/// target is a [`BadTarget`](LineParse::BadTarget), never a silently
/// wrapped id. Known, deliberate divergence: non-ASCII Unicode
/// whitespace (e.g. U+00A0) no longer separates tokens — a byte
/// scanner treats those bytes as part of a (then non-numeric) token;
/// SNAP-convention files are tab/space separated, so this only affects
/// already-exotic inputs.
pub(crate) fn parse_edge_bytes(line: &[u8]) -> LineParse<'_> {
    let mut i = 0;
    let n = line.len();
    while i < n && is_line_space(line[i]) {
        i += 1;
    }
    if i >= n || line[i] == b'#' || line[i] == b'%' {
        return LineParse::Skip;
    }
    let Some(u) = scan_token(line, &mut i) else {
        return LineParse::Skip; // non-numeric source: lenient skip
    };
    while i < n && is_line_space(line[i]) {
        i += 1;
    }
    if i >= n {
        return LineParse::BadTarget(u, None);
    }
    let tok_start = i;
    match scan_token(line, &mut i) {
        Some(v) => LineParse::Edge(u, v),
        None => LineParse::BadTarget(u, Some(&line[tok_start..i])),
    }
}

/// Frame one `fill_buf` chunk into newline-terminated lines, stitching
/// lines that span chunk boundaries through `carry`. This is the single
/// line-framing loop shared by the strict batch reader
/// ([`read_text_edges`]) and the lenient streaming transport
/// (`stream::source::TextFileSource`) — it used to be duplicated in
/// both, with a NOTE admitting a boundary fix to one likely applied to
/// the other; now a carry/refill edge case has exactly one home, pinned
/// by a shared fuzz test (`tests/edge_io.rs`).
///
/// `on_line` sees each complete line (without its `\n`); returning
/// `Ok(false)` stops framing early (capacity-bounded consumers), and
/// the returned byte count — how much of `chunk` was consumed, through
/// that line's newline — must be passed to `BufRead::consume`. At the
/// end of the chunk a trailing partial line is saved into `carry` (and
/// counted as consumed): on EOF the caller flushes `carry` as the final
/// unterminated line.
pub(crate) fn frame_lines<E>(
    chunk: &[u8],
    carry: &mut Vec<u8>,
    mut on_line: impl FnMut(&[u8]) -> Result<bool, E>,
) -> Result<usize, E> {
    let mut start = 0usize;
    while let Some(pos) = chunk[start..].iter().position(|&b| b == b'\n') {
        let line_end = start + pos;
        let keep_going = if carry.is_empty() {
            on_line(&chunk[start..line_end])?
        } else {
            carry.extend_from_slice(&chunk[start..line_end]);
            let r = on_line(carry)?;
            carry.clear();
            r
        };
        start = line_end + 1;
        if !keep_going {
            return Ok(start);
        }
    }
    if start < chunk.len() {
        carry.extend_from_slice(&chunk[start..]);
    }
    Ok(chunk.len())
}

/// Read a SNAP-style text edge list, remapping ids to dense u32.
/// Returns the edge list and the original ids indexed by dense id.
///
/// Comment (`#`/`%`), blank, and entirely non-numeric lines are
/// skipped, as before. A line whose *source* id parses but whose target
/// is missing or malformed is a hard [`io::Error`] — a half-numeric
/// line means a corrupt or truncated file, and silently dropping the
/// edge would skew every downstream metric.
///
/// The intern map and edge vector are pre-sized from the file length
/// (SNAP-style lines run ~12 bytes), so ingesting a large list does not
/// rehash/regrow its way up from empty.
///
/// §Perf: built on the same byte-level machinery as the streaming
/// `stream::source::TextFileSource` — lines are scanned directly in the
/// `BufReader`'s buffer via `fill_buf` with a carry for lines spanning
/// a refill boundary, and ids are decoded by the shared hand-rolled
/// decimal scanner (`parse_edge_bytes`). No per-line `String`, no UTF-8
/// validation, no `split_whitespace`: the per-line allocation the old
/// `lines()`-based reader paid is gone. Ids are interned as full `u64`,
/// so sparse ids beyond `u32` remain valid here (they remap densely) —
/// only genuinely non-numeric or `u64`-overflowing tokens are rejected.
pub fn read_text_edges<P: AsRef<Path>>(path: P) -> io::Result<(EdgeList, Vec<u64>)> {
    let f = File::open(path)?;
    // capped estimate: a wrong metadata size must not trigger a giant
    // pre-allocation
    let est_edges = (f.metadata().map(|m| m.len()).unwrap_or(0) / 12).min(1 << 27) as usize;
    let mut reader = BufReader::with_capacity(1 << 20, f);
    // nodes run well below edges on SNAP shapes (Amazon ~0.36 n/m,
    // Friendster ~0.04): an edges/8 guess avoids most rehashing without
    // a giant mostly-empty table on large files
    let mut map: HashMap<u64, u32> = HashMap::with_capacity((est_edges / 8).min(1 << 22));
    let mut back: Vec<u64> = Vec::new();
    let mut edges = Vec::with_capacity(est_edges);

    fn consume_line(
        line: &[u8],
        lineno: u64,
        map: &mut HashMap<u64, u32>,
        back: &mut Vec<u64>,
        edges: &mut Vec<Edge>,
    ) -> io::Result<()> {
        let mut intern = |id: u64, map: &mut HashMap<u64, u32>| -> u32 {
            *map.entry(id).or_insert_with(|| {
                back.push(id);
                (back.len() - 1) as u32
            })
        };
        match parse_edge_bytes(line) {
            LineParse::Skip => Ok(()),
            LineParse::Edge(u, v) => {
                if u != v {
                    let du = intern(u, map);
                    let dv = intern(v, map);
                    edges.push(Edge::new(du, dv));
                }
                Ok(())
            }
            // a parseable source with a missing or garbage target means
            // the file is corrupt — hard error, never a silent skip
            LineParse::BadTarget(u, None) => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("line {lineno}: edge source {u} has no target"),
            )),
            LineParse::BadTarget(u, Some(tok)) => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "line {lineno}: edge source {u} has malformed target {:?}",
                    String::from_utf8_lossy(tok)
                ),
            )),
        }
    }

    // fill_buf + frame_lines: scan lines in place in the reader's
    // buffer; a line spanning a refill boundary is stitched in `carry`
    // by the shared framing helper (also used by the streaming
    // `stream::source::TextFileSource`).
    let mut carry: Vec<u8> = Vec::with_capacity(64);
    let mut lineno: u64 = 0;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            if !carry.is_empty() {
                lineno += 1;
                consume_line(&carry, lineno, &mut map, &mut back, &mut edges)?;
                carry.clear();
            }
            break;
        }
        let consumed = frame_lines(chunk, &mut carry, |line| {
            lineno += 1;
            consume_line(line, lineno, &mut map, &mut back, &mut edges).map(|()| true)
        })?;
        reader.consume(consumed);
    }
    Ok((EdgeList::new(back.len(), edges), back))
}

/// Write a text edge list (dense ids).
pub fn write_text_edges<P: AsRef<Path>>(path: P, el: &EdgeList) -> io::Result<()> {
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    writeln!(w, "# streamcom edge list: n={} m={}", el.n, el.m())?;
    for e in &el.edges {
        writeln!(w, "{}\t{}", e.u, e.v)?;
    }
    w.flush()
}

/// Write the segmented binary format ([`binfmt`]) with the default
/// segment size. Hard-errors (`InvalidInput`) when `el.n` exceeds the
/// format's u32 id space — the v1 writer silently truncated `el.n as
/// u32` into a wrong-but-plausible header.
pub fn write_binary_edges<P: AsRef<Path>>(path: P, el: &EdgeList) -> io::Result<()> {
    write_binary_edges_with(path, el, binfmt::DEFAULT_SEG_RECORDS)
}

/// Write the segmented binary format with `seg_records` records per
/// full segment (the knob behind `convert --seg-records`; every full
/// segment holds exactly `seg_records` records, which is what keeps
/// segment offsets computable for the parallel scan).
pub fn write_binary_edges_with<P: AsRef<Path>>(
    path: P,
    el: &EdgeList,
    seg_records: u64,
) -> io::Result<()> {
    let header = binfmt::SegHeader::new(el.n, el.edges.len() as u64, seg_records)?;
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    w.write_all(&header.encode())?;
    let mut block = Vec::new();
    for seg in el.edges.chunks(seg_records as usize) {
        binfmt::encode_segment(&mut block, seg);
        w.write_all(&block)?;
    }
    w.flush()
}

/// Read the segmented binary format, verifying the header and every
/// segment checksum.
///
/// Hostile-input hardened: every header-derived size is cross-checked
/// against the actual file length with checked arithmetic
/// ([`binfmt::SegHeader::validate_file_len`]) *before* any edge-sized
/// allocation — a corrupt or hostile header (say, a tiny file claiming
/// m = 2^61) is an `InvalidData` error, never an unbounded
/// `vec![0; m * 8]`.
pub fn read_binary_edges<P: AsRef<Path>>(path: P) -> io::Result<EdgeList> {
    let f = File::open(path)?;
    let file_len = f.metadata()?.len();
    let mut r = BufReader::with_capacity(1 << 20, f);
    let mut head = [0u8; binfmt::HEADER_BYTES];
    r.read_exact(&mut head)?;
    let header = binfmt::SegHeader::decode(&head)?;
    header.validate_file_len(file_len)?;
    // validate_file_len proved every size below is backed by real bytes
    let mut edges = Vec::with_capacity(header.m as usize);
    let mut block = Vec::new();
    for seg in 0..header.seg_count {
        let records = header.records_in(seg);
        block.resize((binfmt::SEG_OVERHEAD_BYTES + records * binfmt::RECORD_BYTES) as usize, 0);
        r.read_exact(&mut block)?;
        binfmt::decode_segment(&block, records, seg, &mut edges)?;
    }
    Ok(EdgeList::new(header.n as usize, edges))
}

/// Read the segmented binary format through one read-only memory map
/// ([`crate::util::mmap`]) instead of a buffered copy loop: each
/// segment is checksum-verified in place ([`binfmt::SegView`]) and its
/// records decoded straight out of the mapping — the only copy is the
/// `Edge` push into the result vector.
///
/// Same hostile-input contract as [`read_binary_edges`]: the header is
/// cross-checked against the *mapped* length before any edge-sized
/// allocation ([`binfmt::parse_mapped`]), so a corrupt or truncated
/// file is an `InvalidData` error at open — never a fault on a short
/// map. On platforms without mmap support this falls back to the
/// buffered reader at compile time, so callers need no `cfg` of their
/// own.
pub fn read_binary_edges_mmap<P: AsRef<Path>>(path: P) -> io::Result<EdgeList> {
    read_binary_edges_mmap_with(path, crate::util::mmap::Advice::Sequential)
}

/// [`read_binary_edges_mmap`] with an explicit page-cache advice
/// ([`crate::util::mmap::Advice`], `--madvise` on the CLI). The advice
/// is best-effort and never changes what is read — only how the kernel
/// stages the pages.
pub fn read_binary_edges_mmap_with<P: AsRef<Path>>(
    path: P,
    advice: crate::util::mmap::Advice,
) -> io::Result<EdgeList> {
    if !crate::util::mmap::supported() {
        return read_binary_edges(path);
    }
    let f = File::open(path)?;
    let map = crate::util::mmap::Mmap::map_file_advised(&f, advice)?;
    drop(f); // the mapping outlives the descriptor
    let bytes = map.as_slice();
    let header = binfmt::parse_mapped(bytes)?;
    // parse_mapped proved every segment range below is in bounds
    let mut edges = Vec::with_capacity(header.m as usize);
    for seg in 0..header.seg_count {
        let records = header.records_in(seg);
        let off = header.seg_offset(seg).expect("validated header") as usize;
        let len = header.seg_bytes(seg) as usize;
        binfmt::SegView::parse(&bytes[off..off + len], records, seg)?.extend_into(&mut edges);
    }
    Ok(EdgeList::new(header.n as usize, edges))
}

/// Write SNAP-style ground truth: one community per line, node ids
/// separated by tabs.
pub fn write_ground_truth<P: AsRef<Path>>(path: P, gt: &GroundTruth) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for c in &gt.communities {
        let line: Vec<String> = c.iter().map(|x| x.to_string()).collect();
        writeln!(w, "{}", line.join("\t"))?;
    }
    w.flush()
}

/// Read SNAP-style ground truth.
///
/// A token that fails to parse as a node id is a hard `InvalidData`
/// error, matching [`read_text_edges`]'s bad-target contract — the old
/// `filter_map(|t| t.parse().ok())` silently dropped it, so a corrupt
/// ground-truth file quietly shifted every NMI/F1 score downstream.
pub fn read_ground_truth<P: AsRef<Path>>(path: P) -> io::Result<GroundTruth> {
    let f = File::open(path)?;
    let mut communities = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut c: Vec<u32> = Vec::new();
        for t in line.split_whitespace() {
            c.push(t.parse().map_err(|_| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("ground truth line {}: unparseable node id {t:?}", lineno + 1),
                )
            })?);
        }
        if !c.is_empty() {
            communities.push(c);
        }
    }
    Ok(GroundTruth::new(communities))
}

/// Write a label assignment (`node<TAB>community` per line).
pub fn write_labels<P: AsRef<Path>>(path: P, labels: &[u32]) -> io::Result<()> {
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    for (i, &c) in labels.iter().enumerate() {
        writeln!(w, "{i}\t{c}")?;
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("streamcom_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn parse_line_variants() {
        assert_eq!(parse_edge_line("1\t2"), Some((1, 2)));
        assert_eq!(parse_edge_line("  3 4  "), Some((3, 4)));
        assert_eq!(parse_edge_line("# comment"), None);
        assert_eq!(parse_edge_line(""), None);
        assert_eq!(parse_edge_line("x y"), None);
    }

    #[test]
    fn byte_scanner_classifies_like_the_str_reader() {
        // the scanner is the shared core of both text readers — its
        // classification must match the old token-wise &str semantics
        assert_eq!(parse_edge_bytes(b"1\t2"), LineParse::Edge(1, 2));
        assert_eq!(parse_edge_bytes(b"  3 4  \r"), LineParse::Edge(3, 4));
        assert_eq!(parse_edge_bytes(b"1 2 3"), LineParse::Edge(1, 2)); // extra tokens ignored
        assert_eq!(parse_edge_bytes(b"# comment"), LineParse::Skip);
        assert_eq!(parse_edge_bytes(b"% header"), LineParse::Skip);
        assert_eq!(parse_edge_bytes(b""), LineParse::Skip);
        assert_eq!(parse_edge_bytes(b"   "), LineParse::Skip);
        // str::parse::<u64> accepts a leading '+'; the scanner must too
        assert_eq!(parse_edge_bytes(b"+1 +2"), LineParse::Edge(1, 2));
        assert_eq!(parse_edge_bytes(b"1 +"), LineParse::BadTarget(1, Some(b"+".as_slice())));
        // vertical tab / form feed separate tokens like split_whitespace
        assert_eq!(parse_edge_bytes(b"1\x0b2"), LineParse::Edge(1, 2));
        assert_eq!(parse_edge_bytes(b"1\x0c2"), LineParse::Edge(1, 2));
        // a partially-numeric token is NOT a number: "12ab" is a
        // non-numeric source (skip), "2ab" a malformed target (error)
        assert_eq!(parse_edge_bytes(b"12ab 34"), LineParse::Skip);
        assert_eq!(
            parse_edge_bytes(b"1 2ab"),
            LineParse::BadTarget(1, Some(b"2ab".as_slice()))
        );
        assert_eq!(parse_edge_bytes(b"42"), LineParse::BadTarget(42, None));
    }

    #[test]
    fn byte_scanner_never_wraps_u64_overflow() {
        // 2^64 + ε as text: the old wrapping scan silently produced a
        // wrong-but-valid id; overflow must classify as non-numeric
        let big = "18446744073709551616"; // u64::MAX + 1
        let line = format!("{big} 5");
        assert_eq!(
            parse_edge_bytes(line.as_bytes()),
            LineParse::Skip,
            "overflowing source"
        );
        let line = format!("5 {big}");
        assert!(
            matches!(parse_edge_bytes(line.as_bytes()), LineParse::BadTarget(5, Some(_))),
            "overflowing target must be a hard error for the strict reader"
        );
        // u64::MAX itself still parses
        assert_eq!(
            parse_edge_bytes(b"18446744073709551615 1"),
            LineParse::Edge(u64::MAX, 1)
        );
    }

    #[test]
    fn text_reader_interns_40bit_ids_without_truncation() {
        // regression: ids beyond u32 must remap densely, never narrow
        let p = tmp("wide.txt");
        let a = 1u64 << 40;
        let b = (1u64 << 40) + 1;
        std::fs::write(&p, format!("{a}\t{b}\n{b}\t7\n")).unwrap();
        let (el, back) = read_text_edges(&p).unwrap();
        assert_eq!(el.n, 3);
        assert_eq!(el.m(), 2);
        assert_eq!(back, vec![a, b, 7]);
        assert_eq!(el.edges, vec![Edge::new(0, 1), Edge::new(1, 2)]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_handles_lines_spanning_buffer_refills() {
        // a file larger than the BufReader's internal buffer exercises
        // the fill_buf + carry path end to end; build one long comment
        // line (> 1 MiB) followed by real edges and a no-newline tail
        let p = tmp("carry.txt");
        let mut data = String::with_capacity((1 << 20) + 64);
        data.push('#');
        for _ in 0..(1 << 20) {
            data.push('x');
        }
        data.push('\n');
        data.push_str("10\t20\n30\t40"); // final line has no newline
        std::fs::write(&p, data).unwrap();
        let (el, back) = read_text_edges(&p).unwrap();
        assert_eq!(el.m(), 2);
        assert_eq!(back, vec![10, 20, 30, 40]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_roundtrip_with_remap() {
        let p = tmp("text.txt");
        std::fs::write(&p, "# header\n100\t200\n200\t300\n100\t300\n7\t7\n").unwrap();
        let (el, back) = read_text_edges(&p).unwrap();
        assert_eq!(el.n, 3);
        assert_eq!(el.m(), 3); // self-loop 7-7 dropped
        assert_eq!(back, vec![100, 200, 300]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_errors_on_malformed_target() {
        // a parseable source with a garbage target means the file is
        // corrupt — that must be a hard error, not a silent skip
        let p = tmp("badv.txt");
        std::fs::write(&p, "1\t2\n3\toops\n4\t5\n").unwrap();
        let err = read_text_edges(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("oops"), "{msg}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_errors_on_missing_target() {
        let p = tmp("nov.txt");
        std::fs::write(&p, "1\t2\n42\n").unwrap();
        let err = read_text_edges(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("no target"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_still_skips_fully_non_numeric_lines() {
        // comment/blank/textual lines keep the old lenient behaviour —
        // only a half-numeric line is evidence of corruption
        let p = tmp("lenient.txt");
        std::fs::write(&p, "% matrix-market-ish header\nfrom to\n\n1 2\n").unwrap();
        let (el, back) = read_text_edges(&p).unwrap();
        assert_eq!(el.m(), 1);
        assert_eq!(back, vec![1, 2]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let p = tmp("edges.bin");
        let el = EdgeList::new(5, vec![Edge::new(0, 1), Edge::new(3, 4), Edge::new(1, 2)]);
        write_binary_edges(&p, &el).unwrap();
        let got = read_binary_edges(&p).unwrap();
        assert_eq!(got.n, 5);
        assert_eq!(got.edges, el.edges);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        // too short for even a header
        let p = tmp("bad.bin");
        std::fs::write(&p, [0u8; 32]).unwrap();
        assert!(read_binary_edges(&p).is_err());
        // a full-size header of garbage names the magic in its error
        std::fs::write(&p, [0u8; 48]).unwrap();
        let err = read_binary_edges(&p).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_multi_segment_roundtrip() {
        // 10 edges in segments of 4 → segments of 4, 4, 2
        let p = tmp("multiseg.bin");
        let el = EdgeList::new(11, (0..10).map(|i| Edge::new(i, i + 1)).collect());
        write_binary_edges_with(&p, &el, 4).unwrap();
        let h = binfmt::SegHeader::new(11, 10, 4).unwrap();
        assert_eq!(std::fs::metadata(&p).unwrap().len(), h.file_len().unwrap());
        let got = read_binary_edges(&p).unwrap();
        assert_eq!(got.n, 11);
        assert_eq!(got.edges, el.edges);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_empty_roundtrip() {
        let p = tmp("empty.bin");
        let el = EdgeList::new(3, vec![]);
        write_binary_edges(&p, &el).unwrap();
        assert_eq!(std::fs::metadata(&p).unwrap().len(), binfmt::HEADER_BYTES as u64);
        let got = read_binary_edges(&p).unwrap();
        assert_eq!(got.n, 3);
        assert!(got.edges.is_empty());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_rejects_hostile_header_before_allocating() {
        // a 48-byte file whose (checksum-valid) header claims m = 2^61:
        // the length cross-check must fail before any edge-sized buffer
        // is sized — this test completing at all is the proof
        let p = tmp("hostile.bin");
        let h = binfmt::SegHeader::new(8, 1u64 << 61, binfmt::DEFAULT_SEG_RECORDS).unwrap();
        std::fs::write(&p, h.encode()).unwrap();
        let err = read_binary_edges(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // …and a plausible-but-truncated m is caught the same way
        let h = binfmt::SegHeader::new(8, 1 << 20, binfmt::DEFAULT_SEG_RECORDS).unwrap();
        std::fs::write(&p, h.encode()).unwrap();
        let err = read_binary_edges(&p).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_detects_payload_corruption() {
        let p = tmp("flip.bin");
        let el = EdgeList::new(9, (0..8).map(|i| Edge::new(i, i + 1)).collect());
        write_binary_edges_with(&p, &el, 3).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let off = binfmt::HEADER_BYTES + 8 + 2; // inside segment 0's records
        bytes[off] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = read_binary_edges(&p).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        assert!(err.to_string().contains("segment 0"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mmap_reader_matches_buffered_reader() {
        // mmap is a transport change, not a format change: byte-for-byte
        // identical EdgeList out of both readers, including the empty
        // (header-only) and multi-segment shapes
        let p = tmp("mmap_eq.bin");
        for (n, m, seg) in [(7usize, 0u32, 4u64), (9, 8, 3), (600, 500, 64)] {
            let el = EdgeList::new(n, (0..m).map(|i| Edge::new(i % 9, (i + 1) % 9)).collect());
            write_binary_edges_with(&p, &el, seg).unwrap();
            let buffered = read_binary_edges(&p).unwrap();
            let mapped = read_binary_edges_mmap(&p).unwrap();
            assert_eq!(mapped.n, buffered.n);
            assert_eq!(mapped.edges, buffered.edges);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mmap_reader_shares_the_hostile_input_contract() {
        // same InvalidData-at-open guarantees as the buffered reader:
        // hostile header, truncated payload, flipped bit — and never a
        // fault on a short map
        let p = tmp("mmap_hostile.bin");
        let h = binfmt::SegHeader::new(8, 1u64 << 61, binfmt::DEFAULT_SEG_RECORDS).unwrap();
        std::fs::write(&p, h.encode()).unwrap();
        assert_eq!(read_binary_edges_mmap(&p).unwrap_err().kind(), io::ErrorKind::InvalidData);

        let el = EdgeList::new(9, (0..8).map(|i| Edge::new(i, i + 1)).collect());
        write_binary_edges_with(&p, &el, 3).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 5]).unwrap();
        assert_eq!(read_binary_edges_mmap(&p).unwrap_err().kind(), io::ErrorKind::InvalidData);

        let mut flipped = full.clone();
        flipped[binfmt::HEADER_BYTES + 8 + 2] ^= 0x40;
        std::fs::write(&p, &flipped).unwrap();
        let err = read_binary_edges_mmap(&p).unwrap_err();
        assert!(err.to_string().contains("segment 0"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_writer_hard_errors_on_oversized_n() {
        // the v1 writer wrote (n as u32) silently; n beyond the id
        // space must now refuse to produce a wrong-but-plausible header
        let p = tmp("wide_n.bin");
        let el = EdgeList::new((1usize << 32) + 1, vec![Edge::new(0, 1)]);
        let err = write_binary_edges(&p, &el).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("u32 id space"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ground_truth_errors_on_garbage_token() {
        // a corrupt token mid-line used to be silently dropped, quietly
        // shifting NMI/F1 — it must be a hard error with a line number
        let p = tmp("gt_bad.txt");
        std::fs::write(&p, "0\t1\t2\n3\tfour\t5\n6\t7\n").unwrap();
        let err = read_ground_truth(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("four"), "{msg}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn frame_lines_stops_early_and_reports_consumed_bytes() {
        // Ok(false) from the callback stops framing mid-chunk; the
        // returned count points just past that line's newline so the
        // caller's consume() leaves the rest for the next call
        let chunk = b"aa\nbb\ncc\ndd";
        let mut carry = Vec::new();
        let mut seen: Vec<Vec<u8>> = Vec::new();
        let consumed = frame_lines(chunk, &mut carry, |line| {
            seen.push(line.to_vec());
            Ok::<bool, std::convert::Infallible>(seen.len() < 2)
        })
        .unwrap();
        assert_eq!(consumed, 6); // "aa\nbb\n"
        assert_eq!(seen, vec![b"aa".to_vec(), b"bb".to_vec()]);
        assert!(carry.is_empty());
        // resuming on the remainder frames "cc" and carries "dd"
        let consumed = frame_lines(&chunk[6..], &mut carry, |line| {
            seen.push(line.to_vec());
            Ok::<bool, std::convert::Infallible>(true)
        })
        .unwrap();
        assert_eq!(consumed, 5);
        assert_eq!(seen.last().unwrap(), b"cc");
        assert_eq!(carry, b"dd");
    }

    #[test]
    fn ground_truth_roundtrip() {
        let p = tmp("gt.txt");
        let gt = GroundTruth::new(vec![vec![0, 1, 2], vec![3, 4]]);
        write_ground_truth(&p, &gt).unwrap();
        let got = read_ground_truth(&p).unwrap();
        assert_eq!(got.communities, gt.communities);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_writer_reader_roundtrip() {
        let p = tmp("rt.txt");
        let el = EdgeList::new(4, vec![Edge::new(0, 1), Edge::new(2, 3)]);
        write_text_edges(&p, &el).unwrap();
        let (got, back) = read_text_edges(&p).unwrap();
        assert_eq!(got.m(), 2);
        assert_eq!(back.len(), 4);
        std::fs::remove_file(&p).ok();
    }
}
