//! Edge-list IO: SNAP-style text, compact binary, ground-truth files.
//!
//! Text format is the SNAP convention the paper's datasets use: one
//! `u <whitespace> v` pair per line, `#`-prefixed comment lines.
//! Arbitrary (sparse) node ids are remapped to dense `u32` on ingest and
//! the mapping is returned so results can be translated back.
//!
//! Binary format (`.bin`): little-endian header `[magic u32, n u32,
//! m u64]` followed by `m` pairs of `u32`. This is what the Table-1
//! benches stream from — it removes the text-parsing confound when
//! comparing against the `cat` lower bound, matching the paper's setup
//! where the algorithm reads a raw edge list.

use std::collections::HashMap;
use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::edge::{Edge, EdgeList};
use super::ground_truth::GroundTruth;

const BIN_MAGIC: u32 = 0x5354_4d43; // "STMC"

/// Parse one text line as an edge; `None` for comments/blank lines.
#[inline]
pub fn parse_edge_line(line: &str) -> Option<(u64, u64)> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
        return None;
    }
    let mut it = line.split_whitespace();
    let u = it.next()?.parse().ok()?;
    let v = it.next()?.parse().ok()?;
    Some((u, v))
}

/// Read a SNAP-style text edge list, remapping ids to dense u32.
/// Returns the edge list and the original ids indexed by dense id.
///
/// Comment (`#`/`%`), blank, and entirely non-numeric lines are
/// skipped, as before. A line whose *source* id parses but whose target
/// is missing or malformed is a hard [`io::Error`] — a half-numeric
/// line means a corrupt or truncated file, and silently dropping the
/// edge would skew every downstream metric.
///
/// The intern map and edge vector are pre-sized from the file length
/// (SNAP-style lines run ~12 bytes), so ingesting a large list does not
/// rehash/regrow its way up from empty.
pub fn read_text_edges<P: AsRef<Path>>(path: P) -> io::Result<(EdgeList, Vec<u64>)> {
    let f = File::open(path)?;
    // capped estimate: a wrong metadata size must not trigger a giant
    // pre-allocation
    let est_edges = (f.metadata().map(|m| m.len()).unwrap_or(0) / 12).min(1 << 27) as usize;
    let reader = BufReader::with_capacity(1 << 20, f);
    // nodes run well below edges on SNAP shapes (Amazon ~0.36 n/m,
    // Friendster ~0.04): an edges/8 guess avoids most rehashing without
    // a giant mostly-empty table on large files
    let mut map: HashMap<u64, u32> = HashMap::with_capacity((est_edges / 8).min(1 << 22));
    let mut back: Vec<u64> = Vec::new();
    let mut edges = Vec::with_capacity(est_edges);
    let intern = |id: u64, map: &mut HashMap<u64, u32>, back: &mut Vec<u64>| -> u32 {
        *map.entry(id).or_insert_with(|| {
            back.push(id);
            (back.len() - 1) as u32
        })
    };
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let Some(u_tok) = it.next() else { continue };
        let Ok(u) = u_tok.parse::<u64>() else {
            continue; // non-numeric line (e.g. a textual header) — skip
        };
        let v = match it.next() {
            None => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("line {}: edge source {u} has no target", lineno + 1),
                ))
            }
            Some(v_tok) => v_tok.parse::<u64>().map_err(|_| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!(
                        "line {}: edge source {u} has malformed target {v_tok:?}",
                        lineno + 1
                    ),
                )
            })?,
        };
        if u == v {
            continue;
        }
        let du = intern(u, &mut map, &mut back);
        let dv = intern(v, &mut map, &mut back);
        edges.push(Edge::new(du, dv));
    }
    Ok((EdgeList::new(back.len(), edges), back))
}

/// Write a text edge list (dense ids).
pub fn write_text_edges<P: AsRef<Path>>(path: P, el: &EdgeList) -> io::Result<()> {
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    writeln!(w, "# streamcom edge list: n={} m={}", el.n, el.m())?;
    for e in &el.edges {
        writeln!(w, "{}\t{}", e.u, e.v)?;
    }
    w.flush()
}

/// Write the compact binary format.
pub fn write_binary_edges<P: AsRef<Path>>(path: P, el: &EdgeList) -> io::Result<()> {
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    w.write_all(&BIN_MAGIC.to_le_bytes())?;
    w.write_all(&(el.n as u32).to_le_bytes())?;
    w.write_all(&(el.edges.len() as u64).to_le_bytes())?;
    for e in &el.edges {
        w.write_all(&e.u.to_le_bytes())?;
        w.write_all(&e.v.to_le_bytes())?;
    }
    w.flush()
}

/// Read the compact binary format.
pub fn read_binary_edges<P: AsRef<Path>>(path: P) -> io::Result<EdgeList> {
    let mut r = BufReader::with_capacity(1 << 20, File::open(path)?);
    let mut head = [0u8; 16];
    r.read_exact(&mut head)?;
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    if magic != BIN_MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let n = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
    let m = u64::from_le_bytes(head[8..16].try_into().unwrap()) as usize;
    let mut buf = vec![0u8; m * 8];
    r.read_exact(&mut buf)?;
    let mut edges = Vec::with_capacity(m);
    for c in buf.chunks_exact(8) {
        edges.push(Edge::new(
            u32::from_le_bytes(c[0..4].try_into().unwrap()),
            u32::from_le_bytes(c[4..8].try_into().unwrap()),
        ));
    }
    Ok(EdgeList::new(n, edges))
}

/// Write SNAP-style ground truth: one community per line, node ids
/// separated by tabs.
pub fn write_ground_truth<P: AsRef<Path>>(path: P, gt: &GroundTruth) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for c in &gt.communities {
        let line: Vec<String> = c.iter().map(|x| x.to_string()).collect();
        writeln!(w, "{}", line.join("\t"))?;
    }
    w.flush()
}

/// Read SNAP-style ground truth.
pub fn read_ground_truth<P: AsRef<Path>>(path: P) -> io::Result<GroundTruth> {
    let f = File::open(path)?;
    let mut communities = Vec::new();
    for line in BufReader::new(f).lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let c: Vec<u32> = line
            .split_whitespace()
            .filter_map(|t| t.parse().ok())
            .collect();
        if !c.is_empty() {
            communities.push(c);
        }
    }
    Ok(GroundTruth::new(communities))
}

/// Write a label assignment (`node<TAB>community` per line).
pub fn write_labels<P: AsRef<Path>>(path: P, labels: &[u32]) -> io::Result<()> {
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    for (i, &c) in labels.iter().enumerate() {
        writeln!(w, "{i}\t{c}")?;
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("streamcom_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn parse_line_variants() {
        assert_eq!(parse_edge_line("1\t2"), Some((1, 2)));
        assert_eq!(parse_edge_line("  3 4  "), Some((3, 4)));
        assert_eq!(parse_edge_line("# comment"), None);
        assert_eq!(parse_edge_line(""), None);
        assert_eq!(parse_edge_line("x y"), None);
    }

    #[test]
    fn text_roundtrip_with_remap() {
        let p = tmp("text.txt");
        std::fs::write(&p, "# header\n100\t200\n200\t300\n100\t300\n7\t7\n").unwrap();
        let (el, back) = read_text_edges(&p).unwrap();
        assert_eq!(el.n, 3);
        assert_eq!(el.m(), 3); // self-loop 7-7 dropped
        assert_eq!(back, vec![100, 200, 300]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_errors_on_malformed_target() {
        // a parseable source with a garbage target means the file is
        // corrupt — that must be a hard error, not a silent skip
        let p = tmp("badv.txt");
        std::fs::write(&p, "1\t2\n3\toops\n4\t5\n").unwrap();
        let err = read_text_edges(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("oops"), "{msg}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_errors_on_missing_target() {
        let p = tmp("nov.txt");
        std::fs::write(&p, "1\t2\n42\n").unwrap();
        let err = read_text_edges(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("no target"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_still_skips_fully_non_numeric_lines() {
        // comment/blank/textual lines keep the old lenient behaviour —
        // only a half-numeric line is evidence of corruption
        let p = tmp("lenient.txt");
        std::fs::write(&p, "% matrix-market-ish header\nfrom to\n\n1 2\n").unwrap();
        let (el, back) = read_text_edges(&p).unwrap();
        assert_eq!(el.m(), 1);
        assert_eq!(back, vec![1, 2]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let p = tmp("edges.bin");
        let el = EdgeList::new(5, vec![Edge::new(0, 1), Edge::new(3, 4), Edge::new(1, 2)]);
        write_binary_edges(&p, &el).unwrap();
        let got = read_binary_edges(&p).unwrap();
        assert_eq!(got.n, 5);
        assert_eq!(got.edges, el.edges);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmp("bad.bin");
        std::fs::write(&p, [0u8; 32]).unwrap();
        assert!(read_binary_edges(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ground_truth_roundtrip() {
        let p = tmp("gt.txt");
        let gt = GroundTruth::new(vec![vec![0, 1, 2], vec![3, 4]]);
        write_ground_truth(&p, &gt).unwrap();
        let got = read_ground_truth(&p).unwrap();
        assert_eq!(got.communities, gt.communities);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_writer_reader_roundtrip() {
        let p = tmp("rt.txt");
        let el = EdgeList::new(4, vec![Edge::new(0, 1), Edge::new(2, 3)]);
        write_text_edges(&p, &el).unwrap();
        let (got, back) = read_text_edges(&p).unwrap();
        assert_eq!(got.m(), 2);
        assert_eq!(back.len(), 4);
        std::fs::remove_file(&p).ok();
    }
}
