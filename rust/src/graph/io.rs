//! Edge-list IO: SNAP-style text, compact binary, ground-truth files.
//!
//! Text format is the SNAP convention the paper's datasets use: one
//! `u <whitespace> v` pair per line, `#`-prefixed comment lines.
//! Arbitrary (sparse) node ids are remapped to dense `u32` on ingest and
//! the mapping is returned so results can be translated back.
//!
//! Binary format (`.bin`): little-endian header `[magic u32, n u32,
//! m u64]` followed by `m` pairs of `u32`. This is what the Table-1
//! benches stream from — it removes the text-parsing confound when
//! comparing against the `cat` lower bound, matching the paper's setup
//! where the algorithm reads a raw edge list.

use std::collections::HashMap;
use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::edge::{Edge, EdgeList};
use super::ground_truth::GroundTruth;

const BIN_MAGIC: u32 = 0x5354_4d43; // "STMC"

/// Parse one text line as an edge; `None` for comments/blank lines.
/// Thin `&str` wrapper over the byte scanner (`parse_edge_bytes`) so
/// there is exactly one line-classification implementation in the repo.
#[inline]
pub fn parse_edge_line(line: &str) -> Option<(u64, u64)> {
    match parse_edge_bytes(line.as_bytes()) {
        LineParse::Edge(u, v) => Some((u, v)),
        _ => None,
    }
}

/// Classification of one text line by the shared byte-level edge
/// scanner (`parse_edge_bytes`). The split matters because the two
/// consumers disagree on what a bad target means: the strict batch
/// reader ([`read_text_edges`]) hard-errors (a half-numeric line is a
/// corrupt file), while the lenient streaming transport
/// (`stream::source::TextFileSource`) skips.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum LineParse<'a> {
    /// Comment (`#`/`%`), blank, or non-numeric-source line — always
    /// skipped, by both consumers.
    Skip,
    /// A well-formed `u <ws> v` pair (64-bit ids, no narrowing here —
    /// the consumer decides whether an id beyond `u32` is remappable).
    Edge(u64, u64),
    /// The source id parsed but the target is missing (`None`) or
    /// malformed/overflowing (the offending token bytes).
    BadTarget(u64, Option<&'a [u8]>),
}

/// ASCII whitespace (the set `u8::is_ascii_whitespace` covers: space,
/// tab, CR, LF, form feed — plus vertical tab, which
/// `str::split_whitespace` also split on).
#[inline]
fn is_line_space(b: u8) -> bool {
    b.is_ascii_whitespace() || b == 0x0B
}

/// Scan the whitespace-delimited token starting at `line[*i..]` as a
/// decimal `u64`. Returns `None` — with the cursor still advanced past
/// the token — when the token is empty, contains a non-digit, or
/// overflows `u64`; an optional leading `+` is accepted, exactly like
/// `str::parse::<u64>`. The overflow check is what keeps a 20-digit id
/// from silently wrapping into a *wrong but plausible* value.
#[inline]
fn scan_token(line: &[u8], i: &mut usize) -> Option<u64> {
    let n = line.len();
    if *i < n && line[*i] == b'+' && *i + 1 < n && line[*i + 1].is_ascii_digit() {
        *i += 1; // "+42" parses like "42"; a bare "+" stays non-numeric
    }
    let start = *i;
    let mut x: u64 = 0;
    let mut ok = true;
    while *i < n && !is_line_space(line[*i]) {
        let b = line[*i];
        if ok && b.is_ascii_digit() {
            match x.checked_mul(10).and_then(|x| x.checked_add((b - b'0') as u64)) {
                Some(next) => x = next,
                None => ok = false,
            }
        } else {
            ok = false;
        }
        *i += 1;
    }
    (ok && *i > start).then_some(x)
}

/// Byte-level scan of one text line as two decimal ids — the shared
/// core of [`read_text_edges`] and the streaming
/// `stream::source::TextFileSource` (no UTF-8 validation, no per-line
/// `String`, hand-rolled decimal scan; see EXPERIMENTS.md §Perf for
/// why this matters on the streaming path). Classification matches the
/// old `&str` reader token for token on ASCII input: a token is
/// numeric only when it is *entirely* ASCII digits (optionally
/// `+`-prefixed, like `str::parse::<u64>`) and fits in `u64` — so
/// `12ab` is a non-numeric source (skip), and `1 2ab` or a 20-digit
/// target is a [`BadTarget`](LineParse::BadTarget), never a silently
/// wrapped id. Known, deliberate divergence: non-ASCII Unicode
/// whitespace (e.g. U+00A0) no longer separates tokens — a byte
/// scanner treats those bytes as part of a (then non-numeric) token;
/// SNAP-convention files are tab/space separated, so this only affects
/// already-exotic inputs.
pub(crate) fn parse_edge_bytes(line: &[u8]) -> LineParse<'_> {
    let mut i = 0;
    let n = line.len();
    while i < n && is_line_space(line[i]) {
        i += 1;
    }
    if i >= n || line[i] == b'#' || line[i] == b'%' {
        return LineParse::Skip;
    }
    let Some(u) = scan_token(line, &mut i) else {
        return LineParse::Skip; // non-numeric source: lenient skip
    };
    while i < n && is_line_space(line[i]) {
        i += 1;
    }
    if i >= n {
        return LineParse::BadTarget(u, None);
    }
    let tok_start = i;
    match scan_token(line, &mut i) {
        Some(v) => LineParse::Edge(u, v),
        None => LineParse::BadTarget(u, Some(&line[tok_start..i])),
    }
}

/// Read a SNAP-style text edge list, remapping ids to dense u32.
/// Returns the edge list and the original ids indexed by dense id.
///
/// Comment (`#`/`%`), blank, and entirely non-numeric lines are
/// skipped, as before. A line whose *source* id parses but whose target
/// is missing or malformed is a hard [`io::Error`] — a half-numeric
/// line means a corrupt or truncated file, and silently dropping the
/// edge would skew every downstream metric.
///
/// The intern map and edge vector are pre-sized from the file length
/// (SNAP-style lines run ~12 bytes), so ingesting a large list does not
/// rehash/regrow its way up from empty.
///
/// §Perf: built on the same byte-level machinery as the streaming
/// `stream::source::TextFileSource` — lines are scanned directly in the
/// `BufReader`'s buffer via `fill_buf` with a carry for lines spanning
/// a refill boundary, and ids are decoded by the shared hand-rolled
/// decimal scanner (`parse_edge_bytes`). No per-line `String`, no UTF-8
/// validation, no `split_whitespace`: the per-line allocation the old
/// `lines()`-based reader paid is gone. Ids are interned as full `u64`,
/// so sparse ids beyond `u32` remain valid here (they remap densely) —
/// only genuinely non-numeric or `u64`-overflowing tokens are rejected.
pub fn read_text_edges<P: AsRef<Path>>(path: P) -> io::Result<(EdgeList, Vec<u64>)> {
    let f = File::open(path)?;
    // capped estimate: a wrong metadata size must not trigger a giant
    // pre-allocation
    let est_edges = (f.metadata().map(|m| m.len()).unwrap_or(0) / 12).min(1 << 27) as usize;
    let mut reader = BufReader::with_capacity(1 << 20, f);
    // nodes run well below edges on SNAP shapes (Amazon ~0.36 n/m,
    // Friendster ~0.04): an edges/8 guess avoids most rehashing without
    // a giant mostly-empty table on large files
    let mut map: HashMap<u64, u32> = HashMap::with_capacity((est_edges / 8).min(1 << 22));
    let mut back: Vec<u64> = Vec::new();
    let mut edges = Vec::with_capacity(est_edges);

    fn consume_line(
        line: &[u8],
        lineno: u64,
        map: &mut HashMap<u64, u32>,
        back: &mut Vec<u64>,
        edges: &mut Vec<Edge>,
    ) -> io::Result<()> {
        let mut intern = |id: u64, map: &mut HashMap<u64, u32>| -> u32 {
            *map.entry(id).or_insert_with(|| {
                back.push(id);
                (back.len() - 1) as u32
            })
        };
        match parse_edge_bytes(line) {
            LineParse::Skip => Ok(()),
            LineParse::Edge(u, v) => {
                if u != v {
                    let du = intern(u, map);
                    let dv = intern(v, map);
                    edges.push(Edge::new(du, dv));
                }
                Ok(())
            }
            // a parseable source with a missing or garbage target means
            // the file is corrupt — hard error, never a silent skip
            LineParse::BadTarget(u, None) => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("line {lineno}: edge source {u} has no target"),
            )),
            LineParse::BadTarget(u, Some(tok)) => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "line {lineno}: edge source {u} has malformed target {:?}",
                    String::from_utf8_lossy(tok)
                ),
            )),
        }
    }

    // fill_buf + carry: scan lines in place in the reader's buffer; a
    // line that spans a refill boundary is stitched in `carry`.
    // NOTE: `stream::source::TextFileSource::next_batch` carries a
    // sibling of this framing loop (incremental, capacity-bounded,
    // infallible — different enough that unifying them would complicate
    // both); a fix to a carry/boundary edge case here likely applies
    // there too.
    let mut carry: Vec<u8> = Vec::with_capacity(64);
    let mut lineno: u64 = 0;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            if !carry.is_empty() {
                lineno += 1;
                consume_line(&carry, lineno, &mut map, &mut back, &mut edges)?;
                carry.clear();
            }
            break;
        }
        let mut start = 0usize;
        while let Some(pos) = chunk[start..].iter().position(|&b| b == b'\n') {
            lineno += 1;
            let line = &chunk[start..start + pos];
            if carry.is_empty() {
                consume_line(line, lineno, &mut map, &mut back, &mut edges)?;
            } else {
                carry.extend_from_slice(line);
                consume_line(&carry, lineno, &mut map, &mut back, &mut edges)?;
                carry.clear();
            }
            start += pos + 1;
        }
        if start < chunk.len() {
            carry.extend_from_slice(&chunk[start..]);
        }
        let consumed = chunk.len();
        reader.consume(consumed);
    }
    Ok((EdgeList::new(back.len(), edges), back))
}

/// Write a text edge list (dense ids).
pub fn write_text_edges<P: AsRef<Path>>(path: P, el: &EdgeList) -> io::Result<()> {
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    writeln!(w, "# streamcom edge list: n={} m={}", el.n, el.m())?;
    for e in &el.edges {
        writeln!(w, "{}\t{}", e.u, e.v)?;
    }
    w.flush()
}

/// Write the compact binary format.
pub fn write_binary_edges<P: AsRef<Path>>(path: P, el: &EdgeList) -> io::Result<()> {
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    w.write_all(&BIN_MAGIC.to_le_bytes())?;
    w.write_all(&(el.n as u32).to_le_bytes())?;
    w.write_all(&(el.edges.len() as u64).to_le_bytes())?;
    for e in &el.edges {
        w.write_all(&e.u.to_le_bytes())?;
        w.write_all(&e.v.to_le_bytes())?;
    }
    w.flush()
}

/// Read the compact binary format.
pub fn read_binary_edges<P: AsRef<Path>>(path: P) -> io::Result<EdgeList> {
    let mut r = BufReader::with_capacity(1 << 20, File::open(path)?);
    let mut head = [0u8; 16];
    r.read_exact(&mut head)?;
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    if magic != BIN_MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let n = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
    let m = u64::from_le_bytes(head[8..16].try_into().unwrap()) as usize;
    let mut buf = vec![0u8; m * 8];
    r.read_exact(&mut buf)?;
    let mut edges = Vec::with_capacity(m);
    for c in buf.chunks_exact(8) {
        edges.push(Edge::new(
            u32::from_le_bytes(c[0..4].try_into().unwrap()),
            u32::from_le_bytes(c[4..8].try_into().unwrap()),
        ));
    }
    Ok(EdgeList::new(n, edges))
}

/// Write SNAP-style ground truth: one community per line, node ids
/// separated by tabs.
pub fn write_ground_truth<P: AsRef<Path>>(path: P, gt: &GroundTruth) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for c in &gt.communities {
        let line: Vec<String> = c.iter().map(|x| x.to_string()).collect();
        writeln!(w, "{}", line.join("\t"))?;
    }
    w.flush()
}

/// Read SNAP-style ground truth.
pub fn read_ground_truth<P: AsRef<Path>>(path: P) -> io::Result<GroundTruth> {
    let f = File::open(path)?;
    let mut communities = Vec::new();
    for line in BufReader::new(f).lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let c: Vec<u32> = line
            .split_whitespace()
            .filter_map(|t| t.parse().ok())
            .collect();
        if !c.is_empty() {
            communities.push(c);
        }
    }
    Ok(GroundTruth::new(communities))
}

/// Write a label assignment (`node<TAB>community` per line).
pub fn write_labels<P: AsRef<Path>>(path: P, labels: &[u32]) -> io::Result<()> {
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    for (i, &c) in labels.iter().enumerate() {
        writeln!(w, "{i}\t{c}")?;
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("streamcom_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn parse_line_variants() {
        assert_eq!(parse_edge_line("1\t2"), Some((1, 2)));
        assert_eq!(parse_edge_line("  3 4  "), Some((3, 4)));
        assert_eq!(parse_edge_line("# comment"), None);
        assert_eq!(parse_edge_line(""), None);
        assert_eq!(parse_edge_line("x y"), None);
    }

    #[test]
    fn byte_scanner_classifies_like_the_str_reader() {
        // the scanner is the shared core of both text readers — its
        // classification must match the old token-wise &str semantics
        assert_eq!(parse_edge_bytes(b"1\t2"), LineParse::Edge(1, 2));
        assert_eq!(parse_edge_bytes(b"  3 4  \r"), LineParse::Edge(3, 4));
        assert_eq!(parse_edge_bytes(b"1 2 3"), LineParse::Edge(1, 2)); // extra tokens ignored
        assert_eq!(parse_edge_bytes(b"# comment"), LineParse::Skip);
        assert_eq!(parse_edge_bytes(b"% header"), LineParse::Skip);
        assert_eq!(parse_edge_bytes(b""), LineParse::Skip);
        assert_eq!(parse_edge_bytes(b"   "), LineParse::Skip);
        // str::parse::<u64> accepts a leading '+'; the scanner must too
        assert_eq!(parse_edge_bytes(b"+1 +2"), LineParse::Edge(1, 2));
        assert_eq!(parse_edge_bytes(b"1 +"), LineParse::BadTarget(1, Some(b"+".as_slice())));
        // vertical tab / form feed separate tokens like split_whitespace
        assert_eq!(parse_edge_bytes(b"1\x0b2"), LineParse::Edge(1, 2));
        assert_eq!(parse_edge_bytes(b"1\x0c2"), LineParse::Edge(1, 2));
        // a partially-numeric token is NOT a number: "12ab" is a
        // non-numeric source (skip), "2ab" a malformed target (error)
        assert_eq!(parse_edge_bytes(b"12ab 34"), LineParse::Skip);
        assert_eq!(
            parse_edge_bytes(b"1 2ab"),
            LineParse::BadTarget(1, Some(b"2ab".as_slice()))
        );
        assert_eq!(parse_edge_bytes(b"42"), LineParse::BadTarget(42, None));
    }

    #[test]
    fn byte_scanner_never_wraps_u64_overflow() {
        // 2^64 + ε as text: the old wrapping scan silently produced a
        // wrong-but-valid id; overflow must classify as non-numeric
        let big = "18446744073709551616"; // u64::MAX + 1
        let line = format!("{big} 5");
        assert_eq!(
            parse_edge_bytes(line.as_bytes()),
            LineParse::Skip,
            "overflowing source"
        );
        let line = format!("5 {big}");
        assert!(
            matches!(parse_edge_bytes(line.as_bytes()), LineParse::BadTarget(5, Some(_))),
            "overflowing target must be a hard error for the strict reader"
        );
        // u64::MAX itself still parses
        assert_eq!(
            parse_edge_bytes(b"18446744073709551615 1"),
            LineParse::Edge(u64::MAX, 1)
        );
    }

    #[test]
    fn text_reader_interns_40bit_ids_without_truncation() {
        // regression: ids beyond u32 must remap densely, never narrow
        let p = tmp("wide.txt");
        let a = 1u64 << 40;
        let b = (1u64 << 40) + 1;
        std::fs::write(&p, format!("{a}\t{b}\n{b}\t7\n")).unwrap();
        let (el, back) = read_text_edges(&p).unwrap();
        assert_eq!(el.n, 3);
        assert_eq!(el.m(), 2);
        assert_eq!(back, vec![a, b, 7]);
        assert_eq!(el.edges, vec![Edge::new(0, 1), Edge::new(1, 2)]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_handles_lines_spanning_buffer_refills() {
        // a file larger than the BufReader's internal buffer exercises
        // the fill_buf + carry path end to end; build one long comment
        // line (> 1 MiB) followed by real edges and a no-newline tail
        let p = tmp("carry.txt");
        let mut data = String::with_capacity((1 << 20) + 64);
        data.push('#');
        for _ in 0..(1 << 20) {
            data.push('x');
        }
        data.push('\n');
        data.push_str("10\t20\n30\t40"); // final line has no newline
        std::fs::write(&p, data).unwrap();
        let (el, back) = read_text_edges(&p).unwrap();
        assert_eq!(el.m(), 2);
        assert_eq!(back, vec![10, 20, 30, 40]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_roundtrip_with_remap() {
        let p = tmp("text.txt");
        std::fs::write(&p, "# header\n100\t200\n200\t300\n100\t300\n7\t7\n").unwrap();
        let (el, back) = read_text_edges(&p).unwrap();
        assert_eq!(el.n, 3);
        assert_eq!(el.m(), 3); // self-loop 7-7 dropped
        assert_eq!(back, vec![100, 200, 300]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_errors_on_malformed_target() {
        // a parseable source with a garbage target means the file is
        // corrupt — that must be a hard error, not a silent skip
        let p = tmp("badv.txt");
        std::fs::write(&p, "1\t2\n3\toops\n4\t5\n").unwrap();
        let err = read_text_edges(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("oops"), "{msg}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_errors_on_missing_target() {
        let p = tmp("nov.txt");
        std::fs::write(&p, "1\t2\n42\n").unwrap();
        let err = read_text_edges(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("no target"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_reader_still_skips_fully_non_numeric_lines() {
        // comment/blank/textual lines keep the old lenient behaviour —
        // only a half-numeric line is evidence of corruption
        let p = tmp("lenient.txt");
        std::fs::write(&p, "% matrix-market-ish header\nfrom to\n\n1 2\n").unwrap();
        let (el, back) = read_text_edges(&p).unwrap();
        assert_eq!(el.m(), 1);
        assert_eq!(back, vec![1, 2]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let p = tmp("edges.bin");
        let el = EdgeList::new(5, vec![Edge::new(0, 1), Edge::new(3, 4), Edge::new(1, 2)]);
        write_binary_edges(&p, &el).unwrap();
        let got = read_binary_edges(&p).unwrap();
        assert_eq!(got.n, 5);
        assert_eq!(got.edges, el.edges);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmp("bad.bin");
        std::fs::write(&p, [0u8; 32]).unwrap();
        assert!(read_binary_edges(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ground_truth_roundtrip() {
        let p = tmp("gt.txt");
        let gt = GroundTruth::new(vec![vec![0, 1, 2], vec![3, 4]]);
        write_ground_truth(&p, &gt).unwrap();
        let got = read_ground_truth(&p).unwrap();
        assert_eq!(got.communities, gt.communities);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_writer_reader_roundtrip() {
        let p = tmp("rt.txt");
        let el = EdgeList::new(4, vec![Edge::new(0, 1), Edge::new(2, 3)]);
        write_text_edges(&p, &el).unwrap();
        let (got, back) = read_text_edges(&p).unwrap();
        assert_eq!(got.m(), 2);
        assert_eq!(back.len(), 4);
        std::fs::remove_file(&p).ok();
    }
}
