//! Edge and edge-list types.
//!
//! Nodes are dense `u32` ids (the generators and the IO remapper
//! guarantee density); an [`Edge`] is an unordered pair. The streaming
//! layers move `Edge` values by the million, so it is `Copy`, 8 bytes,
//! and `#[repr(C)]` for cheap binary IO.

/// One undirected edge. Self-loops are forbidden at construction sites
/// that matter (generators, IO ingest); streaming code tolerates and
/// skips them defensively.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(C)]
pub struct Edge {
    /// First endpoint.
    pub u: u32,
    /// Second endpoint.
    pub v: u32,
}

impl Edge {
    #[inline]
    /// Edge between `u` and `v` (order preserved as given).
    pub fn new(u: u32, v: u32) -> Self {
        Self { u, v }
    }

    /// Canonical orientation (min, max) — used for dedup and tests.
    #[inline]
    pub fn canonical(self) -> Self {
        if self.u <= self.v {
            self
        } else {
            Edge { u: self.v, v: self.u }
        }
    }

    #[inline]
    /// True when both endpoints coincide.
    pub fn is_self_loop(self) -> bool {
        self.u == self.v
    }
}

/// An in-memory edge multiset plus its node-count header.
#[derive(Debug, Clone, Default)]
pub struct EdgeList {
    /// Node count header.
    pub n: usize,
    /// The edge multiset.
    pub edges: Vec<Edge>,
}

impl EdgeList {
    /// Edge list with an explicit node-count header.
    pub fn new(n: usize, edges: Vec<Edge>) -> Self {
        Self { n, edges }
    }

    /// Number of edges.
    pub fn m(&self) -> usize {
        self.edges.len()
    }

    /// Recompute `n` as 1 + max node id (0 for empty).
    pub fn infer_n(edges: &[Edge]) -> usize {
        edges
            .iter()
            .map(|e| e.u.max(e.v) as usize + 1)
            .max()
            .unwrap_or(0)
    }

    /// Node degrees (each endpoint of each edge counts once).
    pub fn degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.n];
        for e in &self.edges {
            d[e.u as usize] += 1;
            d[e.v as usize] += 1;
        }
        d
    }

    /// Total weight w = 2m.
    pub fn total_weight(&self) -> u64 {
        2 * self.edges.len() as u64
    }

    /// Remove self-loops and canonicalise+dedup parallel edges
    /// (the generators already avoid both; IO ingest uses this).
    pub fn simplify(&mut self) {
        self.edges.retain(|e| !e.is_self_loop());
        for e in &mut self.edges {
            *e = e.canonical();
        }
        self.edges.sort_unstable_by_key(|e| (e.u, e.v));
        self.edges.dedup();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_orders_endpoints() {
        assert_eq!(Edge::new(5, 2).canonical(), Edge::new(2, 5));
        assert_eq!(Edge::new(2, 5).canonical(), Edge::new(2, 5));
    }

    #[test]
    fn degrees_count_both_endpoints() {
        let el = EdgeList::new(4, vec![Edge::new(0, 1), Edge::new(1, 2), Edge::new(1, 3)]);
        assert_eq!(el.degrees(), vec![1, 3, 1, 1]);
        assert_eq!(el.total_weight(), 6);
    }

    #[test]
    fn simplify_removes_loops_and_dups() {
        let mut el = EdgeList::new(3, vec![
            Edge::new(0, 1),
            Edge::new(1, 0),
            Edge::new(2, 2),
            Edge::new(1, 2),
        ]);
        el.simplify();
        assert_eq!(el.edges, vec![Edge::new(0, 1), Edge::new(1, 2)]);
    }

    #[test]
    fn infer_n_from_max_id() {
        assert_eq!(EdgeList::infer_n(&[Edge::new(0, 7), Edge::new(3, 2)]), 8);
        assert_eq!(EdgeList::infer_n(&[]), 0);
    }
}
