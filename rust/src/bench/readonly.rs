//! The `cat` lower bound (§4.4): read the edge stream and do nothing.
//!
//! The paper compares its algorithm against `cat` of the edge file to
//! show the streaming pass costs only ~2× the raw read. These helpers
//! reproduce that comparison for both transports the Table 1 harness
//! uses: in-memory edge slices (pure algorithmic lower bound) and files
//! (IO-inclusive lower bound).

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use crate::graph::edge::Edge;

/// In-memory "cat": touch every edge, accumulate a checksum so the
/// optimiser cannot delete the loop.
pub fn readonly_pass(edges: &[Edge]) -> u64 {
    let mut acc = 0u64;
    for e in edges {
        acc = acc.wrapping_add(e.u as u64).wrapping_add((e.v as u64) << 1);
    }
    std::hint::black_box(acc)
}

/// File "cat": stream the bytes, count lines (text) — the closest
/// analogue of `cat file > /dev/null` plus line splitting.
pub fn readonly_file_text<P: AsRef<Path>>(path: P) -> std::io::Result<(u64, u64)> {
    let f = std::fs::File::open(path)?;
    let mut reader = BufReader::with_capacity(1 << 20, f);
    let mut lines = 0u64;
    let mut bytes = 0u64;
    let mut buf = Vec::with_capacity(128);
    loop {
        buf.clear();
        let k = reader.read_until(b'\n', &mut buf)?;
        if k == 0 {
            break;
        }
        bytes += k as u64;
        lines += 1;
    }
    Ok((lines, bytes))
}

/// Binary "cat": stream the file in 1 MiB blocks.
pub fn readonly_file_binary<P: AsRef<Path>>(path: P) -> std::io::Result<u64> {
    let f = std::fs::File::open(path)?;
    let mut reader = BufReader::with_capacity(1 << 20, f);
    let mut total = 0u64;
    let mut buf = vec![0u8; 1 << 20];
    loop {
        let k = reader.read(&mut buf)?;
        if k == 0 {
            break;
        }
        total += k as u64;
        std::hint::black_box(&buf[..k.min(64)]);
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::EdgeList;
    use crate::graph::io;

    #[test]
    fn readonly_pass_touches_all() {
        let edges: Vec<Edge> = (0..1000u32).map(|i| Edge::new(i, i + 1)).collect();
        let a = readonly_pass(&edges);
        let b = readonly_pass(&edges);
        assert_eq!(a, b);
        assert_ne!(a, readonly_pass(&edges[..999]));
    }

    #[test]
    fn file_variants_count_correctly() {
        let dir = std::env::temp_dir();
        let pt = dir.join(format!("sc_ro_{}.txt", std::process::id()));
        let pb = dir.join(format!("sc_ro_{}.bin", std::process::id()));
        let el = EdgeList::new(101, (0..100u32).map(|i| Edge::new(i, i + 1)).collect());
        io::write_text_edges(&pt, &el).unwrap();
        io::write_binary_edges(&pb, &el).unwrap();
        let (lines, bytes) = readonly_file_text(&pt).unwrap();
        assert_eq!(lines, 101); // 100 edges + header comment
        assert!(bytes > 0);
        let b = readonly_file_binary(&pb).unwrap();
        assert_eq!(b, 16 + 100 * 8);
        std::fs::remove_file(&pt).ok();
        std::fs::remove_file(&pb).ok();
    }
}
