//! Benchmark framework + the harnesses that regenerate the paper's
//! evaluation (criterion is unavailable offline; [`framework`] provides
//! the warmup/iterate/robust-stats loop the benches need).
//!
//! Experiment map (DESIGN.md §4):
//!
//! | exp | harness            | bench target                  |
//! |-----|--------------------|-------------------------------|
//! | T1  | [`table1`]         | `benches/table1_runtime.rs`   |
//! | T1b | [`readonly`]       | part of T1                    |
//! | M1  | [`memory`]         | `benches/memory_footprint.rs` |
//! | T2  | [`table2`]         | `benches/table2_quality.rs`   |
//! | S1  | sweep harness      | `benches/vmax_sweep.rs`       |
//! | A1  | ablation harness   | `benches/ablations.rs`        |
//! | P1  | throughput harness | `benches/str_throughput.rs`   |

pub mod framework;
pub mod memory;
pub mod readonly;
pub mod report;
pub mod service;
pub mod table1;
pub mod table2;
pub mod workloads;
