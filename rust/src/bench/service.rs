//! Service-path benchmark: ingest throughput, drain cost, and the
//! sharded-leader byte accounting — the figures that track whether the
//! service keeps its two scaling claims as the code evolves:
//!
//! * drains replay only the new cross suffix (`replay/drain` stays
//!   near the drain cadence, not the stream length), and
//! * drains ship only epoch deltas (`delta_last` stays flat while the
//!   committed base grows).
//!
//! `bench service` prints the table; `--json` additionally writes
//! `BENCH_service.json` so the perf trajectory is machine-readable and
//! can be recorded run over run.

use crate::graph::generators::sbm::{self, SbmConfig};
use crate::service::{ClusterService, CommitHorizon, LeaderStats, ServiceConfig};

use super::memory::fmt_bytes;
use super::report::Table;

/// Workload + service shape for one `bench service` run.
#[derive(Debug, Clone)]
pub struct ServiceBenchConfig {
    /// Planted communities in the SBM workload.
    pub communities: usize,
    /// Nodes per community.
    pub community_size: usize,
    /// Shard workers.
    pub shards: usize,
    /// Leader partitions (0 = one per shard).
    pub leaders: usize,
    /// The paper's volume threshold.
    pub v_max: u64,
    /// Edges between automatic drains.
    pub drain_every: u64,
    /// Commit horizons to sweep (0 = unbounded).
    pub horizons: Vec<u64>,
    /// Workload seed.
    pub seed: u64,
}

impl ServiceBenchConfig {
    /// Default shape scaled by the CLI's `--scale` knob (`1.0` ≈ a
    /// quarter-million-edge stream; the default bench scale of 0.1
    /// keeps CI-friendly runtimes).
    pub fn scaled(scale: f64) -> Self {
        Self {
            communities: ((240.0 * scale).round() as usize).max(6),
            community_size: 60,
            shards: 4,
            leaders: 0,
            v_max: 128,
            drain_every: 4_096,
            horizons: vec![0, 4_096],
            seed: 71,
        }
    }
}

/// One measured configuration (a row of the table / JSON).
#[derive(Debug, Clone)]
pub struct ServiceBenchRow {
    /// Commit horizon (0 = unbounded).
    pub horizon: u64,
    /// Edges ingested.
    pub edges: u64,
    /// Cross-shard edges deferred to the log.
    pub cross_total: u64,
    /// Wall-clock ingest + terminal replay time.
    pub elapsed_secs: f64,
    /// Ingest throughput.
    pub edges_per_sec: f64,
    /// Mid-stream drains performed.
    pub drains: u64,
    /// Mean cross edges replayed per drain (the drain cost).
    pub replay_per_drain: f64,
    /// Delta payload of the last mid-stream drain (bytes).
    pub delta_last_bytes: u64,
    /// Σ delta payload across all drains (bytes).
    pub delta_total_bytes: u64,
    /// Cross edges resident at the final drain point.
    pub cross_retained: u64,
    /// Cross edges committed (final, storage freed).
    pub cross_committed: u64,
    /// Bytes freed by commits.
    pub cross_freed_bytes: u64,
    /// Per-leader-partition retained/committed/freed bytes.
    pub per_leader: Vec<LeaderStats>,
}

/// Stream one SBM workload through the service per configured horizon
/// and collect the table + raw rows.
pub fn run(cfg: &ServiceBenchConfig) -> (Table, Vec<ServiceBenchRow>) {
    let g = sbm::generate(&SbmConfig::equal(
        cfg.communities,
        cfg.community_size,
        0.3,
        0.002,
        cfg.seed,
    ));
    let mut table = Table::new(
        &format!(
            "service bench: {} (n={} m={}, {} shards, drain_every={})",
            g.name,
            g.n(),
            g.m(),
            cfg.shards,
            cfg.drain_every
        ),
        &[
            "horizon",
            "Medges/s",
            "drains",
            "replay/drain",
            "delta_last",
            "x-retained",
            "x-committed",
            "x-freed",
            "Σleader base",
        ],
    );
    let mut rows = Vec::new();
    for &h in &cfg.horizons {
        let mut config = ServiceConfig::new(cfg.shards, cfg.v_max);
        config.leaders = cfg.leaders;
        config.drain_every = cfg.drain_every;
        config.horizon = CommitHorizon::Edges(h); // Edges(0) ⇒ Unbounded
        let mut svc = ClusterService::start(config);
        let handle = svc.handle();
        svc.push_chunk(&g.edges.edges);
        svc.quiesce();
        let s = handle.stats();
        let res = svc.finish();
        let elapsed = res.elapsed.as_secs_f64().max(1e-9);
        let row = ServiceBenchRow {
            horizon: h,
            edges: res.edges_ingested,
            cross_total: s.cross_total,
            elapsed_secs: elapsed,
            edges_per_sec: res.edges_ingested as f64 / elapsed,
            drains: s.drains,
            replay_per_drain: s.cross_replayed_total as f64 / (s.drains.max(1)) as f64,
            delta_last_bytes: s.delta_last_bytes,
            delta_total_bytes: s.delta_total_bytes,
            cross_retained: s.cross_retained,
            cross_committed: s.cross_committed,
            cross_freed_bytes: s.cross_freed_bytes,
            per_leader: s.per_leader.clone(),
        };
        table.push_row(vec![
            if h == 0 { "unbounded".into() } else { h.to_string() },
            format!("{:.2}", row.edges_per_sec / 1e6),
            row.drains.to_string(),
            format!("{:.1}", row.replay_per_drain),
            fmt_bytes(row.delta_last_bytes),
            row.cross_retained.to_string(),
            row.cross_committed.to_string(),
            fmt_bytes(row.cross_freed_bytes),
            fmt_bytes(s.committed_bytes_total()),
        ]);
        rows.push(row);
    }
    (table, rows)
}

/// Render the rows as the `BENCH_service.json` document (hand-rolled —
/// the offline build has no serde; every value is numeric so no string
/// escaping is required beyond the fixed keys).
pub fn to_json(cfg: &ServiceBenchConfig, rows: &[ServiceBenchRow]) -> String {
    let mut out = String::from("{\n  \"bench\": \"service\",\n");
    out.push_str(&format!(
        "  \"workload\": {{\"communities\": {}, \"community_size\": {}, \"seed\": {}}},\n",
        cfg.communities, cfg.community_size, cfg.seed
    ));
    out.push_str(&format!(
        "  \"shards\": {}, \"leaders\": {}, \"v_max\": {}, \"drain_every\": {},\n",
        cfg.shards, cfg.leaders, cfg.v_max, cfg.drain_every
    ));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let per_leader: Vec<String> = r
            .per_leader
            .iter()
            .map(|l| {
                format!(
                    "{{\"retained_bytes\": {}, \"committed_bytes\": {}, \"freed_bytes\": {}}}",
                    l.retained_bytes, l.committed_bytes, l.freed_bytes
                )
            })
            .collect();
        out.push_str(&format!(
            "    {{\"horizon\": {}, \"edges\": {}, \"cross_total\": {}, \
             \"elapsed_secs\": {:.6}, \"edges_per_sec\": {:.1}, \"drains\": {}, \
             \"replay_per_drain\": {:.2}, \"delta_last_bytes\": {}, \
             \"delta_total_bytes\": {}, \"cross_retained\": {}, \
             \"cross_committed\": {}, \"cross_freed_bytes\": {}, \
             \"per_leader\": [{}]}}{}\n",
            r.horizon,
            r.edges,
            r.cross_total,
            r.elapsed_secs,
            r.edges_per_sec,
            r.drains,
            r.replay_per_drain,
            r.delta_last_bytes,
            r.delta_total_bytes,
            r.cross_retained,
            r.cross_committed,
            r.cross_freed_bytes,
            per_leader.join(", "),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ServiceBenchConfig {
        ServiceBenchConfig {
            communities: 6,
            community_size: 20,
            shards: 2,
            leaders: 0,
            v_max: 64,
            drain_every: 128,
            horizons: vec![0, 64],
            seed: 7,
        }
    }

    #[test]
    fn rows_cover_each_horizon_and_json_is_shaped() {
        let cfg = tiny();
        let (table, rows) = run(&cfg);
        assert_eq!(rows.len(), 2);
        assert_eq!(table.rows.len(), 2);
        assert!(rows.iter().all(|r| r.edges > 0 && r.edges_per_sec > 0.0));
        // the bounded run must actually commit and free something
        let bounded = &rows[1];
        assert!(bounded.cross_committed > 0, "{bounded:?}");
        assert!(bounded.cross_freed_bytes > 0);
        assert_eq!(bounded.per_leader.len(), cfg.shards);

        let json = to_json(&cfg, &rows);
        assert!(json.starts_with('{') && json.trim_end().ends_with('}'));
        assert!(json.contains("\"bench\": \"service\""));
        assert!(json.contains("\"delta_last_bytes\""));
        assert!(json.contains("\"per_leader\""));
        // two rows, comma-separated exactly once at the top level list
        assert_eq!(json.matches("\"horizon\"").count(), 2);
    }
}
