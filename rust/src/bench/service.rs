//! Service-path benchmark: ingest throughput, drain cost, the
//! sharded-leader byte accounting, and the ingest-path microbench —
//! the figures that track whether the service keeps its scaling claims
//! as the code evolves:
//!
//! * drains replay only the new cross suffix (`replay/drain` stays
//!   near the drain cadence, not the stream length),
//! * drains ship only epoch deltas (`delta_last` stays flat while the
//!   committed base grows), and
//! * the batch ingest spine stays allocation- and atomic-amortized:
//!   the microbench sweeps shards × batch size on the memory-source
//!   workload and records edges/sec alongside the **measured** pool
//!   hit/miss and chunk-dispatch counters — a regression that
//!   reintroduces a per-chunk allocation shows up as a pool-miss jump
//!   even when throughput noise hides it. (`router_rmws` is *derived*
//!   from those counts by the spine's design — one `ingested` add per
//!   batch, one `dispatched` add per chunk — so it documents the
//!   expected atomic budget per cell; a reintroduced per-*edge* RMW
//!   would surface in edges/sec, not in this column.)
//!
//! `bench service` prints the tables; `--json` additionally writes
//! `BENCH_service.json` so the perf trajectory is machine-readable and
//! can be recorded run over run.

use crate::graph::generators::sbm::{self, SbmConfig};
use crate::graph::io;
use crate::service::{ClusterService, CommitHorizon, CrashPoint, LeaderStats, ServiceConfig};
use crate::stream::pscan::{DirectScan, ParallelScanner};

use super::memory::fmt_bytes;
use super::report::Table;

/// Shard counts swept by the ingest-path microbench.
pub const INGEST_SHARDS_SWEEP: &[usize] = &[1, 4, 8];
/// Ingest batch sizes swept by the microbench (edges per `push_chunk`).
pub const INGEST_BATCH_SWEEP: &[usize] = &[1, 256, 4096];
/// Reader counts swept by the parallel-scan microbench.
pub const INGEST_READERS_SWEEP: &[usize] = &[1, 2, 4];
/// Reader counts swept by the mmap-vs-buffered scan microbench.
pub const MMAP_READERS_SWEEP: &[usize] = &[1, 2, 4];
/// Reader counts swept by the routing (funnel vs direct) microbench.
pub const ROUTING_READERS_SWEEP: &[usize] = &[1, 2, 4];
/// Edges per scanner chunk / ingest batch in the readers sweep.
const SCAN_BATCH: usize = 4_096;
/// Segment size for the bench's binary file — small enough that the
/// bench-scale workload still splits across every swept reader count.
const SCAN_SEG_RECORDS: u64 = 4_096;

/// Workload + service shape for one `bench service` run.
#[derive(Debug, Clone)]
pub struct ServiceBenchConfig {
    /// Planted communities in the SBM workload.
    pub communities: usize,
    /// Nodes per community.
    pub community_size: usize,
    /// Shard workers.
    pub shards: usize,
    /// Leader partitions (0 = one per shard).
    pub leaders: usize,
    /// The paper's volume threshold.
    pub v_max: u64,
    /// Edges between automatic drains.
    pub drain_every: u64,
    /// Commit horizons to sweep (0 = unbounded).
    pub horizons: Vec<u64>,
    /// Workload seed.
    pub seed: u64,
}

impl ServiceBenchConfig {
    /// Default shape scaled by the CLI's `--scale` knob (`1.0` ≈ a
    /// quarter-million-edge stream; the default bench scale of 0.1
    /// keeps CI-friendly runtimes).
    pub fn scaled(scale: f64) -> Self {
        Self {
            communities: ((240.0 * scale).round() as usize).max(6),
            community_size: 60,
            shards: 4,
            leaders: 0,
            v_max: 128,
            drain_every: 4_096,
            horizons: vec![0, 4_096],
            seed: 71,
        }
    }
}

/// One measured configuration (a row of the table / JSON).
#[derive(Debug, Clone)]
pub struct ServiceBenchRow {
    /// Commit horizon (0 = unbounded).
    pub horizon: u64,
    /// Edges ingested.
    pub edges: u64,
    /// Cross-shard edges deferred to the log.
    pub cross_total: u64,
    /// Wall-clock ingest + terminal replay time.
    pub elapsed_secs: f64,
    /// Ingest throughput.
    pub edges_per_sec: f64,
    /// Mid-stream drains performed.
    pub drains: u64,
    /// Mean cross edges replayed per drain (the drain cost).
    pub replay_per_drain: f64,
    /// Delta payload of the last mid-stream drain (bytes).
    pub delta_last_bytes: u64,
    /// Σ delta payload across all drains (bytes).
    pub delta_total_bytes: u64,
    /// Cross edges resident at the final drain point.
    pub cross_retained: u64,
    /// Cross edges committed (final, storage freed).
    pub cross_committed: u64,
    /// Bytes freed by commits.
    pub cross_freed_bytes: u64,
    /// Per-leader-partition retained/committed/freed bytes.
    pub per_leader: Vec<LeaderStats>,
}

/// One ingest-path microbench measurement: a (shards × batch) cell of
/// the sweep over the memory-source workload, pure ingest (automatic
/// drains disabled), with the counters that pin the batch spine's
/// amortization claims.
#[derive(Debug, Clone)]
pub struct IngestBenchRow {
    /// Shard workers.
    pub shards: usize,
    /// Edges per `push_chunk` batch.
    pub batch: usize,
    /// Edges ingested.
    pub edges: u64,
    /// Wall-clock ingest + terminal replay time.
    pub elapsed_secs: f64,
    /// Ingest throughput.
    pub edges_per_sec: f64,
    /// `push_chunk` batches issued.
    pub batches: u64,
    /// Chunks handed to shard mailboxes.
    pub chunks_dispatched: u64,
    /// Chunk-pool checkouts served by recycled buffers.
    pub pool_hits: u64,
    /// Chunk-pool checkouts that allocated (cold warm-up only —
    /// bounded by the buffers that can be in flight at once).
    pub pool_misses: u64,
    /// Buffer bytes returned to the pool.
    pub pool_recycled_bytes: u64,
    /// Router-side atomic RMW budget, **derived** from the measured
    /// batch/chunk counts by the spine's design: one `ingested` add
    /// per batch plus one `dispatched` add per chunk send (the
    /// per-edge spine paid one RMW per *edge* here). Not an
    /// instrumented count — counting the RMWs would itself add one.
    pub router_rmws: u64,
}

impl IngestBenchRow {
    /// Router-side atomic RMWs per thousand ingested edges.
    pub fn rmws_per_kedge(&self) -> f64 {
        self.router_rmws as f64 * 1e3 / (self.edges.max(1)) as f64
    }
}

/// The microbench: sweep [`INGEST_SHARDS_SWEEP`] × [`INGEST_BATCH_SWEEP`]
/// over the same SBM workload as [`run`], pure ingest (drains off), and
/// collect the table + raw rows.
pub fn run_ingest(cfg: &ServiceBenchConfig) -> (Table, Vec<IngestBenchRow>) {
    let g = sbm::generate(&SbmConfig::equal(
        cfg.communities,
        cfg.community_size,
        0.3,
        0.002,
        cfg.seed,
    ));
    let mut table = Table::new(
        &format!(
            "ingest microbench: {} (n={} m={}, memory source, drains off)",
            g.name,
            g.n(),
            g.m()
        ),
        &[
            "shards",
            "batch",
            "Medges/s",
            "batches",
            "chunks",
            "pool hit",
            "pool miss",
            "recycled",
            "rmw/kedge",
        ],
    );
    let mut rows = Vec::new();
    for &shards in INGEST_SHARDS_SWEEP {
        for &batch in INGEST_BATCH_SWEEP {
            let mut config = ServiceConfig::new(shards, cfg.v_max);
            config.drain_every = 0; // pure ingest: no automatic drains
            let mut svc = ClusterService::start(config);
            let handle = svc.handle();
            let mut batches = 0u64;
            for chunk in g.edges.edges.chunks(batch) {
                svc.push_chunk(chunk);
                batches += 1;
            }
            let res = svc.finish();
            let s = handle.stats();
            let elapsed = res.elapsed.as_secs_f64().max(1e-9);
            let row = IngestBenchRow {
                shards,
                batch,
                edges: res.edges_ingested,
                elapsed_secs: elapsed,
                edges_per_sec: res.edges_ingested as f64 / elapsed,
                batches,
                chunks_dispatched: s.chunks_dispatched,
                pool_hits: s.pool.hits,
                pool_misses: s.pool.misses,
                pool_recycled_bytes: s.pool.recycled_bytes,
                router_rmws: batches + s.chunks_dispatched,
            };
            table.push_row(vec![
                row.shards.to_string(),
                row.batch.to_string(),
                format!("{:.2}", row.edges_per_sec / 1e6),
                row.batches.to_string(),
                row.chunks_dispatched.to_string(),
                row.pool_hits.to_string(),
                row.pool_misses.to_string(),
                fmt_bytes(row.pool_recycled_bytes),
                format!("{:.2}", row.rmws_per_kedge()),
            ]);
            rows.push(row);
        }
    }
    (table, rows)
}

/// One parallel-scan microbench measurement: a (format × readers) cell
/// streaming a real file through [`ParallelScanner`] into the service.
#[derive(Debug, Clone)]
pub struct ReaderBenchRow {
    /// Source file format (`"text"` or `"binary"`).
    pub format: &'static str,
    /// Reader threads requested for the scan.
    pub readers: usize,
    /// Edges ingested.
    pub edges: u64,
    /// File bytes parsed by the reader threads.
    pub bytes: u64,
    /// Wall-clock ingest + terminal replay time.
    pub elapsed_secs: f64,
    /// Ingest throughput.
    pub edges_per_sec: f64,
    /// Whether the final partition matched the in-memory baseline
    /// bit-for-bit (the ordered scan makes this the invariant, not a
    /// tolerance — a `false` here is a regression).
    pub labels_match: bool,
}

/// The parallel-scan microbench: write the SBM workload to temporary
/// text and binary files, then sweep [`INGEST_READERS_SWEEP`] reader
/// counts per format, streaming each scan through the full service
/// ingest (drains off). Every cell's final partition is compared
/// against the in-memory `push_chunk` baseline; the ordered sequencer
/// makes bit-identical the expected verdict at any reader count.
pub fn run_readers(cfg: &ServiceBenchConfig) -> (Table, Vec<ReaderBenchRow>) {
    let g = sbm::generate(&SbmConfig::equal(
        cfg.communities,
        cfg.community_size,
        0.3,
        0.002,
        cfg.seed,
    ));
    let baseline = {
        let mut config = ServiceConfig::new(cfg.shards, cfg.v_max);
        config.drain_every = 0;
        let mut svc = ClusterService::start(config);
        for chunk in g.edges.edges.chunks(SCAN_BATCH) {
            svc.push_chunk(chunk);
        }
        svc.finish().labels()
    };

    let dir = std::env::temp_dir();
    let stem = format!("streamcom_bench_scan_{}_{}", std::process::id(), cfg.seed);
    let txt = dir.join(format!("{stem}.txt"));
    let bin = dir.join(format!("{stem}.bin"));
    io::write_text_edges(&txt, &g.edges).expect("write bench text file");
    io::write_binary_edges_with(&bin, &g.edges, SCAN_SEG_RECORDS).expect("write bench binary file");

    let mut table = Table::new(
        &format!(
            "parallel scan: {} (n={} m={}, {} shards, file source, drains off)",
            g.name,
            g.n(),
            g.m(),
            cfg.shards
        ),
        &["format", "readers", "Medges/s", "MB/s", "partition"],
    );
    let mut rows = Vec::new();
    for (format, path) in [("text", &txt), ("binary", &bin)] {
        for &readers in INGEST_READERS_SWEEP {
            let mut config = ServiceConfig::new(cfg.shards, cfg.v_max);
            config.drain_every = 0;
            let mut svc = ClusterService::start(config);
            let mut scanner =
                ParallelScanner::open(path, readers, SCAN_BATCH).expect("open bench scan");
            let stats = scanner.stats();
            svc.ingest(&mut scanner, SCAN_BATCH);
            let err = scanner.take_error();
            let res = svc.finish();
            let elapsed = res.elapsed.as_secs_f64().max(1e-9);
            let row = ReaderBenchRow {
                format,
                readers,
                edges: res.edges_ingested,
                bytes: stats.bytes_read(),
                elapsed_secs: elapsed,
                edges_per_sec: res.edges_ingested as f64 / elapsed,
                labels_match: err.is_none() && res.labels() == baseline,
            };
            table.push_row(vec![
                row.format.to_string(),
                row.readers.to_string(),
                format!("{:.2}", row.edges_per_sec / 1e6),
                format!("{:.1}", row.bytes as f64 / elapsed / 1e6),
                if row.labels_match {
                    "exact".to_string()
                } else {
                    "MISMATCH".to_string()
                },
            ]);
            rows.push(row);
        }
    }
    std::fs::remove_file(&txt).ok();
    std::fs::remove_file(&bin).ok();
    (table, rows)
}

/// One mmap-vs-buffered measurement: the same binary file streamed
/// through both scan transports at one reader count.
#[derive(Debug, Clone)]
pub struct MmapBenchRow {
    /// Scan transport (`"buffered"` or `"mmap"`).
    pub mode: &'static str,
    /// Reader threads requested for the scan.
    pub readers: usize,
    /// Edges ingested.
    pub edges: u64,
    /// File bytes parsed by the reader threads.
    pub bytes: u64,
    /// Wall-clock ingest + terminal replay time.
    pub elapsed_secs: f64,
    /// Ingest throughput.
    pub edges_per_sec: f64,
    /// Whether the final partition matched the in-memory baseline
    /// bit-for-bit (compared via padded labels — the bench seeds the
    /// sketches from the header's `n`, which changes only the
    /// label-vector length, never the partition).
    pub labels_match: bool,
    /// Whether the cell actually ran on a shared memory map (`false`
    /// on non-unix builds, where `open_mmap` degrades to buffered).
    pub mapped: bool,
}

/// The mmap-vs-buffered microbench: write the SBM workload to one
/// binary file, then stream it through both scan transports at each
/// [`MMAP_READERS_SWEEP`] reader count — seeded sketches, drains off —
/// and compare every cell's padded partition against the in-memory
/// baseline. The transport must never change results, only the
/// per-edge cost.
pub fn run_mmap(cfg: &ServiceBenchConfig) -> (Table, Vec<MmapBenchRow>) {
    let g = sbm::generate(&SbmConfig::equal(
        cfg.communities,
        cfg.community_size,
        0.3,
        0.002,
        cfg.seed,
    ));
    let n = g.n();
    let baseline = {
        let mut config = ServiceConfig::new(cfg.shards, cfg.v_max);
        config.drain_every = 0;
        let mut svc = ClusterService::start(config);
        for chunk in g.edges.edges.chunks(SCAN_BATCH) {
            svc.push_chunk(chunk);
        }
        svc.finish().snapshot.labels_padded(n)
    };

    let dir = std::env::temp_dir();
    let stem = format!("streamcom_bench_mmap_{}_{}", std::process::id(), cfg.seed);
    let bin = dir.join(format!("{stem}.bin"));
    io::write_binary_edges_with(&bin, &g.edges, SCAN_SEG_RECORDS).expect("write bench binary file");

    let mut table = Table::new(
        &format!(
            "mmap scan: {} (n={} m={}, {} shards, binary source, seeded sketches, drains off)",
            g.name,
            g.n(),
            g.m(),
            cfg.shards
        ),
        &["mode", "readers", "Medges/s", "MB/s", "mapped", "partition"],
    );
    let mut rows = Vec::new();
    for mode in ["buffered", "mmap"] {
        for &readers in MMAP_READERS_SWEEP {
            let mut config = ServiceConfig::new(cfg.shards, cfg.v_max);
            config.drain_every = 0;
            // the serve fast path under test: header-seeded sketches
            config.initial_nodes = n;
            let mut svc = ClusterService::start(config);
            let mut scanner = if mode == "mmap" {
                ParallelScanner::open_mmap(&bin, readers, SCAN_BATCH)
            } else {
                ParallelScanner::open(&bin, readers, SCAN_BATCH)
            }
            .expect("open bench scan");
            let stats = scanner.stats();
            let mapped = scanner.mmapped();
            svc.ingest(&mut scanner, SCAN_BATCH);
            let err = scanner.take_error();
            let res = svc.finish();
            let elapsed = res.elapsed.as_secs_f64().max(1e-9);
            let row = MmapBenchRow {
                mode,
                readers,
                edges: res.edges_ingested,
                bytes: stats.bytes_read(),
                elapsed_secs: elapsed,
                edges_per_sec: res.edges_ingested as f64 / elapsed,
                labels_match: err.is_none() && res.snapshot.labels_padded(n) == baseline,
                mapped,
            };
            table.push_row(vec![
                row.mode.to_string(),
                row.readers.to_string(),
                format!("{:.2}", row.edges_per_sec / 1e6),
                format!("{:.1}", row.bytes as f64 / elapsed / 1e6),
                if row.mapped { "yes".to_string() } else { "no".to_string() },
                if row.labels_match {
                    "exact".to_string()
                } else {
                    "MISMATCH".to_string()
                },
            ]);
            rows.push(row);
        }
    }
    std::fs::remove_file(&bin).ok();
    (table, rows)
}

/// One routing-mode measurement: the same binary file streamed through
/// the funnel (sequencer + single routing thread) or direct sharded
/// dispatch (readers route, per-shard delivery) at one reader count.
#[derive(Debug, Clone)]
pub struct RoutingBenchRow {
    /// Delivery mode (`"funnel"` or `"direct"`).
    pub mode: &'static str,
    /// Reader threads requested for the scan.
    pub readers: usize,
    /// Edges ingested.
    pub edges: u64,
    /// File bytes parsed by the reader threads.
    pub bytes: u64,
    /// Wall-clock ingest + terminal replay time.
    pub elapsed_secs: f64,
    /// Ingest throughput.
    pub edges_per_sec: f64,
    /// Whether the final partition matched the in-memory baseline
    /// bit-for-bit (padded labels — the bench seeds sketches from the
    /// header's `n`). Routing is a transport choice, never a semantics
    /// choice: a `false` here is a regression, and CI hard-gates it.
    pub labels_match: bool,
}

/// The routing microbench: write the SBM workload to one binary file,
/// then stream it through both delivery modes at each
/// [`ROUTING_READERS_SWEEP`] reader count — mmap transport (buffered
/// fallback off-unix), seeded sketches, drains off — and compare every
/// cell's padded partition against the in-memory baseline. The funnel
/// sequences everything through one routing thread; direct dispatch
/// routes in the readers and muxes per-shard sub-chunks in file order.
/// Same partition either way — that is the tentpole invariant.
pub fn run_routing(cfg: &ServiceBenchConfig) -> (Table, Vec<RoutingBenchRow>) {
    let g = sbm::generate(&SbmConfig::equal(
        cfg.communities,
        cfg.community_size,
        0.3,
        0.002,
        cfg.seed,
    ));
    let n = g.n();
    let baseline = {
        let mut config = ServiceConfig::new(cfg.shards, cfg.v_max);
        config.drain_every = 0;
        let mut svc = ClusterService::start(config);
        for chunk in g.edges.edges.chunks(SCAN_BATCH) {
            svc.push_chunk(chunk);
        }
        svc.finish().snapshot.labels_padded(n)
    };

    let dir = std::env::temp_dir();
    let stem = format!("streamcom_bench_route_{}_{}", std::process::id(), cfg.seed);
    let bin = dir.join(format!("{stem}.bin"));
    io::write_binary_edges_with(&bin, &g.edges, SCAN_SEG_RECORDS).expect("write bench binary file");

    let mut table = Table::new(
        &format!(
            "routing: {} (n={} m={}, {} shards, binary source, seeded sketches, drains off)",
            g.name,
            g.n(),
            g.m(),
            cfg.shards
        ),
        &["mode", "readers", "Medges/s", "MB/s", "partition"],
    );
    let mut rows = Vec::new();
    for mode in ["funnel", "direct"] {
        for &readers in ROUTING_READERS_SWEEP {
            let mut config = ServiceConfig::new(cfg.shards, cfg.v_max);
            config.drain_every = 0;
            config.initial_nodes = n;
            let mut svc = ClusterService::start(config);
            let (res, bytes, err) = if mode == "direct" {
                let mut scan = DirectScan::open_mmap(&bin, readers, SCAN_BATCH, cfg.shards, None)
                    .expect("open bench direct scan");
                let stats = scan.stats();
                svc.ingest_direct(&mut scan);
                let err = scan.take_error();
                (svc.finish(), stats.bytes_read(), err)
            } else {
                let mut scanner = ParallelScanner::open_mmap(&bin, readers, SCAN_BATCH)
                    .expect("open bench scan");
                let stats = scanner.stats();
                svc.ingest(&mut scanner, SCAN_BATCH);
                let err = scanner.take_error();
                (svc.finish(), stats.bytes_read(), err)
            };
            let elapsed = res.elapsed.as_secs_f64().max(1e-9);
            let row = RoutingBenchRow {
                mode,
                readers,
                edges: res.edges_ingested,
                bytes,
                elapsed_secs: elapsed,
                edges_per_sec: res.edges_ingested as f64 / elapsed,
                labels_match: err.is_none() && res.snapshot.labels_padded(n) == baseline,
            };
            table.push_row(vec![
                row.mode.to_string(),
                row.readers.to_string(),
                format!("{:.2}", row.edges_per_sec / 1e6),
                format!("{:.1}", row.bytes as f64 / elapsed / 1e6),
                if row.labels_match {
                    "exact".to_string()
                } else {
                    "MISMATCH".to_string()
                },
            ]);
            rows.push(row);
        }
    }

    // crash → resume cell: a durable direct ingest dies from a torn
    // reader lane mid-stream (simulated dying disk), a fresh service
    // resumes from the per-reader WAL lanes, and the remainder of the
    // stream is re-fed. `labels_match` here is the recovery gate the
    // release CI hard-fails on: crash recovery on the direct route
    // stays bit-identical at bench scale.
    {
        let readers = *ROUTING_READERS_SWEEP.last().expect("non-empty sweep");
        let wal = dir.join(format!("{stem}_wal"));
        std::fs::remove_dir_all(&wal).ok();
        let mut config = ServiceConfig::new(cfg.shards, cfg.v_max);
        config.drain_every = 0;
        config.initial_nodes = n;
        config.wal_dir = Some(wal.clone());
        let fp = config.failpoint.clone();
        // tear reader 0's lane about a third into its share
        fp.arm(CrashPoint::ReaderWalAppend {
            reader: 0,
            after_records: (g.m() / (readers * 3)).max(1) as u64,
            torn_bytes: 11,
        });
        let wal_cfg = config.direct_wal_cfg();
        let mut doomed = ClusterService::start(config);
        let mut scan = DirectScan::open_mmap(&bin, readers, SCAN_BATCH, cfg.shards, wal_cfg)
            .expect("open bench direct scan");
        let stats = scan.stats();
        doomed.ingest_direct(&mut scan);
        let crashed = fp.is_dead();
        drop(doomed); // abortive shutdown: only the synced lanes survive

        let mut config = ServiceConfig::new(cfg.shards, cfg.v_max);
        config.drain_every = 0;
        config.wal_dir = Some(wal.clone());
        let (res, labels_match) = match ClusterService::resume(config) {
            Ok(mut svc) => {
                let at = (svc.handle().stats().edges_ingested as usize).min(g.m());
                for chunk in g.edges.edges[at..].chunks(SCAN_BATCH) {
                    svc.push_chunk(chunk);
                }
                let res = svc.finish();
                let ok = crashed
                    && res.edges_ingested == g.m() as u64
                    && res.snapshot.labels_padded(n) == baseline;
                (Some(res), ok)
            }
            Err(_) => (None, false),
        };
        std::fs::remove_dir_all(&wal).ok();
        let (edges, elapsed) = res
            .map(|r| (r.edges_ingested, r.elapsed.as_secs_f64().max(1e-9)))
            .unwrap_or((0, 1e-9));
        let row = RoutingBenchRow {
            mode: "direct-crash-resume",
            readers,
            edges,
            bytes: stats.bytes_read(),
            elapsed_secs: elapsed,
            edges_per_sec: edges as f64 / elapsed,
            labels_match,
        };
        table.push_row(vec![
            row.mode.to_string(),
            row.readers.to_string(),
            format!("{:.2}", row.edges_per_sec / 1e6),
            format!("{:.1}", row.bytes as f64 / elapsed / 1e6),
            if row.labels_match {
                "exact".to_string()
            } else {
                "MISMATCH".to_string()
            },
        ]);
        rows.push(row);
    }
    std::fs::remove_file(&bin).ok();
    (table, rows)
}

/// Stream one SBM workload through the service per configured horizon
/// and collect the table + raw rows.
pub fn run(cfg: &ServiceBenchConfig) -> (Table, Vec<ServiceBenchRow>) {
    let g = sbm::generate(&SbmConfig::equal(
        cfg.communities,
        cfg.community_size,
        0.3,
        0.002,
        cfg.seed,
    ));
    let mut table = Table::new(
        &format!(
            "service bench: {} (n={} m={}, {} shards, drain_every={})",
            g.name,
            g.n(),
            g.m(),
            cfg.shards,
            cfg.drain_every
        ),
        &[
            "horizon",
            "Medges/s",
            "drains",
            "replay/drain",
            "delta_last",
            "x-retained",
            "x-committed",
            "x-freed",
            "Σleader base",
        ],
    );
    let mut rows = Vec::new();
    for &h in &cfg.horizons {
        let mut config = ServiceConfig::new(cfg.shards, cfg.v_max);
        config.leaders = cfg.leaders;
        config.drain_every = cfg.drain_every;
        config.horizon = CommitHorizon::Edges(h); // Edges(0) ⇒ Unbounded
        let mut svc = ClusterService::start(config);
        let handle = svc.handle();
        // the drain clock is batch-granular: stream in batches no
        // larger than the cadence so the sweep actually measures
        // per-drain cost at the configured cadence
        let batch = cfg.drain_every.clamp(1, 4_096) as usize;
        for chunk in g.edges.edges.chunks(batch) {
            svc.push_chunk(chunk);
        }
        svc.quiesce();
        let s = handle.stats();
        let res = svc.finish();
        let elapsed = res.elapsed.as_secs_f64().max(1e-9);
        let row = ServiceBenchRow {
            horizon: h,
            edges: res.edges_ingested,
            cross_total: s.cross_total,
            elapsed_secs: elapsed,
            edges_per_sec: res.edges_ingested as f64 / elapsed,
            drains: s.drains,
            replay_per_drain: s.cross_replayed_total as f64 / (s.drains.max(1)) as f64,
            delta_last_bytes: s.delta_last_bytes,
            delta_total_bytes: s.delta_total_bytes,
            cross_retained: s.cross_retained,
            cross_committed: s.cross_committed,
            cross_freed_bytes: s.cross_freed_bytes,
            per_leader: s.per_leader.clone(),
        };
        table.push_row(vec![
            if h == 0 { "unbounded".into() } else { h.to_string() },
            format!("{:.2}", row.edges_per_sec / 1e6),
            row.drains.to_string(),
            format!("{:.1}", row.replay_per_drain),
            fmt_bytes(row.delta_last_bytes),
            row.cross_retained.to_string(),
            row.cross_committed.to_string(),
            fmt_bytes(row.cross_freed_bytes),
            fmt_bytes(s.committed_bytes_total()),
        ]);
        rows.push(row);
    }
    (table, rows)
}

/// Render the rows as the `BENCH_service.json` document (hand-rolled —
/// the offline build has no serde; every value is numeric so no string
/// escaping is required beyond the fixed keys). `ingest` carries the
/// shards × batch microbench sweep, `readers` the parallel-scan
/// format × reader-count sweep, `mmap` the mmap-vs-buffered transport
/// sweep, and `routing` the funnel-vs-direct dispatch sweep next to
/// the horizon rows. `"measured": true` marks a document produced by a
/// real run, as opposed to the committed placeholder — CI's verify
/// step keys off it.
pub fn to_json(
    cfg: &ServiceBenchConfig,
    rows: &[ServiceBenchRow],
    ingest: &[IngestBenchRow],
    readers: &[ReaderBenchRow],
    mmap: &[MmapBenchRow],
    routing: &[RoutingBenchRow],
) -> String {
    let mut out = String::from("{\n  \"bench\": \"service\",\n  \"measured\": true,\n");
    out.push_str(&format!(
        "  \"workload\": {{\"communities\": {}, \"community_size\": {}, \"seed\": {}}},\n",
        cfg.communities, cfg.community_size, cfg.seed
    ));
    out.push_str(&format!(
        "  \"shards\": {}, \"leaders\": {}, \"v_max\": {}, \"drain_every\": {},\n",
        cfg.shards, cfg.leaders, cfg.v_max, cfg.drain_every
    ));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let per_leader: Vec<String> = r
            .per_leader
            .iter()
            .map(|l| {
                format!(
                    "{{\"retained_bytes\": {}, \"committed_bytes\": {}, \"freed_bytes\": {}}}",
                    l.retained_bytes, l.committed_bytes, l.freed_bytes
                )
            })
            .collect();
        out.push_str(&format!(
            "    {{\"horizon\": {}, \"edges\": {}, \"cross_total\": {}, \
             \"elapsed_secs\": {:.6}, \"edges_per_sec\": {:.1}, \"drains\": {}, \
             \"replay_per_drain\": {:.2}, \"delta_last_bytes\": {}, \
             \"delta_total_bytes\": {}, \"cross_retained\": {}, \
             \"cross_committed\": {}, \"cross_freed_bytes\": {}, \
             \"per_leader\": [{}]}}{}\n",
            r.horizon,
            r.edges,
            r.cross_total,
            r.elapsed_secs,
            r.edges_per_sec,
            r.drains,
            r.replay_per_drain,
            r.delta_last_bytes,
            r.delta_total_bytes,
            r.cross_retained,
            r.cross_committed,
            r.cross_freed_bytes,
            per_leader.join(", "),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"ingest\": [\n");
    for (i, r) in ingest.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"shards\": {}, \"batch\": {}, \"edges\": {}, \
             \"elapsed_secs\": {:.6}, \"edges_per_sec\": {:.1}, \
             \"batches\": {}, \"chunks_dispatched\": {}, \
             \"pool_hits\": {}, \"pool_misses\": {}, \
             \"pool_recycled_bytes\": {}, \"router_rmws\": {}, \
             \"rmws_per_kedge\": {:.3}}}{}\n",
            r.shards,
            r.batch,
            r.edges,
            r.elapsed_secs,
            r.edges_per_sec,
            r.batches,
            r.chunks_dispatched,
            r.pool_hits,
            r.pool_misses,
            r.pool_recycled_bytes,
            r.router_rmws,
            r.rmws_per_kedge(),
            if i + 1 < ingest.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"readers\": [\n");
    for (i, r) in readers.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"format\": \"{}\", \"readers\": {}, \"edges\": {}, \
             \"bytes\": {}, \"elapsed_secs\": {:.6}, \
             \"edges_per_sec\": {:.1}, \"labels_match\": {}}}{}\n",
            r.format,
            r.readers,
            r.edges,
            r.bytes,
            r.elapsed_secs,
            r.edges_per_sec,
            r.labels_match,
            if i + 1 < readers.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"mmap\": [\n");
    for (i, r) in mmap.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"mode\": \"{}\", \"readers\": {}, \"edges\": {}, \
             \"bytes\": {}, \"elapsed_secs\": {:.6}, \
             \"edges_per_sec\": {:.1}, \"labels_match\": {}, \"mapped\": {}}}{}\n",
            r.mode,
            r.readers,
            r.edges,
            r.bytes,
            r.elapsed_secs,
            r.edges_per_sec,
            r.labels_match,
            r.mapped,
            if i + 1 < mmap.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"routing\": [\n");
    for (i, r) in routing.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"mode\": \"{}\", \"readers\": {}, \"edges\": {}, \
             \"bytes\": {}, \"elapsed_secs\": {:.6}, \
             \"edges_per_sec\": {:.1}, \"labels_match\": {}}}{}\n",
            r.mode,
            r.readers,
            r.edges,
            r.bytes,
            r.elapsed_secs,
            r.edges_per_sec,
            r.labels_match,
            if i + 1 < routing.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ServiceBenchConfig {
        ServiceBenchConfig {
            communities: 6,
            community_size: 20,
            shards: 2,
            leaders: 0,
            v_max: 64,
            drain_every: 128,
            horizons: vec![0, 64],
            seed: 7,
        }
    }

    #[test]
    fn rows_cover_each_horizon_and_json_is_shaped() {
        let cfg = tiny();
        let (table, rows) = run(&cfg);
        assert_eq!(rows.len(), 2);
        assert_eq!(table.rows.len(), 2);
        assert!(rows.iter().all(|r| r.edges > 0 && r.edges_per_sec > 0.0));
        // the bounded run must actually commit and free something
        let bounded = &rows[1];
        assert!(bounded.cross_committed > 0, "{bounded:?}");
        assert!(bounded.cross_freed_bytes > 0);
        assert_eq!(bounded.per_leader.len(), cfg.shards);

        let json = to_json(&cfg, &rows, &[], &[], &[], &[]);
        assert!(json.starts_with('{') && json.trim_end().ends_with('}'));
        assert!(json.contains("\"bench\": \"service\""));
        assert!(json.contains("\"measured\": true"));
        assert!(json.contains("\"delta_last_bytes\""));
        assert!(json.contains("\"per_leader\""));
        assert!(json.contains("\"ingest\""));
        assert!(json.contains("\"readers\""));
        assert!(json.contains("\"mmap\""));
        // two rows, comma-separated exactly once at the top level list
        assert_eq!(json.matches("\"horizon\"").count(), 2);
    }

    #[test]
    fn ingest_microbench_sweeps_and_pins_amortization() {
        let cfg = tiny();
        let (table, rows) = run_ingest(&cfg);
        let cells = INGEST_SHARDS_SWEEP.len() * INGEST_BATCH_SWEEP.len();
        assert_eq!(rows.len(), cells);
        assert_eq!(table.rows.len(), cells);
        for r in &rows {
            assert!(r.edges > 0 && r.edges_per_sec > 0.0, "{r:?}");
            // every edge ingested exactly once, whatever the cell shape
            assert_eq!(r.edges, rows[0].edges, "{r:?}");
            // measured chunk count stays amortized: the router never
            // dispatched anywhere near one chunk per edge (the default
            // chunk_size is 4096; flush partials add at most `shards`)
            assert!(
                r.chunks_dispatched <= r.edges / 1024 + r.shards as u64,
                "{r:?}"
            );
            // pool accounting is live wherever chunks were dispatched
            if r.chunks_dispatched > 0 {
                assert!(r.pool_hits + r.pool_misses > 0, "{r:?}");
            }
        }
        // bigger batches reduce the derived per-edge router budget: the
        // batch=1 column pays one ingested-add per edge by definition
        let small = rows.iter().find(|r| r.shards == 4 && r.batch == 1).unwrap();
        let big = rows.iter().find(|r| r.shards == 4 && r.batch == 4096).unwrap();
        assert!(
            big.rmws_per_kedge() < small.rmws_per_kedge(),
            "batch=4096 {:?} vs batch=1 {:?}",
            big.rmws_per_kedge(),
            small.rmws_per_kedge()
        );

        let json = to_json(&cfg, &[], &rows, &[], &[], &[]);
        assert_eq!(json.matches("\"rmws_per_kedge\"").count(), cells);
    }

    #[test]
    fn readers_sweep_covers_both_formats_and_matches_the_baseline() {
        let cfg = tiny();
        let (table, rows) = run_readers(&cfg);
        let cells = 2 * INGEST_READERS_SWEEP.len();
        assert_eq!(rows.len(), cells);
        assert_eq!(table.rows.len(), cells);
        assert_eq!(rows.iter().filter(|r| r.format == "text").count(), cells / 2);
        assert_eq!(rows.iter().filter(|r| r.format == "binary").count(), cells / 2);
        for r in &rows {
            assert!(r.edges > 0 && r.bytes > 0 && r.edges_per_sec > 0.0, "{r:?}");
            // every cell ingests the whole file exactly once
            assert_eq!(r.edges, rows[0].edges, "{r:?}");
            // the scan is ordered: any reader count reproduces the
            // in-memory baseline partition bit-for-bit
            assert!(r.labels_match, "{r:?}");
        }

        let json = to_json(&cfg, &[], &[], &rows, &[], &[]);
        assert_eq!(json.matches("\"labels_match\"").count(), cells);
        assert!(!json.contains("\"labels_match\": false"));
    }

    #[test]
    fn mmap_sweep_covers_both_transports_and_matches_the_baseline() {
        let cfg = tiny();
        let (table, rows) = run_mmap(&cfg);
        let cells = 2 * MMAP_READERS_SWEEP.len();
        assert_eq!(rows.len(), cells);
        assert_eq!(table.rows.len(), cells);
        assert_eq!(rows.iter().filter(|r| r.mode == "buffered").count(), cells / 2);
        assert_eq!(rows.iter().filter(|r| r.mode == "mmap").count(), cells / 2);
        let mmap_supported = crate::util::mmap::supported();
        for r in &rows {
            assert!(r.edges > 0 && r.bytes > 0 && r.edges_per_sec > 0.0, "{r:?}");
            // every cell ingests the whole file exactly once
            assert_eq!(r.edges, rows[0].edges, "{r:?}");
            // the transport must never change results — only speed
            assert!(r.labels_match, "{r:?}");
            // mmap cells really map on platforms that support it (and
            // honestly report the buffered fallback elsewhere)
            assert_eq!(r.mapped, r.mode == "mmap" && mmap_supported, "{r:?}");
        }

        let json = to_json(&cfg, &[], &[], &[], &rows, &[]);
        assert_eq!(json.matches("\"mapped\"").count(), cells);
        assert!(!json.contains("\"labels_match\": false"));
    }

    #[test]
    fn routing_sweep_covers_both_modes_and_matches_the_baseline() {
        let cfg = tiny();
        let (table, rows) = run_routing(&cfg);
        // funnel + direct sweeps, plus the crash→resume recovery cell
        let cells = 2 * ROUTING_READERS_SWEEP.len() + 1;
        assert_eq!(rows.len(), cells);
        assert_eq!(table.rows.len(), cells);
        assert_eq!(rows.iter().filter(|r| r.mode == "funnel").count(), (cells - 1) / 2);
        assert_eq!(rows.iter().filter(|r| r.mode == "direct").count(), (cells - 1) / 2);
        assert_eq!(
            rows.iter().filter(|r| r.mode == "direct-crash-resume").count(),
            1,
            "the recovery gate cell must always be present"
        );
        for r in &rows {
            assert!(r.edges > 0 && r.bytes > 0 && r.edges_per_sec > 0.0, "{r:?}");
            // every cell ingests the whole file exactly once — the
            // crash cell too: recovered prefix + re-fed remainder
            assert_eq!(r.edges, rows[0].edges, "{r:?}");
            // routing is a transport choice, never a semantics choice,
            // and neither is crashing on a durable route
            assert!(r.labels_match, "{r:?}");
        }

        let json = to_json(&cfg, &[], &[], &[], &[], &rows);
        assert!(json.contains("\"routing\""));
        assert!(json.contains("\"mode\": \"direct-crash-resume\""));
        assert_eq!(json.matches("\"labels_match\"").count(), cells);
        assert!(!json.contains("\"labels_match\": false"));
    }
}
