//! Workload management for the experiment harnesses.
//!
//! Generates the six SNAP-shaped graphs (DESIGN.md §3) at a chosen
//! scale, with optional on-disk caching so repeated bench invocations
//! don't pay generation again: graphs are cached as binary edge files +
//! ground-truth files under `target/workloads/`.

use std::path::PathBuf;

use crate::graph::generators::lfr;
use crate::graph::generators::presets::{SnapPreset, SNAP_PRESETS};
use crate::graph::generators::GeneratedGraph;
use crate::graph::io;

/// Default experiment scale: small enough that the full 6×6 grid
/// finishes in CI-sized time, large enough to show the scaling shape.
pub const DEFAULT_SCALE: f64 = 0.1;

/// Deterministic workload seed (recorded in EXPERIMENTS.md).
pub const WORKLOAD_SEED: u64 = 0x5EED_2017;

/// Which presets to include (index into [`SNAP_PRESETS`]).
pub fn preset_range(max_edges: Option<usize>, scale: f64) -> Vec<&'static SnapPreset> {
    SNAP_PRESETS
        .iter()
        .filter(|p| {
            let m_est = (p.nodes as f64 * scale * p.avg_deg / 2.0) as usize;
            max_edges.map(|cap| m_est <= cap).unwrap_or(true)
        })
        .collect()
}

fn cache_dir() -> PathBuf {
    PathBuf::from("target/workloads")
}

fn cache_paths(name: &str, scale: f64) -> (PathBuf, PathBuf) {
    let d = cache_dir();
    let tag = format!("{name}-s{:.4}-seed{WORKLOAD_SEED:x}", scale);
    (d.join(format!("{tag}.bin")), d.join(format!("{tag}.cmty")))
}

/// Generate (or load from cache) one preset at the given scale.
pub fn load_preset(preset: &SnapPreset, scale: f64, cache: bool) -> GeneratedGraph {
    let (edge_path, gt_path) = cache_paths(preset.name, scale);
    if cache && edge_path.is_file() && gt_path.is_file() {
        if let (Ok(edges), Ok(truth)) =
            (io::read_binary_edges(&edge_path), io::read_ground_truth(&gt_path))
        {
            return GeneratedGraph { name: preset.name.to_string(), edges, truth };
        }
    }
    let cfg = preset.config(scale, WORKLOAD_SEED);
    let g = lfr::generate(&cfg);
    if cache {
        let _ = std::fs::create_dir_all(cache_dir());
        let _ = io::write_binary_edges(&edge_path, &g.edges);
        let _ = io::write_ground_truth(&gt_path, &g.truth);
    }
    g
}

/// All presets fitting under `max_edges` at the given scale.
pub fn load_all(scale: f64, max_edges: Option<usize>, cache: bool) -> Vec<GeneratedGraph> {
    preset_range(max_edges, scale)
        .into_iter()
        .map(|p| load_preset(p, scale, cache))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_range_caps_by_edges() {
        let all = preset_range(None, 1.0);
        assert_eq!(all.len(), 6);
        let small = preset_range(Some(200_000), 1.0);
        assert!(small.len() < 6);
        assert!(!small.is_empty());
    }

    #[test]
    fn load_preset_without_cache_is_deterministic() {
        let p = &SNAP_PRESETS[0];
        let a = load_preset(p, 0.02, false);
        let b = load_preset(p, 0.02, false);
        assert_eq!(a.edges.edges, b.edges.edges);
        assert!(a.m() > 500);
    }

    #[test]
    fn cache_roundtrip_preserves_graph() {
        let p = &SNAP_PRESETS[0];
        let fresh = load_preset(p, 0.015, true); // writes cache
        let cached = load_preset(p, 0.015, true); // reads cache
        assert_eq!(fresh.edges.edges, cached.edges.edges);
        assert_eq!(fresh.truth.communities, cached.truth.communities);
        let (e, c) = cache_paths(p.name, 0.015);
        std::fs::remove_file(e).ok();
        std::fs::remove_file(c).ok();
    }
}
