//! Minimal benchmark runner (offline replacement for criterion).
//!
//! Usage from a `harness = false` bench target:
//!
//! ```ignore
//! let stats = bench("str/amazon-s", Budget::default(), || {
//!     run_the_thing();
//! });
//! println!("{}", stats);
//! ```
//!
//! The runner warms up, then runs timed iterations until both a minimum
//! iteration count and a minimum wall-clock budget are met, and reports
//! robust statistics (median / mean / stddev / min / max).

use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// Iteration budget.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    /// Warm-up iterations (untimed).
    pub warmup_iters: usize,
    /// Minimum timed iterations.
    pub min_iters: usize,
    /// Maximum timed iterations.
    pub max_iters: usize,
    /// Minimum total measurement time.
    pub min_time: Duration,
    /// Overall time budget cap.
    pub max_time: Duration,
}

impl Default for Budget {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 100,
            min_time: Duration::from_millis(200),
            max_time: Duration::from_secs(10),
        }
    }
}

impl Budget {
    /// Budget for expensive end-to-end runs (one warmup, few iters).
    pub fn heavy() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            min_time: Duration::from_millis(100),
            max_time: Duration::from_secs(60),
        }
    }

    /// Single-shot measurement (workloads too big to repeat).
    pub fn once() -> Self {
        Self {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 1,
            min_time: Duration::ZERO,
            max_time: Duration::from_secs(3600),
        }
    }
}

/// Robust statistics over the per-iteration times.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark name.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Median duration.
    pub median: Duration,
    /// Mean duration.
    pub mean: Duration,
    /// Standard deviation.
    pub stddev: Duration,
    /// Fastest sample.
    pub min: Duration,
    /// Slowest sample.
    pub max: Duration,
}

impl Stats {
    fn from_samples(name: &str, mut samples: Vec<Duration>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let median = samples[n / 2];
        let sum: Duration = samples.iter().sum();
        let mean = sum / n as u32;
        let var = samples
            .iter()
            .map(|s| {
                let d = s.as_secs_f64() - mean.as_secs_f64();
                d * d
            })
            .sum::<f64>()
            / n as f64;
        Stats {
            name: name.to_string(),
            iters: n,
            median,
            mean,
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: samples[0],
            max: samples[n - 1],
        }
    }

    /// Median sample in seconds.
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10} median  {:>10} mean  ±{:>9}  ({} iters)",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.mean),
            fmt_duration(self.stddev),
            self.iters
        )
    }
}

/// Human-friendly duration.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Run a closure under the budget and collect stats.
pub fn bench<F: FnMut()>(name: &str, budget: Budget, mut f: F) -> Stats {
    for _ in 0..budget.warmup_iters {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        let done_iters = samples.len();
        let elapsed = start.elapsed();
        if done_iters >= budget.max_iters || elapsed >= budget.max_time {
            break;
        }
        if done_iters >= budget.min_iters && elapsed >= budget.min_time {
            break;
        }
    }
    Stats::from_samples(name, samples)
}

/// Measure one run of a closure returning a value.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_respects_min_iters() {
        let stats = bench(
            "noop",
            Budget {
                warmup_iters: 0,
                min_iters: 7,
                max_iters: 7,
                min_time: Duration::ZERO,
                max_time: Duration::from_secs(1),
            },
            || {
                black_box(1 + 1);
            },
        );
        assert_eq!(stats.iters, 7);
        assert!(stats.median <= stats.max);
        assert!(stats.min <= stats.median);
    }

    #[test]
    fn once_budget_single_iteration() {
        let mut count = 0;
        let stats = bench("one", Budget::once(), || {
            count += 1;
        });
        assert_eq!(count, 1);
        assert_eq!(stats.iters, 1);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(d >= Duration::from_millis(4));
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_duration(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_duration(Duration::from_micros(5)).contains("µs"));
    }
}
