//! Experiment T2 — the paper's Table 2: average F1 and NMI against
//! ground truth for STR and the five baselines.
//!
//! Shape under test: Louvain/OSLOM lead on the small low-mixing graphs;
//! STR ties or wins on the large high-mixing graphs (where most
//! baselines no longer run at all).

use crate::baselines::paper_suite;
use crate::coordinator::algorithm::{StrConfig, StreamingClusterer};
use crate::graph::csr::Csr;
use crate::graph::generators::GeneratedGraph;
use crate::metrics::f1::average_f1_labels;
use crate::metrics::nmi::nmi_labels;

use super::report::{fmt_score, Table};
use super::table1::select_v_max;
use super::workloads;

/// One Table-2 row.
#[derive(Debug, Clone)]
pub struct Table2Row {
    /// Dataset name.
    pub name: String,
    /// (F1, NMI) per baseline in suite order; None = skipped.
    pub baseline_scores: Vec<Option<(f64, f64)>>,
    /// `(F1, NMI)` of the streaming algorithm.
    pub str_scores: (f64, f64),
    /// Selected `v_max`.
    pub v_max: u64,
}

#[derive(Debug, Clone)]
/// Configuration for the Table 2 (quality) harness.
pub struct Table2Config {
    /// Workload scale factor.
    pub scale: f64,
    /// Skip baselines above this edge count.
    pub baseline_edge_cap: usize,
    /// Workload seed.
    pub seed: u64,
    /// Reuse cached workloads.
    pub cache: bool,
}

impl Default for Table2Config {
    fn default() -> Self {
        Self {
            scale: workloads::DEFAULT_SCALE,
            baseline_edge_cap: 20_000_000,
            seed: 7,
            cache: true,
        }
    }
}

/// Score one label vector against a workload's ground truth.
pub fn score(g: &GeneratedGraph, labels: &[u32]) -> (f64, f64) {
    let truth = g.truth.to_labels(g.n());
    (
        average_f1_labels(labels, &truth),
        nmi_labels(labels, &truth),
    )
}

/// Run the full Table-2 grid.
pub fn run(config: &Table2Config) -> (Table, Vec<Table2Row>) {
    let graphs = workloads::load_all(config.scale, None, config.cache);
    let mut rows = Vec::new();
    for g in &graphs {
        let v_max = select_v_max(g);
        let mut c = StreamingClusterer::new(g.n(), StrConfig::new(v_max));
        c.process_chunk(&g.edges.edges);
        let str_scores = score(g, &c.labels());

        let csr = if g.m() <= config.baseline_edge_cap {
            Some(Csr::from_edge_list(&g.edges))
        } else {
            None
        };
        let mut baseline_scores = Vec::new();
        for mut algo in paper_suite(config.seed) {
            let run_it = csr.is_some()
                && algo.practical_for(g.n(), g.m())
                && g.m() <= config.baseline_edge_cap
                && super::table1::baseline_available(&g.name, algo.tag());
            if run_it {
                let labels = algo.detect(csr.as_ref().unwrap());
                baseline_scores.push(Some(score(g, &labels)));
            } else {
                baseline_scores.push(None);
            }
        }
        rows.push(Table2Row {
            name: g.name.clone(),
            baseline_scores,
            str_scores,
            v_max,
        });
    }
    (render(&rows, config.scale), rows)
}

/// Render in the paper's two-block layout (F1 block then NMI block).
pub fn render(rows: &[Table2Row], scale: f64) -> Table {
    let mut t = Table::new(
        &format!("Table 2 — average F1 scores and NMI (scale {scale})"),
        &[
            "dataset", "F1:S", "F1:L", "F1:I", "F1:W", "F1:O", "F1:STR", "NMI:S", "NMI:L",
            "NMI:I", "NMI:W", "NMI:O", "NMI:STR",
        ],
    );
    for r in rows {
        let mut cells = vec![r.name.clone()];
        for s in &r.baseline_scores {
            cells.push(fmt_score(s.map(|x| x.0)));
        }
        cells.push(fmt_score(Some(r.str_scores.0)));
        for s in &r.baseline_scores {
            cells.push(fmt_score(s.map(|x| x.1)));
        }
        cells.push(fmt_score(Some(r.str_scores.1)));
        t.push_row(cells);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_runs_and_scores_are_probabilities() {
        let cfg = Table2Config { scale: 0.01, cache: false, ..Default::default() };
        let (_t, rows) = run(&cfg);
        assert_eq!(rows.len(), 6);
        for r in &rows {
            let (f1, nmi) = r.str_scores;
            assert!((0.0..=1.0).contains(&f1), "{}: f1={f1}", r.name);
            assert!((0.0..=1.0).contains(&nmi), "{}: nmi={nmi}", r.name);
            // STR must produce a non-trivial detection on every graph
            assert!(f1 > 0.05, "{}: degenerate F1 {f1}", r.name);
        }
    }

    #[test]
    fn str_beats_louvain_on_large_high_mixing_rows() {
        // The paper's reproduced quality crossover (Table 2): Louvain's
        // resolution limit collapses on the large graphs with small
        // ground-truth communities, while STR holds up. (SCD stays
        // strong on our synthetic stand-ins because generated truth is
        // triangle-aligned — divergence documented in EXPERIMENTS.md.)
        let cfg = Table2Config { scale: 0.02, cache: false, ..Default::default() };
        let (_t, rows) = run(&cfg);
        // Louvain is suite index 1; it runs on youtube/livejournal/orkut.
        // The resolution-limit gap widens with scale, so at this test
        // scale we require STR to win the majority of the large rows
        // (at the default bench scale it wins all three — see
        // EXPERIMENTS.md T2).
        let mut compared = 0;
        let mut wins = 0;
        for r in rows.iter().filter(|r| {
            r.name == "livejournal-s" || r.name == "orkut-s" || r.name == "youtube-s"
        }) {
            if let Some((louvain_f1, _)) = r.baseline_scores[1] {
                compared += 1;
                if r.str_scores.0 > louvain_f1 {
                    wins += 1;
                }
            }
        }
        assert!(compared >= 2, "expected Louvain on ≥2 large rows");
        assert!(
            wins * 2 > compared,
            "STR beat Louvain on only {wins}/{compared} large rows"
        );
        // STR itself must stay non-degenerate on every large row
        for r in &rows {
            assert!(r.str_scores.0 > 0.1, "{}: STR F1 degenerate", r.name);
        }
    }
}
