//! Table rendering for the experiment harnesses — prints the same
//! row/column structure as the paper's tables.

/// A simple aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    /// Table caption.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Row cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as aligned monospace text.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                // first column left-aligned, the rest right-aligned
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavoured markdown rendering (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.headers.len())
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format seconds like the paper's Table 1 (3 significant digits).
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 10.0 {
        format!("{s:.1}")
    } else if s >= 0.1 {
        format!("{s:.2}")
    } else {
        format!("{s:.3}")
    }
}

/// Format a score like Table 2 (2 decimals), `-` for skipped cells.
pub fn fmt_score(x: Option<f64>) -> String {
    match x {
        Some(v) => format!("{v:.2}"),
        None => "-".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("T", &["name", "x"]);
        t.push_row(vec!["a".into(), "1.0".into()]);
        t.push_row(vec!["long-name".into(), "22.5".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("long-name"));
        let lines: Vec<&str> = r.lines().collect();
        // header, separator, 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.push_row(vec!["only-one".into()]);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("T", &["a", "b"]);
        t.push_row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.starts_with("| a | b |\n|---|---|\n"));
    }

    #[test]
    fn fmt_secs_sigfigs() {
        assert_eq!(fmt_secs(13464.0), "13464");
        assert_eq!(fmt_secs(85.7), "85.7");
        assert_eq!(fmt_secs(1.84), "1.84");
        assert_eq!(fmt_secs(0.05), "0.050");
    }

    #[test]
    fn fmt_score_dash_for_none() {
        assert_eq!(fmt_score(None), "-");
        assert_eq!(fmt_score(Some(0.234)), "0.23");
    }
}
