//! Experiment T1 — the paper's Table 1: dataset sizes + execution time
//! for STR and the five baselines, plus the T1b `cat` lower bound.
//!
//! Differences from the paper are mechanical (DESIGN.md §3): workloads
//! are the SNAP-shaped generated graphs at `--scale`, and the baselines
//! are our Rust implementations. The *shape* under test: STR is ≥10×
//! faster than the fastest baseline on every graph and within ~2× of the
//! readonly pass; baselines drop out as graphs grow (blank cells).

use crate::baselines::paper_suite;
use crate::coordinator::algorithm::{StrConfig, StreamingClusterer};
use crate::coordinator::selection::{select, NativeEngine, SelectionRule};
use crate::coordinator::sweep::MultiSweep;
use crate::graph::csr::Csr;
use crate::graph::generators::GeneratedGraph;

use super::framework::time_once;
use super::readonly::readonly_pass;
use super::report::{fmt_secs, Table};
use super::workloads;

/// One Table-1 row.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Dataset name.
    pub name: String,
    /// Node count.
    pub n: usize,
    /// Edge count.
    pub m: usize,
    /// Baseline times in suite order (None = skipped, like the paper's
    /// blank cells).
    pub baseline_secs: Vec<Option<f64>>,
    /// STR wall-clock seconds.
    pub str_secs: f64,
    /// Read-only pass seconds (lower bound).
    pub readonly_secs: f64,
    /// v_max used for the timed STR run (sweep-selected).
    pub v_max: u64,
}

/// Configuration for the harness.
#[derive(Debug, Clone)]
pub struct Table1Config {
    /// Workload scale factor.
    pub scale: f64,
    /// Skip any baseline whose `practical_for` rejects the graph or
    /// whose estimated cost exceeds this many edges·passes (mirrors the
    /// paper's 6-hour timeout policy, scaled).
    pub baseline_edge_cap: usize,
    /// Workload seed.
    pub seed: u64,
    /// Reuse cached workloads.
    pub cache: bool,
}

impl Default for Table1Config {
    fn default() -> Self {
        Self {
            scale: workloads::DEFAULT_SCALE,
            baseline_edge_cap: 20_000_000,
            seed: 7,
            cache: true,
        }
    }
}

/// Sweep-select a v_max for a workload (the §2.5 procedure; not part of
/// the timed region — the paper also reports single-parameter runs).
///
/// Community *volumes* scale with mean degree, so the geometric ladder
/// is anchored at the graph's average degree: `v_max ≈ avg_deg · 2^i`
/// spans "a couple of nodes" up to "≈128 average nodes" of volume.
pub fn select_v_max(g: &GeneratedGraph) -> u64 {
    let avg_deg = (2 * g.m()).max(1) as u64 / g.n().max(1) as u64;
    let base = avg_deg.max(4);
    let ladder = MultiSweep::geometric_ladder(base, 8);
    let mut sweep = MultiSweep::new(g.n(), ladder.clone());
    sweep.process_chunk(&g.edges.edges);
    let (winner, _) = select(&sweep, &mut NativeEngine, SelectionRule::DensityScore);
    ladder[winner]
}

/// Mirror the paper's Table-1 blank cells: on the SNAP presets, only
/// the baselines the paper itself could run within its 6-hour timeout
/// are executed (at the authors' scale the others timed out or
/// crashed; see `presets::SnapPreset::available`). Non-preset workloads
/// run everything the `practical_for` guards allow.
pub fn baseline_available(workload: &str, tag: &str) -> bool {
    match crate::graph::generators::presets::find(workload) {
        Some(p) => p.available.contains(tag),
        None => true,
    }
}

/// Time STR (single pass, chunked) on an in-memory stream.
pub fn time_str(g: &GeneratedGraph, v_max: u64) -> (f64, Vec<u32>) {
    let (labels, dt) = time_once(|| {
        let mut c = StreamingClusterer::new(g.n(), StrConfig::new(v_max));
        c.process_chunk(&g.edges.edges);
        c.labels()
    });
    (dt.as_secs_f64(), labels)
}

/// Run the full Table-1 grid.
pub fn run(config: &Table1Config) -> (Table, Vec<Table1Row>) {
    let graphs = workloads::load_all(config.scale, None, config.cache);
    let mut rows = Vec::new();
    for g in &graphs {
        let v_max = select_v_max(g);
        let (str_secs, _) = time_str(g, v_max);
        let (_, ro) = time_once(|| readonly_pass(&g.edges.edges));

        let mut baseline_secs = Vec::new();
        let csr = if g.m() <= config.baseline_edge_cap {
            Some(Csr::from_edge_list(&g.edges))
        } else {
            None
        };
        for mut algo in paper_suite(config.seed) {
            let run_it = csr.is_some()
                && algo.practical_for(g.n(), g.m())
                && g.m() <= config.baseline_edge_cap
                && baseline_available(&g.name, algo.tag());
            if run_it {
                let csr = csr.as_ref().unwrap();
                let (_, dt) = time_once(|| algo.detect(csr));
                baseline_secs.push(Some(dt.as_secs_f64()));
            } else {
                baseline_secs.push(None);
            }
        }
        rows.push(Table1Row {
            name: g.name.clone(),
            n: g.n(),
            m: g.m(),
            baseline_secs,
            str_secs,
            readonly_secs: ro.as_secs_f64(),
            v_max,
        });
    }
    (render(&rows, config.scale), rows)
}

/// Render rows in the paper's Table-1 layout (+ readonly column).
pub fn render(rows: &[Table1Row], scale: f64) -> Table {
    let mut t = Table::new(
        &format!("Table 1 — dataset sizes and execution times in seconds (scale {scale})"),
        &["dataset", "|V|", "|E|", "S", "L", "I", "W", "O", "STR", "read", "vmax"],
    );
    for r in rows {
        let mut cells = vec![r.name.clone(), r.n.to_string(), r.m.to_string()];
        for b in &r.baseline_secs {
            cells.push(b.map(fmt_secs).unwrap_or_else(|| "-".into()));
        }
        cells.push(fmt_secs(r.str_secs));
        cells.push(fmt_secs(r.readonly_secs));
        cells.push(r.v_max.to_string());
        t.push_row(cells);
    }
    t
}

/// The paper's headline check: min baseline time / STR time per row.
pub fn speedup_vs_fastest_baseline(row: &Table1Row) -> Option<f64> {
    let fastest = row
        .baseline_secs
        .iter()
        .flatten()
        .fold(f64::INFINITY, |a, &b| a.min(b));
    if fastest.is_finite() {
        Some(fastest / row.str_secs.max(1e-12))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> Table1Config {
        Table1Config { scale: 0.01, cache: false, ..Default::default() }
    }

    #[test]
    fn grid_runs_at_tiny_scale() {
        let (_table, rows) = run(&tiny_config());
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.str_secs > 0.0);
            assert!(r.m > 0);
        }
        // edge counts increase like the paper's rows
        assert!(rows.last().unwrap().m > rows.first().unwrap().m);
    }

    #[test]
    fn str_beats_fastest_baseline_on_every_row() {
        let (_t, rows) = run(&tiny_config());
        for r in &rows {
            if let Some(speedup) = speedup_vs_fastest_baseline(r) {
                assert!(
                    speedup > 1.0,
                    "{}: STR slower than a baseline (speedup {speedup:.2})",
                    r.name
                );
            }
        }
    }

    #[test]
    fn render_has_paper_columns() {
        let (t, _) = run(&Table1Config { scale: 0.005, cache: false, ..Default::default() });
        let s = t.render();
        for col in ["S", "L", "I", "W", "O", "STR"] {
            assert!(s.contains(col), "missing column {col}");
        }
    }
}
