//! Memory accounting for the §4.4 memory-consumption experiment.
//!
//! Two complementary accountings:
//!
//! * **Analytic** — deterministic byte counts from the data-structure
//!   definitions: the STR sketch is `16 B/node` (4+4+8), an edge list is
//!   `16 B/edge` with 64-bit node ids exactly as the paper counts it
//!   (its lower bound for the non-streaming algorithms).
//! * **Allocator** — a counting global allocator
//!   ([`CountingAllocator`]) that the bench binaries install to report
//!   live/peak heap for whole runs, catching anything the analytic
//!   model misses.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Paper's accounting: one edge = two 64-bit node ids.
pub const BYTES_PER_EDGE_STORED: u64 = 16;
/// STR sketch: degree u32 + community u32 + volume u64.
pub const BYTES_PER_NODE_SKETCH: u64 = 16;

/// Analytic footprint of storing the edge list (all baselines' floor).
pub fn edge_list_bytes(m: u64) -> u64 {
    m * BYTES_PER_EDGE_STORED
}

/// Analytic footprint of the streaming sketch.
pub fn sketch_bytes(n: u64) -> u64 {
    n * BYTES_PER_NODE_SKETCH
}

/// Human-readable bytes.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1000.0 && u < UNITS.len() - 1 {
        x /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{x:.1} {}", UNITS[u])
    }
}

/// Counting wrapper around the system allocator. Install in a bench
/// binary with:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: CountingAllocator = CountingAllocator::new();
/// ```
pub struct CountingAllocator {
    live: AtomicU64,
    peak: AtomicU64,
    total: AtomicU64,
}

impl CountingAllocator {
    /// Zeroed counters (const so it can back a `#[global_allocator]`).
    pub const fn new() -> Self {
        Self {
            live: AtomicU64::new(0),
            peak: AtomicU64::new(0),
            total: AtomicU64::new(0),
        }
    }

    /// Bytes currently allocated.
    pub fn live_bytes(&self) -> u64 {
        self.live.load(Ordering::Relaxed)
    }

    /// High-water mark of live bytes.
    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Cumulative bytes ever allocated.
    pub fn total_allocated(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Reset the peak to the current live level (scoped measurements).
    pub fn reset_peak(&self) {
        self.peak.store(self.live_bytes(), Ordering::Relaxed);
    }

    fn on_alloc(&self, size: usize) {
        let live = self.live.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
        self.total.fetch_add(size as u64, Ordering::Relaxed);
        self.peak.fetch_max(live, Ordering::Relaxed);
    }

    fn on_dealloc(&self, size: usize) {
        self.live.fetch_sub(size as u64, Ordering::Relaxed);
    }
}

impl Default for CountingAllocator {
    fn default() -> Self {
        Self::new()
    }
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            self.on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) };
        self.on_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = unsafe { System.realloc(ptr, layout, new_size) };
        if !p.is_null() {
            self.on_dealloc(layout.size());
            self.on_alloc(new_size);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_matches_paper_scale() {
        // paper: Amazon edge list 14.8 MB at 925_872 edges
        let amazon = edge_list_bytes(925_872);
        assert_eq!(amazon, 14_813_952);
        // paper: Friendster edge list 28.9 GB
        let friendster = edge_list_bytes(1_806_067_135);
        assert!((28.8e9..29.1e9).contains(&(friendster as f64)));
    }

    #[test]
    fn sketch_is_much_smaller_than_edges_on_snap_shapes() {
        // Friendster: 65.6M nodes → ~1.05 GB sketch vs 28.9 GB edges
        let sketch = sketch_bytes(65_608_366);
        let edges = edge_list_bytes(1_806_067_135);
        assert!(sketch * 20 < edges);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert!(fmt_bytes(14_813_952).contains("MB"));
        assert!(fmt_bytes(28_897_074_160).contains("GB"));
    }

    #[test]
    fn counting_allocator_tracks_alloc_dealloc() {
        // not installed globally here; exercise the raw hooks
        let a = CountingAllocator::new();
        a.on_alloc(1000);
        a.on_alloc(500);
        assert_eq!(a.live_bytes(), 1500);
        assert_eq!(a.peak_bytes(), 1500);
        a.on_dealloc(1000);
        assert_eq!(a.live_bytes(), 500);
        assert_eq!(a.peak_bytes(), 1500);
        a.reset_peak();
        assert_eq!(a.peak_bytes(), 500);
        assert_eq!(a.total_allocated(), 1500);
    }
}
