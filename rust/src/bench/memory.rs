//! Memory accounting for the §4.4 memory-consumption experiment.
//!
//! Two complementary accountings:
//!
//! * **Analytic** — deterministic byte counts from the data-structure
//!   definitions: the STR sketch is `16 B/node` (4+4+8), an edge list is
//!   `16 B/edge` with 64-bit node ids exactly as the paper counts it
//!   (its lower bound for the non-streaming algorithms).
//! * **Allocator** — a counting global allocator
//!   ([`CountingAllocator`]) that the bench binaries install to report
//!   live/peak heap for whole runs, catching anything the analytic
//!   model misses.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Paper's accounting: one edge = two 64-bit node ids.
pub const BYTES_PER_EDGE_STORED: u64 = 16;
/// STR sketch: degree u32 + community u32 + volume u64.
pub const BYTES_PER_NODE_SKETCH: u64 = 16;
/// Cross-log retained edge: two dense u32 node ids (`graph::edge::Edge`).
pub const BYTES_PER_CROSS_EDGE_RETAINED: u64 = 8;
/// Frozen decision record (endpoint + community, both u32); the cross
/// log keeps two per drained edge while a bounded commit horizon is
/// active, freed together with the edges when the epoch commits.
pub const BYTES_PER_FROZEN_DECISION: u64 = 8;

/// Analytic footprint of storing the edge list (all baselines' floor).
pub fn edge_list_bytes(m: u64) -> u64 {
    m * BYTES_PER_EDGE_STORED
}

/// Analytic footprint of the streaming sketch.
pub fn sketch_bytes(n: u64) -> u64 {
    n * BYTES_PER_NODE_SKETCH
}

/// Expected cross-shard edge fraction under uniform hash-sharding:
/// `1 − 1/shards` of the stream defers to the cross log.
pub fn expected_cross_fraction(shards: u64) -> f64 {
    1.0 - 1.0 / shards.max(1) as f64
}

/// Resident bytes of a cross log holding `retained_edges` edges and
/// `frozen_entries` frozen decision records.
pub fn cross_log_bytes(retained_edges: u64, frozen_entries: u64) -> u64 {
    retained_edges * BYTES_PER_CROSS_EDGE_RETAINED
        + frozen_entries * BYTES_PER_FROZEN_DECISION
}

/// Service cross-log footprint on an `m`-edge stream over `shards`
/// workers with an **unbounded** commit horizon: the whole expected
/// cross fraction stays resident until `finish` (no frozen records are
/// kept — nothing ever commits).
pub fn cross_log_unbounded_bytes(m: u64, shards: u64) -> u64 {
    let cross = (m as f64 * expected_cross_fraction(shards)) as u64;
    cross_log_bytes(cross, 0)
}

/// Service cross-log footprint with commit horizon `h` (cross edges):
/// retention is capped at `h` plus one epoch regardless of `m`, with
/// two frozen decision records per retained drained edge — the
/// Table-2-style figure that shows the bound. The epoch slack mirrors
/// `service::crosslog::epoch_len_for`. `h = 0` follows the CLI's
/// "0 = unbounded" convention and returns the unbounded figure.
pub fn cross_log_bounded_bytes(m: u64, shards: u64, h: u64) -> u64 {
    let horizon = crate::service::CommitHorizon::Edges(h).normalized();
    if horizon.is_unbounded() {
        return cross_log_unbounded_bytes(m, shards);
    }
    let cross = (m as f64 * expected_cross_fraction(shards)) as u64;
    let epoch = crate::service::crosslog::epoch_len_for(horizon);
    let retained = cross.min(h + epoch);
    cross_log_bytes(retained, 2 * retained)
}

/// Human-readable bytes.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1000.0 && u < UNITS.len() - 1 {
        x /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{x:.1} {}", UNITS[u])
    }
}

/// Counting wrapper around the system allocator. Install in a bench
/// binary with:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: CountingAllocator = CountingAllocator::new();
/// ```
pub struct CountingAllocator {
    live: AtomicU64,
    peak: AtomicU64,
    total: AtomicU64,
}

impl CountingAllocator {
    /// Zeroed counters (const so it can back a `#[global_allocator]`).
    pub const fn new() -> Self {
        Self {
            live: AtomicU64::new(0),
            peak: AtomicU64::new(0),
            total: AtomicU64::new(0),
        }
    }

    /// Bytes currently allocated.
    pub fn live_bytes(&self) -> u64 {
        self.live.load(Ordering::Relaxed)
    }

    /// High-water mark of live bytes.
    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Cumulative bytes ever allocated.
    pub fn total_allocated(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Reset the peak to the current live level (scoped measurements).
    pub fn reset_peak(&self) {
        self.peak.store(self.live_bytes(), Ordering::Relaxed);
    }

    fn on_alloc(&self, size: usize) {
        let live = self.live.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
        self.total.fetch_add(size as u64, Ordering::Relaxed);
        self.peak.fetch_max(live, Ordering::Relaxed);
    }

    fn on_dealloc(&self, size: usize) {
        self.live.fetch_sub(size as u64, Ordering::Relaxed);
    }
}

impl Default for CountingAllocator {
    fn default() -> Self {
        Self::new()
    }
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            self.on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) };
        self.on_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = unsafe { System.realloc(ptr, layout, new_size) };
        if !p.is_null() {
            self.on_dealloc(layout.size());
            self.on_alloc(new_size);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_matches_paper_scale() {
        // paper: Amazon edge list 14.8 MB at 925_872 edges
        let amazon = edge_list_bytes(925_872);
        assert_eq!(amazon, 14_813_952);
        // paper: Friendster edge list 28.9 GB
        let friendster = edge_list_bytes(1_806_067_135);
        assert!((28.8e9..29.1e9).contains(&(friendster as f64)));
    }

    #[test]
    fn sketch_is_much_smaller_than_edges_on_snap_shapes() {
        // Friendster: 65.6M nodes → ~1.05 GB sketch vs 28.9 GB edges
        let sketch = sketch_bytes(65_608_366);
        let edges = edge_list_bytes(1_806_067_135);
        assert!(sketch * 20 < edges);
    }

    #[test]
    fn bounded_cross_log_is_independent_of_stream_length() {
        // Friendster-scale stream, 4 shards: unbounded retention tracks
        // the cross fraction (~75% of 1.8B edges), the bounded log stays
        // at h + one epoch whatever m is
        let m = 1_806_067_135u64;
        let unbounded = cross_log_unbounded_bytes(m, 4);
        assert!(unbounded > 10_000_000_000, "{unbounded}");
        let h = 1_000_000u64;
        let bounded = cross_log_bounded_bytes(m, 4, h);
        assert_eq!(bounded, cross_log_bounded_bytes(10 * m, 4, h));
        // h + one epoch edges, 8 B each + two 8 B frozen records
        let epoch = crate::service::crosslog::epoch_len_for(
            crate::service::CommitHorizon::Edges(h),
        );
        assert_eq!(bounded, (h + epoch) * (8 + 16));
        assert!(bounded * 100 < unbounded, "bound must dominate at scale");
    }

    #[test]
    fn zero_horizon_estimate_follows_the_unbounded_convention() {
        // the CLI's --horizon 0 means unbounded; the estimator must not
        // report a tiny capped figure for it
        let m = 1_806_067_135u64;
        assert_eq!(
            cross_log_bounded_bytes(m, 4, 0),
            cross_log_unbounded_bytes(m, 4)
        );
    }

    #[test]
    fn short_streams_never_exceed_their_own_cross_fraction() {
        // when the stream is smaller than the horizon, retention is just
        // the cross fraction — the cap never inflates the estimate
        let m = 1_000u64;
        assert_eq!(
            cross_log_bounded_bytes(m, 4, 1_000_000),
            cross_log_bytes(750, 1500)
        );
        assert_eq!(expected_cross_fraction(1), 0.0);
        assert_eq!(expected_cross_fraction(4), 0.75);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert!(fmt_bytes(14_813_952).contains("MB"));
        assert!(fmt_bytes(28_897_074_160).contains("GB"));
    }

    #[test]
    fn counting_allocator_tracks_alloc_dealloc() {
        // not installed globally here; exercise the raw hooks
        let a = CountingAllocator::new();
        a.on_alloc(1000);
        a.on_alloc(500);
        assert_eq!(a.live_bytes(), 1500);
        assert_eq!(a.peak_bytes(), 1500);
        a.on_dealloc(1000);
        assert_eq!(a.live_bytes(), 500);
        assert_eq!(a.peak_bytes(), 1500);
        a.reset_peak();
        assert_eq!(a.peak_bytes(), 500);
        assert_eq!(a.total_allocated(), 1500);
    }
}
