//! Two-pass refinement — an extension beyond the paper (in the spirit
//! of its §5 future work).
//!
//! Pass 1 is the paper's streaming algorithm; its known failure mode is
//! *over-fragmentation*: the volume threshold stops growth, so one true
//! community often ends up split across several detected ones (visible
//! in Table 2 as STR's F1 gap to Louvain on the small graphs).
//!
//! Pass 2 re-streams the edges once more, accumulating only the
//! *community-level* weighted graph (one counter per pair of detected
//! communities that share an edge), and runs Louvain on that coarse
//! graph — which is tiny (C communities, C ≪ n), so the cost of the
//! modularity optimisation the paper rules out at node level becomes
//! negligible at community level. Memory stays far below the edge list:
//! `O(n + #coarse-edges)`.
//!
//! The result merges fragments without touching per-node decisions:
//! final label = Louvain label of the pass-1 community. The A1 ablation
//! bench and the unit tests quantify the F1/modularity gain.

use std::collections::HashMap;

use crate::baselines::louvain::cluster_weighted;
use crate::graph::edge::Edge;

/// Refine pass-1 `labels` by clustering the coarse community graph that
/// a second pass over `edges` induces. Returns the composed labels.
pub fn refine_two_pass(edges: &[Edge], labels: &[u32], seed: u64) -> Vec<u32> {
    // dense-remap pass-1 communities
    let mut dense: HashMap<u32, u32> = HashMap::new();
    let mut of = |l: u32, dense: &mut HashMap<u32, u32>| -> u32 {
        let next = dense.len() as u32;
        *dense.entry(l).or_insert(next)
    };
    let node_comm: Vec<u32> = labels.iter().map(|&l| of(l, &mut dense)).collect();
    let c = dense.len();
    if c <= 1 {
        return labels.to_vec();
    }

    // coarse weighted graph (second streaming pass; self-loops carry 2x
    // internal weight per the aggregation convention)
    let mut weights: HashMap<(u32, u32), f64> = HashMap::new();
    for e in edges {
        if e.is_self_loop() {
            continue;
        }
        let (a, b) = (
            node_comm[e.u as usize],
            node_comm[e.v as usize],
        );
        if a == b {
            *weights.entry((a, a)).or_insert(0.0) += 2.0;
        } else {
            let key = if a < b { (a, b) } else { (b, a) };
            *weights.entry(key).or_insert(0.0) += 1.0;
        }
    }
    let mut adj: Vec<Vec<(u32, f64)>> = vec![Vec::new(); c];
    // deterministic construction: sorted key order
    let mut items: Vec<((u32, u32), f64)> = weights.into_iter().collect();
    items.sort_unstable_by_key(|&(k, _)| k);
    for ((a, b), w) in items {
        adj[a as usize].push((b, w));
        if a != b {
            adj[b as usize].push((a, w));
        }
    }
    for run in &mut adj {
        run.sort_unstable_by_key(|&(v, _)| v);
    }

    // Louvain on the coarse graph, then compose
    let coarse = cluster_weighted(adj, seed);
    node_comm.iter().map(|&cc| coarse[cc as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::algorithm::cluster_edges;
    use crate::graph::generators::sbm::{self, SbmConfig};
    use crate::metrics::{f1::average_f1_labels, modularity::modularity};

    #[test]
    fn merges_fragmented_triangle_pair() {
        // a 6-cycle plus chords forming two dense halves; run STR with a
        // tiny v_max to force fragmentation, then refine
        let g = sbm::generate(&SbmConfig::equal(4, 30, 0.5, 0.005, 51));
        let fragmented = cluster_edges(g.n(), &g.edges.edges, 4); // tiny v_max
        let refined = refine_two_pass(&g.edges.edges, &fragmented, 1);
        let count = |l: &[u32]| {
            l.iter().collect::<std::collections::HashSet<_>>().len()
        };
        assert!(count(&refined) < count(&fragmented));
    }

    #[test]
    fn improves_modularity_on_sbm() {
        let g = sbm::generate(&SbmConfig::equal(8, 40, 0.35, 0.005, 52));
        let pass1 = cluster_edges(g.n(), &g.edges.edges, 32);
        let refined = refine_two_pass(&g.edges.edges, &pass1, 2);
        let q1 = modularity(g.n(), &g.edges.edges, &pass1);
        let q2 = modularity(g.n(), &g.edges.edges, &refined);
        assert!(q2 >= q1 - 1e-9, "refinement lost modularity: {q1} → {q2}");
    }

    #[test]
    fn improves_f1_on_fragmenting_vmax() {
        let g = sbm::generate(&SbmConfig::equal(8, 40, 0.35, 0.005, 53));
        let truth = g.truth.to_labels(g.n());
        let pass1 = cluster_edges(g.n(), &g.edges.edges, 16);
        let refined = refine_two_pass(&g.edges.edges, &pass1, 3);
        let f1_1 = average_f1_labels(&pass1, &truth);
        let f1_2 = average_f1_labels(&refined, &truth);
        assert!(f1_2 > f1_1, "refinement did not help: {f1_1} → {f1_2}");
    }

    #[test]
    fn noop_on_single_community() {
        let edges = vec![Edge::new(0, 1), Edge::new(1, 2)];
        let labels = vec![7, 7, 7];
        assert_eq!(refine_two_pass(&edges, &labels, 1), labels);
    }

    #[test]
    fn deterministic() {
        let g = sbm::generate(&SbmConfig::equal(5, 30, 0.4, 0.01, 54));
        let pass1 = cluster_edges(g.n(), &g.edges.edges, 16);
        let a = refine_two_pass(&g.edges.edges, &pass1, 9);
        let b = refine_two_pass(&g.edges.edges, &pass1, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn composition_preserves_pass1_cohesion() {
        // nodes sharing a pass-1 community always share a refined one
        let g = sbm::generate(&SbmConfig::equal(5, 30, 0.4, 0.01, 55));
        let pass1 = cluster_edges(g.n(), &g.edges.edges, 16);
        let refined = refine_two_pass(&g.edges.edges, &pass1, 4);
        for i in 0..g.n() {
            for j in (i + 1)..g.n() {
                if pass1[i] == pass1[j] {
                    assert_eq!(refined[i], refined[j], "split a pass-1 community");
                }
            }
        }
    }
}
