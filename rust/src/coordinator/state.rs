//! The paper's sketch: exactly three integers per node.
//!
//! Algorithm 1 keeps dictionaries `d` (degree), `c` (community) and `v`
//! (community volume). Node ids here are dense `u32`, so the dictionaries
//! become three flat arrays — the same representation the authors' C++
//! implementation uses. Community ids live in the node-id space: a
//! node's initial community is itself, so `v` is indexed by community id
//! without a separate allocator (the paper's fresh-index counter `k` is
//! an artifact of its dictionary formulation; using the node's own id is
//! the standard equivalent choice and keeps `v` the same size as `c`).
//!
//! Memory: 4 + 4 + 8 bytes/node (volume is u64 so the billion-edge
//! regime cannot overflow) — the paper's "three integers per node".

/// Sketch state for one streaming run.
#[derive(Debug, Clone)]
pub struct StreamState {
    /// d_i — degree observed so far.
    pub degree: Vec<u32>,
    /// c_i — current community (u32::MAX = node not yet seen).
    pub community: Vec<u32>,
    /// v_k — community volume, indexed by community id (= node id space).
    pub volume: Vec<u64>,
    /// Edges processed (t).
    pub edges_processed: u64,
}

/// Sentinel community id for nodes the stream has not mentioned.
pub const UNSEEN: u32 = u32::MAX;

impl StreamState {
    /// Pre-sized for `n` nodes (grows on demand if the stream mentions
    /// larger ids).
    pub fn new(n: usize) -> Self {
        Self {
            degree: vec![0; n],
            community: vec![UNSEEN; n],
            volume: vec![0; n],
            edges_processed: 0,
        }
    }

    /// Current node-space size.
    pub fn n(&self) -> usize {
        self.degree.len()
    }

    /// Grow to hold node id `i`.
    #[inline]
    pub fn ensure(&mut self, i: u32) {
        let need = i as usize + 1;
        if need > self.degree.len() {
            self.degree.resize(need, 0);
            self.community.resize(need, UNSEEN);
            self.volume.resize(need, 0);
        }
    }

    /// First-touch initialisation: a node starts in its own community.
    #[inline]
    pub fn touch(&mut self, i: u32) {
        if self.community[i as usize] == UNSEEN {
            self.community[i as usize] = i;
        }
    }

    /// Current community labels, with unseen nodes as singletons.
    pub fn labels(&self) -> Vec<u32> {
        self.community
            .iter()
            .enumerate()
            .map(|(i, &c)| if c == UNSEEN { i as u32 } else { c })
            .collect()
    }

    /// Sketch bytes (the memory-consumption experiment, §4.4).
    pub fn memory_bytes(&self) -> usize {
        self.degree.len() * 4 + self.community.len() * 4 + self.volume.len() * 8
    }

    /// Sum of community volumes — must equal 2 · edges_processed
    /// (invariant checked by the property tests).
    pub fn total_volume(&self) -> u64 {
        self.volume.iter().sum()
    }

    /// Recompute every community volume from membership:
    /// `v_k = Σ_{i : c_i = k} d_i`.
    ///
    /// This equality is an invariant of the decision rule (each degree
    /// increment is paired with a volume increment on the node's current
    /// community, and a join moves exactly the joining node's degree),
    /// and it survives disjoint merges. The service's incremental drain
    /// relies on it: after folding fresh shard degrees and the frozen
    /// cross-edge decisions into one sketch, the volumes are *derived*
    /// in one O(n) pass instead of being replayed edge by edge.
    pub fn recompute_volumes(&mut self) {
        self.volume.iter_mut().for_each(|v| *v = 0);
        for i in 0..self.community.len() {
            let c = self.community[i];
            if c != UNSEEN {
                self.volume[c as usize] += self.degree[i] as u64;
            }
        }
    }

    /// Number of non-empty communities.
    pub fn community_count(&self) -> usize {
        let mut seen = vec![false; self.n()];
        let mut count = 0;
        for (i, &c) in self.community.iter().enumerate() {
            let c = if c == UNSEEN {
                continue;
            } else {
                c as usize
            };
            if !seen[c] {
                seen[c] = true;
                count += 1;
            }
            let _ = i;
        }
        count
    }

    /// (volume, size) per non-empty community, sorted by volume
    /// descending. Used by selection and reporting.
    pub fn community_volumes(&self) -> Vec<(u32, u64, u32)> {
        let n = self.n();
        let mut size = vec![0u32; n];
        for &c in &self.community {
            if c != UNSEEN {
                size[c as usize] += 1;
            }
        }
        let mut out: Vec<(u32, u64, u32)> = (0..n)
            .filter(|&k| size[k] > 0)
            .map(|k| (k as u32, self.volume[k], size[k]))
            .collect();
        out.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_initialises_own_community() {
        let mut st = StreamState::new(4);
        st.touch(2);
        assert_eq!(st.community[2], 2);
        st.community[2] = 0;
        st.touch(2); // idempotent — must not reset
        assert_eq!(st.community[2], 0);
    }

    #[test]
    fn ensure_grows() {
        let mut st = StreamState::new(2);
        st.ensure(10);
        assert_eq!(st.n(), 11);
        assert_eq!(st.community[10], UNSEEN);
    }

    #[test]
    fn labels_default_unseen_to_singletons() {
        let mut st = StreamState::new(3);
        st.touch(0);
        st.community[0] = 2;
        assert_eq!(st.labels(), vec![2, 1, 2]);
    }

    #[test]
    fn recompute_volumes_matches_membership_sums() {
        let mut st = StreamState::new(5);
        st.degree = vec![3, 1, 2, 0, 4];
        st.community = vec![0, 0, 2, UNSEEN, 2];
        st.volume = vec![99, 99, 99, 99, 99]; // garbage in
        st.recompute_volumes();
        assert_eq!(st.volume, vec![4, 0, 6, 0, 0]);
        assert_eq!(st.total_volume(), 10);
    }

    #[test]
    fn memory_is_sixteen_bytes_per_node() {
        let st = StreamState::new(1000);
        assert_eq!(st.memory_bytes(), 16_000);
    }

    #[test]
    fn community_volumes_sorted_desc() {
        let mut st = StreamState::new(4);
        for i in 0..4 {
            st.touch(i);
        }
        st.community = vec![0, 0, 2, 3];
        st.volume = vec![10, 0, 30, 5];
        let cv = st.community_volumes();
        assert_eq!(cv[0], (2, 30, 1));
        assert_eq!(cv[1], (0, 10, 2));
        assert_eq!(cv[2], (3, 5, 1));
    }
}
