//! Algorithm 1 — the single-pass streaming decision rule.
//!
//! For each arriving edge `(i, j)`:
//!
//! 1. first-touch: unseen endpoints start in their own community;
//! 2. `d_i += 1`, `d_j += 1`, `v[c_i] += 1`, `v[c_j] += 1`;
//! 3. if `v[c_i] ≤ v_max` and `v[c_j] ≤ v_max`, the node whose community
//!    has the *smaller* volume joins the other's community, moving its
//!    degree of volume with it.
//!
//! Theorem 1 justifies the rule: when the threshold holds, the join
//! increases the streaming modularity `Q_{t+1}`.
//!
//! [`StrConfig`] also exposes the ablation axes studied by
//! `benches/ablations.rs`: the tie-break direction, the threshold form,
//! and a size-based (rather than volume-based) condition — each a design
//! choice the paper fixes; the ablations show the paper's choices are
//! the right defaults.

use crate::graph::edge::Edge;
use crate::stream::source::EdgeSource;
use crate::util::rng::Xoshiro256;

use super::state::StreamState;

/// Threshold predicate variants (ablation A1; `BothAtMost` is the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ThresholdRule {
    /// Paper: `v[c_i] ≤ v_max && v[c_j] ≤ v_max`.
    BothAtMost,
    /// `v[c_i] + v[c_j] ≤ 2 v_max`.
    SumAtMost,
    /// Only the joining (smaller) side must satisfy `≤ v_max`.
    SmallerAtMost,
}

/// Tie-break when `v[c_i] == v[c_j]` (paper: j joins i, i.e. [`JToI`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TieBreak {
    /// Paper's arbitrary deterministic choice.
    JToI,
    /// Mirror image of the paper's choice (i joins j).
    IToJ,
    /// The paper's suggested randomised variant.
    Random,
}

/// Configuration for one streaming run.
#[derive(Debug, Clone)]
pub struct StrConfig {
    /// The single parameter of the paper.
    pub v_max: u64,
    /// Threshold predicate (ablation axis).
    pub threshold: ThresholdRule,
    /// Tie-break rule on equal volumes.
    pub tie_break: TieBreak,
    /// Ablation: use community *size* (node count) instead of volume in
    /// the threshold test (decisions still move volume).
    pub size_condition: bool,
    /// Seed for [`TieBreak::Random`].
    pub seed: u64,
}

impl StrConfig {
    /// Paper defaults for threshold `v_max` (BothAtMost, JToI, volume-based).
    pub fn new(v_max: u64) -> Self {
        Self {
            v_max,
            threshold: ThresholdRule::BothAtMost,
            tie_break: TieBreak::JToI,
            size_condition: false,
            seed: 0,
        }
    }
}

/// Per-run decision counters (observability; negligible cost).
#[derive(Debug, Clone, Copy, Default)]
pub struct StrStats {
    /// Edges processed.
    pub edges: u64,
    /// Accepted joins.
    pub joins: u64,
    /// Edges arriving within one community.
    pub same_community: u64,
    /// Joins rejected by the threshold.
    pub threshold_rejects: u64,
    /// Self-loops ignored.
    pub self_loops_skipped: u64,
}

/// Streaming clusterer: [`StreamState`] + the decision rule.
///
/// One instance is one pass of the paper's Algorithm 1: feed it each
/// edge exactly once (in stream order) and read the partition off the
/// sketch at any point.
///
/// ```
/// use streamcom::coordinator::algorithm::{StrConfig, StreamingClusterer};
/// use streamcom::graph::edge::Edge;
///
/// let mut c = StreamingClusterer::new(2, StrConfig::new(8));
/// c.process_edge(Edge::new(0, 1));
/// // first edge: both endpoints unseen, volumes tie → j joins i
/// assert_eq!(c.labels(), vec![0, 0]);
/// // the conservation invariant Σ v_k = 2t holds after every edge
/// assert_eq!(c.state.total_volume(), 2 * c.state.edges_processed);
/// ```
#[derive(Debug, Clone)]
pub struct StreamingClusterer {
    /// The three-integers-per-node sketch.
    pub state: StreamState,
    /// The run's configuration (threshold, tie-break, ablation axes).
    pub config: StrConfig,
    /// Per-run decision counters.
    pub stats: StrStats,
    /// Community sizes, maintained only under `size_condition` (the
    /// paper's sketch does not need them).
    sizes: Vec<u32>,
    rng: Xoshiro256,
}

impl StreamingClusterer {
    /// Fresh sketch over `n` pre-sized nodes (grows on demand).
    pub fn new(n: usize, config: StrConfig) -> Self {
        Self::with_state(StreamState::new(n), config)
    }

    /// Resume the decision rule on an existing sketch — the leader's
    /// entry point: merge shard states (or restore a persisted sketch),
    /// then keep streaming through it. Under `size_condition` the
    /// community sizes are rebuilt from membership in one pass; decision
    /// counters start fresh.
    pub fn with_state(state: StreamState, config: StrConfig) -> Self {
        let sizes = if config.size_condition {
            let mut sizes = vec![0u32; state.n()];
            for &c in &state.community {
                if c != super::state::UNSEEN {
                    sizes[c as usize] += 1;
                }
            }
            sizes
        } else {
            Vec::new()
        };
        let rng = Xoshiro256::new(config.seed);
        Self { state, config, stats: StrStats::default(), sizes, rng }
    }

    /// Process a single edge (the paper's loop body).
    ///
    /// Growth (`ensure`) runs here per edge; the chunked hot loop
    /// ([`process_chunk`](Self::process_chunk)) hoists it to one
    /// pre-scan per chunk instead.
    #[inline]
    pub fn process_edge(&mut self, e: Edge) {
        if e.is_self_loop() {
            self.stats.self_loops_skipped += 1;
            return;
        }
        self.state.ensure(e.u.max(e.v));
        if self.config.size_condition {
            let need = self.state.n();
            if self.sizes.len() < need {
                self.sizes.resize(need, 0);
            }
        }
        self.process_edge_ensured(e);
    }

    /// The decision rule with growth hoisted out. Caller contract:
    /// `state.ensure(max(e.u, e.v))` has already run (and, under
    /// `size_condition`, `sizes` has been resized to `state.n()`).
    ///
    /// §Perf note: under that contract every index below is in bounds
    /// by construction (`i, j < n`; community ids live in the node-id
    /// space so `ci, cj < n` too). The accesses use `get_unchecked` —
    /// measured ~15% of per-edge cost in the bounds-checked version
    /// (EXPERIMENTS.md §Perf).
    #[inline]
    fn process_edge_ensured(&mut self, e: Edge) {
        if e.is_self_loop() {
            self.stats.self_loops_skipped += 1;
            return;
        }
        let st = &mut self.state;
        debug_assert!((e.u.max(e.v) as usize) < st.n(), "caller skipped ensure");
        let (i, j) = (e.u as usize, e.v as usize);

        // SAFETY: the caller contract (checked above in debug builds)
        // guarantees ensure() grew all three arrays to max(i, j) + 1,
        // and community values are node ids < n (set only from e.u /
        // e.v / prior community ids).
        let (ci, cj, vi, vj) = unsafe {
            // first touch: own community (size 1)
            if *st.community.get_unchecked(i) == super::state::UNSEEN {
                *st.community.get_unchecked_mut(i) = e.u;
                if self.config.size_condition {
                    self.sizes[i] = 1;
                }
            }
            if *st.community.get_unchecked(j) == super::state::UNSEEN {
                *st.community.get_unchecked_mut(j) = e.v;
                if self.config.size_condition {
                    self.sizes[j] = 1;
                }
            }

            *st.degree.get_unchecked_mut(i) += 1;
            *st.degree.get_unchecked_mut(j) += 1;
            let ci = *st.community.get_unchecked(i) as usize;
            let cj = *st.community.get_unchecked(j) as usize;
            *st.volume.get_unchecked_mut(ci) += 1;
            *st.volume.get_unchecked_mut(cj) += 1;
            (ci, cj, *st.volume.get_unchecked(ci), *st.volume.get_unchecked(cj))
        };
        st.edges_processed += 1;
        self.stats.edges += 1;

        if ci == cj {
            self.stats.same_community += 1;
            return;
        }

        let (mi, mj) = if self.config.size_condition {
            (self.sizes[ci] as u64, self.sizes[cj] as u64)
        } else {
            (vi, vj)
        };
        let vmax = self.config.v_max;
        let pass = match self.config.threshold {
            ThresholdRule::BothAtMost => mi <= vmax && mj <= vmax,
            ThresholdRule::SumAtMost => mi + mj <= 2 * vmax,
            ThresholdRule::SmallerAtMost => mi.min(mj) <= vmax,
        };
        if !pass {
            self.stats.threshold_rejects += 1;
            return;
        }

        // which endpoint moves? paper: smaller volume joins larger;
        // equality resolved by the tie-break rule.
        let i_joins = match vi.cmp(&vj) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => match self.config.tie_break {
                TieBreak::JToI => false,
                TieBreak::IToJ => true,
                TieBreak::Random => self.rng.bernoulli(0.5),
            },
        };

        if i_joins {
            let d = st.degree[i] as u64;
            st.volume[cj] += d;
            st.volume[ci] -= d;
            st.community[i] = cj as u32;
            if self.config.size_condition {
                self.sizes[cj] += 1;
                self.sizes[ci] -= 1;
            }
        } else {
            let d = st.degree[j] as u64;
            st.volume[ci] += d;
            st.volume[cj] -= d;
            st.community[j] = ci as u32;
            if self.config.size_condition {
                self.sizes[ci] += 1;
                self.sizes[cj] -= 1;
            }
        }
        self.stats.joins += 1;
    }

    /// Process a chunk (the hot loop of the chunked pipeline).
    ///
    /// §Perf: the chunk's max node id is pre-scanned so the sketch
    /// grows (`ensure`) **once per chunk** instead of per edge, keeping
    /// the per-edge core to the paper's three-array update with no
    /// growth checks. Pre-growing to the chunk max can size the sketch
    /// slightly earlier than the edge-at-a-time path would (e.g. ids
    /// seen only on skipped self-loops later in the chunk); that never
    /// changes a label — fresh slots are UNSEEN singletons — and the
    /// parity suites pin chunked ≡ per-edge ≡ sequential bit-for-bit.
    #[inline]
    pub fn process_chunk(&mut self, chunk: &[Edge]) {
        let Some(max_id) = chunk.iter().map(|e| e.u.max(e.v)).max() else {
            return; // empty chunk: nothing to grow, nothing to process
        };
        self.state.ensure(max_id);
        if self.config.size_condition {
            let need = self.state.n();
            if self.sizes.len() < need {
                self.sizes.resize(need, 0);
            }
        }
        for &e in chunk {
            self.process_edge_ensured(e);
        }
    }

    /// Drain an entire source.
    pub fn run<S: EdgeSource>(&mut self, source: &mut S, batch: usize) {
        let mut buf = Vec::with_capacity(batch);
        while source.next_batch(&mut buf) > 0 {
            self.process_chunk(&buf);
        }
    }

    /// Final community labels.
    pub fn labels(&self) -> Vec<u32> {
        self.state.labels()
    }
}

/// One-call convenience over an in-memory edge slice.
pub fn cluster_edges(n: usize, edges: &[Edge], v_max: u64) -> Vec<u32> {
    let mut c = StreamingClusterer::new(n, StrConfig::new(v_max));
    c.process_chunk(edges);
    c.labels()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two triangles bridged by one edge — the canonical two-community
    /// toy. Stream order: intra edges first (they are "early").
    fn two_triangles() -> (usize, Vec<Edge>) {
        let edges = vec![
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(0, 2),
            Edge::new(3, 4),
            Edge::new(4, 5),
            Edge::new(3, 5),
            Edge::new(2, 3), // bridge
        ];
        (6, edges)
    }

    #[test]
    fn separates_two_triangles() {
        let (n, edges) = two_triangles();
        let labels = cluster_edges(n, &edges, 4);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_eq!(labels[4], labels[5]);
        assert_ne!(labels[0], labels[3], "bridge must not merge: {labels:?}");
    }

    #[test]
    fn huge_vmax_merges_aggressively() {
        // STR moves *nodes*, never whole communities, so even with an
        // unbounded threshold the bridge only pulls node 3 across — the
        // partition coarsens but need not collapse to one label.
        let (n, edges) = two_triangles();
        let labels = cluster_edges(n, &edges, 1_000_000);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[0], labels[2]);
        assert_eq!(labels[0], labels[3], "bridge join must happen: {labels:?}");
        let distinct: std::collections::HashSet<_> = labels.iter().collect();
        assert!(distinct.len() <= 2, "{labels:?}");
    }

    #[test]
    fn vmax_one_mostly_singletons() {
        // v_max = 1: after the first update volumes are already 1 each,
        // so the very first edge joins (1 <= 1) but later edges cannot.
        let (n, edges) = two_triangles();
        let labels = cluster_edges(n, &edges, 1);
        // at least nodes of different triangles never merge
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn volume_conservation_invariant() {
        let (n, edges) = two_triangles();
        let mut c = StreamingClusterer::new(n, StrConfig::new(4));
        for (t, &e) in edges.iter().enumerate() {
            c.process_edge(e);
            assert_eq!(
                c.state.total_volume(),
                2 * (t as u64 + 1),
                "volume conservation broken at t={t}"
            );
        }
    }

    #[test]
    fn paper_walkthrough_first_edge() {
        // first edge (0,1): both unseen, d=1 each, v0=v1=1; 1<=vmax and
        // tie → j joins i (paper line 15-18): c_1 = c_0 = 0,
        // v0 = 1 + d_j = 2, v1 = 0.
        let mut c = StreamingClusterer::new(2, StrConfig::new(8));
        c.process_edge(Edge::new(0, 1));
        assert_eq!(c.state.community, vec![0, 0]);
        assert_eq!(c.state.volume, vec![2, 0]);
        assert_eq!(c.state.degree, vec![1, 1]);
    }

    #[test]
    fn tie_break_itoj_mirrors() {
        let mut cfg = StrConfig::new(8);
        cfg.tie_break = TieBreak::IToJ;
        let mut c = StreamingClusterer::new(2, cfg);
        c.process_edge(Edge::new(0, 1));
        assert_eq!(c.state.community, vec![1, 1]);
    }

    #[test]
    fn with_state_resumes_exactly_where_the_sketch_left_off() {
        let mut a = StreamingClusterer::new(2, StrConfig::new(8));
        a.process_edge(Edge::new(0, 1));
        let mut resumed = StreamingClusterer::with_state(a.state.clone(), StrConfig::new(8));
        resumed.process_edge(Edge::new(1, 2));

        let mut oneshot = StreamingClusterer::new(3, StrConfig::new(8));
        oneshot.process_edge(Edge::new(0, 1));
        oneshot.process_edge(Edge::new(1, 2));

        assert_eq!(resumed.state.community, oneshot.state.community);
        assert_eq!(resumed.state.volume, oneshot.state.volume);
        assert_eq!(resumed.state.edges_processed, oneshot.state.edges_processed);
    }

    #[test]
    fn process_chunk_matches_per_edge_processing() {
        // the chunked loop pre-grows to the chunk max; the sketch it
        // produces must match edge-at-a-time processing exactly
        use crate::graph::generators::sbm::{self, SbmConfig};
        let g = sbm::generate(&SbmConfig::equal(6, 25, 0.35, 0.01, 77));
        for size_condition in [false, true] {
            let mut cfg = StrConfig::new(16);
            cfg.size_condition = size_condition;
            let mut per_edge = StreamingClusterer::new(0, cfg.clone());
            for &e in &g.edges.edges {
                per_edge.process_edge(e);
            }
            let mut chunked = StreamingClusterer::new(0, cfg);
            for chunk in g.edges.edges.chunks(37) {
                chunked.process_chunk(chunk);
            }
            assert_eq!(per_edge.state.community, chunked.state.community);
            assert_eq!(per_edge.state.degree, chunked.state.degree);
            assert_eq!(per_edge.state.volume, chunked.state.volume);
            assert_eq!(per_edge.stats.joins, chunked.stats.joins);
        }
    }

    #[test]
    fn prescan_growth_from_self_loops_never_changes_labels() {
        // a chunk whose max id appears only on a skipped self-loop
        // grows the sketch early; the extra slots must stay UNSEEN
        // singletons and the decision stream must be untouched
        let mut c = StreamingClusterer::new(0, StrConfig::new(8));
        c.process_chunk(&[Edge::new(0, 1), Edge::new(9, 9)]);
        assert_eq!(c.stats.self_loops_skipped, 1);
        assert_eq!(c.state.edges_processed, 1);
        let labels = c.labels();
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[9], 9, "self-loop id must stay a singleton");
    }

    #[test]
    fn empty_chunk_is_a_no_op() {
        let mut c = StreamingClusterer::new(0, StrConfig::new(8));
        c.process_chunk(&[]);
        assert_eq!(c.state.n(), 0);
        assert_eq!(c.state.edges_processed, 0);
    }

    #[test]
    fn self_loops_are_skipped() {
        let mut c = StreamingClusterer::new(2, StrConfig::new(8));
        c.process_edge(Edge::new(1, 1));
        assert_eq!(c.state.edges_processed, 0);
        assert_eq!(c.stats.self_loops_skipped, 1);
    }

    #[test]
    fn stats_partition_edge_outcomes() {
        let (n, edges) = two_triangles();
        let mut c = StreamingClusterer::new(n, StrConfig::new(4));
        c.process_chunk(&edges);
        let s = c.stats;
        assert_eq!(s.edges, 7);
        assert_eq!(s.joins + s.same_community + s.threshold_rejects, s.edges);
    }

    #[test]
    fn parallel_edges_counted_independently() {
        // multigraph: same edge twice — second is intra-community
        let mut c = StreamingClusterer::new(2, StrConfig::new(8));
        c.process_edge(Edge::new(0, 1));
        c.process_edge(Edge::new(0, 1));
        assert_eq!(c.state.edges_processed, 2);
        assert_eq!(c.stats.same_community, 1);
        assert_eq!(c.state.total_volume(), 4);
    }

    #[test]
    fn grows_beyond_initial_n() {
        let mut c = StreamingClusterer::new(0, StrConfig::new(8));
        c.process_edge(Edge::new(100, 200));
        assert_eq!(c.state.n(), 201);
        assert_eq!(c.labels()[100], c.labels()[200]);
    }

    #[test]
    fn sbm_recovers_planted_partition_decently() {
        use crate::graph::generators::sbm::{self, SbmConfig};
        let g = sbm::generate(&SbmConfig::equal(10, 50, 0.4, 0.002, 123));
        let labels = cluster_edges(g.n(), &g.edges.edges, 64);
        // measure purity: majority-truth fraction within detected comms
        let truth = g.truth.to_labels(g.n());
        let mut by_comm: std::collections::HashMap<u32, Vec<u32>> = Default::default();
        for (i, &l) in labels.iter().enumerate() {
            by_comm.entry(l).or_default().push(truth[i]);
        }
        let mut pure = 0usize;
        let mut total = 0usize;
        for (_, members) in by_comm {
            let mut counts: std::collections::HashMap<u32, usize> = Default::default();
            for t in &members {
                *counts.entry(*t).or_default() += 1;
            }
            pure += counts.values().max().copied().unwrap_or(0);
            total += members.len();
        }
        let purity = pure as f64 / total as f64;
        assert!(purity > 0.8, "purity={purity}");
    }
}
