//! Dynamic-graph extension (§5 future work): edge deletions.
//!
//! The paper's algorithm is insert-only; §5 notes that "modifications to
//! the algorithm design could be made to handle events such as edge
//! deletions". This module implements the natural such modification:
//!
//! * **Insert** — exactly Algorithm 1.
//! * **Delete(i, j)** — reverse the sketch updates: `d_i -= 1`,
//!   `d_j -= 1`, `v[c_i] -= 1`, `v[c_j] -= 1`. No community split is
//!   attempted (splits need edge memory, which the 3-int sketch
//!   deliberately lacks); instead a node whose degree returns to zero is
//!   *evicted* to its own singleton community, and the eviction moves no
//!   volume (its remaining volume contribution is zero by then).
//!
//! The sketch stays consistent: `Σ v_k = 2 · (inserts − deletes)` always
//! holds, and a deleted edge that was never inserted is rejected.
//! Deleting all edges returns every node to a singleton.
//!
//! The quality consequence of deletions-without-splits is measured by
//! `benches/ablations.rs::dynamic_churn` (detection degrades gracefully
//! with churn rate instead of collapsing).

use crate::graph::edge::Edge;

use super::algorithm::{StrConfig, StreamingClusterer};
use super::state::UNSEEN;

/// A dynamic stream event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// Add one edge (exactly Algorithm 1).
    Insert(Edge),
    /// Remove one previously-inserted edge.
    Delete(Edge),
}

/// Errors from dynamic processing.
#[derive(Debug, PartialEq, Eq)]
pub enum DynamicError {
    /// Deleting an edge whose endpoints were never seen / have no degree.
    DeleteUnknown(Edge),
}

/// Insert-and-delete streaming clusterer.
#[derive(Debug, Clone)]
pub struct DynamicClusterer {
    inner: StreamingClusterer,
    /// Insert events applied.
    pub inserts: u64,
    /// Delete events applied.
    pub deletes: u64,
}

impl DynamicClusterer {
    /// Empty dynamic clusterer over `n` pre-sized nodes.
    pub fn new(n: usize, config: StrConfig) -> Self {
        Self { inner: StreamingClusterer::new(n, config), inserts: 0, deletes: 0 }
    }

    /// The underlying sketch.
    pub fn state(&self) -> &super::state::StreamState {
        &self.inner.state
    }

    /// Current community labels (unseen nodes as singletons).
    pub fn labels(&self) -> Vec<u32> {
        self.inner.labels()
    }

    /// Net edges currently in the graph.
    pub fn live_edges(&self) -> u64 {
        self.inserts - self.deletes
    }

    /// Apply one insert/delete event.
    pub fn apply(&mut self, event: Event) -> Result<(), DynamicError> {
        match event {
            Event::Insert(e) => {
                self.inner.process_edge(e);
                if !e.is_self_loop() {
                    self.inserts += 1;
                }
                Ok(())
            }
            Event::Delete(e) => self.delete(e),
        }
    }

    fn delete(&mut self, e: Edge) -> Result<(), DynamicError> {
        if e.is_self_loop() {
            return Ok(());
        }
        let st = &mut self.inner.state;
        let (i, j) = (e.u as usize, e.v as usize);
        if i >= st.n()
            || j >= st.n()
            || st.degree[i] == 0
            || st.degree[j] == 0
            || st.community[i] == UNSEEN
            || st.community[j] == UNSEEN
        {
            return Err(DynamicError::DeleteUnknown(e));
        }
        st.degree[i] -= 1;
        st.degree[j] -= 1;
        let ci = st.community[i] as usize;
        let cj = st.community[j] as usize;
        debug_assert!(st.volume[ci] > 0 && st.volume[cj] > 0);
        st.volume[ci] = st.volume[ci].saturating_sub(1);
        st.volume[cj] = st.volume[cj].saturating_sub(1);
        st.edges_processed = st.edges_processed.saturating_sub(1);
        self.deletes += 1;

        // eviction: an isolated node returns to its own community
        for (node, comm) in [(i, ci), (j, cj)] {
            if st.degree[node] == 0 && comm != node {
                st.community[node] = node as u32;
            }
        }
        Ok(())
    }

    /// Insert a batch of edges through the same chunk-processing spine
    /// the sharded service's router dispatches to
    /// (`StreamingClusterer::process_chunk`): one pre-grow pass over
    /// the batch, then the exact per-edge algorithm. Equivalent to
    /// applying [`Event::Insert`] per edge — inserts never fail — but
    /// amortizes the growth checks, which is what lets the CLI's event
    /// mode batch consecutive inserts (parity-tested against the batch
    /// path on the golden streams).
    pub fn insert_batch(&mut self, edges: &[Edge]) {
        self.inner.process_chunk(edges);
        self.inserts += edges.iter().filter(|e| !e.is_self_loop()).count() as u64;
    }

    /// Apply a batch of events, counting failures.
    pub fn apply_all(&mut self, events: &[Event]) -> u64 {
        let mut failures = 0;
        for &ev in events {
            if self.apply(ev).is_err() {
                failures += 1;
            }
        }
        failures
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_events() -> Vec<Event> {
        vec![
            Event::Insert(Edge::new(0, 1)),
            Event::Insert(Edge::new(1, 2)),
            Event::Insert(Edge::new(0, 2)),
        ]
    }

    #[test]
    fn insert_then_delete_restores_volume_balance() {
        let mut d = DynamicClusterer::new(3, StrConfig::new(8));
        assert_eq!(d.apply_all(&triangle_events()), 0);
        assert_eq!(d.state().total_volume(), 6);
        d.apply(Event::Delete(Edge::new(0, 1))).unwrap();
        assert_eq!(d.state().total_volume(), 4);
        assert_eq!(d.live_edges(), 2);
    }

    #[test]
    fn delete_unknown_edge_rejected() {
        let mut d = DynamicClusterer::new(3, StrConfig::new(8));
        assert_eq!(
            d.apply(Event::Delete(Edge::new(0, 1))),
            Err(DynamicError::DeleteUnknown(Edge::new(0, 1)))
        );
    }

    #[test]
    fn deleting_everything_leaves_singleton_volumes() {
        let mut d = DynamicClusterer::new(3, StrConfig::new(8));
        d.apply_all(&triangle_events());
        for e in [Edge::new(0, 1), Edge::new(1, 2), Edge::new(0, 2)] {
            d.apply(Event::Delete(e)).unwrap();
        }
        assert_eq!(d.state().total_volume(), 0);
        assert_eq!(d.live_edges(), 0);
        // all nodes isolated → all evicted to their own communities
        let labels = d.labels();
        assert_eq!(labels.len(), 3);
        let set: std::collections::HashSet<_> = labels.iter().collect();
        assert_eq!(set.len(), 3, "labels={labels:?}");
    }

    #[test]
    fn churn_keeps_invariant() {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(3);
        let mut d = DynamicClusterer::new(64, StrConfig::new(16));
        let mut live: Vec<Edge> = Vec::new();
        for _ in 0..5000 {
            if live.is_empty() || rng.bernoulli(0.7) {
                let u = rng.range(0, 64) as u32;
                let mut v = rng.range(0, 64) as u32;
                if u == v {
                    v = (v + 1) % 64;
                }
                let e = Edge::new(u, v);
                d.apply(Event::Insert(e)).unwrap();
                live.push(e);
            } else {
                let idx = rng.range(0, live.len());
                let e = live.swap_remove(idx);
                d.apply(Event::Delete(e)).unwrap();
            }
            assert_eq!(d.state().total_volume(), 2 * d.live_edges());
        }
    }

    #[test]
    fn insert_batch_matches_per_event_inserts() {
        // the batched insert path must be the per-event path, exactly —
        // same sketch, same counters (self-loops skipped by both)
        let edges = [
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(2, 2),
            Edge::new(0, 2),
            Edge::new(3, 4),
        ];
        let mut batched = DynamicClusterer::new(0, StrConfig::new(8));
        batched.insert_batch(&edges);
        let mut single = DynamicClusterer::new(0, StrConfig::new(8));
        for &e in &edges {
            single.apply(Event::Insert(e)).unwrap();
        }
        assert_eq!(batched.inserts, single.inserts);
        assert_eq!(batched.live_edges(), 4);
        assert_eq!(batched.labels(), single.labels());
        assert_eq!(batched.state().total_volume(), single.state().total_volume());
    }

    #[test]
    fn self_loop_events_are_noops() {
        let mut d = DynamicClusterer::new(2, StrConfig::new(8));
        d.apply(Event::Insert(Edge::new(1, 1))).unwrap();
        d.apply(Event::Delete(Edge::new(1, 1))).unwrap();
        assert_eq!(d.live_edges(), 0);
    }
}
