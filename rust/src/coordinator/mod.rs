//! The paper's contribution: the streaming clustering coordinator.
//!
//! * [`state`] — the three-integers-per-node sketch (degree, community,
//!   community volume) of Algorithm 1.
//! * [`algorithm`] — the single-pass edge-processing rule, plus the
//!   ablation variants benchmarked by `benches/ablations.rs`.
//! * [`sweep`] — the §2.5 multi-parameter run: one pass, `A` concurrent
//!   `v_max` values sharing the degree table.
//! * [`selection`] — sketch-only scoring of sweep results (entropy /
//!   density, computed either natively or via the PJRT artifacts).
//! * [`parallel`] — sharded batch execution: the batch preset of the
//!   clustering service (one routing core, see `service::router`),
//!   plus the shared-atomic-sketch concurrent mode.
//! * [`dynamic`] — the §5 future-work extension: edge deletions.

pub mod algorithm;
pub mod dynamic;
pub mod parallel;
pub mod refine;
pub mod selection;
pub mod state;
pub mod sweep;

pub use algorithm::{StreamingClusterer, StrConfig};
pub use state::StreamState;
