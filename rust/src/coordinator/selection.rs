//! Sketch-only selection of the best sweep result (§2.5).
//!
//! The paper's constraint: the winner must be picked using only the
//! `(c, v)` dictionaries — metrics like modularity that need the graph
//! are off-limits. We score each sweep with entropy `H(v)` and average
//! density `D(c, v)` (the two §2.5 metrics), computed by a
//! [`MetricEngine`]:
//!
//! * [`NativeEngine`] — pure-Rust reference implementation;
//! * `runtime::PjrtEngine` — the AOT-compiled JAX/Pallas artifact
//!   (`sweep_metrics.hlo.txt`), same math, executed via PJRT. The two
//!   are cross-checked by integration tests.
//!
//! Padding contract (DESIGN.md §7): per sweep, the top `K-1` communities
//! by volume occupy buckets `0..K-1` and *all remaining* communities are
//! merged into the tail bucket `K-1` (volumes summed, sizes summed — the
//! entropy/balance of the tail is approximated as one community, which
//! is exact whenever the sweep has ≤ K communities).

use super::sweep::MultiSweep;

/// Number of sweep rows the AOT artifact expects.
pub const NUM_SWEEPS: usize = 8;
/// Padded community buckets per sweep.
pub const VOLUME_BUCKETS: usize = 4096;

/// Scores for one sweep row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepScores {
    /// Volume entropy `H(v)`.
    pub entropy: f32,
    /// Average intra-community density `D`.
    pub density: f32,
    /// Balance term `Σ p²`.
    pub balance: f32,
    /// Non-empty community count.
    pub ncomms: f32,
    /// density · log(1 + ncomms) — the default selector.
    pub density_score: f32,
    /// entropy − balance — the alternative selector.
    pub balance_score: f32,
}

/// Strategy used to pick the winning sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionRule {
    /// argmax density_score (default; robust against the all-singleton
    /// degenerate sketch).
    DensityScore,
    /// argmax balance_score.
    BalanceScore,
}

/// Engine computing [`SweepScores`] from padded sketch tables.
pub trait MetricEngine {
    /// vols/sizes are `A × K` row-major; w is the per-row total weight.
    fn sweep_metrics(
        &mut self,
        vols: &[f32],
        sizes: &[f32],
        w: &[f32],
        a: usize,
        k: usize,
    ) -> Vec<SweepScores>;
}

/// Pure-Rust metric engine (bit-for-bit the ref.py math).
#[derive(Debug, Default, Clone)]
pub struct NativeEngine;

impl MetricEngine for NativeEngine {
    fn sweep_metrics(
        &mut self,
        vols: &[f32],
        sizes: &[f32],
        w: &[f32],
        a: usize,
        k: usize,
    ) -> Vec<SweepScores> {
        assert_eq!(vols.len(), a * k);
        assert_eq!(sizes.len(), a * k);
        assert_eq!(w.len(), a);
        (0..a)
            .map(|row| {
                let vr = &vols[row * k..(row + 1) * k];
                let sr = &sizes[row * k..(row + 1) * k];
                let wt = w[row];
                let mut h = 0.0f64;
                let mut dnum = 0.0f64;
                let mut bal = 0.0f64;
                let mut nc = 0.0f64;
                for i in 0..k {
                    let v = vr[i] as f64;
                    let s = sr[i] as f64;
                    if wt > 0.0 && v > 0.0 {
                        let p = v / wt as f64;
                        h -= p * p.ln();
                        bal += p * p;
                    }
                    if s > 1.0 {
                        dnum += v / (s * (s - 1.0));
                    }
                    if s > 0.0 {
                        nc += 1.0;
                    }
                }
                let density = if nc > 0.0 { dnum / nc } else { 0.0 };
                SweepScores {
                    entropy: h as f32,
                    density: density as f32,
                    balance: bal as f32,
                    ncomms: nc as f32,
                    density_score: (density * (1.0 + nc).ln()) as f32,
                    balance_score: (h - bal) as f32,
                }
            })
            .collect()
    }
}

/// Padded tables ready for either engine.
#[derive(Debug, Clone)]
pub struct PaddedSketch {
    /// Row-major `A × K` community volumes.
    pub vols: Vec<f32>,
    /// Row-major `A × K` community sizes.
    pub sizes: Vec<f32>,
    /// Per-row total weight `2t`.
    pub w: Vec<f32>,
    /// Row count `A`.
    pub a: usize,
    /// Bucket count `K`.
    pub k: usize,
}

/// Build the padded `(A, K)` tables from a finished [`MultiSweep`].
/// Rows beyond the sweep count are zero (scored as empty).
pub fn pad_sweep(sweep: &MultiSweep, a: usize, k: usize) -> PaddedSketch {
    assert!(sweep.num_sweeps() <= a, "sweep count exceeds artifact rows");
    let mut vols = vec![0f32; a * k];
    let mut sizes = vec![0f32; a * k];
    let mut w = vec![0f32; a];
    for row in 0..sweep.num_sweeps() {
        let cv = sweep.community_volumes(row);
        w[row] = (2 * sweep.edges_processed) as f32;
        let head = cv.len().min(k - 1);
        for (b, &(vol, size)) in cv[..head].iter().enumerate() {
            vols[row * k + b] = vol as f32;
            sizes[row * k + b] = size as f32;
        }
        // tail bucket merges the rest
        let (mut tv, mut ts) = (0u64, 0u64);
        for &(vol, size) in &cv[head..] {
            tv += vol;
            ts += size as u64;
        }
        if ts > 0 {
            vols[row * k + (k - 1)] = tv as f32;
            sizes[row * k + (k - 1)] = ts as f32;
        }
    }
    PaddedSketch { vols, sizes, w, a, k }
}

/// Score all sweeps and return `(winner index, scores)`.
///
/// A *fragmentation filter* runs before the argmax: sweeps whose
/// community count exceeds `n / 3` (mean community size < 3 nodes) are
/// excluded when any non-fragmented sweep exists. Density monotonically
/// rewards fragmentation, so without the filter the smallest `v_max`
/// always wins; the filter is still sketch-only (it needs only `n` and
/// the community count).
pub fn select(
    sweep: &MultiSweep,
    engine: &mut dyn MetricEngine,
    rule: SelectionRule,
) -> (usize, Vec<SweepScores>) {
    let padded = pad_sweep(sweep, NUM_SWEEPS, VOLUME_BUCKETS);
    let scores = engine.sweep_metrics(
        &padded.vols,
        &padded.sizes,
        &padded.w,
        padded.a,
        padded.k,
    );
    let live = &scores[..sweep.num_sweeps()];
    let key = |s: &SweepScores| match rule {
        SelectionRule::DensityScore => s.density_score,
        SelectionRule::BalanceScore => s.balance_score,
    };
    // the padded table caps its ncomms at K (tail merging), so the
    // fragmentation filter uses the sketch's *true* community counts
    let true_counts: Vec<usize> = (0..sweep.num_sweeps())
        .map(|a| sweep.community_volumes(a).len())
        .collect();
    let frag_cap = sweep.n() / 3;
    let unfragmented = true_counts.iter().any(|&c| c > 0 && c <= frag_cap);
    let winner = live
        .iter()
        .enumerate()
        .filter(|&(i, _)| !unfragmented || true_counts[i] <= frag_cap)
        .max_by(|(_, x), (_, y)| key(x).partial_cmp(&key(y)).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    (winner, scores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::sbm::{self, SbmConfig};

    fn run_sweep() -> MultiSweep {
        let g = sbm::generate(&SbmConfig::equal(8, 40, 0.35, 0.005, 21));
        let mut sweep = MultiSweep::new(g.n(), MultiSweep::geometric_ladder(2, 8));
        sweep.process_chunk(&g.edges.edges);
        sweep
    }

    #[test]
    fn padding_conserves_volume_mass() {
        let sweep = run_sweep();
        let p = pad_sweep(&sweep, NUM_SWEEPS, VOLUME_BUCKETS);
        for row in 0..sweep.num_sweeps() {
            let total: f64 = p.vols[row * p.k..(row + 1) * p.k]
                .iter()
                .map(|&x| x as f64)
                .sum();
            assert_eq!(total as u64, 2 * sweep.edges_processed, "row {row}");
        }
    }

    #[test]
    fn tail_bucket_used_when_overflowing() {
        let sweep = run_sweep();
        // force a tiny K so the tail engages
        let p = pad_sweep(&sweep, NUM_SWEEPS, 4);
        let row0 = &p.sizes[0..4];
        assert!(row0[3] > 0.0, "tail empty: {row0:?}");
    }

    #[test]
    fn native_engine_entropy_of_uniform() {
        let mut e = NativeEngine;
        let k = 8;
        let vols = vec![1.0f32; k];
        let sizes = vec![2.0f32; k];
        let w = vec![k as f32];
        let s = e.sweep_metrics(&vols, &sizes, &w, 1, k);
        assert!((s[0].entropy - (k as f32).ln()).abs() < 1e-5);
        assert!((s[0].balance - 1.0 / k as f32).abs() < 1e-6);
        assert_eq!(s[0].ncomms, k as f32);
    }

    #[test]
    fn selection_picks_reasonable_vmax_on_sbm() {
        // communities of 40 nodes, ~0.35 intra density → volume ≈
        // 40 · 15 ≈ 600. The ladder 2..256: the winner should not be the
        // tiny-v_max rows (all singletons) nor produce 1 giant community.
        let sweep = run_sweep();
        let (winner, scores) = select(&sweep, &mut NativeEngine, SelectionRule::DensityScore);
        let nc = scores[winner].ncomms;
        assert!(nc >= 2.0, "winner collapsed to {nc} communities");
        assert!(
            (scores[winner].ncomms as usize) < sweep.n(),
            "winner is all singletons"
        );
    }

    #[test]
    fn zero_rows_scored_as_empty() {
        let g = sbm::generate(&SbmConfig::equal(4, 20, 0.4, 0.01, 3));
        let mut sweep = MultiSweep::new(g.n(), vec![8, 64]); // only 2 rows
        sweep.process_chunk(&g.edges.edges);
        let (winner, scores) = select(&sweep, &mut NativeEngine, SelectionRule::DensityScore);
        assert!(winner < 2);
        for s in &scores[2..] {
            assert_eq!(s.ncomms, 0.0);
        }
    }
}
