//! Sharded batch execution — a preset over the service's routing core.
//!
//! The single-pass algorithm is sequential by nature (each decision
//! reads state written by earlier edges), but its state is *node-local*:
//! a decision for edge `(i, j)` touches only the sketches of `i`, `j`
//! and their communities. We exploit that with hash-sharding
//! (`stream::shard`):
//!
//! * **Workers** — edges whose endpoints hash to the same shard are
//!   processed by that shard's worker on its own `StreamingClusterer`.
//!   Workers never share nodes, so their community id spaces are
//!   disjoint by construction (community ids are node ids).
//! * **Leader** — cross-shard edges are buffered. At the end of the
//!   stream the worker states are merged (disjoint array union) and
//!   the cross edges are replayed through the merged state with the
//!   standard rule.
//!
//! This module used to carry its own dispatcher implementing that
//! pipeline; it was a line-for-line twin of the service's router and
//! has been deleted. [`run_parallel`] is now the **batch preset of
//! [`ClusterService`]** ([`ServiceConfig::batch`]): the same routing
//! core (`service::router`), the same workers, the same terminal
//! replay — one code path for every execution mode, which is what
//! makes "service ≡ batch" true by construction rather than by test.
//!
//! **Contract:** the batch preset pins the cross-edge log's commit
//! horizon to `CommitHorizon::Unbounded`. Batch semantics *are* the
//! full-history terminal replay — every cross edge is re-decided
//! against the final shard sketches — so the preset must never let the
//! service's bounded-memory mode (`CommitHorizon::Edges`, which makes
//! old drained cross decisions final and frees their storage) leak into
//! `run_parallel`. The golden suite and the `horizon ≥ stream length ≡
//! Unbounded ≡ batch` property pin this equivalence.
//!
//! This is *deferred cross-edge resolution*: intra-shard edges see
//! exactly the sequential algorithm; cross-shard edges are processed
//! late, as if they had arrived at the end of the stream. Under the
//! paper's own intuition (intra-community edges arrive early,
//! inter-community edges late) this reordering is benign — and the
//! Table 2 parity test (`rust/tests/parallel_parity.rs`) verifies the
//! detection quality matches the sequential run on SBM workloads.

use crate::graph::edge::Edge;
use crate::service::{ClusterService, ServiceConfig};

use super::algorithm::StrConfig;
use super::state::{StreamState, UNSEEN};

pub use crate::service::router::merge_disjoint_states;

/// Configuration for the parallel run.
///
/// ```
/// use streamcom::coordinator::parallel::{run_parallel, ParallelConfig};
/// use streamcom::graph::edge::Edge;
///
/// let edges = vec![Edge::new(0, 1), Edge::new(1, 2), Edge::new(3, 4)];
/// let result = run_parallel(5, &edges, &ParallelConfig::new(2, 8));
/// // every edge is processed exactly once, locally or by the leader
/// assert_eq!(result.local_edges + result.cross_edges, 3);
/// assert_eq!(result.state.total_volume(), 2 * 3);
/// ```
#[derive(Debug, Clone)]
pub struct ParallelConfig {
    /// Number of shard workers.
    pub shards: usize,
    /// Per-worker streaming configuration (the paper's `v_max` etc.).
    pub str_config: StrConfig,
    /// Bounded queue depth per worker (chunks).
    pub queue_depth: usize,
    /// Edges per dispatched chunk.
    pub chunk_size: usize,
}

impl ParallelConfig {
    /// Defaults: queue depth 8, chunk size 16 Ki edges.
    pub fn new(shards: usize, v_max: u64) -> Self {
        Self {
            shards,
            str_config: StrConfig::new(v_max),
            queue_depth: 8,
            chunk_size: 16_384,
        }
    }
}

/// Outcome of a parallel run.
#[derive(Debug)]
pub struct ParallelResult {
    /// Final merged sketch.
    pub state: StreamState,
    /// Intra-shard edges processed by workers (self-loops excluded —
    /// the decision rule skips them).
    pub local_edges: u64,
    /// Cross-shard edges replayed by the leader.
    pub cross_edges: u64,
}

impl ParallelResult {
    /// Final community labels (unseen nodes as singletons).
    pub fn labels(&self) -> Vec<u32> {
        self.state.labels()
    }
}

/// Run the batch coordinator over an in-memory stream: the service in
/// its batch preset (automatic drains off, commit horizon pinned
/// unbounded). Edges are routed through the shared core
/// (`service::router`), `shards` workers consume their mailboxes
/// concurrently, and `finish` merges the worker sketches and replays
/// **all** cross edges in arrival order.
pub fn run_parallel(n: usize, edges: &[Edge], config: &ParallelConfig) -> ParallelResult {
    let mut cfg = ServiceConfig::batch(config.shards.max(1), config.str_config.v_max);
    cfg.str_config = config.str_config.clone();
    cfg.mailbox_depth = config.queue_depth.max(1);
    cfg.chunk_size = config.chunk_size.max(1);

    let mut service = ClusterService::start(cfg);
    service.push_chunk(edges);
    let result = service.finish();

    // the service sizes its sketch to the max streamed id; batch callers
    // pass an explicit n — pad so labels() covers [0, n) like the
    // pre-sized sequential run does
    let mut state = result.state().clone();
    if n > 0 {
        state.ensure((n - 1) as u32);
    }
    ParallelResult {
        state,
        local_edges: result.snapshot.local_edges,
        cross_edges: result.snapshot.cross_edges,
    }
}

// ---------------------------------------------------------------------
// Concurrent mode: shared atomic sketch.
// ---------------------------------------------------------------------

use std::sync::atomic::{AtomicI64, AtomicU32, AtomicU64, Ordering};

/// Shared-state concurrent execution (§Perf): the three per-node
/// integers become atomics and all workers stream disjoint slices of
/// the edge list against the *same* sketch with `Relaxed` operations.
///
/// Races are benign for this heuristic: a stale community/volume read
/// can mis-route one join decision, but every volume update is a paired
/// `fetch_add`/`fetch_sub`, so the conservation invariant
/// `Σ v_k = 2·t` holds *exactly* even under contention (asserted by the
/// tests), and detection quality matches the sequential run to within
/// noise (see `parallel_quality` tests). This is the mode that actually
/// speeds up wall-clock; the sharded leader/worker mode above is the
/// distribution-shaped architecture (disjoint state, deterministic).
pub struct AtomicSketch {
    degree: Vec<AtomicU32>,
    community: Vec<AtomicU32>,
    volume: Vec<AtomicI64>,
    edges: AtomicU64,
}

impl AtomicSketch {
    /// Zeroed shared sketch over `n` nodes.
    pub fn new(n: usize) -> Self {
        Self {
            degree: (0..n).map(|_| AtomicU32::new(0)).collect(),
            community: (0..n).map(|_| AtomicU32::new(UNSEEN)).collect(),
            volume: (0..n).map(|_| AtomicI64::new(0)).collect(),
            edges: AtomicU64::new(0),
        }
    }

    #[inline]
    fn process_edge(&self, e: Edge, v_max: i64) {
        if e.is_self_loop() {
            return;
        }
        let (i, j) = (e.u as usize, e.v as usize);
        debug_assert!(i < self.degree.len() && j < self.degree.len());

        // first touch: own community
        let _ = self.community[i].compare_exchange(
            UNSEEN,
            e.u,
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
        let _ = self.community[j].compare_exchange(
            UNSEEN,
            e.v,
            Ordering::Relaxed,
            Ordering::Relaxed,
        );

        let di = self.degree[i].fetch_add(1, Ordering::Relaxed) as i64 + 1;
        let dj = self.degree[j].fetch_add(1, Ordering::Relaxed) as i64 + 1;
        let ci = self.community[i].load(Ordering::Relaxed) as usize;
        let cj = self.community[j].load(Ordering::Relaxed) as usize;
        let vi = self.volume[ci].fetch_add(1, Ordering::Relaxed) + 1;
        let vj = self.volume[cj].fetch_add(1, Ordering::Relaxed) + 1;
        self.edges.fetch_add(1, Ordering::Relaxed);

        if ci == cj {
            return;
        }
        if vi <= v_max && vj <= v_max {
            // strict comparison = the paper's j-joins-i tie-break
            if vi < vj {
                self.volume[cj].fetch_add(di, Ordering::Relaxed);
                self.volume[ci].fetch_sub(di, Ordering::Relaxed);
                self.community[i].store(cj as u32, Ordering::Relaxed);
            } else {
                self.volume[ci].fetch_add(dj, Ordering::Relaxed);
                self.volume[cj].fetch_sub(dj, Ordering::Relaxed);
                self.community[j].store(ci as u32, Ordering::Relaxed);
            }
        }
    }

    /// Snapshot the labels (unseen nodes as singletons).
    pub fn labels(&self) -> Vec<u32> {
        self.community
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let c = c.load(Ordering::Relaxed);
                if c == UNSEEN {
                    i as u32
                } else {
                    c
                }
            })
            .collect()
    }

    /// Sum of community volumes (= 2·edges when quiescent).
    pub fn total_volume(&self) -> i64 {
        self.volume.iter().map(|v| v.load(Ordering::Relaxed)).sum()
    }

    /// Edges processed so far.
    pub fn edges_processed(&self) -> u64 {
        self.edges.load(Ordering::Relaxed)
    }
}

/// Stream `edges` through a shared atomic sketch with `threads` workers
/// over disjoint slices. Node ids must be `< n` (callers stream
/// pre-generated or pre-remapped graphs; grow-on-demand is incompatible
/// with lock-free sharing).
pub fn run_concurrent(n: usize, edges: &[Edge], v_max: u64, threads: usize) -> AtomicSketch {
    let sketch = AtomicSketch::new(n);
    let threads = threads.max(1);
    let chunk = edges.len().div_ceil(threads);
    std::thread::scope(|s| {
        for slice in edges.chunks(chunk.max(1)) {
            let sketch = &sketch;
            s.spawn(move || {
                for &e in slice {
                    sketch.process_edge(e, v_max as i64);
                }
            });
        }
    });
    sketch
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::sbm::{self, SbmConfig};
    use crate::metrics;

    #[test]
    fn single_shard_equals_sequential() {
        let g = sbm::generate(&SbmConfig::equal(6, 30, 0.4, 0.01, 5));
        let seq = super::super::algorithm::cluster_edges(g.n(), &g.edges.edges, 32);
        let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(1, 32));
        assert_eq!(par.labels(), seq);
    }

    #[test]
    fn volume_conservation_after_merge_and_replay() {
        let g = sbm::generate(&SbmConfig::equal(8, 40, 0.3, 0.01, 9));
        let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(4, 64));
        assert_eq!(par.state.total_volume(), 2 * par.state.edges_processed);
        assert_eq!(
            par.state.edges_processed,
            g.m() as u64,
            "every edge must be processed exactly once"
        );
        assert_eq!(par.local_edges + par.cross_edges, g.m() as u64);
    }

    #[test]
    fn parallel_quality_close_to_sequential_on_sbm() {
        let g = sbm::generate(&SbmConfig::equal(10, 50, 0.35, 0.003, 13));
        let truth = g.truth.to_labels(g.n());
        let seq = super::super::algorithm::cluster_edges(g.n(), &g.edges.edges, 128);
        let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(4, 128));
        let nmi_seq = metrics::nmi::nmi_labels(&seq, &truth);
        let nmi_par = metrics::nmi::nmi_labels(&par.labels(), &truth);
        assert!(
            nmi_par > nmi_seq * 0.7,
            "parallel NMI {nmi_par} too far below sequential {nmi_seq}"
        );
    }

    #[test]
    fn concurrent_conserves_volume_exactly() {
        let g = sbm::generate(&SbmConfig::equal(10, 50, 0.3, 0.005, 23));
        for threads in [1, 2, 4, 8] {
            let sketch = run_concurrent(g.n(), &g.edges.edges, 128, threads);
            assert_eq!(sketch.edges_processed(), g.m() as u64, "threads={threads}");
            assert_eq!(
                sketch.total_volume(),
                2 * g.m() as i64,
                "conservation broken at threads={threads}"
            );
        }
    }

    #[test]
    fn concurrent_single_thread_matches_sequential() {
        let g = sbm::generate(&SbmConfig::equal(6, 30, 0.35, 0.01, 29));
        let seq = super::super::algorithm::cluster_edges(g.n(), &g.edges.edges, 64);
        let conc = run_concurrent(g.n(), &g.edges.edges, 64, 1).labels();
        assert_eq!(seq, conc);
    }

    #[test]
    fn concurrent_quality_close_to_sequential() {
        let g = sbm::generate(&SbmConfig::equal(10, 50, 0.35, 0.003, 31));
        let truth = g.truth.to_labels(g.n());
        let seq = super::super::algorithm::cluster_edges(g.n(), &g.edges.edges, 128);
        let conc = run_concurrent(g.n(), &g.edges.edges, 128, 8).labels();
        let nmi_seq = metrics::nmi::nmi_labels(&seq, &truth);
        let nmi_conc = metrics::nmi::nmi_labels(&conc, &truth);
        assert!(
            nmi_conc > nmi_seq * 0.8,
            "concurrent NMI {nmi_conc} vs sequential {nmi_seq}"
        );
    }

    #[test]
    fn concurrent_labels_are_valid() {
        let g = sbm::generate(&SbmConfig::equal(8, 40, 0.3, 0.01, 37));
        let labels = run_concurrent(g.n(), &g.edges.edges, 64, 4).labels();
        assert!(labels.iter().all(|&l| (l as usize) < g.n()));
    }

    #[test]
    fn workers_touch_disjoint_nodes() {
        // merge_disjoint_states debug-asserts disjointness; run a real
        // workload under it
        let g = sbm::generate(&SbmConfig::equal(5, 40, 0.3, 0.02, 17));
        let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(3, 64));
        assert!(par.state.n() >= g.n());
    }

    #[test]
    fn batch_preset_result_is_padded_to_n() {
        // callers score labels against ground truth of a known node
        // count; the wrapper must deliver the pre-sized-run shape
        let edges = vec![Edge::new(0, 1), Edge::new(1, 2)];
        let par = run_parallel(10, &edges, &ParallelConfig::new(2, 8));
        assert_eq!(par.labels().len(), 10);
        assert_eq!(par.labels()[9], 9, "trailing unseen node is a singleton");
    }
}
