//! §2.5 multi-parameter streaming: one pass, many `v_max` values.
//!
//! The degree table `d` is shared across all parameter values (degrees
//! do not depend on `v_max`); only `c` and `v` are duplicated per sweep,
//! exactly as the paper prescribes. The pass is still single-touch per
//! edge: each arriving edge updates every sweep's sketch.
//!
//! After the pass, [`crate::coordinator::selection`] scores the sweeps
//! from their sketches alone (no access to the graph) and picks the
//! winner.

use crate::graph::edge::Edge;
use crate::stream::source::EdgeSource;

use super::state::UNSEEN;

/// One-pass, A-parameter streaming state.
#[derive(Debug, Clone)]
pub struct MultiSweep {
    /// The sweep's `v_max` ladder.
    pub v_maxes: Vec<u64>,
    /// Shared degree table.
    pub degree: Vec<u32>,
    /// Per-sweep community table, `community[a][i]`.
    pub community: Vec<Vec<u32>>,
    /// Per-sweep volume table, `volume[a][k]`.
    pub volume: Vec<Vec<u64>>,
    /// Edges processed (`t`).
    pub edges_processed: u64,
}

impl MultiSweep {
    /// Sweep over `v_maxes` with `n` pre-sized nodes.
    pub fn new(n: usize, v_maxes: Vec<u64>) -> Self {
        assert!(!v_maxes.is_empty());
        let a = v_maxes.len();
        Self {
            v_maxes,
            degree: vec![0; n],
            community: vec![vec![UNSEEN; n]; a],
            volume: vec![vec![0; n]; a],
            edges_processed: 0,
        }
    }

    /// Geometric ladder `base · 2^i`, the standard sweep for the paper's
    /// single integer parameter.
    pub fn geometric_ladder(base: u64, count: usize) -> Vec<u64> {
        (0..count).map(|i| base << i).collect()
    }

    /// Number of parameter values `A`.
    pub fn num_sweeps(&self) -> usize {
        self.v_maxes.len()
    }

    /// Current node-space size.
    pub fn n(&self) -> usize {
        self.degree.len()
    }

    #[inline]
    fn ensure(&mut self, i: u32) {
        let need = i as usize + 1;
        if need > self.degree.len() {
            self.degree.resize(need, 0);
            for c in &mut self.community {
                c.resize(need, UNSEEN);
            }
            for v in &mut self.volume {
                v.resize(need, 0);
            }
        }
    }

    /// Process one edge across all sweeps (Algorithm 1 body, vectorised
    /// over the parameter axis).
    #[inline]
    pub fn process_edge(&mut self, e: Edge) {
        if e.is_self_loop() {
            return;
        }
        self.ensure(e.u.max(e.v));
        let (i, j) = (e.u as usize, e.v as usize);
        self.degree[i] += 1;
        self.degree[j] += 1;
        let (di, dj) = (self.degree[i] as u64, self.degree[j] as u64);
        self.edges_processed += 1;

        for a in 0..self.v_maxes.len() {
            let vmax = self.v_maxes[a];
            let comm = &mut self.community[a];
            let vol = &mut self.volume[a];
            if comm[i] == UNSEEN {
                comm[i] = e.u;
            }
            if comm[j] == UNSEEN {
                comm[j] = e.v;
            }
            let ci = comm[i] as usize;
            let cj = comm[j] as usize;
            vol[ci] += 1;
            vol[cj] += 1;
            if ci == cj {
                continue;
            }
            let (vi, vj) = (vol[ci], vol[cj]);
            if vi <= vmax && vj <= vmax {
                // strict: on equality j joins i (paper §2.3, TieBreak::JToI)
                if vi < vj {
                    vol[cj] += di;
                    vol[ci] -= di;
                    comm[i] = cj as u32;
                } else {
                    vol[ci] += dj;
                    vol[cj] -= dj;
                    comm[j] = ci as u32;
                }
            }
        }
    }

    /// Process a chunk of edges across all sweeps.
    pub fn process_chunk(&mut self, chunk: &[Edge]) {
        for &e in chunk {
            self.process_edge(e);
        }
    }

    /// Drain an entire source through the sweep.
    pub fn run<S: EdgeSource>(&mut self, source: &mut S, batch: usize) {
        let mut buf = Vec::with_capacity(batch);
        while source.next_batch(&mut buf) > 0 {
            self.process_chunk(&buf);
        }
    }

    /// Labels of sweep `a`.
    pub fn labels(&self, a: usize) -> Vec<u32> {
        self.community[a]
            .iter()
            .enumerate()
            .map(|(i, &c)| if c == UNSEEN { i as u32 } else { c })
            .collect()
    }

    /// (volume, size) pairs of non-empty communities of sweep `a`,
    /// sorted by volume descending (selection input).
    pub fn community_volumes(&self, a: usize) -> Vec<(u64, u32)> {
        let n = self.n();
        let mut size = vec![0u32; n];
        for &c in &self.community[a] {
            if c != UNSEEN {
                size[c as usize] += 1;
            }
        }
        let mut out: Vec<(u64, u32)> = (0..n)
            .filter(|&k| size[k] > 0)
            .map(|k| (self.volume[a][k], size[k]))
            .collect();
        out.sort_unstable_by(|x, y| y.0.cmp(&x.0));
        out
    }

    /// Memory for the sweep: shared degrees + A · (c, v).
    pub fn memory_bytes(&self) -> usize {
        self.degree.len() * 4
            + self.community.iter().map(|c| c.len() * 4).sum::<usize>()
            + self.volume.iter().map(|v| v.len() * 8).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::algorithm::{cluster_edges, StrConfig, StreamingClusterer};

    fn graph() -> (usize, Vec<Edge>) {
        use crate::graph::generators::sbm::{self, SbmConfig};
        let g = sbm::generate(&SbmConfig::equal(6, 30, 0.4, 0.01, 7));
        (g.n(), g.edges.edges)
    }

    #[test]
    fn sweep_matches_individual_runs() {
        let (n, edges) = graph();
        let v_maxes = vec![4u64, 32, 256];
        let mut sweep = MultiSweep::new(n, v_maxes.clone());
        sweep.process_chunk(&edges);
        for (a, &vm) in v_maxes.iter().enumerate() {
            let single = cluster_edges(n, &edges, vm);
            assert_eq!(sweep.labels(a), single, "sweep {a} (v_max={vm}) diverged");
        }
    }

    #[test]
    fn volume_conservation_per_sweep() {
        let (n, edges) = graph();
        let mut sweep = MultiSweep::new(n, vec![2, 16, 128, 1024]);
        sweep.process_chunk(&edges);
        for a in 0..sweep.num_sweeps() {
            let tot: u64 = sweep.volume[a].iter().sum();
            assert_eq!(tot, 2 * sweep.edges_processed, "sweep {a}");
        }
    }

    #[test]
    fn geometric_ladder() {
        assert_eq!(MultiSweep::geometric_ladder(4, 5), vec![4, 8, 16, 32, 64]);
    }

    #[test]
    fn shared_degree_equals_single_run_degrees() {
        let (n, edges) = graph();
        let mut sweep = MultiSweep::new(n, vec![8, 64]);
        sweep.process_chunk(&edges);
        let mut single = StreamingClusterer::new(n, StrConfig::new(8));
        single.process_chunk(&edges);
        assert_eq!(sweep.degree, single.state.degree);
    }

    #[test]
    fn larger_vmax_never_more_communities() {
        let (n, edges) = graph();
        let mut sweep = MultiSweep::new(n, MultiSweep::geometric_ladder(2, 8));
        sweep.process_chunk(&edges);
        let counts: Vec<usize> = (0..sweep.num_sweeps())
            .map(|a| sweep.community_volumes(a).len())
            .collect();
        // not strictly monotone in theory, but over a geometric ladder on
        // an SBM the trend must be decreasing from first to last
        assert!(
            counts.first().unwrap() >= counts.last().unwrap(),
            "counts={counts:?}"
        );
    }
}
