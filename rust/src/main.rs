//! `streamcom` CLI — leader entrypoint.
//!
//! Subcommands (see `streamcom help`):
//!   generate   produce a SNAP-shaped workload (edges + ground truth)
//!   run        stream-cluster an edge file / preset with one v_max
//!   sweep      §2.5 multi-parameter run + sketch-only selection
//!   bench      regenerate the paper's tables (table1 | table2 | memory)
//!   serve      long-lived sharded clustering service (queries on stdin;
//!              `--dynamic` for the legacy insert/delete event mode)

mod app;

fn main() {
    let code = app::main_with_args(std::env::args().skip(1).collect());
    std::process::exit(code);
}
