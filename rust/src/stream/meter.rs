//! Throughput metering for the streaming path.
//!
//! Counts edges/bytes against wall-clock time, with optional periodic
//! progress callbacks (used by the CLI's `--progress` and the Table 1
//! harness). Pure observation: metering never touches the hot loop more
//! than an add and a compare.

use std::time::{Duration, Instant};

/// A running throughput meter.
#[derive(Debug)]
pub struct Meter {
    start: Instant,
    edges: u64,
    bytes: u64,
    last_report_edges: u64,
    report_every: u64,
}

/// A finished measurement.
#[derive(Debug, Clone, Copy)]
pub struct MeterReport {
    /// Edges counted.
    pub edges: u64,
    /// Bytes counted.
    pub bytes: u64,
    /// Wall-clock measured.
    pub elapsed: Duration,
}

impl MeterReport {
    /// Edge throughput over the measured interval.
    pub fn edges_per_sec(&self) -> f64 {
        self.edges as f64 / self.elapsed.as_secs_f64().max(1e-12)
    }

    /// Byte throughput in MB/s.
    pub fn mbytes_per_sec(&self) -> f64 {
        self.bytes as f64 / 1e6 / self.elapsed.as_secs_f64().max(1e-12)
    }
}

impl Meter {
    /// Start measuring now.
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
            edges: 0,
            bytes: 0,
            last_report_edges: 0,
            report_every: u64::MAX,
        }
    }

    /// Enable progress reporting every `every` edges.
    pub fn with_progress(mut self, every: u64) -> Self {
        self.report_every = every.max(1);
        self
    }

    #[inline]
    /// Record `k` more edges.
    pub fn add_edges(&mut self, k: u64) {
        self.edges += k;
    }

    #[inline]
    /// Record `k` more bytes.
    pub fn add_bytes(&mut self, k: u64) {
        self.bytes += k;
    }

    /// True when a progress report is due (resets the trigger).
    #[inline]
    pub fn progress_due(&mut self) -> bool {
        if self.edges - self.last_report_edges >= self.report_every {
            self.last_report_edges = self.edges;
            true
        } else {
            false
        }
    }

    /// Current counters against elapsed time.
    pub fn snapshot(&self) -> MeterReport {
        MeterReport {
            edges: self.edges,
            bytes: self.bytes,
            elapsed: self.start.elapsed(),
        }
    }

    /// Consume the meter and return the final report.
    pub fn finish(self) -> MeterReport {
        self.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let mut m = Meter::start();
        m.add_edges(100);
        m.add_edges(50);
        m.add_bytes(1000);
        let r = m.finish();
        assert_eq!(r.edges, 150);
        assert_eq!(r.bytes, 1000);
        assert!(r.edges_per_sec() > 0.0);
    }

    #[test]
    fn progress_trigger_fires_per_interval() {
        let mut m = Meter::start().with_progress(100);
        m.add_edges(99);
        assert!(!m.progress_due());
        m.add_edges(1);
        assert!(m.progress_due());
        assert!(!m.progress_due()); // resets
        m.add_edges(250);
        assert!(m.progress_due());
    }
}
