//! Parallel source scan: N reader threads, two delivery modes.
//!
//! The paper's bottleneck at 10^9 edges is moving edges from disk into
//! the per-node counters. PR 7 parallelised parse + checksum across
//! reader threads; PR 8 cut the per-edge parse to an 8-byte decode on
//! the mmap path. At that point the re-merge — N readers funnelling
//! back into ONE ingest thread that routes every edge — became the
//! pipeline's last O(m) single-threaded stage. This module therefore
//! offers two delivery modes with the same ordering contract:
//!
//! # Funnel mode ([`ParallelScanner`])
//!
//! Each reader thread owns a byte range of the input (binary:
//! segment-aligned via the computable offsets in `graph::binfmt`;
//! text: advanced to newline boundaries), parses it into edge chunks,
//! and ships them through its own bounded queue. A single sequencer —
//! the [`EdgeSource`] implementation — drains those queues **in range
//! order**, so the global edge order equals file order for *any*
//! reader count: the final partition is bit-identical whether one
//! reader scans the file or eight do, and WAL sequence numbers stay
//! well-defined. The cost is that one downstream thread still runs
//! `Router::push_batch` for every edge.
//!
//! # Direct mode ([`DirectScan`])
//!
//! For segmented binary inputs the routing decision itself moves into
//! the reader threads, deleting the funnel from the hot path. Every
//! record of a segmented file has a **global sequence index**
//! computable without any cross-thread coordination — each full
//! segment holds exactly `seg_records` records, so edge `i` of segment
//! `s` is stream position `s * seg_records + i`. Each reader partitions
//! its decoded edges through the shared [`Sharder`] into per-destination
//! sub-chunks ([`SeqChunk`]: a destination's edges in file order,
//! tagged `first_seq..=last_seq`) and ships them into per-(reader,
//! destination) bounded queues. On the consumer side one [`DestFeed`]
//! per destination (`shards` locals + one cross lane) concatenates its
//! reader queues **in range order**, so each destination sees exactly
//! the subsequence of the file bound for it, in file order — the same
//! per-shard edge order, cross-log arrival order, and (count-keyed)
//! epoch-seal boundaries as the funneled single-reader run, at any
//! reader count. `service::ClusterService::ingest_direct` consumes the
//! feeds with one muxer thread per shard plus a cross consumer.
//!
//! Direct mode composes with durability: when a `DirectWalCfg` is
//! passed at open, each reader owns a private WAL lane per destination
//! (`shard-{s}.r{k}` / `cross.r{k}`) and appends every routed chunk —
//! with its per-edge global seq tags — *before* enqueueing it, flushed
//! per chunk and fsynced at reader exit. Because seqs are globally
//! unique and per-lane ascending, recovery reduces the lane union to
//! one durable seq cut (`service::wal::durable_cut`) and replays the
//! suffix through the same `Sharder` route, bit-identical in the
//! exactness domains.
//!
//! # Route/fallback matrix (resolved by the CLI's `--route`)
//!
//! | input / flags                            | mode                  |
//! |------------------------------------------|-----------------------|
//! | binary or mmap scan, no pacing           | direct (auto default) |
//! | binary or mmap scan + `--wal-dir`        | direct — readers append their routed chunks to per-reader WAL lanes before enqueueing |
//! | text input                               | funnel (no fixed record geometry ⇒ no coordination-free seq) |
//! | `--pace`, or `--resume`'s positional slicing | funnel (both need the single global arrival stream) |
//! | `--route funnel`                         | funnel (explicit)     |
//!
//! Memory is bounded by construction in both modes: each queue holds
//! at most [`READ_AHEAD_CHUNKS`] chunks of ≤ `batch` edges, so a
//! stalled consumer backpressures every reader through the channel's
//! blocking `send` — the same discipline as the service mailboxes.
//!
//! Neither mode has an error channel in its pull path, so reader
//! failures (I/O error, checksum mismatch) close that reader's queues
//! and park the first message — uniformly prefixed with the reader's
//! index and byte span — in [`ParallelScanner::take_error`] /
//! [`DirectScan::take_error`]; callers check it after the drain.
//!
//! # mmap transport (`open_mmap` on either scanner)
//!
//! For binary inputs the per-range `File` handles can be replaced with
//! **one** shared read-only mapping (`util::mmap::Mmap`, advice per
//! `util::mmap::Advice`): the scanner owns an `Arc<Mmap>`, every
//! reader thread borrows a clone and walks its disjoint segment range
//! directly in the mapped bytes — checksums verified in place via
//! `binfmt::SegView`, records decoded straight into the outgoing
//! chunk. Ownership story: one map, N borrowing readers, unmap after
//! join — `Drop` closes the queues and joins the reader threads
//! *first* (their `Arc` clones die there), then the scanner's own
//! `Arc` drops and `munmap` runs. The header is validated against the
//! real mapped length before any thread spawns, so segment offsets can
//! never leave the map (a short file is `InvalidData` at open, never a
//! SIGBUS). On non-unix targets `open_mmap` degrades at compile time
//! to the buffered per-range-handle path with identical semantics.

use std::fs::File;
use std::io::{self, BufRead, BufReader, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};

use super::shard::{Route, Sharder};
use super::source::{emit_lenient, EdgeSource};
use crate::graph::binfmt;
use crate::graph::edge::Edge;
use crate::graph::io::frame_lines;
use crate::service::wal::{DirectWal, DirectWalCfg};
use crate::util::channel::{Channel, SendError};
use crate::util::mmap::{self, Advice, Mmap};

/// Chunks each reader may buffer ahead of the sequencer. Together with
/// the batch size this bounds scan memory at
/// `readers × READ_AHEAD_CHUNKS × batch` edges.
pub const READ_AHEAD_CHUNKS: usize = 8;

/// Input format of a scanned edge file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScanFormat {
    /// SNAP-style text (`u <ws> v` lines) — ranges split at newlines.
    Text,
    /// Segmented binary (`graph::binfmt`) — ranges split at segments.
    Binary,
}

impl ScanFormat {
    /// Infer the format from the file extension (`.bin` ⇒ binary),
    /// matching the convention the CLI already uses everywhere else.
    pub fn infer<P: AsRef<Path>>(path: P) -> Self {
        match path.as_ref().extension().and_then(|e| e.to_str()) {
            Some("bin") => ScanFormat::Binary,
            _ => ScanFormat::Text,
        }
    }
}

/// Shared scan counters, updated by reader threads (relaxed atomics —
/// they are observability, not synchronisation).
#[derive(Debug, Default)]
pub struct ScanStats {
    bytes_read: AtomicU64,
    oversized: AtomicU64,
    malformed: AtomicU64,
    segments_verified: AtomicU64,
}

impl ScanStats {
    /// Total bytes consumed across all readers.
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.load(Ordering::Relaxed)
    }

    /// Text lines skipped because an id exceeded `u32` (see
    /// `source::TextFileSource::oversized_skipped`).
    pub fn oversized_skipped(&self) -> u64 {
        self.oversized.load(Ordering::Relaxed)
    }

    /// Text lines skipped because the target was missing/malformed
    /// (see `source::TextFileSource::malformed_skipped`).
    pub fn malformed_skipped(&self) -> u64 {
        self.malformed.load(Ordering::Relaxed)
    }

    /// Binary segments whose record count + checksum verified.
    pub fn segments_verified(&self) -> u64 {
        self.segments_verified.load(Ordering::Relaxed)
    }
}

/// Plan newline-aligned byte ranges for `readers` text readers: raw
/// even splits advanced to the next line start, so every line belongs
/// to exactly one range and concatenating the ranges in order yields
/// the file verbatim. Empty ranges (tiny files) are dropped.
pub fn plan_text_ranges<P: AsRef<Path>>(path: P, readers: usize) -> io::Result<Vec<(u64, u64)>> {
    let mut f = File::open(path)?;
    let len = f.metadata()?.len();
    let readers = readers.max(1) as u64;
    let mut bounds = Vec::with_capacity(readers as usize + 1);
    bounds.push(0u64);
    for i in 1..readers {
        let target = ((len as u128 * i as u128) / readers as u128) as u64;
        bounds.push(next_line_start(&mut f, target, len)?);
    }
    bounds.push(len);
    Ok(bounds.windows(2).filter(|w| w[1] > w[0]).map(|w| (w[0], w[1])).collect())
}

/// First byte position at or after `target` that starts a line (i.e.
/// just past the next `\n`), or `len` when no newline follows.
fn next_line_start(f: &mut File, target: u64, len: u64) -> io::Result<u64> {
    if target == 0 || target >= len {
        return Ok(target.min(len));
    }
    f.seek(SeekFrom::Start(target))?;
    let mut pos = target;
    let mut probe = [0u8; 4096];
    loop {
        let n = f.read(&mut probe)?;
        if n == 0 {
            return Ok(len);
        }
        if let Some(i) = probe[..n].iter().position(|&b| b == b'\n') {
            return Ok(pos + i as u64 + 1);
        }
        pos += n as u64;
    }
}

/// Split `seg_count` segments into contiguous `[s0, s1)` ranges, one
/// per reader (readers clamped to the segment count — a two-segment
/// file gets two readers no matter what was asked for).
pub fn plan_segment_ranges(seg_count: u64, readers: usize) -> Vec<(u64, u64)> {
    if seg_count == 0 {
        return Vec::new();
    }
    let readers = (readers.max(1) as u64).min(seg_count);
    let per = seg_count / readers;
    let extra = seg_count % readers;
    let mut ranges = Vec::with_capacity(readers as usize);
    let mut s = 0u64;
    for i in 0..readers {
        let take = per + u64::from(i < extra);
        ranges.push((s, s + take));
        s += take;
    }
    ranges
}

/// Byte span `[b0, b1)` of segment range `[s0, s1)`, for the uniform
/// reader error prefix. `s1 > s0` by construction — planners never
/// emit an empty range.
fn seg_byte_span(header: &binfmt::SegHeader, s0: u64, s1: u64) -> (u64, u64) {
    let b0 = header.seg_offset(s0).expect("validated header");
    let b1 = header.seg_offset(s1 - 1).expect("validated header") + header.seg_bytes(s1 - 1);
    (b0, b1)
}

fn run_text_reader(
    path: &Path,
    start: u64,
    end: u64,
    batch: usize,
    tx: &Channel<Vec<Edge>>,
    stats: &ScanStats,
) -> io::Result<()> {
    let mut f = File::open(path)?;
    f.seek(SeekFrom::Start(start))?;
    let mut reader = BufReader::with_capacity(1 << 20, f.take(end - start));
    let mut carry: Vec<u8> = Vec::with_capacity(64);
    let mut buf: Vec<Edge> = Vec::with_capacity(batch);
    let mut oversized = 0u64;
    let mut malformed = 0u64;
    let mut bytes = 0u64;
    let mut hung_up = false;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            if !carry.is_empty() {
                // the final unterminated line (last range only — every
                // other range ends just past a newline by construction;
                // its bytes were already counted when stashed)
                let tail = std::mem::take(&mut carry);
                emit_lenient(&tail, &mut buf, &mut oversized, &mut malformed);
            }
            break;
        }
        let consumed = match frame_lines(chunk, &mut carry, |line| {
            emit_lenient(line, &mut buf, &mut oversized, &mut malformed);
            if buf.len() >= batch {
                let full = std::mem::replace(&mut buf, Vec::with_capacity(batch));
                if tx.send(full).is_err() {
                    // receiver dropped the scanner: benign early stop
                    hung_up = true;
                    return Ok(false);
                }
            }
            Ok::<bool, std::convert::Infallible>(true)
        }) {
            Ok(c) => c,
            Err(never) => match never {},
        };
        bytes += consumed as u64;
        reader.consume(consumed);
        if hung_up {
            break;
        }
    }
    if !buf.is_empty() && !hung_up {
        let _ = tx.send(buf);
    }
    stats.bytes_read.fetch_add(bytes, Ordering::Relaxed);
    stats.oversized.fetch_add(oversized, Ordering::Relaxed);
    stats.malformed.fetch_add(malformed, Ordering::Relaxed);
    Ok(())
}

fn run_binary_reader(
    path: &Path,
    header: binfmt::SegHeader,
    segs: (u64, u64),
    batch: usize,
    tx: &Channel<Vec<Edge>>,
    stats: &ScanStats,
) -> io::Result<()> {
    let mut f = File::open(path)?;
    // the header was validate_file_len-checked at open: offsets exist
    let off = header.seg_offset(segs.0).expect("validated header");
    f.seek(SeekFrom::Start(off))?;
    let mut reader = BufReader::with_capacity(1 << 20, f);
    let mut block = Vec::new();
    let mut edges: Vec<Edge> = Vec::new();
    for seg in segs.0..segs.1 {
        let records = header.records_in(seg);
        block.resize((binfmt::SEG_OVERHEAD_BYTES + records * binfmt::RECORD_BYTES) as usize, 0);
        reader.read_exact(&mut block)?;
        edges.clear();
        binfmt::decode_segment(&block, records, seg, &mut edges)?;
        stats.segments_verified.fetch_add(1, Ordering::Relaxed);
        stats.bytes_read.fetch_add(block.len() as u64, Ordering::Relaxed);
        for part in edges.chunks(batch) {
            if tx.send(part.to_vec()).is_err() {
                return Ok(()); // receiver dropped the scanner
            }
        }
    }
    Ok(())
}

/// Zero-copy reader over a shared mapping: verify each owned segment's
/// checksum in place and decode records straight into outgoing chunks
/// (the mmap counterpart of [`run_binary_reader`] — no file handle, no
/// block buffer, no staging vec). `map` is the thread's borrowed view
/// of the scanner's one mapping; slicing is safe because the header
/// was validated against the real mapped length at open.
fn run_mmap_reader(
    map: &Mmap,
    header: binfmt::SegHeader,
    segs: (u64, u64),
    batch: usize,
    tx: &Channel<Vec<Edge>>,
    stats: &ScanStats,
) -> io::Result<()> {
    let bytes = map.as_slice();
    let mut chunk: Vec<Edge> = Vec::with_capacity(batch);
    for seg in segs.0..segs.1 {
        let records = header.records_in(seg);
        let off = header.seg_offset(seg).expect("validated header") as usize;
        let len = header.seg_bytes(seg) as usize;
        let view = binfmt::SegView::parse(&bytes[off..off + len], records, seg)?;
        stats.segments_verified.fetch_add(1, Ordering::Relaxed);
        stats.bytes_read.fetch_add(len as u64, Ordering::Relaxed);
        for e in view.edges() {
            chunk.push(e);
            if chunk.len() == batch {
                let full = std::mem::replace(&mut chunk, Vec::with_capacity(batch));
                if tx.send(full).is_err() {
                    return Ok(()); // receiver dropped the scanner
                }
            }
        }
    }
    if !chunk.is_empty() {
        let _ = tx.send(chunk);
    }
    Ok(())
}

/// N-reader parallel scan over one edge file, consumed as an ordinary
/// [`EdgeSource`]: readers parse their ranges concurrently, the
/// sequencer hands edges out in file order (module docs explain why
/// order is preserved rather than merely semantics).
pub struct ParallelScanner {
    queues: Vec<Channel<Vec<Edge>>>,
    threads: Vec<JoinHandle<()>>,
    /// queue currently being drained (ranges are in file order)
    current: usize,
    /// chunk received but not yet fully handed to a caller
    leftover: Vec<Edge>,
    leftover_pos: usize,
    stats: Arc<ScanStats>,
    error: Arc<Mutex<Option<String>>>,
    len_hint: Option<usize>,
    /// the one shared mapping in mmap mode (`None` on the buffered
    /// path). Reader threads hold borrowed `Arc` clones; this last
    /// `Arc` drops after `Drop` joins them — unmap-after-join.
    map: Option<Arc<Mmap>>,
}

impl ParallelScanner {
    /// Open `path` with the format inferred from its extension
    /// (`.bin` ⇒ segmented binary, anything else text).
    pub fn open<P: AsRef<Path>>(path: P, readers: usize, batch: usize) -> io::Result<Self> {
        let format = ScanFormat::infer(&path);
        Self::open_with(path, format, readers, batch)
    }

    /// Open `path` as `format` with `readers` reader threads shipping
    /// chunks of up to `batch` edges (both clamped to ≥ 1; binary
    /// readers are further clamped to the segment count). The header of
    /// a binary file is decoded and length-validated *here*, so a
    /// corrupt or hostile header fails the open, not a reader thread.
    pub fn open_with<P: AsRef<Path>>(
        path: P,
        format: ScanFormat,
        readers: usize,
        batch: usize,
    ) -> io::Result<Self> {
        let path: PathBuf = path.as_ref().to_path_buf();
        let readers = readers.max(1);
        let batch = batch.max(1);
        let stats = Arc::new(ScanStats::default());
        let error: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let mut queues = Vec::new();
        let mut threads = Vec::new();
        let mut len_hint = None;

        match format {
            ScanFormat::Text => {
                let ranges = plan_text_ranges(&path, readers)?;
                let n = ranges.len();
                for (i, (start, end)) in ranges.into_iter().enumerate() {
                    let q: Channel<Vec<Edge>> = Channel::bounded(READ_AHEAD_CHUNKS);
                    let tx = q.clone();
                    let p = path.clone();
                    let st = Arc::clone(&stats);
                    let err = Arc::clone(&error);
                    threads.push(thread::spawn(move || {
                        if let Err(e) = run_text_reader(&p, start, end, batch, &tx, &st) {
                            let mut slot = err.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(format!(
                                    "reader {i}/{n} (text, bytes {start}..{end}): {e}"
                                ));
                            }
                        }
                        tx.close();
                    }));
                    queues.push(q);
                }
            }
            ScanFormat::Binary => {
                let f = File::open(&path)?;
                let file_len = f.metadata()?.len();
                let mut r = BufReader::new(f);
                let mut head = [0u8; binfmt::HEADER_BYTES];
                r.read_exact(&mut head)?;
                let header = binfmt::SegHeader::decode(&head)?;
                header.validate_file_len(file_len)?;
                len_hint = usize::try_from(header.m).ok();
                let ranges = plan_segment_ranges(header.seg_count, readers);
                let n = ranges.len();
                for (i, (s0, s1)) in ranges.into_iter().enumerate() {
                    let q: Channel<Vec<Edge>> = Channel::bounded(READ_AHEAD_CHUNKS);
                    let tx = q.clone();
                    let p = path.clone();
                    let st = Arc::clone(&stats);
                    let err = Arc::clone(&error);
                    threads.push(thread::spawn(move || {
                        if let Err(e) = run_binary_reader(&p, header, (s0, s1), batch, &tx, &st) {
                            let (b0, b1) = seg_byte_span(&header, s0, s1);
                            let mut slot = err.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(format!(
                                    "reader {i}/{n} (binary, segments {s0}..{s1}, bytes {b0}..{b1}): {e}"
                                ));
                            }
                        }
                        tx.close();
                    }));
                    queues.push(q);
                }
            }
        }
        Ok(Self {
            queues,
            threads,
            current: 0,
            leftover: Vec::new(),
            leftover_pos: 0,
            stats,
            error,
            len_hint,
            map: None,
        })
    }

    /// Open a segmented binary file in zero-copy mmap mode: one shared
    /// read-only mapping, `readers` threads walking disjoint segment
    /// ranges of it (module docs §mmap mode). Header validation happens
    /// here against the real mapped length — a hostile or truncated
    /// file fails the open as `InvalidData`, never a short-map fault in
    /// a reader. On non-unix targets this is a compile-time fallback to
    /// [`open_with`](Self::open_with)'s buffered binary path (identical
    /// stream, per-range file handles).
    pub fn open_mmap<P: AsRef<Path>>(path: P, readers: usize, batch: usize) -> io::Result<Self> {
        Self::open_mmap_advised(path, readers, batch, Advice::Sequential)
    }

    /// [`open_mmap`](Self::open_mmap) with an explicit page-cache
    /// [`Advice`] (`--madvise` on the CLI). Advice is best-effort and
    /// cannot change the edge stream — only how the kernel stages the
    /// pages behind it.
    pub fn open_mmap_advised<P: AsRef<Path>>(
        path: P,
        readers: usize,
        batch: usize,
        advice: Advice,
    ) -> io::Result<Self> {
        if !mmap::supported() {
            return Self::open_with(path, ScanFormat::Binary, readers, batch);
        }
        let readers = readers.max(1);
        let batch = batch.max(1);
        let f = File::open(path.as_ref())?;
        let map = Arc::new(Mmap::map_file_advised(&f, advice)?);
        drop(f); // the mapping keeps the pages alive
        let header = binfmt::parse_mapped(map.as_slice())?;
        let stats = Arc::new(ScanStats::default());
        let error: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let mut queues = Vec::new();
        let mut threads = Vec::new();
        let ranges = plan_segment_ranges(header.seg_count, readers);
        let n = ranges.len();
        for (i, (s0, s1)) in ranges.into_iter().enumerate() {
            let q: Channel<Vec<Edge>> = Channel::bounded(READ_AHEAD_CHUNKS);
            let tx = q.clone();
            let m = Arc::clone(&map);
            let st = Arc::clone(&stats);
            let err = Arc::clone(&error);
            threads.push(thread::spawn(move || {
                if let Err(e) = run_mmap_reader(&m, header, (s0, s1), batch, &tx, &st) {
                    let (b0, b1) = seg_byte_span(&header, s0, s1);
                    let mut slot = err.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(format!(
                            "reader {i}/{n} (mmap, segments {s0}..{s1}, bytes {b0}..{b1}): {e}"
                        ));
                    }
                }
                tx.close();
            }));
            queues.push(q);
        }
        Ok(Self {
            queues,
            threads,
            current: 0,
            leftover: Vec::new(),
            leftover_pos: 0,
            stats,
            error,
            len_hint: usize::try_from(header.m).ok(),
            map: Some(map),
        })
    }

    /// Number of reader threads actually running (after clamping).
    pub fn readers(&self) -> usize {
        self.queues.len()
    }

    /// `true` when the scan runs over one shared mapping (`open_mmap`
    /// on a unix target); `false` on the buffered path, including the
    /// non-unix `open_mmap` fallback.
    pub fn mmapped(&self) -> bool {
        self.map.is_some()
    }

    /// Shared scan counters (live — safe to read mid-scan).
    pub fn stats(&self) -> Arc<ScanStats> {
        Arc::clone(&self.stats)
    }

    /// First reader failure, if any (I/O error or segment checksum
    /// mismatch). Check after the drain: a failed reader closes its
    /// queue early, so the stream ends short instead of blocking.
    pub fn take_error(&mut self) -> Option<String> {
        self.error.lock().unwrap().take()
    }
}

impl EdgeSource for ParallelScanner {
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize {
        buf.clear();
        while buf.len() < buf.capacity() {
            if self.leftover_pos < self.leftover.len() {
                let take =
                    (buf.capacity() - buf.len()).min(self.leftover.len() - self.leftover_pos);
                buf.extend_from_slice(&self.leftover[self.leftover_pos..self.leftover_pos + take]);
                self.leftover_pos += take;
                continue;
            }
            let Some(q) = self.queues.get(self.current) else {
                break; // every range drained
            };
            match q.recv() {
                Some(chunk) => {
                    self.leftover = chunk;
                    self.leftover_pos = 0;
                }
                None => self.current += 1, // this range is done: next
            }
        }
        buf.len()
    }

    fn len_hint(&self) -> Option<usize> {
        self.len_hint
    }
}

impl Drop for ParallelScanner {
    fn drop(&mut self) {
        // closing the queues turns any blocked reader `send` into an
        // error, so readers exit promptly even on early drop
        for q in &self.queues {
            q.close();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // `self.map` (the last Arc<Mmap>) drops after this body — i.e.
        // after every borrowing reader has joined: unmap-after-join.
    }
}

// --- direct sharded dispatch ----------------------------------------

/// A routed sub-chunk: one destination's edges in file order, tagged
/// with the global sequence index of the first and last edge. Sequence
/// indices are stream positions in the *whole* file (`seg_index ×
/// seg_records + offset`), so consecutive chunks of one destination
/// have strictly increasing, generally non-contiguous spans — the gaps
/// are edges bound elsewhere.
#[derive(Debug)]
pub struct SeqChunk {
    /// Global sequence index of `edges[0]`.
    pub first_seq: u64,
    /// Global sequence index of `edges[last]`.
    pub last_seq: u64,
    /// The destination's edges, in file order.
    pub edges: Vec<Edge>,
}

/// Per-destination pending buffers for one direct reader: edges are
/// routed as they decode and flushed as [`SeqChunk`]s when a
/// destination fills `batch`. Destination `shards` is the cross lane.
///
/// With durability on (`wal` present), every routed edge is appended
/// to its destination's per-reader WAL lane as it is buffered, and the
/// lane is flushed immediately before the chunk's queue push — the
/// WAL-before-enqueue ordering the durable cut depends on. The
/// `ReaderEnqueue` crash point fires between the two.
struct RouteBuffers<'a> {
    sharder: Sharder,
    batch: usize,
    pending: Vec<SeqChunk>,
    txs: &'a [Channel<SeqChunk>],
    wal: Option<DirectWal>,
    /// Set when a crash point stopped this reader mid-stream: pending
    /// buffers must die with it, exactly as a killed process's would.
    stopped: bool,
}

impl<'a> RouteBuffers<'a> {
    fn new(
        sharder: Sharder,
        batch: usize,
        txs: &'a [Channel<SeqChunk>],
        wal: Option<DirectWal>,
    ) -> Self {
        debug_assert_eq!(txs.len(), sharder.shards() + 1);
        let pending = txs
            .iter()
            .map(|_| SeqChunk { first_seq: 0, last_seq: 0, edges: Vec::with_capacity(batch) })
            .collect();
        Self { sharder, batch, pending, txs, wal, stopped: false }
    }

    /// Routing destination → WAL lane (`None` is the cross lane).
    fn lane(&self, d: usize) -> Option<usize> {
        if d == self.sharder.shards() {
            None
        } else {
            Some(d)
        }
    }

    /// Land `full` in its WAL lane, then enqueue it. A `SendError`
    /// means the reader must stop: either the consumer hung up
    /// (scanner aborted/dropped — benign) or the armed crash point
    /// killed the reader between its WAL flush and the queue push.
    fn ship(&mut self, d: usize, full: SeqChunk) -> Result<(), SendError> {
        if let Some(w) = self.wal.as_mut() {
            if !w.flush_chunk(self.lane(d)) {
                self.stopped = true;
                return Err(SendError);
            }
        }
        self.txs[d].send(full)
    }

    /// Route one edge; a `SendError` means the reader should stop
    /// quietly (see [`ship`](Self::ship)).
    fn push(&mut self, seq: u64, e: Edge) -> Result<(), SendError> {
        let d = match self.sharder.route(e) {
            Route::Local(w) => w,
            Route::Cross => self.sharder.shards(),
        };
        if let Some(w) = self.wal.as_mut() {
            let lane = if d == self.sharder.shards() { None } else { Some(d) };
            w.append(lane, seq, e);
        }
        let p = &mut self.pending[d];
        if p.edges.is_empty() {
            p.first_seq = seq;
        }
        p.last_seq = seq;
        p.edges.push(e);
        if p.edges.len() >= self.batch {
            let full = std::mem::replace(
                p,
                SeqChunk { first_seq: 0, last_seq: 0, edges: Vec::with_capacity(self.batch) },
            );
            self.ship(d, full)?;
        }
        Ok(())
    }

    /// Ship every non-empty pending buffer (end of the reader's
    /// range), then fsync the reader's WAL lanes — the reader-exit
    /// sync that makes the end-of-stream checkpoint cut durable.
    fn flush(&mut self) -> Result<(), SendError> {
        if self.stopped {
            return Ok(());
        }
        for d in 0..self.pending.len() {
            if !self.pending[d].edges.is_empty() {
                let full = std::mem::replace(
                    &mut self.pending[d],
                    SeqChunk { first_seq: 0, last_seq: 0, edges: Vec::new() },
                );
                self.ship(d, full)?;
            }
        }
        if let Some(w) = self.wal.as_mut() {
            w.sync();
        }
        Ok(())
    }
}

/// Buffered direct reader: decode each owned segment, route every edge
/// through the shared [`Sharder`], tag it with its global sequence
/// index, and ship per-destination sub-chunks.
fn run_direct_binary_reader(
    path: &Path,
    header: binfmt::SegHeader,
    segs: (u64, u64),
    batch: usize,
    sharder: Sharder,
    txs: &[Channel<SeqChunk>],
    stats: &ScanStats,
    wal: Option<DirectWal>,
) -> io::Result<()> {
    let mut f = File::open(path)?;
    let off = header.seg_offset(segs.0).expect("validated header");
    f.seek(SeekFrom::Start(off))?;
    let mut reader = BufReader::with_capacity(1 << 20, f);
    let mut block = Vec::new();
    let mut edges: Vec<Edge> = Vec::new();
    let mut bufs = RouteBuffers::new(sharder, batch, txs, wal);
    for seg in segs.0..segs.1 {
        let records = header.records_in(seg);
        block.resize((binfmt::SEG_OVERHEAD_BYTES + records * binfmt::RECORD_BYTES) as usize, 0);
        reader.read_exact(&mut block)?;
        edges.clear();
        binfmt::decode_segment(&block, records, seg, &mut edges)?;
        stats.segments_verified.fetch_add(1, Ordering::Relaxed);
        stats.bytes_read.fetch_add(block.len() as u64, Ordering::Relaxed);
        let base = seg * header.seg_records;
        for (i, &e) in edges.iter().enumerate() {
            if bufs.push(base + i as u64, e).is_err() {
                return Ok(()); // consumer hung up: benign early stop
            }
        }
    }
    let _ = bufs.flush();
    Ok(())
}

/// Zero-copy direct reader: the mmap counterpart of
/// [`run_direct_binary_reader`] — checksums verified in place, records
/// routed straight out of the mapping.
fn run_direct_mmap_reader(
    map: &Mmap,
    header: binfmt::SegHeader,
    segs: (u64, u64),
    batch: usize,
    sharder: Sharder,
    txs: &[Channel<SeqChunk>],
    stats: &ScanStats,
    wal: Option<DirectWal>,
) -> io::Result<()> {
    let bytes = map.as_slice();
    let mut bufs = RouteBuffers::new(sharder, batch, txs, wal);
    for seg in segs.0..segs.1 {
        let records = header.records_in(seg);
        let off = header.seg_offset(seg).expect("validated header") as usize;
        let len = header.seg_bytes(seg) as usize;
        let view = binfmt::SegView::parse(&bytes[off..off + len], records, seg)?;
        stats.segments_verified.fetch_add(1, Ordering::Relaxed);
        stats.bytes_read.fetch_add(len as u64, Ordering::Relaxed);
        let base = seg * header.seg_records;
        for (i, e) in view.edges().enumerate() {
            if bufs.push(base + i as u64, e).is_err() {
                return Ok(()); // consumer hung up: benign early stop
            }
        }
    }
    let _ = bufs.flush();
    Ok(())
}

/// Direct sharded dispatch over one segmented binary file: `readers`
/// threads route their own segments through a shared [`Sharder`] and
/// deliver per-destination [`SeqChunk`]s; per-destination [`DestFeed`]s
/// replay each destination's subsequence in file order (module docs
/// §direct mode). Text inputs are unsupported by construction — they
/// have no fixed record geometry, so there is no coordination-free
/// global sequence index.
pub struct DirectScan {
    /// `queues[reader][dest]`; dest `shards` is the cross lane.
    queues: Vec<Vec<Channel<SeqChunk>>>,
    threads: Vec<JoinHandle<()>>,
    shards: usize,
    stats: Arc<ScanStats>,
    error: Arc<Mutex<Option<String>>>,
    len_hint: Option<usize>,
    feeds_taken: bool,
    /// the one shared mapping in mmap mode (`None` buffered);
    /// unmap-after-join as in [`ParallelScanner`].
    map: Option<Arc<Mmap>>,
    /// shared WAL byte counter when the scan writes durable lanes
    /// (`None` with durability off) — see [`Self::wal_bytes`].
    wal_bytes: Option<Arc<AtomicU64>>,
}

impl DirectScan {
    /// Open `path` (segmented binary) with buffered per-range file
    /// handles, routing into `shards` local lanes + one cross lane.
    /// The header is decoded and length-validated here, so a corrupt
    /// or hostile header fails the open, not a reader thread. With
    /// `wal` set, each reader appends its routed chunks to per-reader
    /// durable lanes before enqueueing them (module docs §direct
    /// mode).
    pub fn open<P: AsRef<Path>>(
        path: P,
        readers: usize,
        batch: usize,
        shards: usize,
        wal: Option<DirectWalCfg>,
    ) -> io::Result<Self> {
        let path: PathBuf = path.as_ref().to_path_buf();
        let batch = batch.max(1);
        let sharder = Sharder::new(shards.max(1));
        let f = File::open(&path)?;
        let file_len = f.metadata()?.len();
        let mut r = BufReader::new(f);
        let mut head = [0u8; binfmt::HEADER_BYTES];
        r.read_exact(&mut head)?;
        let header = binfmt::SegHeader::decode(&head)?;
        header.validate_file_len(file_len)?;
        let mut scan = Self::shell(sharder.shards(), usize::try_from(header.m).ok(), None);
        scan.wal_bytes = wal.as_ref().map(|c| Arc::clone(&c.bytes));
        let ranges = plan_segment_ranges(header.seg_count, readers.max(1));
        let n = ranges.len();
        for (i, (s0, s1)) in ranges.into_iter().enumerate() {
            let txs = scan.add_reader_queues(sharder.shards());
            let p = path.clone();
            let st = Arc::clone(&scan.stats);
            let err = Arc::clone(&scan.error);
            let cfg = wal.clone();
            scan.threads.push(thread::spawn(move || {
                let res = match cfg.as_ref().map(|c| DirectWal::open(c, i)).transpose() {
                    Ok(w) => {
                        run_direct_binary_reader(&p, header, (s0, s1), batch, sharder, &txs, &st, w)
                    }
                    Err(e) => Err(e),
                };
                if let Err(e) = res {
                    let (b0, b1) = seg_byte_span(&header, s0, s1);
                    let mut slot = err.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(format!(
                            "reader {i}/{n} (binary, segments {s0}..{s1}, bytes {b0}..{b1}): {e}"
                        ));
                    }
                }
                for tx in &txs {
                    tx.close();
                }
            }));
        }
        Ok(scan)
    }

    /// [`open`](Self::open) over one shared read-only mapping with
    /// default (sequential) advice. Non-unix targets fall back to the
    /// buffered path at compile time with identical semantics.
    pub fn open_mmap<P: AsRef<Path>>(
        path: P,
        readers: usize,
        batch: usize,
        shards: usize,
        wal: Option<DirectWalCfg>,
    ) -> io::Result<Self> {
        Self::open_mmap_advised(path, readers, batch, shards, wal, Advice::Sequential)
    }

    /// [`open_mmap`](Self::open_mmap) with an explicit page-cache
    /// [`Advice`] (`--madvise` on the CLI).
    pub fn open_mmap_advised<P: AsRef<Path>>(
        path: P,
        readers: usize,
        batch: usize,
        shards: usize,
        wal: Option<DirectWalCfg>,
        advice: Advice,
    ) -> io::Result<Self> {
        if !mmap::supported() {
            return Self::open(path, readers, batch, shards, wal);
        }
        let batch = batch.max(1);
        let sharder = Sharder::new(shards.max(1));
        let f = File::open(path.as_ref())?;
        let map = Arc::new(Mmap::map_file_advised(&f, advice)?);
        drop(f); // the mapping keeps the pages alive
        let header = binfmt::parse_mapped(map.as_slice())?;
        let mut scan = Self::shell(
            sharder.shards(),
            usize::try_from(header.m).ok(),
            Some(Arc::clone(&map)),
        );
        scan.wal_bytes = wal.as_ref().map(|c| Arc::clone(&c.bytes));
        let ranges = plan_segment_ranges(header.seg_count, readers.max(1));
        let n = ranges.len();
        for (i, (s0, s1)) in ranges.into_iter().enumerate() {
            let txs = scan.add_reader_queues(sharder.shards());
            let m = Arc::clone(&map);
            let st = Arc::clone(&scan.stats);
            let err = Arc::clone(&scan.error);
            let cfg = wal.clone();
            scan.threads.push(thread::spawn(move || {
                let res = match cfg.as_ref().map(|c| DirectWal::open(c, i)).transpose() {
                    Ok(w) => {
                        run_direct_mmap_reader(&m, header, (s0, s1), batch, sharder, &txs, &st, w)
                    }
                    Err(e) => Err(e),
                };
                if let Err(e) = res {
                    let (b0, b1) = seg_byte_span(&header, s0, s1);
                    let mut slot = err.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(format!(
                            "reader {i}/{n} (mmap, segments {s0}..{s1}, bytes {b0}..{b1}): {e}"
                        ));
                    }
                }
                for tx in &txs {
                    tx.close();
                }
            }));
        }
        Ok(scan)
    }

    /// An empty scan with shared counters, ready to take readers.
    fn shell(shards: usize, len_hint: Option<usize>, map: Option<Arc<Mmap>>) -> Self {
        Self {
            queues: Vec::new(),
            threads: Vec::new(),
            shards,
            stats: Arc::new(ScanStats::default()),
            error: Arc::new(Mutex::new(None)),
            len_hint,
            feeds_taken: false,
            map,
            wal_bytes: None,
        }
    }

    /// Register one reader's `shards + 1` destination queues and hand
    /// back the reader-side clones.
    fn add_reader_queues(&mut self, shards: usize) -> Vec<Channel<SeqChunk>> {
        let row: Vec<Channel<SeqChunk>> =
            (0..=shards).map(|_| Channel::bounded(READ_AHEAD_CHUNKS)).collect();
        let txs = row.clone();
        self.queues.push(row);
        txs
    }

    /// One [`DestFeed`] per shard plus the cross-lane feed, each
    /// replaying its destination's subsequence in file order. Panics
    /// if called twice — a feed owns its destination's cursor.
    pub fn feeds(&mut self) -> (Vec<DestFeed>, DestFeed) {
        assert!(!self.feeds_taken, "DirectScan::feeds may only be taken once");
        self.feeds_taken = true;
        let shard_feeds = (0..self.shards).map(|d| self.feed_for(d)).collect();
        (shard_feeds, self.feed_for(self.shards))
    }

    /// The consumer cursor for destination `d` (reader queues in range
    /// order).
    fn feed_for(&self, d: usize) -> DestFeed {
        DestFeed {
            queues: self.queues.iter().map(|row| row[d].clone()).collect(),
            current: 0,
            prev_seq: None,
        }
    }

    /// A detached handle that aborts the scan: closing every queue
    /// stops the readers (their sends error) and ends every feed after
    /// the buffered chunks drain.
    pub fn abort_handle(&self) -> ScanAbort {
        ScanAbort { queues: self.queues.iter().flatten().cloned().collect() }
    }

    /// Number of reader threads actually running (after clamping to
    /// the segment count).
    pub fn readers(&self) -> usize {
        self.queues.len()
    }

    /// Local destination lanes (the shard count routed for).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// `true` when the scan runs over one shared mapping.
    pub fn mmapped(&self) -> bool {
        self.map.is_some()
    }

    /// Shared scan counters (live — safe to read mid-scan).
    pub fn stats(&self) -> Arc<ScanStats> {
        Arc::clone(&self.stats)
    }

    /// Edge count from the header, when it fits a `usize`.
    pub fn len_hint(&self) -> Option<usize> {
        self.len_hint
    }

    /// Total bytes the readers have appended to their WAL lanes so
    /// far (live — the counter shared through [`DirectWalCfg`]), or
    /// `None` when the scan was opened without durability.
    pub fn wal_bytes(&self) -> Option<u64> {
        self.wal_bytes.as_ref().map(|b| b.load(Ordering::Relaxed))
    }

    /// First reader failure, if any — same contract and uniform
    /// message format as [`ParallelScanner::take_error`].
    pub fn take_error(&mut self) -> Option<String> {
        self.error.lock().unwrap().take()
    }
}

impl Drop for DirectScan {
    fn drop(&mut self) {
        for q in self.queues.iter().flatten() {
            q.close();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // `self.map` drops after this body: unmap-after-join.
    }
}

/// The consumer cursor for one destination of a [`DirectScan`]:
/// concatenates that destination's per-reader queues in range order,
/// which replays exactly the subsequence of the file bound for this
/// destination, in file order. Chunk spans are strictly increasing
/// (debug-asserted) — the reorder needs no heap because readers own
/// contiguous, sorted segment ranges.
pub struct DestFeed {
    queues: Vec<Channel<SeqChunk>>,
    current: usize,
    prev_seq: Option<u64>,
}

impl DestFeed {
    /// Next sub-chunk in global-sequence order; `None` once every
    /// reader has finished (or the scan was aborted and drained).
    pub fn recv(&mut self) -> Option<SeqChunk> {
        while let Some(q) = self.queues.get(self.current) {
            match q.recv() {
                Some(chunk) => {
                    if let Some(p) = self.prev_seq {
                        debug_assert!(
                            chunk.first_seq > p,
                            "sub-chunk sequence went backwards: {} after {p}",
                            chunk.first_seq
                        );
                    }
                    debug_assert!(!chunk.edges.is_empty());
                    self.prev_seq = Some(chunk.last_seq);
                    return Some(chunk);
                }
                None => self.current += 1, // this reader is done: next
            }
        }
        None
    }
}

/// Closes every queue of a [`DirectScan`] — see
/// [`DirectScan::abort_handle`].
pub struct ScanAbort {
    queues: Vec<Channel<SeqChunk>>,
}

impl ScanAbort {
    /// Abort the scan. Idempotent; safe from any thread.
    pub fn abort(&self) {
        for q in &self.queues {
            q.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::EdgeList;
    use crate::graph::io::write_binary_edges_with;
    use crate::stream::source::{collect, BinaryFileSource, TextFileSource};

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("streamcom_pscan_{}_{name}", std::process::id()));
        p
    }

    /// Deterministic LCG (no rand crate offline).
    fn lcg(seed: u64) -> impl FnMut() -> u64 {
        let mut x = seed.max(1);
        move || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            x >> 33
        }
    }

    fn messy_text(lines: usize, seed: u64) -> String {
        let mut rng = lcg(seed);
        let mut s = String::new();
        for i in 0..lines {
            match rng() % 12 {
                0 => s.push_str("# a comment line of middling length\n"),
                1 => s.push('\n'),
                2 => s.push_str(&format!("{} {}\n", rng() % 300, rng() % 300)), // may self-loop
                3 => s.push_str(&format!("{} oops\n", rng() % 300)),            // malformed
                4 => s.push_str(&format!("{} {}\n", 1u64 << 40, rng() % 300)),  // oversized
                _ => s.push_str(&format!("{}\t{}\n", i % 997, (i * 7 + 1) % 997)),
            }
        }
        s
    }

    #[test]
    fn segment_ranges_cover_contiguously_and_clamp() {
        assert_eq!(plan_segment_ranges(0, 4), vec![]);
        assert_eq!(plan_segment_ranges(2, 8), vec![(0, 1), (1, 2)], "clamped to seg count");
        let r = plan_segment_ranges(10, 3);
        assert_eq!(r, vec![(0, 4), (4, 7), (7, 10)]);
        assert_eq!(r.first().unwrap().0, 0);
        assert_eq!(r.last().unwrap().1, 10);
    }

    #[test]
    fn text_ranges_align_to_line_starts_and_cover_the_file() {
        let p = tmp("ranges.txt");
        let data = messy_text(400, 7);
        std::fs::write(&p, &data).unwrap();
        for readers in 1..=5 {
            let ranges = plan_text_ranges(&p, readers).unwrap();
            assert_eq!(ranges.first().unwrap().0, 0);
            assert_eq!(ranges.last().unwrap().1, data.len() as u64);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            for &(s, _) in &ranges[1..] {
                assert_eq!(data.as_bytes()[s as usize - 1], b'\n', "boundary at a line start");
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_scan_matches_single_reader_edge_for_edge() {
        let p = tmp("order.txt");
        std::fs::write(&p, messy_text(3000, 42)).unwrap();
        let mut single = TextFileSource::open(&p).unwrap();
        let want = collect(&mut single, 64);
        assert!(!want.is_empty());
        for readers in 1..=4 {
            let mut sc = ParallelScanner::open_with(&p, ScanFormat::Text, readers, 64).unwrap();
            let got = collect(&mut sc, 64);
            assert_eq!(got, want, "readers={readers}");
            assert!(sc.take_error().is_none());
            let stats = sc.stats();
            assert_eq!(stats.oversized_skipped(), single.oversized_skipped());
            assert_eq!(stats.malformed_skipped(), single.malformed_skipped());
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_scan_matches_single_reader_edge_for_edge() {
        let p = tmp("order.bin");
        let mut rng = lcg(99);
        let edges: Vec<Edge> =
            (0..5000).map(|_| Edge::new((rng() % 800) as u32, (rng() % 800) as u32)).collect();
        let el = EdgeList::new(800, edges);
        write_binary_edges_with(&p, &el, 64).unwrap(); // 79 segments
        let mut single = BinaryFileSource::open(&p).unwrap();
        let want = collect(&mut single, 97);
        assert_eq!(want, el.edges);
        for readers in [1usize, 2, 3, 8, 200] {
            let mut sc = ParallelScanner::open_with(&p, ScanFormat::Binary, readers, 97).unwrap();
            assert_eq!(sc.len_hint(), Some(5000));
            assert!(sc.readers() <= 79, "clamped to segment count");
            let got = collect(&mut sc, 97);
            assert_eq!(got, want, "readers={readers}");
            assert!(sc.take_error().is_none());
            assert_eq!(sc.stats().segments_verified(), 79);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupt_segment_surfaces_through_take_error() {
        let p = tmp("corrupt.bin");
        let el = EdgeList::new(101, (0..100u32).map(|i| Edge::new(i, i + 1)).collect());
        write_binary_edges_with(&p, &el, 16).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let seg2 = binfmt::HEADER_BYTES + 2 * (16 + 16 * 8);
        bytes[seg2 + 8 + 3] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let mut sc = ParallelScanner::open_with(&p, ScanFormat::Binary, 2, 32).unwrap();
        let _ = collect(&mut sc, 32);
        let err = sc.take_error().expect("corruption must surface");
        assert!(err.contains("segment 2"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn early_drop_does_not_hang() {
        let p = tmp("drop.txt");
        std::fs::write(&p, messy_text(20_000, 5)).unwrap();
        let mut sc = ParallelScanner::open_with(&p, ScanFormat::Text, 4, 16).unwrap();
        let mut buf = Vec::with_capacity(16);
        assert!(sc.next_batch(&mut buf) > 0);
        drop(sc); // readers blocked on full queues must still exit
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn hostile_binary_header_fails_the_open_not_a_thread() {
        let p = tmp("hostile.bin");
        let h = binfmt::SegHeader::new(8, 1u64 << 61, binfmt::DEFAULT_SEG_RECORDS).unwrap();
        std::fs::write(&p, h.encode()).unwrap();
        let err = ParallelScanner::open_with(&p, ScanFormat::Binary, 4, 32).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // the mmap open shares the gate (falls back to the same gate on
        // non-unix) — InvalidData, not a fault on the short map
        let err = ParallelScanner::open_mmap(&p, 4, 32).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mmap_scan_matches_buffered_scan_edge_for_edge() {
        let p = tmp("mmap_order.bin");
        let mut rng = lcg(4242);
        let edges: Vec<Edge> =
            (0..5000).map(|_| Edge::new((rng() % 800) as u32, (rng() % 800) as u32)).collect();
        let el = EdgeList::new(800, edges);
        write_binary_edges_with(&p, &el, 64).unwrap(); // 79 segments
        let mut single = BinaryFileSource::open(&p).unwrap();
        let want = collect(&mut single, 97);
        assert_eq!(want, el.edges);
        for readers in [1usize, 2, 4, 200] {
            let mut sc = ParallelScanner::open_mmap(&p, readers, 97).unwrap();
            assert_eq!(sc.len_hint(), Some(5000));
            assert!(sc.readers() <= 79, "clamped to segment count");
            assert_eq!(sc.mmapped(), mmap::supported());
            let got = collect(&mut sc, 97);
            assert_eq!(got, want, "readers={readers}");
            assert!(sc.take_error().is_none());
            let stats = sc.stats();
            assert_eq!(stats.segments_verified(), 79);
            assert!(stats.bytes_read() > 0);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mmap_scan_surfaces_corruption_through_take_error() {
        let p = tmp("mmap_corrupt.bin");
        let el = EdgeList::new(101, (0..100u32).map(|i| Edge::new(i, i + 1)).collect());
        write_binary_edges_with(&p, &el, 16).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let seg2 = binfmt::HEADER_BYTES + 2 * (16 + 16 * 8);
        bytes[seg2 + 8 + 3] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let mut sc = ParallelScanner::open_mmap(&p, 2, 32).unwrap();
        let _ = collect(&mut sc, 32);
        let err = sc.take_error().expect("corruption must surface");
        assert!(err.contains("segment 2"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mmap_scan_early_drop_unmaps_after_join() {
        // drop mid-stream with full queues: readers must exit, join,
        // and the mapping must be released without a hang or fault
        let p = tmp("mmap_drop.bin");
        let edges: Vec<Edge> =
            (0..20_000u32).map(|i| Edge::new(i % 2000, (i + 1) % 2000)).collect();
        let el = EdgeList::new(2001, edges);
        write_binary_edges_with(&p, &el, 64).unwrap();
        let mut sc = ParallelScanner::open_mmap(&p, 4, 16).unwrap();
        let mut buf = Vec::with_capacity(16);
        assert!(sc.next_batch(&mut buf) > 0);
        drop(sc);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mmap_scan_handles_the_empty_file() {
        let p = tmp("mmap_empty.bin");
        let el = EdgeList::new(0, Vec::new());
        write_binary_edges_with(&p, &el, 16).unwrap();
        let mut sc = ParallelScanner::open_mmap(&p, 4, 32).unwrap();
        assert_eq!(sc.readers(), 0, "no segments, no readers");
        assert_eq!(collect(&mut sc, 32), vec![]);
        assert!(sc.take_error().is_none());
        std::fs::remove_file(&p).ok();
    }

    // --- direct sharded dispatch ------------------------------------

    /// Drain one feed on its own thread (feeds must drain concurrently
    /// — a lone consumer would deadlock against reader backpressure on
    /// the other destinations' queues).
    fn spawn_drain(mut feed: DestFeed) -> JoinHandle<Vec<(u64, u64, Vec<Edge>)>> {
        thread::spawn(move || {
            let mut out = Vec::new();
            while let Some(c) = feed.recv() {
                out.push((c.first_seq, c.last_seq, c.edges));
            }
            out
        })
    }

    /// Expected (global position, edge) stream for one destination:
    /// the file subsequence the shared sharder routes there.
    fn expected_for(el: &EdgeList, sharder: Sharder, dest: usize) -> Vec<(u64, Edge)> {
        el.edges
            .iter()
            .enumerate()
            .filter(|&(_, &e)| {
                let d = match sharder.route(e) {
                    Route::Local(w) => w,
                    Route::Cross => sharder.shards(),
                };
                d == dest
            })
            .map(|(i, &e)| (i as u64, e))
            .collect()
    }

    fn assert_chunks_replay(
        chunks: &[(u64, u64, Vec<Edge>)],
        expected: &[(u64, Edge)],
        what: &str,
    ) {
        let mut k = 0usize;
        for (first, last, edges) in chunks {
            assert!(!edges.is_empty(), "{what}: empty chunk");
            assert_eq!(*first, expected[k].0, "{what}: first_seq at {k}");
            assert_eq!(*last, expected[k + edges.len() - 1].0, "{what}: last_seq at {k}");
            for (j, e) in edges.iter().enumerate() {
                assert_eq!(*e, expected[k + j].1, "{what}: edge at {}", k + j);
            }
            k += edges.len();
        }
        assert_eq!(k, expected.len(), "{what}: edge count");
    }

    #[test]
    fn direct_scan_replays_each_destination_in_file_order() {
        // both transports, several reader counts: every destination
        // (4 locals + cross) must see exactly its file subsequence with
        // exact global sequence tags — seg_records=64 makes the global
        // index of edge i equal i, so the tags are checkable in closed
        // form
        let p = tmp("direct_order.bin");
        let mut rng = lcg(2024);
        let edges: Vec<Edge> =
            (0..5000).map(|_| Edge::new((rng() % 800) as u32, (rng() % 800) as u32)).collect();
        let el = EdgeList::new(800, edges);
        write_binary_edges_with(&p, &el, 64).unwrap(); // 79 segments
        let shards = 4;
        let sharder = Sharder::new(shards);
        for mmapped in [false, true] {
            for readers in [1usize, 2, 3, 200] {
                let mut sc = if mmapped {
                    DirectScan::open_mmap(&p, readers, 97, shards, None).unwrap()
                } else {
                    DirectScan::open(&p, readers, 97, shards, None).unwrap()
                };
                assert_eq!(sc.len_hint(), Some(5000));
                assert_eq!(sc.shards(), shards);
                assert!(sc.readers() <= 79, "clamped to segment count");
                let (shard_feeds, cross_feed) = sc.feeds();
                let handles: Vec<_> = shard_feeds.into_iter().map(spawn_drain).collect();
                let cross = spawn_drain(cross_feed);
                for (d, h) in handles.into_iter().enumerate() {
                    let got = h.join().unwrap();
                    let want = expected_for(&el, sharder, d);
                    assert_chunks_replay(&got, &want, &format!("shard {d} readers={readers}"));
                }
                let got = cross.join().unwrap();
                let want = expected_for(&el, sharder, shards);
                assert_chunks_replay(&got, &want, &format!("cross readers={readers}"));
                assert!(sc.take_error().is_none());
                assert_eq!(sc.stats().segments_verified(), 79);
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn direct_scan_surfaces_corruption_with_the_uniform_reader_prefix() {
        let p = tmp("direct_corrupt.bin");
        let el = EdgeList::new(101, (0..100u32).map(|i| Edge::new(i, i + 1)).collect());
        write_binary_edges_with(&p, &el, 16).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let seg2 = binfmt::HEADER_BYTES + 2 * (16 + 16 * 8);
        bytes[seg2 + 8 + 3] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let mut sc = DirectScan::open(&p, 2, 32, 2, None).unwrap();
        let (shard_feeds, cross_feed) = sc.feeds();
        let handles: Vec<_> = shard_feeds.into_iter().map(spawn_drain).collect();
        let cross = spawn_drain(cross_feed);
        for h in handles {
            let _ = h.join().unwrap();
        }
        let _ = cross.join().unwrap();
        let err = sc.take_error().expect("corruption must surface");
        assert!(err.starts_with("reader "), "{err}");
        assert!(err.contains("segment 2"), "{err}");
        assert!(err.contains("bytes "), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn direct_scan_abort_and_early_drop_do_not_hang() {
        let p = tmp("direct_drop.bin");
        let edges: Vec<Edge> =
            (0..20_000u32).map(|i| Edge::new(i % 2000, (i + 1) % 2000)).collect();
        let el = EdgeList::new(2001, edges);
        write_binary_edges_with(&p, &el, 64).unwrap();
        let mut sc = DirectScan::open_mmap(&p, 4, 16, 4, None).unwrap();
        let abort = sc.abort_handle();
        let (shard_feeds, cross_feed) = sc.feeds();
        let mut feeds: Vec<DestFeed> = shard_feeds;
        feeds.push(cross_feed);
        // pull one chunk off the first feed, then abort: every feed
        // must terminate even though most queues were full
        let first = feeds[0].recv();
        assert!(first.is_some(), "shard 0 must see at least one chunk");
        abort.abort();
        let handles: Vec<_> = feeds.into_iter().map(spawn_drain).collect();
        for h in handles {
            let _ = h.join().unwrap();
        }
        drop(sc);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn direct_scan_hostile_header_fails_the_open_not_a_thread() {
        let p = tmp("direct_hostile.bin");
        let h = binfmt::SegHeader::new(8, 1u64 << 61, binfmt::DEFAULT_SEG_RECORDS).unwrap();
        std::fs::write(&p, h.encode()).unwrap();
        let err = DirectScan::open(&p, 4, 32, 4, None).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        let err = DirectScan::open_mmap(&p, 4, 32, 4, None).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn uniform_error_prefix_names_reader_and_byte_span_on_every_path() {
        // truncate a multi-segment file mid-payload *after* open so the
        // buffered binary reader hits a clean EOF error, then check the
        // parked message carries the uniform prefix
        let p = tmp("uniform_err.bin");
        let el = EdgeList::new(301, (0..300u32).map(|i| Edge::new(i, i + 1)).collect());
        write_binary_edges_with(&p, &el, 32).unwrap();
        let clean = std::fs::read(&p).unwrap();
        let mut sc = ParallelScanner::open_with(&p, ScanFormat::Binary, 2, 64).unwrap();
        // racing the readers is fine either way: if they finish before
        // the truncation lands there is simply no error to inspect
        std::fs::write(&p, &clean[..clean.len() / 2]).unwrap();
        let _ = collect(&mut sc, 64);
        if let Some(err) = sc.take_error() {
            assert!(err.starts_with("reader "), "{err}");
            assert!(err.contains("segments "), "{err}");
            assert!(err.contains("bytes "), "{err}");
        }
        std::fs::remove_file(&p).ok();
    }
}
