//! Hash-sharding primitives for the routing core.
//!
//! Node space is split across `shards` by multiplicative hashing.
//! An edge whose endpoints fall in the same shard is routed to that
//! shard's worker; a *cross-shard* edge is deferred, because its
//! decision needs both shards' community state. The one consumer of
//! these primitives is `service::router` — the single routing core
//! behind both the service and the batch coordinator.

use crate::graph::edge::Edge;

/// Multiplicative (Fibonacci) hash of a node id into `shards` buckets.
#[inline]
pub fn shard_of(node: u32, shards: usize) -> usize {
    debug_assert!(shards > 0);
    let h = (node as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    ((h >> 32) as usize * shards) >> 32
}

/// Routing decision for one edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Both endpoints in shard `i`.
    Local(usize),
    /// Endpoints in different shards → leader.
    Cross,
}

#[inline]
/// Classify an edge: same-shard (`Local`) or leader-bound (`Cross`).
pub fn route(edge: Edge, shards: usize) -> Route {
    let a = shard_of(edge.u, shards);
    let b = shard_of(edge.v, shards);
    if a == b {
        Route::Local(a)
    } else {
        Route::Cross
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for shards in [1, 2, 3, 8, 16] {
            for node in 0..1000u32 {
                let s = shard_of(node, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(node, shards));
            }
        }
    }

    #[test]
    fn shard_of_is_roughly_balanced() {
        let shards = 8;
        let mut counts = vec![0usize; shards];
        for node in 0..80_000u32 {
            counts[shard_of(node, shards)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn route_classification() {
        let shards = 4;
        // find a same-shard pair and a cross pair deterministically
        let mut same = None;
        let mut cross = None;
        'outer: for u in 0..100u32 {
            for v in (u + 1)..100u32 {
                let e = Edge::new(u, v);
                match route(e, shards) {
                    Route::Local(_) if same.is_none() => same = Some(e),
                    Route::Cross if cross.is_none() => cross = Some(e),
                    _ => {}
                }
                if same.is_some() && cross.is_some() {
                    break 'outer;
                }
            }
        }
        assert!(same.is_some() && cross.is_some());
    }

    #[test]
    fn route_partitions_every_edge_exactly_once() {
        let shards = 4;
        let chunk: Vec<Edge> = (0..1000u32).map(|i| Edge::new(i, (i * 7) % 500)).collect();
        let chunk: Vec<Edge> = chunk.into_iter().filter(|e| !e.is_self_loop()).collect();
        let mut nlocal = 0;
        let mut ncross = 0;
        for &e in &chunk {
            match route(e, shards) {
                Route::Local(_) => nlocal += 1,
                Route::Cross => ncross += 1,
            }
        }
        assert_eq!(nlocal + ncross, chunk.len());
        assert!(nlocal > 0 && ncross > 0, "both classes must occur");
    }

    #[test]
    fn self_loops_always_route_local() {
        // the service's incremental drain relies on the cross buffer
        // never containing self-loops
        for shards in [1, 2, 4, 16] {
            for u in 0..200u32 {
                assert!(matches!(route(Edge::new(u, u), shards), Route::Local(_)));
            }
        }
    }
}
