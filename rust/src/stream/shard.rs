//! Hash-sharding primitives for the routing core.
//!
//! Node space is split across `shards` by multiplicative hashing.
//! An edge whose endpoints fall in the same shard is routed to that
//! shard's worker; a *cross-shard* edge is deferred, because its
//! decision needs both shards' community state. The hot-path consumer
//! of these primitives is `service::router` — the single routing core
//! behind both the service and the batch coordinator — which holds a
//! [`Sharder`] so the power-of-two fast path is chosen once per run
//! instead of once per edge; the free functions remain for one-off
//! callers (leader partitioning, tests).

use crate::graph::edge::Edge;

/// The multiplier of the multiplicative (Fibonacci) hash.
const FIB: u64 = 0x9E37_79B9_7F4A_7C15;

/// Multiplicative (Fibonacci) hash of a node id into `shards` buckets.
#[inline]
pub fn shard_of(node: u32, shards: usize) -> usize {
    debug_assert!(shards > 0);
    let h = (node as u64).wrapping_mul(FIB);
    ((h >> 32) as usize * shards) >> 32
}

/// A precomputed shard router: the per-edge "which bucket?" decision of
/// [`shard_of`] with the bucket-count dispatch hoisted to construction
/// time. When `shards` is a power of two `2^k` the generic
/// multiply-shift reduction collapses to a plain shift of the hash
/// (`((h >> 32) · 2^k) >> 32 = h >> (64 − k)` for `k ≤ 32`), saving a
/// multiply on every endpoint of every edge on the hot path; any other
/// count keeps the generic path. Both paths are **bit-identical** to
/// [`shard_of`] (unit-tested exhaustively), so the fast path can never
/// change where an edge lands — only how fast the answer is computed.
#[derive(Debug, Clone, Copy)]
pub struct Sharder {
    shards: usize,
    /// `64 − log2(shards)` when `shards` is a power of two in
    /// `[2, 2^32]`; `0` selects the generic multiply path.
    shift: u32,
}

impl Sharder {
    /// Precompute the routing mode for `shards` buckets.
    pub fn new(shards: usize) -> Self {
        debug_assert!(shards > 0);
        let k = shards.trailing_zeros();
        let shift = if shards.is_power_of_two() && (1..=32).contains(&k) {
            64 - k
        } else {
            0
        };
        Self { shards, shift }
    }

    /// The bucket count this router was built for.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// True when the power-of-two shift path is active.
    pub fn is_pow2_fast_path(&self) -> bool {
        self.shift != 0
    }

    /// Bucket of `node` — identical to `shard_of(node, self.shards())`.
    #[inline]
    pub fn shard_of(&self, node: u32) -> usize {
        if self.shards == 1 {
            return 0;
        }
        let h = (node as u64).wrapping_mul(FIB);
        if self.shift != 0 {
            (h >> self.shift) as usize
        } else {
            ((h >> 32) as usize * self.shards) >> 32
        }
    }

    /// Classify an edge — identical to `route(edge, self.shards())`.
    #[inline]
    pub fn route(&self, edge: Edge) -> Route {
        let a = self.shard_of(edge.u);
        let b = self.shard_of(edge.v);
        if a == b {
            Route::Local(a)
        } else {
            Route::Cross
        }
    }
}

/// Routing decision for one edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Both endpoints in shard `i`.
    Local(usize),
    /// Endpoints in different shards → leader.
    Cross,
}

#[inline]
/// Classify an edge: same-shard (`Local`) or leader-bound (`Cross`).
pub fn route(edge: Edge, shards: usize) -> Route {
    let a = shard_of(edge.u, shards);
    let b = shard_of(edge.v, shards);
    if a == b {
        Route::Local(a)
    } else {
        Route::Cross
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for shards in [1, 2, 3, 8, 16] {
            for node in 0..1000u32 {
                let s = shard_of(node, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(node, shards));
            }
        }
    }

    #[test]
    fn shard_of_is_roughly_balanced() {
        let shards = 8;
        let mut counts = vec![0usize; shards];
        for node in 0..80_000u32 {
            counts[shard_of(node, shards)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn route_classification() {
        let shards = 4;
        // find a same-shard pair and a cross pair deterministically
        let mut same = None;
        let mut cross = None;
        'outer: for u in 0..100u32 {
            for v in (u + 1)..100u32 {
                let e = Edge::new(u, v);
                match route(e, shards) {
                    Route::Local(_) if same.is_none() => same = Some(e),
                    Route::Cross if cross.is_none() => cross = Some(e),
                    _ => {}
                }
                if same.is_some() && cross.is_some() {
                    break 'outer;
                }
            }
        }
        assert!(same.is_some() && cross.is_some());
    }

    #[test]
    fn route_partitions_every_edge_exactly_once() {
        let shards = 4;
        let chunk: Vec<Edge> = (0..1000u32).map(|i| Edge::new(i, (i * 7) % 500)).collect();
        let chunk: Vec<Edge> = chunk.into_iter().filter(|e| !e.is_self_loop()).collect();
        let mut nlocal = 0;
        let mut ncross = 0;
        for &e in &chunk {
            match route(e, shards) {
                Route::Local(_) => nlocal += 1,
                Route::Cross => ncross += 1,
            }
        }
        assert_eq!(nlocal + ncross, chunk.len());
        assert!(nlocal > 0 && ncross > 0, "both classes must occur");
    }

    #[test]
    fn sharder_is_bit_identical_to_shard_of_for_every_mode() {
        // the golden suites pin routing bit-for-bit, so the pow2 shift
        // path must agree with the generic multiply everywhere —
        // including the extremes of the id space
        for shards in [1usize, 2, 3, 4, 5, 7, 8, 16, 31, 32, 64, 1024] {
            let s = Sharder::new(shards);
            assert_eq!(s.shards(), shards);
            for node in (0..20_000u32).chain(u32::MAX - 20_000..=u32::MAX) {
                assert_eq!(
                    s.shard_of(node),
                    shard_of(node, shards),
                    "shards={shards} node={node}"
                );
            }
        }
    }

    #[test]
    fn sharder_pow2_fast_path_activates_exactly_on_powers_of_two() {
        for (shards, pow2) in [
            (1usize, false), // single shard short-circuits to 0
            (2, true),
            (3, false),
            (4, true),
            (6, false),
            (8, true),
            (4096, true),
        ] {
            assert_eq!(
                Sharder::new(shards).is_pow2_fast_path(),
                pow2,
                "shards={shards}"
            );
        }
    }

    #[test]
    fn sharder_route_matches_free_route() {
        for shards in [1usize, 2, 3, 4, 8] {
            let s = Sharder::new(shards);
            for u in 0..200u32 {
                for v in 0..50u32 {
                    let e = Edge::new(u, v * 17);
                    assert_eq!(s.route(e), route(e, shards), "shards={shards} {e:?}");
                }
            }
        }
    }

    #[test]
    fn self_loops_always_route_local() {
        // the service's incremental drain relies on the cross buffer
        // never containing self-loops
        for shards in [1, 2, 4, 16] {
            for u in 0..200u32 {
                assert!(matches!(route(Edge::new(u, u), shards), Route::Local(_)));
            }
        }
    }
}
