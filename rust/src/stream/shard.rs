//! Hash-sharding of an edge stream for the parallel coordinator.
//!
//! Node space is split across `shards` by multiplicative hashing.
//! An edge whose endpoints fall in the same shard is routed to that
//! shard's queue; a *cross-shard* edge goes to the leader queue, because
//! its decision needs both shards' community state (see
//! `coordinator/parallel.rs` for how the leader resolves them).

use crate::graph::edge::Edge;
use crate::util::channel::Channel;

/// Multiplicative (Fibonacci) hash of a node id into `shards` buckets.
#[inline]
pub fn shard_of(node: u32, shards: usize) -> usize {
    debug_assert!(shards > 0);
    let h = (node as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    ((h >> 32) as usize * shards) >> 32
}

/// Routing decision for one edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Both endpoints in shard `i`.
    Local(usize),
    /// Endpoints in different shards → leader.
    Cross,
}

#[inline]
/// Classify an edge: same-shard (`Local`) or leader-bound (`Cross`).
pub fn route(edge: Edge, shards: usize) -> Route {
    let a = shard_of(edge.u, shards);
    let b = shard_of(edge.v, shards);
    if a == b {
        Route::Local(a)
    } else {
        Route::Cross
    }
}

/// Fan a chunk out to per-shard queues + leader queue. Returns
/// (local count, cross count).
pub fn dispatch_chunk(
    chunk: &[Edge],
    shards: usize,
    local_queues: &[Channel<Vec<Edge>>],
    leader_queue: &Channel<Vec<Edge>>,
) -> (usize, usize) {
    debug_assert_eq!(local_queues.len(), shards);
    let mut per_shard: Vec<Vec<Edge>> = (0..shards).map(|_| Vec::new()).collect();
    let mut cross = Vec::new();
    for &e in chunk {
        match route(e, shards) {
            Route::Local(s) => per_shard[s].push(e),
            Route::Cross => cross.push(e),
        }
    }
    let mut nlocal = 0;
    for (s, batch) in per_shard.into_iter().enumerate() {
        if !batch.is_empty() {
            nlocal += batch.len();
            // a closed queue means the worker aborted; drop silently,
            // the coordinator surfaces the error
            let _ = local_queues[s].send(batch);
        }
    }
    let ncross = cross.len();
    if !cross.is_empty() {
        let _ = leader_queue.send(cross);
    }
    (nlocal, ncross)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for shards in [1, 2, 3, 8, 16] {
            for node in 0..1000u32 {
                let s = shard_of(node, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(node, shards));
            }
        }
    }

    #[test]
    fn shard_of_is_roughly_balanced() {
        let shards = 8;
        let mut counts = vec![0usize; shards];
        for node in 0..80_000u32 {
            counts[shard_of(node, shards)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn route_classification() {
        let shards = 4;
        // find a same-shard pair and a cross pair deterministically
        let mut same = None;
        let mut cross = None;
        'outer: for u in 0..100u32 {
            for v in (u + 1)..100u32 {
                let e = Edge::new(u, v);
                match route(e, shards) {
                    Route::Local(_) if same.is_none() => same = Some(e),
                    Route::Cross if cross.is_none() => cross = Some(e),
                    _ => {}
                }
                if same.is_some() && cross.is_some() {
                    break 'outer;
                }
            }
        }
        assert!(same.is_some() && cross.is_some());
    }

    #[test]
    fn dispatch_partitions_every_edge_exactly_once() {
        let shards = 4;
        let queues: Vec<Channel<Vec<Edge>>> =
            (0..shards).map(|_| Channel::bounded(64)).collect();
        let leader = Channel::bounded(64);
        let chunk: Vec<Edge> = (0..1000u32).map(|i| Edge::new(i, (i * 7) % 500)).collect();
        let chunk: Vec<Edge> = chunk.into_iter().filter(|e| !e.is_self_loop()).collect();
        let (nlocal, ncross) = dispatch_chunk(&chunk, shards, &queues, &leader);
        assert_eq!(nlocal + ncross, chunk.len());
        let mut delivered = 0;
        for q in &queues {
            q.close();
            while let Some(batch) = q.try_recv() {
                for e in &batch {
                    assert!(matches!(route(*e, shards), Route::Local(_)));
                }
                delivered += batch.len();
            }
        }
        leader.close();
        while let Some(batch) = leader.try_recv() {
            for e in &batch {
                assert_eq!(route(*e, shards), Route::Cross);
            }
            delivered += batch.len();
        }
        assert_eq!(delivered, chunk.len());
    }
}
