//! Pull-based edge sources.
//!
//! A source yields edges *once*, in stream order, in batches (batching
//! amortises per-edge dispatch without violating the single-pass
//! contract — the paper's algorithm still touches each edge exactly
//! once). `len_hint` lets harnesses pre-size reports, not algorithms.

use std::fs::File;
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use crate::graph::edge::Edge;
use crate::graph::io::{parse_edge_bytes, LineParse};

/// A single-pass edge stream.
pub trait EdgeSource: Send {
    /// Fill `buf` with up to `buf.capacity()` edges; returns the number
    /// written. 0 = stream exhausted. `buf` is cleared first.
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize;

    /// Optional total edge count (for reporting only).
    fn len_hint(&self) -> Option<usize> {
        None
    }
}

/// Shared body of the in-memory sources: copy the next batch (up to
/// `buf.capacity()` edges) out of `edges[*pos..]`, advancing the
/// cursor. Returns the number of edges written.
#[inline]
fn slice_next_batch(edges: &[Edge], pos: &mut usize, buf: &mut Vec<Edge>) -> usize {
    buf.clear();
    let take = buf.capacity().min(edges.len() - *pos);
    buf.extend_from_slice(&edges[*pos..*pos + take]);
    *pos += take;
    take
}

/// Stream over an in-memory edge slice (the common bench path).
pub struct MemorySource<'a> {
    edges: &'a [Edge],
    pos: usize,
}

impl<'a> MemorySource<'a> {
    /// Stream over a borrowed edge slice.
    pub fn new(edges: &'a [Edge]) -> Self {
        Self { edges, pos: 0 }
    }
}

impl EdgeSource for MemorySource<'_> {
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize {
        slice_next_batch(self.edges, &mut self.pos, buf)
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.edges.len())
    }
}

/// Owned variant of [`MemorySource`] (for moving across threads).
pub struct OwnedMemorySource {
    edges: Vec<Edge>,
    pos: usize,
}

impl OwnedMemorySource {
    /// Stream over an owned edge vector.
    pub fn new(edges: Vec<Edge>) -> Self {
        Self { edges, pos: 0 }
    }
}

impl EdgeSource for OwnedMemorySource {
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize {
        slice_next_batch(&self.edges, &mut self.pos, buf)
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.edges.len())
    }
}

/// Stream a SNAP-style text edge file. Node ids must already be dense
/// u32 (the harness writes files that way); sparse-id files should go
/// through `graph::io::read_text_edges` instead. Unlike
/// `read_text_edges` — which hard-errors on half-numeric (corrupt)
/// lines — this transport stays lenient and skips anything it cannot
/// scan: `EdgeSource::next_batch` has no error channel, and the
/// streaming path trades strictness for throughput by design — but the
/// two corruption-shaped drop classes are **counted**, never silent: a
/// line whose ids parse but exceed `u32`
/// ([`oversized_skipped`](Self::oversized_skipped) — narrowing would
/// alias another node, worse than dropping), and a numeric-source line
/// with a missing/malformed target
/// ([`malformed_skipped`](Self::malformed_skipped) — what the strict
/// reader hard-errors on).
///
/// §Perf: this is a streaming-path transport, so parsing is byte-level
/// — lines are scanned in place in the reader's buffer (no UTF-8
/// validation) by the shared `graph::io::parse_edge_bytes` scanner
/// instead of `split_whitespace` + `parse`. This took STR-from-text
/// from 4.7× the `cat` bound to ~2× (the paper's Friendster ratio);
/// see EXPERIMENTS.md §Perf.
pub struct TextFileSource {
    reader: BufReader<File>,
    /// carry for a line spanning a buffer refill boundary
    carry: Vec<u8>,
    bytes_read: u64,
    /// lines whose ids parsed but did not fit in u32 (skipped)
    oversized: u64,
    /// lines with a numeric source but a missing/malformed target —
    /// what the strict reader hard-errors on (skipped here)
    malformed: u64,
    eof: bool,
}

impl TextFileSource {
    /// Open a SNAP-style text edge file for streaming.
    pub fn open<P: AsRef<Path>>(path: P) -> std::io::Result<Self> {
        Ok(Self {
            reader: BufReader::with_capacity(1 << 20, File::open(path)?),
            carry: Vec::with_capacity(64),
            bytes_read: 0,
            oversized: 0,
            malformed: 0,
            eof: false,
        })
    }

    /// Bytes consumed from the file so far.
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read
    }

    /// Lines skipped because an id parsed but exceeded `u32` (these
    /// were previously *truncated* into wrong-but-valid edges — the
    /// counter makes the drop observable instead of silent).
    pub fn oversized_skipped(&self) -> u64 {
        self.oversized
    }

    /// Lines skipped because the source id parsed but the target was
    /// missing or malformed — the corruption class the strict reader
    /// (`graph::io::read_text_edges`) hard-errors on. The lenient
    /// transport has no error channel, so the counter is how the drop
    /// stays observable.
    pub fn malformed_skipped(&self) -> u64 {
        self.malformed
    }

    #[inline]
    fn emit(line: &[u8], buf: &mut Vec<Edge>, oversized: &mut u64, malformed: &mut u64) {
        // lenient transport: only well-formed pairs become edges;
        // comment/non-numeric lines skip silently, the two observable
        // drop classes (bad target, oversized id) are counted
        match parse_edge_bytes(line) {
            LineParse::Edge(u, v) => {
                // oversized before self-loop: the counter covers every
                // line whose ids cannot be dense u32, loops included
                if u > u32::MAX as u64 || v > u32::MAX as u64 {
                    // an id that cannot be a dense u32 would alias
                    // another node if narrowed with `as` — skip + count
                    *oversized += 1;
                    return;
                }
                if u == v {
                    return;
                }
                buf.push(Edge::new(u as u32, v as u32));
            }
            LineParse::BadTarget(..) => *malformed += 1,
            LineParse::Skip => {}
        }
    }
}

impl EdgeSource for TextFileSource {
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize {
        use std::io::BufRead;
        buf.clear();
        while buf.len() < buf.capacity() && !self.eof {
            // scan lines directly in the reader's internal buffer —
            // no per-line copy (§Perf). A sibling of this framing loop
            // lives in graph::io::read_text_edges (one-shot, fallible);
            // carry/boundary fixes likely apply to both.
            let chunk = match self.reader.fill_buf() {
                Ok(c) => c,
                Err(_) => break,
            };
            if chunk.is_empty() {
                self.eof = true;
                if !self.carry.is_empty() {
                    let carry = std::mem::take(&mut self.carry);
                    Self::emit(&carry, buf, &mut self.oversized, &mut self.malformed);
                }
                break;
            }
            let mut start = 0usize;
            let mut consumed = 0usize;
            while let Some(pos) = chunk[start..].iter().position(|&b| b == b'\n') {
                let line = &chunk[start..start + pos];
                if self.carry.is_empty() {
                    Self::emit(line, buf, &mut self.oversized, &mut self.malformed);
                } else {
                    self.carry.extend_from_slice(line);
                    let carry = std::mem::take(&mut self.carry);
                    Self::emit(&carry, buf, &mut self.oversized, &mut self.malformed);
                    self.carry = carry;
                    self.carry.clear();
                }
                start += pos + 1;
                consumed = start;
                if buf.len() >= buf.capacity() {
                    break;
                }
            }
            if consumed == 0 && start == 0 && buf.len() < buf.capacity() {
                // no newline in the whole chunk: stash and refill
                self.carry.extend_from_slice(chunk);
                consumed = chunk.len();
            } else if buf.len() < buf.capacity() && consumed < chunk.len() {
                // trailing partial line: stash it
                self.carry.extend_from_slice(&chunk[consumed..]);
                consumed = chunk.len();
            }
            self.bytes_read += consumed as u64;
            self.reader.consume(consumed);
        }
        buf.len()
    }
}

/// Stream the compact binary format written by `graph::io`.
///
/// §Perf: the read buffer is owned and reused across batches — a fresh
/// `vec![0; want*8]` per batch cost ~25% of streaming throughput
/// (EXPERIMENTS.md §Perf).
pub struct BinaryFileSource {
    reader: BufReader<File>,
    remaining: u64,
    scratch: Vec<u8>,
}

impl BinaryFileSource {
    /// Open a binary edge file (validates the header).
    pub fn open<P: AsRef<Path>>(path: P) -> std::io::Result<Self> {
        let mut reader = BufReader::with_capacity(1 << 20, File::open(path)?);
        let mut head = [0u8; 16];
        reader.read_exact(&mut head)?;
        let m = u64::from_le_bytes(head[8..16].try_into().unwrap());
        Ok(Self { reader, remaining: m, scratch: Vec::new() })
    }
}

impl EdgeSource for BinaryFileSource {
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize {
        buf.clear();
        let want = (buf.capacity() as u64).min(self.remaining) as usize;
        if want == 0 {
            return 0;
        }
        self.scratch.resize(want * 8, 0);
        match self.reader.read_exact(&mut self.scratch) {
            Ok(()) => {}
            Err(_) => return 0,
        }
        for c in self.scratch.chunks_exact(8) {
            buf.push(Edge::new(
                u32::from_le_bytes(c[0..4].try_into().unwrap()),
                u32::from_le_bytes(c[4..8].try_into().unwrap()),
            ));
        }
        self.remaining -= want as u64;
        want
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.remaining as usize)
    }
}

/// Drain a source into a Vec (tests/harness convenience).
pub fn collect(source: &mut dyn EdgeSource, batch: usize) -> Vec<Edge> {
    let mut out = Vec::new();
    let mut buf = Vec::with_capacity(batch);
    while source.next_batch(&mut buf) > 0 {
        out.extend_from_slice(&buf);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::EdgeList;
    use crate::graph::io;

    fn edges() -> Vec<Edge> {
        (0..100u32).map(|i| Edge::new(i, i + 1)).collect()
    }

    #[test]
    fn memory_source_batches_exactly() {
        let es = edges();
        let mut src = MemorySource::new(&es);
        let mut buf = Vec::with_capacity(32);
        assert_eq!(src.next_batch(&mut buf), 32);
        assert_eq!(src.next_batch(&mut buf), 32);
        assert_eq!(src.next_batch(&mut buf), 32);
        assert_eq!(src.next_batch(&mut buf), 4);
        assert_eq!(src.next_batch(&mut buf), 0);
    }

    #[test]
    fn owned_source_batches_identically_to_borrowed() {
        // both sources share slice_next_batch; pin the equivalence
        let es = edges();
        let mut borrowed = MemorySource::new(&es);
        let mut owned = OwnedMemorySource::new(es.clone());
        let mut a = Vec::with_capacity(17);
        let mut b = Vec::with_capacity(17);
        loop {
            let na = borrowed.next_batch(&mut a);
            let nb = owned.next_batch(&mut b);
            assert_eq!(na, nb);
            assert_eq!(a, b);
            if na == 0 {
                break;
            }
        }
        assert_eq!(borrowed.len_hint(), owned.len_hint());
    }

    #[test]
    fn collect_roundtrips_memory() {
        let es = edges();
        let mut src = MemorySource::new(&es);
        assert_eq!(collect(&mut src, 7), es);
    }

    #[test]
    fn text_file_source_streams() {
        let p = std::env::temp_dir().join(format!("sc_src_{}.txt", std::process::id()));
        let el = EdgeList::new(101, edges());
        io::write_text_edges(&p, &el).unwrap();
        let mut src = TextFileSource::open(&p).unwrap();
        let got = collect(&mut src, 13);
        assert_eq!(got, el.edges);
        assert!(src.bytes_read() > 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn oversized_ids_are_skipped_and_counted() {
        // regression: a 40-bit id used to be narrowed with `as u32`
        // into a wrong-but-valid edge (2^40 → node 0). The lenient
        // transport must skip the line and count it instead.
        let p = std::env::temp_dir().join(format!("sc_src_wide_{}.txt", std::process::id()));
        let wide = 1u64 << 40;
        std::fs::write(
            &p,
            format!("1 2\n{wide} 3\n4 {}\n{wide} {wide}\n5 6\n", wide + 1),
        )
        .unwrap();
        let mut src = TextFileSource::open(&p).unwrap();
        let got = collect(&mut src, 8);
        assert_eq!(got, vec![Edge::new(1, 2), Edge::new(5, 6)]);
        // two oversized pairs + one oversized self-loop, all counted
        assert_eq!(src.oversized_skipped(), 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn lenient_source_counts_malformed_lines_strict_reader_rejects() {
        // the shared scanner classifies; this transport has no error
        // channel, so BadTarget lines skip here — counted, so the drop
        // is observable (graph::io::read_text_edges hard-errors on the
        // same lines — covered by its own tests)
        let p = std::env::temp_dir().join(format!("sc_src_bad_{}.txt", std::process::id()));
        std::fs::write(&p, "# header\n1 2\n3 oops\n4\n5 6\n").unwrap();
        let mut src = TextFileSource::open(&p).unwrap();
        let got = collect(&mut src, 8);
        assert_eq!(got, vec![Edge::new(1, 2), Edge::new(5, 6)]);
        assert_eq!(src.oversized_skipped(), 0);
        assert_eq!(src.malformed_skipped(), 2, "'3 oops' and bare '4'");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_file_source_streams() {
        let p = std::env::temp_dir().join(format!("sc_src_{}.bin", std::process::id()));
        let el = EdgeList::new(101, edges());
        io::write_binary_edges(&p, &el).unwrap();
        let mut src = BinaryFileSource::open(&p).unwrap();
        assert_eq!(src.len_hint(), Some(100));
        let got = collect(&mut src, 13);
        assert_eq!(got, el.edges);
        std::fs::remove_file(&p).ok();
    }
}
