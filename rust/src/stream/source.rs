//! Pull-based edge sources.
//!
//! A source yields edges *once*, in stream order, in batches (batching
//! amortises per-edge dispatch without violating the single-pass
//! contract — the paper's algorithm still touches each edge exactly
//! once). `len_hint` lets harnesses pre-size reports, not algorithms.

use std::fs::File;
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use crate::graph::binfmt;
use crate::graph::edge::Edge;
use crate::graph::io::{frame_lines, parse_edge_bytes, LineParse};

/// A single-pass edge stream.
pub trait EdgeSource: Send {
    /// Fill `buf` with up to `buf.capacity()` edges; returns the number
    /// written. 0 = stream exhausted. `buf` is cleared first.
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize;

    /// Optional total edge count (for reporting only).
    fn len_hint(&self) -> Option<usize> {
        None
    }
}

/// Shared body of the in-memory sources: copy the next batch (up to
/// `buf.capacity()` edges) out of `edges[*pos..]`, advancing the
/// cursor. Returns the number of edges written.
#[inline]
fn slice_next_batch(edges: &[Edge], pos: &mut usize, buf: &mut Vec<Edge>) -> usize {
    buf.clear();
    let take = buf.capacity().min(edges.len() - *pos);
    buf.extend_from_slice(&edges[*pos..*pos + take]);
    *pos += take;
    take
}

/// Stream over an in-memory edge slice (the common bench path).
pub struct MemorySource<'a> {
    edges: &'a [Edge],
    pos: usize,
}

impl<'a> MemorySource<'a> {
    /// Stream over a borrowed edge slice.
    pub fn new(edges: &'a [Edge]) -> Self {
        Self { edges, pos: 0 }
    }
}

impl EdgeSource for MemorySource<'_> {
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize {
        slice_next_batch(self.edges, &mut self.pos, buf)
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.edges.len())
    }
}

/// Owned variant of [`MemorySource`] (for moving across threads).
pub struct OwnedMemorySource {
    edges: Vec<Edge>,
    pos: usize,
}

impl OwnedMemorySource {
    /// Stream over an owned edge vector.
    pub fn new(edges: Vec<Edge>) -> Self {
        Self { edges, pos: 0 }
    }
}

impl EdgeSource for OwnedMemorySource {
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize {
        slice_next_batch(&self.edges, &mut self.pos, buf)
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.edges.len())
    }
}

/// Stream a SNAP-style text edge file. Node ids must already be dense
/// u32 (the harness writes files that way); sparse-id files should go
/// through `graph::io::read_text_edges` instead. Unlike
/// `read_text_edges` — which hard-errors on half-numeric (corrupt)
/// lines — this transport stays lenient and skips anything it cannot
/// scan: `EdgeSource::next_batch` has no error channel, and the
/// streaming path trades strictness for throughput by design — but the
/// two corruption-shaped drop classes are **counted**, never silent: a
/// line whose ids parse but exceed `u32`
/// ([`oversized_skipped`](Self::oversized_skipped) — narrowing would
/// alias another node, worse than dropping), and a numeric-source line
/// with a missing/malformed target
/// ([`malformed_skipped`](Self::malformed_skipped) — what the strict
/// reader hard-errors on).
///
/// §Perf: this is a streaming-path transport, so parsing is byte-level
/// — lines are scanned in place in the reader's buffer (no UTF-8
/// validation) by the shared `graph::io::parse_edge_bytes` scanner
/// instead of `split_whitespace` + `parse`. This took STR-from-text
/// from 4.7× the `cat` bound to ~2× (the paper's Friendster ratio);
/// see EXPERIMENTS.md §Perf.
pub struct TextFileSource {
    reader: BufReader<File>,
    /// carry for a line spanning a buffer refill boundary
    carry: Vec<u8>,
    bytes_read: u64,
    /// lines whose ids parsed but did not fit in u32 (skipped)
    oversized: u64,
    /// lines with a numeric source but a missing/malformed target —
    /// what the strict reader hard-errors on (skipped here)
    malformed: u64,
    eof: bool,
}

impl TextFileSource {
    /// Open a SNAP-style text edge file for streaming.
    pub fn open<P: AsRef<Path>>(path: P) -> std::io::Result<Self> {
        Ok(Self {
            reader: BufReader::with_capacity(1 << 20, File::open(path)?),
            carry: Vec::with_capacity(64),
            bytes_read: 0,
            oversized: 0,
            malformed: 0,
            eof: false,
        })
    }

    /// Bytes consumed from the file so far.
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read
    }

    /// Lines skipped because an id parsed but exceeded `u32` (these
    /// were previously *truncated* into wrong-but-valid edges — the
    /// counter makes the drop observable instead of silent).
    pub fn oversized_skipped(&self) -> u64 {
        self.oversized
    }

    /// Lines skipped because the source id parsed but the target was
    /// missing or malformed — the corruption class the strict reader
    /// (`graph::io::read_text_edges`) hard-errors on. The lenient
    /// transport has no error channel, so the counter is how the drop
    /// stays observable.
    pub fn malformed_skipped(&self) -> u64 {
        self.malformed
    }
}

/// Lenient-transport line consumer: only well-formed pairs become
/// edges; comment/non-numeric lines skip silently, the two observable
/// drop classes (bad target, oversized id) are counted. Shared by
/// [`TextFileSource`] and the parallel text scan
/// (`stream::pscan`) so both transports classify byte-for-byte alike.
#[inline]
pub(crate) fn emit_lenient(
    line: &[u8],
    buf: &mut Vec<Edge>,
    oversized: &mut u64,
    malformed: &mut u64,
) {
    match parse_edge_bytes(line) {
        LineParse::Edge(u, v) => {
            // oversized before self-loop: the counter covers every
            // line whose ids cannot be dense u32, loops included
            if u > u32::MAX as u64 || v > u32::MAX as u64 {
                // an id that cannot be a dense u32 would alias
                // another node if narrowed with `as` — skip + count
                *oversized += 1;
                return;
            }
            if u == v {
                return;
            }
            buf.push(Edge::new(u as u32, v as u32));
        }
        LineParse::BadTarget(..) => *malformed += 1,
        LineParse::Skip => {}
    }
}

impl EdgeSource for TextFileSource {
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize {
        use std::io::BufRead;
        buf.clear();
        while buf.len() < buf.capacity() && !self.eof {
            // scan lines directly in the reader's internal buffer —
            // no per-line copy (§Perf)
            let chunk = match self.reader.fill_buf() {
                Ok(c) => c,
                Err(_) => break,
            };
            if chunk.is_empty() {
                self.eof = true;
                if !self.carry.is_empty() {
                    let carry = std::mem::take(&mut self.carry);
                    emit_lenient(&carry, buf, &mut self.oversized, &mut self.malformed);
                }
                break;
            }
            // the shared framing helper (graph::io::frame_lines, also
            // the strict reader's loop); Ok(false) stops it the moment
            // buf fills, leaving the rest of the chunk for next call
            let oversized = &mut self.oversized;
            let malformed = &mut self.malformed;
            let consumed = match frame_lines(chunk, &mut self.carry, |line| {
                emit_lenient(line, buf, oversized, malformed);
                Ok::<bool, std::convert::Infallible>(buf.len() < buf.capacity())
            }) {
                Ok(c) => c,
                Err(never) => match never {},
            };
            self.bytes_read += consumed as u64;
            self.reader.consume(consumed);
        }
        buf.len()
    }
}

/// Stream the segmented binary format written by `graph::io` (layout
/// in `graph::binfmt`). The header is validated on open — every
/// header-derived size is cross-checked against the real file length
/// before any allocation — and each segment's record count + trailing
/// checksum is verified as it is loaded.
///
/// `EdgeSource::next_batch` has no error channel, so a segment that
/// fails verification mid-stream stops the source (returns 0) and
/// parks the message in [`error`](Self::error) — callers that care
/// check it after the drain, and a truncated stream never silently
/// passes as complete because `len_hint` still reports the shortfall.
///
/// §Perf: the segment block buffer and decoded-edge buffer are owned
/// and reused across batches — a fresh allocation per batch cost ~25%
/// of streaming throughput back when this read raw records
/// (EXPERIMENTS.md §Perf).
pub struct BinaryFileSource {
    reader: BufReader<File>,
    header: binfmt::SegHeader,
    /// next segment to load and verify
    next_seg: u64,
    /// decoded edges of the current segment, served through `seg_pos`
    seg_buf: Vec<Edge>,
    seg_pos: usize,
    /// edges handed to callers so far (for `len_hint`)
    served: u64,
    /// reusable raw segment block
    block: Vec<u8>,
    error: Option<String>,
}

impl BinaryFileSource {
    /// Open a segmented binary edge file (validates the header against
    /// the actual file length before any edge-sized allocation).
    pub fn open<P: AsRef<Path>>(path: P) -> std::io::Result<Self> {
        let f = File::open(path)?;
        let file_len = f.metadata()?.len();
        let mut reader = BufReader::with_capacity(1 << 20, f);
        let mut head = [0u8; binfmt::HEADER_BYTES];
        reader.read_exact(&mut head)?;
        let header = binfmt::SegHeader::decode(&head)?;
        header.validate_file_len(file_len)?;
        Ok(Self {
            reader,
            header,
            next_seg: 0,
            seg_buf: Vec::new(),
            seg_pos: 0,
            served: 0,
            block: Vec::new(),
            error: None,
        })
    }

    /// The verification failure that stopped the stream, if any.
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }

    /// Load + verify the next segment into `seg_buf`; false on EOF or
    /// on a verification failure (recorded in `error`).
    fn load_segment(&mut self) -> bool {
        if self.error.is_some() || self.next_seg >= self.header.seg_count {
            return false;
        }
        let seg = self.next_seg;
        let records = self.header.records_in(seg);
        self.block
            .resize((binfmt::SEG_OVERHEAD_BYTES + records * binfmt::RECORD_BYTES) as usize, 0);
        self.seg_buf.clear();
        self.seg_pos = 0;
        let loaded = self
            .reader
            .read_exact(&mut self.block)
            .and_then(|()| binfmt::decode_segment(&self.block, records, seg, &mut self.seg_buf));
        match loaded {
            Ok(()) => {
                self.next_seg += 1;
                true
            }
            Err(e) => {
                self.error = Some(e.to_string());
                false
            }
        }
    }
}

impl EdgeSource for BinaryFileSource {
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize {
        buf.clear();
        while buf.len() < buf.capacity() {
            if self.seg_pos == self.seg_buf.len() && !self.load_segment() {
                break;
            }
            let take = (buf.capacity() - buf.len()).min(self.seg_buf.len() - self.seg_pos);
            buf.extend_from_slice(&self.seg_buf[self.seg_pos..self.seg_pos + take]);
            self.seg_pos += take;
        }
        self.served += buf.len() as u64;
        buf.len()
    }

    fn len_hint(&self) -> Option<usize> {
        Some((self.header.m - self.served) as usize)
    }
}

/// Zero-copy variant of [`BinaryFileSource`]: the whole file is
/// memory-mapped once on open ([`util::mmap::Mmap`], `MADV_SEQUENTIAL`)
/// and batches decode straight out of the mapping — no segment block
/// buffer, no decoded-segment staging vec, no `read_exact` copies.
/// Segment checksums are still verified in place (via
/// [`binfmt::SegView`]) *before* any record of that segment is served,
/// so the error contract is byte-for-byte the buffered reader's:
/// hostile headers and truncation fail the open as `InvalidData`
/// (`binfmt::parse_mapped` cross-checks the header against the real
/// mapped length, so segment offsets can never run off the map — a
/// short file is an error at open, never a SIGBUS), and a mid-file bit
/// flip stops the stream with the failure parked in
/// [`error`](Self::error).
///
/// On non-unix targets `open` fails with `ErrorKind::Unsupported`;
/// callers fall back to [`BinaryFileSource`] (see
/// `util::mmap::supported`).
pub struct MmapBinarySource {
    map: crate::util::mmap::Mmap,
    header: binfmt::SegHeader,
    /// next segment to verify
    next_seg: u64,
    /// byte cursor within the current verified segment's record payload
    cur_pos: usize,
    /// end of the current verified segment's record payload
    cur_end: usize,
    /// edges handed to callers so far (for `len_hint`)
    served: u64,
    error: Option<String>,
}

impl MmapBinarySource {
    /// Map a segmented binary edge file and validate its header against
    /// the real mapped length (same gates as [`BinaryFileSource::open`],
    /// still before any edge-sized allocation — there is none at all on
    /// this path).
    pub fn open<P: AsRef<Path>>(path: P) -> std::io::Result<Self> {
        let f = File::open(path)?;
        let map = crate::util::mmap::Mmap::map_file(&f)?;
        let header = binfmt::parse_mapped(map.as_slice())?;
        Ok(Self {
            map,
            header,
            next_seg: 0,
            cur_pos: 0,
            cur_end: 0,
            served: 0,
            error: None,
        })
    }

    /// The decoded, validated file header.
    pub fn header(&self) -> &binfmt::SegHeader {
        &self.header
    }

    /// The verification failure that stopped the stream, if any.
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }

    /// Verify the next segment's checksum in place and point the record
    /// cursor at its payload; false on EOF or a verification failure
    /// (recorded in `error`).
    fn load_segment(&mut self) -> bool {
        if self.error.is_some() || self.next_seg >= self.header.seg_count {
            return false;
        }
        let seg = self.next_seg;
        let records = self.header.records_in(seg);
        // in bounds: parse_mapped validated the header against the map
        let off = self.header.seg_offset(seg).expect("validated header") as usize;
        let len = self.header.seg_bytes(seg) as usize;
        let block = &self.map.as_slice()[off..off + len];
        match binfmt::SegView::parse(block, records, seg) {
            Ok(view) => {
                // the record payload sits 8 B into the block; remember
                // absolute byte offsets so no borrow outlives this call
                self.cur_pos = off + 8;
                self.cur_end = self.cur_pos + view.raw().len();
                self.next_seg += 1;
                true
            }
            Err(e) => {
                self.error = Some(e.to_string());
                false
            }
        }
    }
}

impl EdgeSource for MmapBinarySource {
    fn next_batch(&mut self, buf: &mut Vec<Edge>) -> usize {
        buf.clear();
        while buf.len() < buf.capacity() {
            if self.cur_pos == self.cur_end && !self.load_segment() {
                break;
            }
            let rec = binfmt::RECORD_BYTES as usize;
            let take = (buf.capacity() - buf.len()).min((self.cur_end - self.cur_pos) / rec);
            let bytes = &self.map.as_slice()[self.cur_pos..self.cur_pos + take * rec];
            for c in bytes.chunks_exact(rec) {
                buf.push(Edge::new(
                    u32::from_le_bytes(c[0..4].try_into().unwrap()),
                    u32::from_le_bytes(c[4..8].try_into().unwrap()),
                ));
            }
            self.cur_pos += take * rec;
        }
        self.served += buf.len() as u64;
        buf.len()
    }

    fn len_hint(&self) -> Option<usize> {
        Some((self.header.m - self.served) as usize)
    }
}

/// Drain a source into a Vec (tests/harness convenience).
pub fn collect(source: &mut dyn EdgeSource, batch: usize) -> Vec<Edge> {
    let mut out = Vec::new();
    let mut buf = Vec::with_capacity(batch);
    while source.next_batch(&mut buf) > 0 {
        out.extend_from_slice(&buf);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::edge::EdgeList;
    use crate::graph::io;

    fn edges() -> Vec<Edge> {
        (0..100u32).map(|i| Edge::new(i, i + 1)).collect()
    }

    #[test]
    fn memory_source_batches_exactly() {
        let es = edges();
        let mut src = MemorySource::new(&es);
        let mut buf = Vec::with_capacity(32);
        assert_eq!(src.next_batch(&mut buf), 32);
        assert_eq!(src.next_batch(&mut buf), 32);
        assert_eq!(src.next_batch(&mut buf), 32);
        assert_eq!(src.next_batch(&mut buf), 4);
        assert_eq!(src.next_batch(&mut buf), 0);
    }

    #[test]
    fn owned_source_batches_identically_to_borrowed() {
        // both sources share slice_next_batch; pin the equivalence
        let es = edges();
        let mut borrowed = MemorySource::new(&es);
        let mut owned = OwnedMemorySource::new(es.clone());
        let mut a = Vec::with_capacity(17);
        let mut b = Vec::with_capacity(17);
        loop {
            let na = borrowed.next_batch(&mut a);
            let nb = owned.next_batch(&mut b);
            assert_eq!(na, nb);
            assert_eq!(a, b);
            if na == 0 {
                break;
            }
        }
        assert_eq!(borrowed.len_hint(), owned.len_hint());
    }

    #[test]
    fn collect_roundtrips_memory() {
        let es = edges();
        let mut src = MemorySource::new(&es);
        assert_eq!(collect(&mut src, 7), es);
    }

    #[test]
    fn text_file_source_streams() {
        let p = std::env::temp_dir().join(format!("sc_src_{}.txt", std::process::id()));
        let el = EdgeList::new(101, edges());
        io::write_text_edges(&p, &el).unwrap();
        let mut src = TextFileSource::open(&p).unwrap();
        let got = collect(&mut src, 13);
        assert_eq!(got, el.edges);
        assert!(src.bytes_read() > 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn oversized_ids_are_skipped_and_counted() {
        // regression: a 40-bit id used to be narrowed with `as u32`
        // into a wrong-but-valid edge (2^40 → node 0). The lenient
        // transport must skip the line and count it instead.
        let p = std::env::temp_dir().join(format!("sc_src_wide_{}.txt", std::process::id()));
        let wide = 1u64 << 40;
        std::fs::write(
            &p,
            format!("1 2\n{wide} 3\n4 {}\n{wide} {wide}\n5 6\n", wide + 1),
        )
        .unwrap();
        let mut src = TextFileSource::open(&p).unwrap();
        let got = collect(&mut src, 8);
        assert_eq!(got, vec![Edge::new(1, 2), Edge::new(5, 6)]);
        // two oversized pairs + one oversized self-loop, all counted
        assert_eq!(src.oversized_skipped(), 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn lenient_source_counts_malformed_lines_strict_reader_rejects() {
        // the shared scanner classifies; this transport has no error
        // channel, so BadTarget lines skip here — counted, so the drop
        // is observable (graph::io::read_text_edges hard-errors on the
        // same lines — covered by its own tests)
        let p = std::env::temp_dir().join(format!("sc_src_bad_{}.txt", std::process::id()));
        std::fs::write(&p, "# header\n1 2\n3 oops\n4\n5 6\n").unwrap();
        let mut src = TextFileSource::open(&p).unwrap();
        let got = collect(&mut src, 8);
        assert_eq!(got, vec![Edge::new(1, 2), Edge::new(5, 6)]);
        assert_eq!(src.oversized_skipped(), 0);
        assert_eq!(src.malformed_skipped(), 2, "'3 oops' and bare '4'");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_file_source_streams() {
        let p = std::env::temp_dir().join(format!("sc_src_{}.bin", std::process::id()));
        let el = EdgeList::new(101, edges());
        io::write_binary_edges(&p, &el).unwrap();
        let mut src = BinaryFileSource::open(&p).unwrap();
        assert_eq!(src.len_hint(), Some(100));
        let got = collect(&mut src, 13);
        assert_eq!(got, el.edges);
        assert!(src.error().is_none());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_file_source_streams_across_segments() {
        // batch size deliberately not a divisor of the segment size, so
        // batches straddle segment boundaries
        let p = std::env::temp_dir().join(format!("sc_src_seg_{}.bin", std::process::id()));
        let el = EdgeList::new(101, edges());
        io::write_binary_edges_with(&p, &el, 7).unwrap();
        let mut src = BinaryFileSource::open(&p).unwrap();
        let got = collect(&mut src, 13);
        assert_eq!(got, el.edges);
        assert!(src.error().is_none());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_file_source_stops_and_reports_on_corruption() {
        let p = std::env::temp_dir().join(format!("sc_src_corrupt_{}.bin", std::process::id()));
        let el = EdgeList::new(101, edges());
        io::write_binary_edges_with(&p, &el, 32).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // flip one payload byte inside segment 1
        let seg1 = binfmt::HEADER_BYTES + (16 + 32 * 8);
        bytes[seg1 + 8 + 4] ^= 1;
        std::fs::write(&p, &bytes).unwrap();
        let mut src = BinaryFileSource::open(&p).unwrap();
        let got = collect(&mut src, 13);
        assert_eq!(got, el.edges[..32].to_vec(), "clean prefix still streams");
        let err = src.error().expect("corruption must be reported");
        assert!(err.contains("segment 1"), "{err}");
        assert!(src.len_hint().unwrap() > 0, "shortfall stays visible");
        std::fs::remove_file(&p).ok();
    }

    #[cfg(unix)]
    #[test]
    fn mmap_source_streams_identically_to_buffered() {
        let p = std::env::temp_dir().join(format!("sc_src_mmap_{}.bin", std::process::id()));
        let el = EdgeList::new(101, edges());
        io::write_binary_edges_with(&p, &el, 7).unwrap();
        let mut buffered = BinaryFileSource::open(&p).unwrap();
        let mut mapped = MmapBinarySource::open(&p).unwrap();
        assert_eq!(mapped.len_hint(), Some(100));
        assert_eq!(mapped.header().m, 100);
        // batch size straddles segment boundaries on both paths
        assert_eq!(collect(&mut mapped, 13), collect(&mut buffered, 13));
        assert!(mapped.error().is_none());
        assert_eq!(mapped.len_hint(), Some(0));
        std::fs::remove_file(&p).ok();
    }

    #[cfg(unix)]
    #[test]
    fn mmap_source_rejects_hostile_and_truncated_files_at_open() {
        let p = std::env::temp_dir().join(format!("sc_src_mmap_bad_{}.bin", std::process::id()));
        let el = EdgeList::new(101, edges());
        io::write_binary_edges_with(&p, &el, 32).unwrap();
        let good = std::fs::read(&p).unwrap();

        // hostile header claiming a huge m: InvalidData at open, before
        // any segment is touched (never a short-map fault)
        let mut hostile = good.clone();
        hostile[16..24].copy_from_slice(&(1u64 << 61).to_le_bytes());
        let check = binfmt::fnv1a(&hostile[0..40]);
        hostile[40..48].copy_from_slice(&check.to_le_bytes());
        std::fs::write(&p, &hostile).unwrap();
        let err = MmapBinarySource::open(&p).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);

        // truncated file: the length gate fires at open
        std::fs::write(&p, &good[..good.len() - 10]).unwrap();
        let err = MmapBinarySource::open(&p).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("does not match the header"), "{err}");

        // shorter than a header
        std::fs::write(&p, &good[..20]).unwrap();
        let err = MmapBinarySource::open(&p).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        std::fs::remove_file(&p).ok();
    }

    #[cfg(unix)]
    #[test]
    fn mmap_source_stops_and_reports_on_corruption() {
        let p = std::env::temp_dir().join(format!("sc_src_mmap_flip_{}.bin", std::process::id()));
        let el = EdgeList::new(101, edges());
        io::write_binary_edges_with(&p, &el, 32).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let seg1 = binfmt::HEADER_BYTES + (16 + 32 * 8);
        bytes[seg1 + 8 + 4] ^= 1;
        std::fs::write(&p, &bytes).unwrap();
        let mut src = MmapBinarySource::open(&p).unwrap();
        let got = collect(&mut src, 13);
        assert_eq!(got, el.edges[..32].to_vec(), "clean prefix still streams");
        let err = src.error().expect("corruption must be reported");
        assert!(err.contains("segment 1"), "{err}");
        assert!(src.len_hint().unwrap() > 0, "shortfall stays visible");
        std::fs::remove_file(&p).ok();
    }
}
