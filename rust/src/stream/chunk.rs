//! Chunked read-ahead pipeline with backpressure.
//!
//! Decouples the IO thread from the compute thread: a producer drains an
//! [`EdgeSource`] into fixed-size chunks pushed through a bounded
//! [`Channel`]. When compute is the bottleneck the channel fills and the
//! producer blocks — bounded memory, by construction (`depth` chunks of
//! `chunk_size` edges, ~8 bytes each).

use std::thread::JoinHandle;

use crate::graph::edge::Edge;
use crate::util::channel::Channel;

use super::source::EdgeSource;

/// Configuration for the pipeline.
#[derive(Debug, Clone, Copy)]
pub struct ChunkConfig {
    /// Edges per chunk.
    pub chunk_size: usize,
    /// Max in-flight chunks (backpressure bound).
    pub depth: usize,
}

impl Default for ChunkConfig {
    fn default() -> Self {
        Self { chunk_size: 65_536, depth: 4 }
    }
}

/// Receiving side of a running pipeline.
pub struct ChunkStream {
    rx: Channel<Vec<Edge>>,
    producer: Option<JoinHandle<u64>>,
}

impl ChunkStream {
    /// Spawn the producer thread over `source`.
    pub fn spawn<S: EdgeSource + 'static>(mut source: S, config: ChunkConfig) -> Self {
        let ch: Channel<Vec<Edge>> = Channel::bounded(config.depth);
        let tx = ch.clone();
        let producer = std::thread::spawn(move || {
            let mut total = 0u64;
            loop {
                let mut buf = Vec::with_capacity(config.chunk_size);
                let k = source.next_batch(&mut buf);
                if k == 0 {
                    break;
                }
                total += k as u64;
                if tx.send(buf).is_err() {
                    break; // consumer hung up
                }
            }
            tx.close();
            total
        });
        Self { rx: ch, producer: Some(producer) }
    }

    /// Next chunk, or `None` at end of stream.
    pub fn next_chunk(&self) -> Option<Vec<Edge>> {
        self.rx.recv()
    }

    /// Abort: close the channel so the producer stops.
    pub fn cancel(&self) {
        self.rx.close();
    }

    /// Join the producer; returns total edges produced.
    pub fn finish(mut self) -> u64 {
        self.rx.close();
        self.producer
            .take()
            .map(|h| h.join().unwrap_or(0))
            .unwrap_or(0)
    }

    /// Channel stats: (peak depth, chunks pushed, chunks popped).
    pub fn stats(&self) -> (usize, u64, u64) {
        self.rx.stats()
    }
}

impl Drop for ChunkStream {
    fn drop(&mut self) {
        self.rx.close();
        if let Some(h) = self.producer.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::source::OwnedMemorySource;

    fn edges(n: u32) -> Vec<Edge> {
        (0..n).map(|i| Edge::new(i, i + 1)).collect()
    }

    #[test]
    fn delivers_all_edges_in_order() {
        let es = edges(10_000);
        let stream = ChunkStream::spawn(
            OwnedMemorySource::new(es.clone()),
            ChunkConfig { chunk_size: 333, depth: 3 },
        );
        let mut got = Vec::new();
        while let Some(chunk) = stream.next_chunk() {
            got.extend(chunk);
        }
        assert_eq!(got, es);
    }

    #[test]
    fn backpressure_bounds_in_flight_chunks() {
        let es = edges(100_000);
        let stream = ChunkStream::spawn(
            OwnedMemorySource::new(es),
            ChunkConfig { chunk_size: 1000, depth: 2 },
        );
        // consume slowly; peak depth must never exceed the bound
        let mut count = 0u64;
        while let Some(chunk) = stream.next_chunk() {
            count += chunk.len() as u64;
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
        let (peak, _, _) = stream.stats();
        assert!(peak <= 2, "peak={peak}");
        assert_eq!(count, 100_000);
    }

    #[test]
    fn cancel_stops_producer() {
        let es = edges(1_000_000);
        let stream = ChunkStream::spawn(
            OwnedMemorySource::new(es),
            ChunkConfig { chunk_size: 100, depth: 2 },
        );
        let _ = stream.next_chunk();
        stream.cancel();
        let produced = stream.finish();
        assert!(produced < 1_000_000, "producer should stop early, got {produced}");
    }
}
