//! Edge-streaming substrate.
//!
//! The paper's model is an *insert-only edge stream*: each edge is seen
//! exactly once, in arbitrary order, and may never be stored. This
//! module provides that stream as infrastructure:
//!
//! * [`source`] — [`source::EdgeSource`]: pull-based edge producers
//!   (in-memory, text file, binary file — buffered or zero-copy
//!   memory-mapped, synthetic generator-backed).
//! * [`chunk`] — chunked pipelining of a source through a bounded
//!   channel: a producer thread reads ahead while the consumer
//!   processes, with backpressure when the consumer lags.
//! * [`shard`] — hash-sharding an edge stream across worker queues for
//!   the parallel coordinator; edges whose endpoints map to different
//!   shards are routed to the *leader* queue (cross-shard edges need
//!   global state — see `coordinator/parallel.rs`).
//! * [`pscan`] — parallel source scan: N reader threads each parse a
//!   byte range of one file (binary: segment-aligned; text: newline-
//!   aligned) and a sequencer re-emits them in file order, so the
//!   stream is bit-identical to a single reader's at any reader count.
//!   Binary scans can share one read-only mapping across all readers
//!   (`pscan::ParallelScanner::open_mmap` — zero-copy, unix only,
//!   buffered fallback elsewhere).
//! * [`meter`] — throughput metering (edges/s, bytes/s) for the
//!   Table 1 harness and the §Perf pass.

pub mod chunk;
pub mod meter;
pub mod pscan;
pub mod shard;
pub mod source;

pub use source::EdgeSource;
