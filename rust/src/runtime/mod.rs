//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs Python **once** to lower the L2 model to HLO
//! text (`artifacts/*.hlo.txt`); this module is the only consumer. The
//! [`PjrtRuntime`] compiles each module on the CPU PJRT client at
//! start-up and keeps the loaded executables; per-call cost is one
//! host-literal round-trip. Python never runs on the streaming path.
//!
//! [`PjrtEngine`] implements [`MetricEngine`] so
//! `coordinator::selection` can score sweeps through the compiled
//! kernels; [`NativeEngine`](crate::coordinator::selection::NativeEngine)
//! is the drop-in pure-Rust twin, and `rust/tests/runtime_integration.rs`
//! cross-checks the two.

pub mod artifacts;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::selection::{MetricEngine, SweepScores};
use artifacts::{ArtifactSet, CONTINGENCY, EDGE_BLOCK, NUM_SWEEPS, VOLUME_BUCKETS};

/// Compiled PJRT executables for every artifact.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    sweep_metrics: xla::PjRtLoadedExecutable,
    modularity: xla::PjRtLoadedExecutable,
    nmi: xla::PjRtLoadedExecutable,
}

impl PjrtRuntime {
    /// Compile all artifacts from the given set.
    pub fn load(set: &ArtifactSet) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let compile = |path: &std::path::Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
        };
        Ok(Self {
            sweep_metrics: compile(&set.sweep_metrics)?,
            modularity: compile(&set.modularity)?,
            nmi: compile(&set.nmi)?,
            client,
        })
    }

    /// Locate artifacts via `STREAMCOM_ARTIFACTS` or `./artifacts` and load.
    pub fn load_default() -> Result<Self> {
        let set = ArtifactSet::discover().context("artifacts not found — run `make artifacts`")?;
        Self::load(&set)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn run1(exe: &xla::PjRtLoadedExecutable, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // lowered with return_tuple=True → 1-tuple
        let out = result.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Execute `sweep_metrics.hlo.txt`: `(A·K, A·K, A)` → `A × 6` scores.
    pub fn sweep_metrics(&self, vols: &[f32], sizes: &[f32], w: &[f32]) -> Result<Vec<[f32; 6]>> {
        let (a, k) = (NUM_SWEEPS, VOLUME_BUCKETS);
        if vols.len() != a * k || sizes.len() != a * k || w.len() != a {
            return Err(anyhow!(
                "sweep_metrics shape mismatch: vols={} sizes={} w={}",
                vols.len(),
                sizes.len(),
                w.len()
            ));
        }
        let lv = xla::Literal::vec1(vols).reshape(&[a as i64, k as i64])?;
        let ls = xla::Literal::vec1(sizes).reshape(&[a as i64, k as i64])?;
        let lw = xla::Literal::vec1(w);
        let flat = Self::run1(&self.sweep_metrics, &[lv, ls, lw])?;
        if flat.len() != a * 6 {
            return Err(anyhow!("sweep_metrics output len {}", flat.len()));
        }
        Ok((0..a)
            .map(|r| {
                let mut row = [0f32; 6];
                row.copy_from_slice(&flat[r * 6..(r + 1) * 6]);
                row
            })
            .collect())
    }

    /// Execute `modularity.hlo.txt` over one padded edge block:
    /// returns `(intra, Σ vol²)`.
    pub fn modularity_partials(
        &self,
        ci: &[i32],
        cj: &[i32],
        mask: &[f32],
        vols: &[f32],
    ) -> Result<(f64, f64)> {
        if ci.len() != EDGE_BLOCK
            || cj.len() != EDGE_BLOCK
            || mask.len() != EDGE_BLOCK
            || vols.len() != VOLUME_BUCKETS
        {
            return Err(anyhow!("modularity shape mismatch"));
        }
        let out = Self::run1(
            &self.modularity,
            &[
                xla::Literal::vec1(ci),
                xla::Literal::vec1(cj),
                xla::Literal::vec1(mask),
                xla::Literal::vec1(vols),
            ],
        )?;
        Ok((out[0] as f64, out[1] as f64))
    }

    /// Execute `nmi.hlo.txt` on a `C × C` contingency table:
    /// returns `(mi, h_u, h_v)` in nats.
    pub fn nmi_terms(&self, cont: &[f32]) -> Result<(f64, f64, f64)> {
        if cont.len() != CONTINGENCY * CONTINGENCY {
            return Err(anyhow!("nmi shape mismatch: {}", cont.len()));
        }
        let lc = xla::Literal::vec1(cont)
            .reshape(&[CONTINGENCY as i64, CONTINGENCY as i64])?;
        let out = Self::run1(&self.nmi, &[lc])?;
        Ok((out[0] as f64, out[1] as f64, out[2] as f64))
    }

    /// Avg-normalised NMI via the artifact.
    pub fn nmi(&self, cont: &[f32]) -> Result<f64> {
        let (mi, hu, hv) = self.nmi_terms(cont)?;
        let denom = 0.5 * (hu + hv);
        Ok(if denom <= 0.0 {
            if hu == hv {
                1.0
            } else {
                0.0
            }
        } else {
            (mi / denom).clamp(0.0, 1.0)
        })
    }
}

/// [`MetricEngine`] backed by the PJRT sweep-metrics executable.
pub struct PjrtEngine {
    runtime: PjrtRuntime,
    /// Calls made (observability for the §Perf budget checks).
    pub calls: u64,
}

impl PjrtEngine {
    pub fn new(runtime: PjrtRuntime) -> Self {
        Self { runtime, calls: 0 }
    }

    pub fn load_default() -> Result<Self> {
        Ok(Self::new(PjrtRuntime::load_default()?))
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }
}

impl MetricEngine for PjrtEngine {
    fn sweep_metrics(
        &mut self,
        vols: &[f32],
        sizes: &[f32],
        w: &[f32],
        a: usize,
        k: usize,
    ) -> Vec<SweepScores> {
        assert_eq!(a, NUM_SWEEPS, "PjrtEngine is compiled for A={NUM_SWEEPS}");
        assert_eq!(k, VOLUME_BUCKETS, "PjrtEngine is compiled for K={VOLUME_BUCKETS}");
        self.calls += 1;
        let rows = self
            .runtime
            .sweep_metrics(vols, sizes, w)
            .expect("pjrt sweep_metrics failed");
        rows.into_iter()
            .map(|r| SweepScores {
                entropy: r[0],
                density: r[1],
                balance: r[2],
                ncomms: r[3],
                density_score: r[4],
                balance_score: r[5],
            })
            .collect()
    }
}
