//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs Python **once** to lower the L2 model to HLO
//! text (`artifacts/*.hlo.txt`); this module is the only consumer. The
//! [`PjrtRuntime`] compiles each module on the CPU PJRT client at
//! start-up and keeps the loaded executables; per-call cost is one
//! host-literal round-trip. Python never runs on the streaming path.
//!
//! [`PjrtEngine`] implements [`MetricEngine`] so
//! `coordinator::selection` can score sweeps through the compiled
//! kernels; [`NativeEngine`](crate::coordinator::selection::NativeEngine)
//! is the drop-in pure-Rust twin, and `rust/tests/runtime_integration.rs`
//! cross-checks the two.
//!
//! ## Offline builds (`pjrt` feature)
//!
//! The PJRT client comes from the `xla` bindings, which are not part of
//! the default (offline, dependency-free) build. The real runtime is
//! gated behind `--features pjrt`; enabling it additionally requires
//! adding the `xla` dependency to `Cargo.toml` in an environment that
//! has it. Without the feature this module compiles a stub whose
//! constructors return [`RuntimeError`], so every caller falls back to
//! the native engine gracefully.

pub mod artifacts;

use crate::coordinator::selection::{MetricEngine, SweepScores};

/// Error type for artifact discovery and runtime execution (the default
/// build carries no `anyhow`; this is the crate-local equivalent).
#[derive(Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    /// Build an error from any printable message.
    pub fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        Self(e.to_string())
    }
}

/// Result alias used throughout the runtime layer.
pub type Result<T> = std::result::Result<T, RuntimeError>;

#[cfg(not(feature = "pjrt"))]
mod stub {
    //! Featureless stand-ins: constructors fail cleanly so callers fall
    //! back to [`NativeEngine`](crate::coordinator::selection::NativeEngine).

    use super::*;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` feature (offline \
         default). Metric selection uses the native engine instead.";

    /// Stub runtime (real implementation requires `--features pjrt`).
    pub struct PjrtRuntime {
        _private: (),
    }

    impl PjrtRuntime {
        /// Always fails in the stub build.
        pub fn load(_set: &artifacts::ArtifactSet) -> Result<Self> {
            Err(RuntimeError::new(UNAVAILABLE))
        }

        /// Always fails in the stub build.
        pub fn load_default() -> Result<Self> {
            Err(RuntimeError::new(UNAVAILABLE))
        }

        /// Platform name of the PJRT client (unreachable in the stub).
        pub fn platform(&self) -> String {
            unreachable!("stub PjrtRuntime cannot be constructed")
        }
    }

    /// Stub engine; [`PjrtEngine::load_default`] always errs, so the
    /// [`MetricEngine`] impl below is never reachable at runtime.
    pub struct PjrtEngine {
        _runtime: PjrtRuntime,
        /// Calls made (observability parity with the real engine).
        pub calls: u64,
    }

    impl PjrtEngine {
        /// Wrap a loaded runtime (unreachable in the stub build).
        pub fn new(runtime: PjrtRuntime) -> Self {
            Self { _runtime: runtime, calls: 0 }
        }

        /// Always fails in the stub build.
        pub fn load_default() -> Result<Self> {
            Err(RuntimeError::new(UNAVAILABLE))
        }
    }

    impl MetricEngine for PjrtEngine {
        fn sweep_metrics(
            &mut self,
            _vols: &[f32],
            _sizes: &[f32],
            _w: &[f32],
            _a: usize,
            _k: usize,
        ) -> Vec<SweepScores> {
            unreachable!("stub PjrtEngine cannot be constructed")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtEngine, PjrtRuntime};

#[cfg(feature = "pjrt")]
mod real {
    use super::artifacts::{ArtifactSet, CONTINGENCY, EDGE_BLOCK, NUM_SWEEPS, VOLUME_BUCKETS};
    use super::*;

    /// Compiled PJRT executables for every artifact.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        sweep_metrics: xla::PjRtLoadedExecutable,
        modularity: xla::PjRtLoadedExecutable,
        nmi: xla::PjRtLoadedExecutable,
    }

    impl PjrtRuntime {
        /// Compile all artifacts from the given set.
        pub fn load(set: &ArtifactSet) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| RuntimeError::new(format!("pjrt cpu client: {e:?}")))?;
            let compile = |path: &std::path::Path| -> Result<xla::PjRtLoadedExecutable> {
                let proto = xla::HloModuleProto::from_text_file(path)
                    .map_err(|e| RuntimeError::new(format!("parse {}: {e:?}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map_err(|e| RuntimeError::new(format!("compile {}: {e:?}", path.display())))
            };
            Ok(Self {
                sweep_metrics: compile(&set.sweep_metrics)?,
                modularity: compile(&set.modularity)?,
                nmi: compile(&set.nmi)?,
                client,
            })
        }

        /// Locate artifacts via `STREAMCOM_ARTIFACTS` or `./artifacts` and load.
        pub fn load_default() -> Result<Self> {
            let set = ArtifactSet::discover().map_err(|e| {
                RuntimeError::new(format!("artifacts not found — run `make artifacts`: {e}"))
            })?;
            Self::load(&set)
        }

        /// Platform name of the PJRT client.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn run1(exe: &xla::PjRtLoadedExecutable, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
            let result = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| RuntimeError::new(format!("execute: {e:?}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| RuntimeError::new(format!("to_literal: {e:?}")))?;
            // lowered with return_tuple=True → 1-tuple
            let out = result
                .to_tuple1()
                .map_err(|e| RuntimeError::new(format!("tuple: {e:?}")))?;
            out.to_vec::<f32>()
                .map_err(|e| RuntimeError::new(format!("to_vec: {e:?}")))
        }

        /// Execute `sweep_metrics.hlo.txt`: `(A·K, A·K, A)` → `A × 6` scores.
        pub fn sweep_metrics(
            &self,
            vols: &[f32],
            sizes: &[f32],
            w: &[f32],
        ) -> Result<Vec<[f32; 6]>> {
            let (a, k) = (NUM_SWEEPS, VOLUME_BUCKETS);
            if vols.len() != a * k || sizes.len() != a * k || w.len() != a {
                return Err(RuntimeError::new(format!(
                    "sweep_metrics shape mismatch: vols={} sizes={} w={}",
                    vols.len(),
                    sizes.len(),
                    w.len()
                )));
            }
            let lv = xla::Literal::vec1(vols)
                .reshape(&[a as i64, k as i64])
                .map_err(|e| RuntimeError::new(format!("reshape vols: {e:?}")))?;
            let ls = xla::Literal::vec1(sizes)
                .reshape(&[a as i64, k as i64])
                .map_err(|e| RuntimeError::new(format!("reshape sizes: {e:?}")))?;
            let lw = xla::Literal::vec1(w);
            let flat = Self::run1(&self.sweep_metrics, &[lv, ls, lw])?;
            if flat.len() != a * 6 {
                return Err(RuntimeError::new(format!(
                    "sweep_metrics output len {}",
                    flat.len()
                )));
            }
            Ok((0..a)
                .map(|r| {
                    let mut row = [0f32; 6];
                    row.copy_from_slice(&flat[r * 6..(r + 1) * 6]);
                    row
                })
                .collect())
        }

        /// Execute `modularity.hlo.txt` over one padded edge block:
        /// returns `(intra, Σ vol²)`.
        pub fn modularity_partials(
            &self,
            ci: &[i32],
            cj: &[i32],
            mask: &[f32],
            vols: &[f32],
        ) -> Result<(f64, f64)> {
            if ci.len() != EDGE_BLOCK
                || cj.len() != EDGE_BLOCK
                || mask.len() != EDGE_BLOCK
                || vols.len() != VOLUME_BUCKETS
            {
                return Err(RuntimeError::new("modularity shape mismatch"));
            }
            let out = Self::run1(
                &self.modularity,
                &[
                    xla::Literal::vec1(ci),
                    xla::Literal::vec1(cj),
                    xla::Literal::vec1(mask),
                    xla::Literal::vec1(vols),
                ],
            )?;
            Ok((out[0] as f64, out[1] as f64))
        }

        /// Execute `nmi.hlo.txt` on a `C × C` contingency table:
        /// returns `(mi, h_u, h_v)` in nats.
        pub fn nmi_terms(&self, cont: &[f32]) -> Result<(f64, f64, f64)> {
            if cont.len() != CONTINGENCY * CONTINGENCY {
                return Err(RuntimeError::new(format!("nmi shape mismatch: {}", cont.len())));
            }
            let lc = xla::Literal::vec1(cont)
                .reshape(&[CONTINGENCY as i64, CONTINGENCY as i64])
                .map_err(|e| RuntimeError::new(format!("reshape cont: {e:?}")))?;
            let out = Self::run1(&self.nmi, &[lc])?;
            Ok((out[0] as f64, out[1] as f64, out[2] as f64))
        }

        /// Avg-normalised NMI via the artifact.
        pub fn nmi(&self, cont: &[f32]) -> Result<f64> {
            let (mi, hu, hv) = self.nmi_terms(cont)?;
            let denom = 0.5 * (hu + hv);
            Ok(if denom <= 0.0 {
                if hu == hv {
                    1.0
                } else {
                    0.0
                }
            } else {
                (mi / denom).clamp(0.0, 1.0)
            })
        }
    }

    /// [`MetricEngine`] backed by the PJRT sweep-metrics executable.
    pub struct PjrtEngine {
        runtime: PjrtRuntime,
        /// Calls made (observability for the §Perf budget checks).
        pub calls: u64,
    }

    impl PjrtEngine {
        /// Wrap a loaded runtime.
        pub fn new(runtime: PjrtRuntime) -> Self {
            Self { runtime, calls: 0 }
        }

        /// Load artifacts from the default location.
        pub fn load_default() -> Result<Self> {
            Ok(Self::new(PjrtRuntime::load_default()?))
        }

        /// Access the underlying runtime.
        pub fn runtime(&self) -> &PjrtRuntime {
            &self.runtime
        }
    }

    impl MetricEngine for PjrtEngine {
        fn sweep_metrics(
            &mut self,
            vols: &[f32],
            sizes: &[f32],
            w: &[f32],
            a: usize,
            k: usize,
        ) -> Vec<SweepScores> {
            assert_eq!(a, NUM_SWEEPS, "PjrtEngine is compiled for A={NUM_SWEEPS}");
            assert_eq!(k, VOLUME_BUCKETS, "PjrtEngine is compiled for K={VOLUME_BUCKETS}");
            self.calls += 1;
            let rows = self
                .runtime
                .sweep_metrics(vols, sizes, w)
                .expect("pjrt sweep_metrics failed");
            rows.into_iter()
                .map(|r| SweepScores {
                    entropy: r[0],
                    density: r[1],
                    balance: r[2],
                    ncomms: r[3],
                    density_score: r[4],
                    balance_score: r[5],
                })
                .collect()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::{PjrtEngine, PjrtRuntime};

#[cfg(test)]
mod tests {
    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn stub_engine_fails_cleanly() {
        let err = super::PjrtEngine::load_default().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        let err = super::PjrtRuntime::load_default().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn runtime_error_wraps_io() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: super::RuntimeError = io.into();
        assert!(e.to_string().contains("gone"));
    }
}
