//! Artifact discovery and the fixed AOT shape contract.
//!
//! The shapes here must stay in sync with `python/compile/kernels/ref.py`
//! and DESIGN.md §7; `manifest.txt` (written by `python -m compile.aot`)
//! is validated at load time so a stale artifact directory fails fast
//! instead of mis-executing.

use std::path::{Path, PathBuf};

use super::{Result, RuntimeError};

/// A — sweep rows.
pub const NUM_SWEEPS: usize = 8;
/// K — padded volume buckets.
pub const VOLUME_BUCKETS: usize = 4096;
/// B — modularity edge block.
pub const EDGE_BLOCK: usize = 4096;
/// C — contingency classes per side.
pub const CONTINGENCY: usize = 256;

/// Paths of the three artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    /// Artifact directory.
    pub dir: PathBuf,
    /// Path to `sweep_metrics.hlo.txt`.
    pub sweep_metrics: PathBuf,
    /// Path to `modularity.hlo.txt`.
    pub modularity: PathBuf,
    /// Path to `nmi.hlo.txt`.
    pub nmi: PathBuf,
}

impl ArtifactSet {
    /// Build from a directory, verifying presence and the manifest.
    pub fn from_dir<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let set = Self {
            sweep_metrics: dir.join("sweep_metrics.hlo.txt"),
            modularity: dir.join("modularity.hlo.txt"),
            nmi: dir.join("nmi.hlo.txt"),
            dir,
        };
        for p in [&set.sweep_metrics, &set.modularity, &set.nmi] {
            if !p.is_file() {
                return Err(RuntimeError::new(format!("missing artifact {}", p.display())));
            }
        }
        set.validate_manifest()?;
        Ok(set)
    }

    /// `STREAMCOM_ARTIFACTS` env var, else `./artifacts`, else the
    /// workspace-relative `artifacts/` next to the executable.
    pub fn discover() -> Result<Self> {
        if let Ok(dir) = std::env::var("STREAMCOM_ARTIFACTS") {
            return Self::from_dir(dir);
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(cand).is_dir() {
                if let Ok(set) = Self::from_dir(cand) {
                    return Ok(set);
                }
            }
        }
        Err(RuntimeError::new("no artifact directory found"))
    }

    /// Check the manifest shape lines match this build's constants.
    fn validate_manifest(&self) -> Result<()> {
        let path = self.dir.join("manifest.txt");
        if !path.is_file() {
            // tolerated: hand-copied artifacts without a manifest
            return Ok(());
        }
        let text = std::fs::read_to_string(&path)?;
        let expect = [
            (
                "sweep_metrics",
                format!("float32[{NUM_SWEEPS},{VOLUME_BUCKETS}]"),
            ),
            ("modularity", format!("int32[{EDGE_BLOCK}]")),
            ("nmi", format!("float32[{CONTINGENCY},{CONTINGENCY}]")),
        ];
        for (name, shape) in expect {
            let line = text
                .lines()
                .find(|l| l.starts_with(name))
                .ok_or_else(|| RuntimeError::new(format!("manifest missing entry {name}")))?;
            if !line.contains(&shape) {
                return Err(RuntimeError::new(format!(
                    "manifest shape drift for {name}: expected {shape} in {line:?}"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_constants_match_python_contract() {
        // mirror of python/compile/kernels/ref.py — a drift here breaks
        // the runtime at load, this test breaks it at `cargo test`
        assert_eq!(NUM_SWEEPS, 8);
        assert_eq!(VOLUME_BUCKETS, 4096);
        assert_eq!(EDGE_BLOCK, 4096);
        assert_eq!(CONTINGENCY, 256);
    }

    #[test]
    fn from_dir_fails_cleanly_when_missing() {
        let err = ArtifactSet::from_dir("/nonexistent-dir-xyz").unwrap_err();
        assert!(err.to_string().contains("missing artifact"));
    }

    #[test]
    fn selection_constants_agree() {
        use crate::coordinator::selection;
        assert_eq!(selection::NUM_SWEEPS, NUM_SWEEPS);
        assert_eq!(selection::VOLUME_BUCKETS, VOLUME_BUCKETS);
    }
}
