//! Scoring: the paper's two benchmark metrics (average F1, NMI) plus
//! modularity and the sketch-only metrics (entropy, density,
//! conductance).
//!
//! Rust implementations are the reference used by the harnesses; the
//! NMI and modularity paths also exist as PJRT artifacts
//! (`runtime::PjrtEngine`) and the integration tests cross-check the
//! two.

pub mod f1;
pub mod modularity;
pub mod nmi;
pub mod quality;

/// Convert a label vector into a community → members map with dense
/// community indices (helper shared by the scorers).
pub fn labels_to_communities(labels: &[u32]) -> Vec<Vec<u32>> {
    use std::collections::HashMap;
    let mut index: HashMap<u32, usize> = HashMap::new();
    let mut comms: Vec<Vec<u32>> = Vec::new();
    for (i, &l) in labels.iter().enumerate() {
        let k = *index.entry(l).or_insert_with(|| {
            comms.push(Vec::new());
            comms.len() - 1
        });
        comms[k].push(i as u32);
    }
    comms
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_to_communities_groups() {
        let comms = labels_to_communities(&[5, 5, 9, 5, 9]);
        assert_eq!(comms.len(), 2);
        assert_eq!(comms[0], vec![0, 1, 3]);
        assert_eq!(comms[1], vec![2, 4]);
    }
}
