//! Sketch-only quality metrics (entropy, density) plus conductance.
//!
//! Entropy and average density are the paper's §2.5 selection metrics —
//! computable from the `(c, v)` sketch alone. Conductance needs the
//! graph and is used by the evaluation harness as an extra diagnostic
//! (it is the WCC-adjacent metric SCD's paper reports).

use crate::graph::edge::Edge;

/// Entropy H(v) = −Σ_k (v_k/w) ln(v_k/w) over non-empty communities.
pub fn entropy(volumes: &[u64]) -> f64 {
    let w: u64 = volumes.iter().sum();
    if w == 0 {
        return 0.0;
    }
    let wf = w as f64;
    volumes
        .iter()
        .filter(|&&v| v > 0)
        .map(|&v| {
            let p = v as f64 / wf;
            -p * p.ln()
        })
        .sum()
}

/// Average density D = (1/|P|) Σ_{k: |C_k|>1} v_k / (|C_k|(|C_k|−1))
/// over (volume, size) pairs of non-empty communities.
pub fn average_density(comms: &[(u64, u32)]) -> f64 {
    if comms.is_empty() {
        return 0.0;
    }
    let sum: f64 = comms
        .iter()
        .filter(|&&(_, s)| s > 1)
        .map(|&(v, s)| v as f64 / (s as f64 * (s as f64 - 1.0)))
        .sum();
    sum / comms.len() as f64
}

/// Per-community conductance φ(C) = cut(C) / min(Vol(C), w − Vol(C)),
/// returned as the volume-weighted average over communities with
/// non-zero volume. Lower is better.
pub fn weighted_conductance(n: usize, edges: &[Edge], labels: &[u32]) -> f64 {
    assert!(labels.len() >= n);
    if edges.is_empty() {
        return 0.0;
    }
    let max_label = labels.iter().copied().max().unwrap_or(0) as usize;
    let mut cut = vec![0u64; max_label + 1];
    let mut vol = vec![0u64; max_label + 1];
    for e in edges {
        let (cu, cv) = (labels[e.u as usize] as usize, labels[e.v as usize] as usize);
        vol[cu] += 1;
        vol[cv] += 1;
        if cu != cv {
            cut[cu] += 1;
            cut[cv] += 1;
        }
    }
    let w: u64 = 2 * edges.len() as u64;
    let mut num = 0.0;
    let mut den = 0.0;
    for k in 0..=max_label {
        if vol[k] == 0 {
            continue;
        }
        let bound = vol[k].min(w - vol[k]);
        let phi = if bound == 0 { 0.0 } else { cut[k] as f64 / bound as f64 };
        num += phi * vol[k] as f64;
        den += vol[k] as f64;
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_uniform_is_log_k() {
        let v = vec![5u64; 8];
        assert!((entropy(&v) - (8f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn entropy_single_community_zero() {
        assert_eq!(entropy(&[42]), 0.0);
        assert_eq!(entropy(&[]), 0.0);
    }

    #[test]
    fn density_pairs() {
        // one community: size 2, volume 2 → 2/(2·1) = 1
        assert!((average_density(&[(2, 2)]) - 1.0).abs() < 1e-12);
        // singletons contribute 0 but count in |P|
        assert!((average_density(&[(2, 2), (1, 1)]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn conductance_perfect_split_low_bridge_high() {
        let edges = vec![
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(0, 2),
            Edge::new(3, 4),
            Edge::new(4, 5),
            Edge::new(3, 5),
            Edge::new(2, 3),
        ];
        let split = vec![0, 0, 0, 1, 1, 1];
        let merged_half = vec![0, 1, 0, 1, 0, 1];
        let phi_split = weighted_conductance(6, &edges, &split);
        let phi_bad = weighted_conductance(6, &edges, &merged_half);
        assert!(phi_split < phi_bad, "{phi_split} !< {phi_bad}");
        // split: each side cut=1, vol=7 → φ = 1/7
        assert!((phi_split - 1.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn conductance_single_community_zero() {
        let edges = vec![Edge::new(0, 1), Edge::new(1, 2)];
        assert_eq!(weighted_conductance(3, &edges, &[0, 0, 0]), 0.0);
    }
}
