//! Newman modularity of a partition.
//!
//! Q = Σ_C [ int(C)/m − (Vol(C)/2m)² ] where int(C) is the number of
//! edges inside C. Computed in one edge pass + one node pass, O(n + m).
//! This is both the paper's §3 objective and Louvain's target function;
//! `baselines::louvain` uses the incremental form, and the tests here
//! pin the two to each other.

use crate::graph::edge::Edge;

/// Modularity of `labels` over the edge multiset.
pub fn modularity(n: usize, edges: &[Edge], labels: &[u32]) -> f64 {
    assert!(labels.len() >= n);
    let m = edges.len();
    if m == 0 {
        return 0.0;
    }
    let mf = m as f64;
    // intra-edge count and per-community volume
    let mut intra: std::collections::HashMap<u32, u64> = Default::default();
    let mut vol: std::collections::HashMap<u32, u64> = Default::default();
    for e in edges {
        let (cu, cv) = (labels[e.u as usize], labels[e.v as usize]);
        *vol.entry(cu).or_insert(0) += 1;
        *vol.entry(cv).or_insert(0) += 1;
        if cu == cv {
            *intra.entry(cu).or_insert(0) += 1;
        }
    }
    let w = 2.0 * mf;
    let mut q = 0.0;
    for (&c, &v) in &vol {
        let int_c = intra.get(&c).copied().unwrap_or(0) as f64;
        q += int_c / mf - (v as f64 / w) * (v as f64 / w);
    }
    q
}

/// The streaming partial sums (intra count, Σ vol²) — the exact math of
/// the `modularity.hlo.txt` artifact, natively. Combine with
/// `combine_partials`.
pub fn partials(edges: &[Edge], labels: &[u32]) -> (f64, f64) {
    let mut intra = 0u64;
    let mut vol: std::collections::HashMap<u32, u64> = Default::default();
    for e in edges {
        let (cu, cv) = (labels[e.u as usize], labels[e.v as usize]);
        *vol.entry(cu).or_insert(0) += 1;
        *vol.entry(cv).or_insert(0) += 1;
        if cu == cv {
            intra += 1;
        }
    }
    let volsq: f64 = vol.values().map(|&v| (v as f64) * (v as f64)).sum();
    (intra as f64, volsq)
}

/// Q from (intra, Σ vol²) given edge count m.
pub fn combine_partials(intra: f64, volsq: f64, m: u64) -> f64 {
    if m == 0 {
        return 0.0;
    }
    let mf = m as f64;
    intra / mf - volsq / (4.0 * mf * mf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_triangles() -> (usize, Vec<Edge>) {
        (
            6,
            vec![
                Edge::new(0, 1),
                Edge::new(1, 2),
                Edge::new(0, 2),
                Edge::new(3, 4),
                Edge::new(4, 5),
                Edge::new(3, 5),
                Edge::new(2, 3),
            ],
        )
    }

    #[test]
    fn known_value_two_triangles() {
        // classic example: Q = 2·(3/7 − (7/14)²) = 6/7 − 1/2 = 5/14
        let (n, edges) = two_triangles();
        let labels = vec![0, 0, 0, 1, 1, 1];
        let q = modularity(n, &edges, &labels);
        assert!((q - 5.0 / 14.0).abs() < 1e-12, "q={q}");
    }

    #[test]
    fn single_community_zero() {
        let (n, edges) = two_triangles();
        let labels = vec![0; 6];
        let q = modularity(n, &edges, &labels);
        assert!(q.abs() < 1e-12);
    }

    #[test]
    fn all_singletons_negative() {
        let (n, edges) = two_triangles();
        let labels: Vec<u32> = (0..6).collect();
        assert!(modularity(n, &edges, &labels) < 0.0);
    }

    #[test]
    fn good_partition_beats_bad() {
        let (n, edges) = two_triangles();
        let good = vec![0, 0, 0, 1, 1, 1];
        let bad = vec![0, 1, 0, 1, 0, 1];
        assert!(modularity(n, &edges, &good) > modularity(n, &edges, &bad));
    }

    #[test]
    fn partials_compose_to_modularity() {
        let (n, edges) = two_triangles();
        let labels = vec![0, 0, 0, 1, 1, 1];
        let (intra, volsq) = partials(&edges, &labels);
        let q = combine_partials(intra, volsq, edges.len() as u64);
        assert!((q - modularity(n, &edges, &labels)).abs() < 1e-12);
    }

    #[test]
    fn blockwise_partials_equal_global() {
        let (n, edges) = two_triangles();
        let labels = vec![0, 0, 0, 1, 1, 1];
        // intra sums are blockwise additive; volsq must come from the
        // full volume table (exactly how the runtime splits the work:
        // per-block intra from the kernel + one volsq from the final
        // volume table)
        let (i1, _) = partials(&edges[..4], &labels);
        let (i2, _) = partials(&edges[4..], &labels);
        let (intra, volsq) = partials(&edges, &labels);
        assert_eq!(i1 + i2, intra);
        let q = combine_partials(i1 + i2, volsq, edges.len() as u64);
        assert!((q - modularity(n, &edges, &labels)).abs() < 1e-12);
        let _ = n;
    }

    #[test]
    fn multigraph_edges_count_with_multiplicity() {
        let edges = vec![Edge::new(0, 1), Edge::new(0, 1), Edge::new(2, 3)];
        let labels = vec![0, 0, 1, 1];
        // m = 3, intra = 3; vol(0) = 4, vol(1) = 2, w = 6
        let q = modularity(4, &edges, &labels);
        let expected = 2.0 / 3.0 - (4.0f64 / 6.0).powi(2) + 1.0 / 3.0 - (2.0f64 / 6.0).powi(2);
        assert!((q - expected).abs() < 1e-12, "q={q} expected={expected}");
    }
}
