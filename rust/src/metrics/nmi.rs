//! Normalized Mutual Information between two disjoint partitions.
//!
//! NMI = 2·I(U;V) / (H(U) + H(V))  (the common "avg" normalisation; the
//! "max" normalisation is also exposed). Contingency counts are built
//! sparsely in O(n); the dense padded-table path used by the PJRT
//! artifact (`nmi.hlo.txt`) lives in [`contingency_table`], which caps
//! each side at `C` classes by keeping the largest and merging the rest
//! into a tail class — the same approximation the padded kernel input
//! requires, cross-checked against the sparse exact path in tests.

use std::collections::HashMap;

/// Normalisation variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NmiNorm {
    /// Normalise by the mean of the two entropies.
    Avg,
    /// Normalise by the larger entropy.
    Max,
}

fn entropy_from_counts(counts: &[u64], n: f64) -> f64 {
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.ln()
        })
        .sum()
}

/// Exact sparse NMI over label vectors (same length).
pub fn nmi_labels_norm(a: &[u32], b: &[u32], norm: NmiNorm) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return 0.0;
    }
    let nf = n as f64;

    let mut ca: HashMap<u32, u64> = HashMap::new();
    let mut cb: HashMap<u32, u64> = HashMap::new();
    let mut joint: HashMap<(u32, u32), u64> = HashMap::new();
    for i in 0..n {
        *ca.entry(a[i]).or_insert(0) += 1;
        *cb.entry(b[i]).or_insert(0) += 1;
        *joint.entry((a[i], b[i])).or_insert(0) += 1;
    }
    let ha = entropy_from_counts(&ca.values().copied().collect::<Vec<_>>(), nf);
    let hb = entropy_from_counts(&cb.values().copied().collect::<Vec<_>>(), nf);

    let mut mi = 0.0;
    for (&(u, v), &c) in &joint {
        let pij = c as f64 / nf;
        let pi = ca[&u] as f64 / nf;
        let pj = cb[&v] as f64 / nf;
        mi += pij * (pij / (pi * pj)).ln();
    }

    let denom = match norm {
        NmiNorm::Avg => 0.5 * (ha + hb),
        NmiNorm::Max => ha.max(hb),
    };
    if denom <= 0.0 {
        // both partitions trivial (single cluster): identical ⇒ 1
        return if ha == hb { 1.0 } else { 0.0 };
    }
    (mi / denom).clamp(0.0, 1.0)
}

/// Default (avg-normalised) NMI.
pub fn nmi_labels(a: &[u32], b: &[u32]) -> f64 {
    nmi_labels_norm(a, b, NmiNorm::Avg)
}

/// Build the dense `C × C` contingency table the PJRT NMI artifact
/// consumes: the `C−1` largest classes on each side keep their own row/
/// column; all remaining classes merge into the tail index `C−1`.
pub fn contingency_table(a: &[u32], b: &[u32], c: usize) -> Vec<f32> {
    assert_eq!(a.len(), b.len());
    assert!(c >= 2);
    let count = |labels: &[u32]| -> HashMap<u32, u64> {
        let mut m = HashMap::new();
        for &l in labels {
            *m.entry(l).or_insert(0) += 1;
        }
        m
    };
    let ca = count(a);
    let cb = count(b);
    let top = |m: &HashMap<u32, u64>| -> HashMap<u32, usize> {
        let mut items: Vec<(u32, u64)> = m.iter().map(|(&k, &v)| (k, v)).collect();
        items.sort_unstable_by(|x, y| y.1.cmp(&x.1).then(x.0.cmp(&y.0)));
        items
            .into_iter()
            .enumerate()
            .map(|(rank, (k, _))| (k, rank.min(c - 1)))
            .collect()
    };
    let ia = top(&ca);
    let ib = top(&cb);
    let mut table = vec![0f32; c * c];
    for i in 0..a.len() {
        let r = ia[&a[i]];
        let col = ib[&b[i]];
        table[r * c + col] += 1.0;
    }
    table
}

/// NMI computed from a dense contingency table (the artifact's math,
/// natively — used to cross-check the PJRT path).
pub fn nmi_from_table(table: &[f32], c: usize, norm: NmiNorm) -> f64 {
    let total: f64 = table.iter().map(|&x| x as f64).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut pi = vec![0.0f64; c];
    let mut pj = vec![0.0f64; c];
    for r in 0..c {
        for col in 0..c {
            let p = table[r * c + col] as f64 / total;
            pi[r] += p;
            pj[col] += p;
        }
    }
    let mut mi = 0.0;
    for r in 0..c {
        for col in 0..c {
            let p = table[r * c + col] as f64 / total;
            if p > 0.0 && pi[r] > 0.0 && pj[col] > 0.0 {
                mi += p * (p / (pi[r] * pj[col])).ln();
            }
        }
    }
    let h = |p: &[f64]| -> f64 {
        p.iter().filter(|&&x| x > 0.0).map(|&x| -x * x.ln()).sum()
    };
    let (ha, hb) = (h(&pi), h(&pj));
    let denom = match norm {
        NmiNorm::Avg => 0.5 * (ha + hb),
        NmiNorm::Max => ha.max(hb),
    };
    if denom <= 0.0 {
        return if ha == hb { 1.0 } else { 0.0 };
    }
    (mi / denom).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_partitions_nmi_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((nmi_labels(&a, &a) - 1.0).abs() < 1e-12);
        // renaming labels does not matter
        let b = vec![9, 9, 4, 4, 7, 7];
        assert!((nmi_labels(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_partitions_nmi_zero() {
        // perfectly crossed 2×2 design: every combination equally likely
        let a = vec![0, 0, 1, 1];
        let b = vec![0, 1, 0, 1];
        assert!(nmi_labels(&a, &b).abs() < 1e-12);
    }

    #[test]
    fn single_cluster_vs_split() {
        let a = vec![0, 0, 0, 0];
        let b = vec![0, 0, 1, 1];
        // H(a) = 0 → degenerate; avg-norm denominator = H(b)/2 > 0, MI = 0
        assert_eq!(nmi_labels(&a, &b), 0.0);
        assert_eq!(nmi_labels(&a, &a), 1.0);
    }

    #[test]
    fn partial_agreement_in_between() {
        let a = vec![0, 0, 0, 1, 1, 1];
        let b = vec![0, 0, 1, 1, 1, 1];
        let s = nmi_labels(&a, &b);
        assert!(s > 0.2 && s < 0.9, "s={s}");
    }

    #[test]
    fn max_norm_leq_avg_relation() {
        // max norm denominator >= avg denominator → NMI_max <= NMI_avg
        let a = vec![0, 0, 1, 1, 2, 2, 3, 3];
        let b = vec![0, 0, 0, 1, 1, 2, 2, 3];
        let avg = nmi_labels_norm(&a, &b, NmiNorm::Avg);
        let max = nmi_labels_norm(&a, &b, NmiNorm::Max);
        assert!(max <= avg + 1e-12);
    }

    #[test]
    fn dense_table_matches_sparse_when_classes_fit() {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(5);
        let n = 500;
        let a: Vec<u32> = (0..n).map(|_| rng.range(0, 10) as u32).collect();
        let b: Vec<u32> = (0..n).map(|_| rng.range(0, 12) as u32).collect();
        let sparse = nmi_labels_norm(&a, &b, NmiNorm::Avg);
        let table = contingency_table(&a, &b, 64);
        let dense = nmi_from_table(&table, 64, NmiNorm::Avg);
        assert!((sparse - dense).abs() < 1e-9, "{sparse} vs {dense}");
    }

    #[test]
    fn table_tail_merging_is_graceful() {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(6);
        let n = 2000;
        // 40 classes but table capped at 16: tail merge loses some MI
        // but must stay within a reasonable band of the exact value
        let a: Vec<u32> = (0..n).map(|_| rng.range(0, 40) as u32).collect();
        let b: Vec<u32> = a
            .iter()
            .map(|&x| if rng.bernoulli(0.8) { x } else { rng.range(0, 40) as u32 })
            .collect();
        let exact = nmi_labels(&a, &b);
        let table = contingency_table(&a, &b, 16);
        let approx = nmi_from_table(&table, 16, NmiNorm::Avg);
        assert!(approx <= exact + 1e-9);
        assert!(approx > exact * 0.5, "approx={approx} exact={exact}");
    }

    #[test]
    fn contingency_counts_sum_to_n() {
        let a = vec![0, 1, 2, 3, 4, 5, 6, 7];
        let b = vec![0, 0, 1, 1, 2, 2, 3, 3];
        let t = contingency_table(&a, &b, 4);
        let total: f32 = t.iter().sum();
        assert_eq!(total, 8.0);
    }
}
