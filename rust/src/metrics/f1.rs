//! Average F1 score between detected and ground-truth communities.
//!
//! The paper uses the SCD authors' definition (Prat-Pérez et al. 2014,
//! also Yang & Leskovec 2013): for each detected community take the F1
//! of its best-matching ground-truth community, and vice versa; the
//! score is the average of the two directional means:
//!
//!   F1 = ½ ( 1/|D| Σ_{d∈D} max_{g∈G} F1(d, g)
//!          + 1/|G| Σ_{g∈G} max_{d∈D} F1(g, d) ).
//!
//! Computed with an inverted index (node → communities) so each
//! direction is O(Σ overlaps), not O(|D|·|G|).

use std::collections::HashMap;

/// F1 of two node sets given their intersection size.
#[inline]
fn f1(inter: usize, a: usize, b: usize) -> f64 {
    if inter == 0 {
        return 0.0;
    }
    let p = inter as f64 / a as f64;
    let r = inter as f64 / b as f64;
    2.0 * p * r / (p + r)
}

/// One directional mean: for each community in `from`, the best F1
/// against `to`.
fn directional(from: &[Vec<u32>], to: &[Vec<u32>], node_to_to: &HashMap<u32, Vec<u32>>) -> f64 {
    if from.is_empty() {
        return 0.0;
    }
    let mut sum = 0.0;
    let mut overlap: HashMap<u32, usize> = HashMap::new();
    for d in from {
        overlap.clear();
        for node in d {
            if let Some(gs) = node_to_to.get(node) {
                for &g in gs {
                    *overlap.entry(g).or_insert(0) += 1;
                }
            }
        }
        let best = overlap
            .iter()
            .map(|(&g, &inter)| f1(inter, d.len(), to[g as usize].len()))
            .fold(0.0, f64::max);
        sum += best;
    }
    sum / from.len() as f64
}

fn invert(comms: &[Vec<u32>]) -> HashMap<u32, Vec<u32>> {
    let mut idx: HashMap<u32, Vec<u32>> = HashMap::new();
    for (k, c) in comms.iter().enumerate() {
        for &node in c {
            idx.entry(node).or_default().push(k as u32);
        }
    }
    idx
}

/// Average F1 between two covers (sets of node sets; overlap allowed).
pub fn average_f1(detected: &[Vec<u32>], truth: &[Vec<u32>]) -> f64 {
    if detected.is_empty() || truth.is_empty() {
        return 0.0;
    }
    let inv_truth = invert(truth);
    let inv_det = invert(detected);
    0.5 * (directional(detected, truth, &inv_truth) + directional(truth, detected, &inv_det))
}

/// Convenience over label vectors.
pub fn average_f1_labels(detected: &[u32], truth: &[u32]) -> f64 {
    let d = super::labels_to_communities(detected);
    let t = super::labels_to_communities(truth);
    average_f1(&d, &t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_partitions_score_one() {
        let a = vec![vec![0, 1, 2], vec![3, 4]];
        assert!((average_f1(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_covers_score_zero() {
        let a = vec![vec![0, 1]];
        let b = vec![vec![2, 3]];
        assert_eq!(average_f1(&a, &b), 0.0);
    }

    #[test]
    fn pairwise_f1_formula() {
        // d = {0,1,2,3}, g = {2,3,4} → inter 2, p = 0.5, r = 2/3,
        // F1 = 2·0.5·(2/3)/(0.5+2/3) = 4/7
        let d = vec![vec![0, 1, 2, 3]];
        let g = vec![vec![2, 3, 4]];
        let expected = 4.0 / 7.0;
        assert!((average_f1(&d, &g) - expected).abs() < 1e-12);
    }

    #[test]
    fn asymmetric_split_detection() {
        // truth one community; detection splits it in half:
        // direction D→G: each half has F1 = 2·1·0.5/1.5 = 2/3 → mean 2/3
        // direction G→D: best match also 2/3
        let g = vec![vec![0, 1, 2, 3]];
        let d = vec![vec![0, 1], vec![2, 3]];
        let expected = 2.0 / 3.0;
        assert!((average_f1(&d, &g) - expected).abs() < 1e-12);
    }

    #[test]
    fn label_interface_matches_cover_interface() {
        let det = vec![0, 0, 1, 1, 2];
        let tru = vec![7, 7, 7, 9, 9];
        let a = average_f1_labels(&det, &tru);
        let b = average_f1(
            &super::super::labels_to_communities(&det),
            &super::super::labels_to_communities(&tru),
        );
        assert_eq!(a, b);
        assert!(a > 0.0 && a < 1.0);
    }

    #[test]
    fn more_accurate_detection_scores_higher() {
        let truth = vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]];
        let good = vec![vec![0, 1, 2, 3], vec![4, 5, 6], vec![7]];
        let bad = vec![vec![0, 4], vec![1, 5], vec![2, 6], vec![3, 7]];
        assert!(average_f1(&good, &truth) > average_f1(&bad, &truth));
    }

    #[test]
    fn overlapping_truth_accepted() {
        let truth = vec![vec![0, 1, 2], vec![2, 3, 4]]; // node 2 in both
        let det = vec![vec![0, 1, 2], vec![3, 4]];
        let s = average_f1(&det, &truth);
        assert!(s > 0.7 && s <= 1.0);
    }
}
