//! Minimal thread pool for the parallel coordinator and bench harness.
//!
//! No rayon offline; this pool provides the two shapes we need:
//! fire-and-forget task execution and `scope`-style fork/join over
//! closures that return values.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Spawn a pool of `threads` workers.
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Self { workers, tx: Some(tx) }
    }

    /// Number of logical CPUs (fallback 4).
    pub fn default_threads() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Run `f` on an idle worker (FIFO dispatch).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Run `f(i)` for `i in 0..n` across the pool, collecting results in
    /// index order. Blocks until all complete.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let out = f(i);
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            results[i] = Some(v);
        }
        results.into_iter().map(|o| o.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One-shot fork/join without keeping a pool alive: spawn `n` scoped
/// threads running `f(i)` and collect results in index order.
pub fn scoped_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Send + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    thread::scope(|s| {
        let handles: Vec<_> = (0..n).map(|i| s.spawn({ let f = &f; move || f(i) })).collect();
        for (i, h) in handles.into_iter().enumerate() {
            out[i] = Some(h.join().expect("scoped worker panicked"));
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn pool_executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_index_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map(20, |i| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_borrows_environment() {
        let data: Vec<u64> = (0..16).collect();
        let out = scoped_map(4, |i| data[i * 4..(i + 1) * 4].iter().sum::<u64>());
        assert_eq!(out.iter().sum::<u64>(), data.iter().sum::<u64>());
    }
}
