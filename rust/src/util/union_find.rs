//! Union–find (disjoint set union) with path halving + union by size.
//!
//! Used by the graph generators (connectivity checks), Walktrap's
//! agglomerative merge tracking, and the test suite's partition
//! invariants.

/// Disjoint-set forest over `0..n`.
#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
    components: usize,
}

impl UnionFind {
    /// Disjoint-set over `n` singleton elements.
    pub fn new(n: usize) -> Self {
        Self {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
            components: n,
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    /// True when the structure holds no elements.
    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Number of disjoint components.
    pub fn components(&self) -> usize {
        self.components
    }

    /// Find with path halving (iterative, allocation-free).
    pub fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] as usize != x {
            let gp = self.parent[self.parent[x] as usize];
            self.parent[x] = gp;
            x = gp as usize;
        }
        x
    }

    /// Union by size; returns `true` if the two sets were merged.
    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra] < self.size[rb] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb] = ra as u32;
        self.size[ra] += self.size[rb];
        self.components -= 1;
        true
    }

    /// Size of the set containing `x`.
    pub fn set_size(&mut self, x: usize) -> usize {
        let r = self.find(x);
        self.size[r] as usize
    }

    /// True when `a` and `b` are in the same set.
    pub fn same_set(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }

    /// Canonical labels: `labels[i]` = smallest member of i's set.
    pub fn labels(&mut self) -> Vec<u32> {
        let n = self.len();
        let mut canon = vec![u32::MAX; n];
        let mut labels = vec![0u32; n];
        for i in 0..n {
            let r = self.find(i);
            if canon[r] == u32::MAX {
                canon[r] = i as u32;
            }
            labels[i] = canon[r];
        }
        labels
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_union_find() {
        let mut uf = UnionFind::new(10);
        assert_eq!(uf.components(), 10);
        assert!(uf.union(0, 1));
        assert!(uf.union(1, 2));
        assert!(!uf.union(0, 2));
        assert_eq!(uf.components(), 8);
        assert!(uf.same_set(0, 2));
        assert!(!uf.same_set(0, 3));
        assert_eq!(uf.set_size(1), 3);
    }

    #[test]
    fn labels_are_canonical_min() {
        let mut uf = UnionFind::new(6);
        uf.union(3, 5);
        uf.union(5, 1);
        let labels = uf.labels();
        assert_eq!(labels[1], labels[3]);
        assert_eq!(labels[3], labels[5]);
        assert_eq!(labels[1], 1); // min member
        assert_eq!(labels[0], 0);
    }

    #[test]
    fn chain_unions_single_component() {
        let n = 1000;
        let mut uf = UnionFind::new(n);
        for i in 0..n - 1 {
            uf.union(i, i + 1);
        }
        assert_eq!(uf.components(), 1);
        assert_eq!(uf.set_size(0), n);
    }
}
