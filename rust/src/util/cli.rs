//! Minimal, dependency-free CLI argument parser.
//!
//! The offline build has no `clap`; this module provides the small
//! subset the `streamcom` binary and the bench harnesses need:
//! subcommands, `--flag`, `--key value` / `--key=value`, positional
//! arguments, typed accessors with defaults, and generated help text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative description of one option (for help text + validation).
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Option name (without `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Default value shown in help.
    pub default: Option<&'static str>,
    /// True for boolean flags (no value).
    pub is_flag: bool,
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Subcommand (first non-option token).
    pub command: Option<String>,
    /// Positional arguments.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

#[derive(Debug)]
/// Parse/validation error with a human-readable message.
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv[1..]`. The first non-option token becomes the
    /// subcommand; later non-option tokens are positional.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, CliError> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` ends option parsing
                    out.positional.extend(iter);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// True when `--name` was passed bare or as `--name=true`.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// Raw value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Value of `--name`, or `default` when absent.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse `--name` into `T`; `Ok(None)` when absent.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| CliError(format!("invalid value for --{name}: {s:?}"))),
        }
    }

    /// `--name` as `usize`, or `default` when absent.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        Ok(self.get_parse::<usize>(name)?.unwrap_or(default))
    }

    /// `--name` as `u64`, or `default` when absent.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        Ok(self.get_parse::<u64>(name)?.unwrap_or(default))
    }

    /// `--name` as `f64`, or `default` when absent.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        Ok(self.get_parse::<f64>(name)?.unwrap_or(default))
    }

    /// Error on options not present in `specs` (typo protection).
    pub fn validate(&self, specs: &[OptSpec]) -> Result<(), CliError> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !specs.iter().any(|s| s.name == k) {
                return Err(CliError(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

/// Render help text for a subcommand.
pub fn render_help(command: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{command} — {about}\n\nOptions:");
    for spec in specs {
        let default = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        let val = if spec.is_flag { "" } else { " <value>" };
        let _ = writeln!(s, "  --{}{val}\n      {}{default}", spec.name, spec.help);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // NB: a bare `--flag` followed by a non-option token would bind
        // as `--flag token`; flags therefore go last or use `=`.
        let a = parse(&["run", "--nodes", "100", "--vmax=8", "input.txt", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("nodes"), Some("100"));
        assert_eq!(a.get("vmax"), Some("8"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["input.txt"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["x", "--n", "42", "--p", "0.5"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 42);
        assert_eq!(a.f64_or("p", 0.0).unwrap(), 0.5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!(a.get_parse::<usize>("p").is_err());
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["x", "--quiet"]);
        assert!(a.flag("quiet"));
        assert!(!a.flag("loud"));
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["x", "--", "--not-an-option"]);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn validate_rejects_unknown() {
        let a = parse(&["x", "--bogus", "1"]);
        let specs = [OptSpec { name: "nodes", help: "", default: None, is_flag: false }];
        assert!(a.validate(&specs).is_err());
    }

    #[test]
    fn help_renders() {
        let specs = [OptSpec {
            name: "nodes",
            help: "node count",
            default: Some("1000"),
            is_flag: false,
        }];
        let h = render_help("run", "run the thing", &specs);
        assert!(h.contains("--nodes"));
        assert!(h.contains("default: 1000"));
    }
}
