//! Deterministic, dependency-free PRNGs.
//!
//! The offline build has no `rand` crate, so we carry our own generators:
//! [`SplitMix64`] for seeding and [`Xoshiro256`] (xoshiro256**) as the
//! workhorse. Both are the reference algorithms from Blackman & Vigna;
//! xoshiro256** passes BigCrush and is more than fast enough for the
//! graph generators, stream shufflers and property tests in this crate.
//!
//! Every consumer takes an explicit seed so that all experiments are
//! reproducible bit-for-bit (EXPERIMENTS.md records the seeds).

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Start the sequence at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the crate-wide PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference implementation's advice.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    /// Next raw 64-bit output (xoshiro256**).
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` using the top 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift
    /// (with rejection to remove modulo bias).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let l = m as u64;
            if l >= bound || l >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Geometric skip: number of failures before the first success of a
    /// Bernoulli(p) sequence. Used by the generators to sample Erdős–Rényi
    /// / planted-partition blocks in O(#edges) instead of O(n²)
    /// (Batagelj–Brandes skipping).
    #[inline]
    pub fn geometric(&mut self, p: f64) -> u64 {
        if p >= 1.0 {
            return 0;
        }
        if p <= 0.0 {
            return u64::MAX;
        }
        let u = self.next_f64().max(f64::MIN_POSITIVE);
        (u.ln() / (1.0 - p).ln()).floor() as u64
    }

    /// Standard normal via Box–Muller (used by the LFR-ish generators).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Power-law sample in `[xmin, xmax]` with exponent `gamma > 1`
    /// (inverse-CDF of the truncated continuous power law, rounded).
    pub fn power_law(&mut self, xmin: f64, xmax: f64, gamma: f64) -> f64 {
        let a = 1.0 - gamma;
        let lo = xmin.powf(a);
        let hi = xmax.powf(a);
        let u = self.next_f64();
        (lo + u * (hi - lo)).powf(1.0 / a)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Xoshiro256 {
        Xoshiro256::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // First outputs for seed 1234567 from the reference C code.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn xoshiro_is_deterministic_and_forks_differ() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut f1 = r1.fork();
        assert_ne!(f1.next_u64(), r2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut r = Xoshiro256::new(9);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            // each bucket ~10k; allow 10% slack
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn geometric_mean_close_to_expected() {
        let mut r = Xoshiro256::new(11);
        let p = 0.1;
        let n = 20_000;
        let sum: u64 = (0..n).map(|_| r.geometric(p)).sum();
        let mean = sum as f64 / n as f64;
        let expected = (1.0 - p) / p; // 9.0
        assert!((mean - expected).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn power_law_within_bounds_and_skewed() {
        let mut r = Xoshiro256::new(13);
        let mut below_mid = 0;
        for _ in 0..10_000 {
            let x = r.power_law(1.0, 100.0, 2.5);
            assert!((1.0..=100.0).contains(&x));
            if x < 10.0 {
                below_mid += 1;
            }
        }
        // heavy skew towards xmin for gamma = 2.5
        assert!(below_mid > 8_000, "below_mid={below_mid}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(17);
        let mut v: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Xoshiro256::new(19);
        let hits = (0..50_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((14_000..16_000).contains(&hits), "hits={hits}");
    }
}
