//! From-scratch substrates: everything the offline build cannot pull
//! from crates.io.
//!
//! * [`rng`] — SplitMix64 / xoshiro256** PRNGs (no `rand`)
//! * [`cli`] — argument parser (no `clap`)
//! * [`channel`] — bounded MPMC channel with backpressure (no `crossbeam`)
//! * [`pool`] — thread pool + scoped fork/join (no `rayon`)
//! * [`union_find`] — disjoint-set forest
//! * [`proptest`] — tiny property-testing harness (no `proptest` crate)
//! * [`mmap`] — read-only memory-mapped files (no `memmap2`)

pub mod channel;
pub mod cli;
pub mod mmap;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod union_find;
