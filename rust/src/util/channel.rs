//! Bounded MPMC channel with blocking backpressure.
//!
//! This is the streaming substrate's transport: producers (edge sources,
//! shard routers) block when the queue is full — that *is* the
//! backpressure mechanism the DESIGN.md stream layer calls for — and
//! consumers block when it is empty. Built on `Mutex` + `Condvar`
//! (no crossbeam available offline). Close semantics: any handle can
//! `close()`; receivers drain remaining items then see `None`.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct State<T> {
    buf: VecDeque<T>,
    cap: usize,
    closed: bool,
    /// high-water mark, for observability/tests
    peak: usize,
    pushed: u64,
    popped: u64,
}

/// Sender/receiver handle (clonable; MPMC).
pub struct Channel<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

/// Error returned when sending into a closed channel.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError;

impl<T> Channel<T> {
    /// Create a channel holding at most `cap` items.
    pub fn bounded(cap: usize) -> Self {
        assert!(cap > 0, "channel capacity must be > 0");
        Self {
            inner: Arc::new(Inner {
                queue: Mutex::new(State {
                    // don't pre-reserve unbounded capacities
                    buf: VecDeque::with_capacity(cap.min(1024)),
                    cap,
                    closed: false,
                    peak: 0,
                    pushed: 0,
                    popped: 0,
                }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
            }),
        }
    }

    /// Blocking send; applies backpressure when full.
    pub fn send(&self, item: T) -> Result<(), SendError> {
        let mut st = self.inner.queue.lock().unwrap();
        while st.buf.len() >= st.cap && !st.closed {
            st = self.inner.not_full.wait(st).unwrap();
        }
        if st.closed {
            return Err(SendError);
        }
        st.buf.push_back(item);
        st.pushed += 1;
        let len = st.buf.len();
        if len > st.peak {
            st.peak = len;
        }
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking send; `Ok(false)` when full.
    pub fn try_send(&self, item: T) -> Result<bool, SendError> {
        let mut st = self.inner.queue.lock().unwrap();
        if st.closed {
            return Err(SendError);
        }
        if st.buf.len() >= st.cap {
            return Ok(false);
        }
        st.buf.push_back(item);
        st.pushed += 1;
        let len = st.buf.len();
        if len > st.peak {
            st.peak = len;
        }
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(true)
    }

    /// Blocking receive; `None` once the channel is closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                st.popped += 1;
                drop(st);
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        let item = st.buf.pop_front();
        if item.is_some() {
            st.popped += 1;
            drop(st);
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Close the channel; wakes all waiters. Idempotent.
    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    /// True once any handle has called `close`.
    pub fn is_closed(&self) -> bool {
        self.inner.queue.lock().unwrap().closed
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().buf.len()
    }

    /// True when no items are queued.
    pub fn is_empty(&self) -> bool {
        self.inner.queue.lock().unwrap().buf.is_empty()
    }

    /// (peak occupancy, total pushed, total popped) — backpressure stats.
    pub fn stats(&self) -> (usize, u64, u64) {
        let st = self.inner.queue.lock().unwrap();
        (st.peak, st.pushed, st.popped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order_single_thread() {
        let ch = Channel::bounded(8);
        for i in 0..5 {
            ch.send(i).unwrap();
        }
        ch.close();
        let got: Vec<i32> = std::iter::from_fn(|| ch.recv()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn backpressure_blocks_until_drained() {
        let ch = Channel::bounded(2);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert!(!ch.try_send(3).unwrap()); // full

        let tx = ch.clone();
        let producer = thread::spawn(move || {
            tx.send(3).unwrap(); // blocks until a recv
            tx.close();
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(ch.recv(), Some(1));
        producer.join().unwrap();
        assert_eq!(ch.recv(), Some(2));
        assert_eq!(ch.recv(), Some(3));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn close_unblocks_receivers() {
        let ch: Channel<u32> = Channel::bounded(1);
        let rx = ch.clone();
        let consumer = thread::spawn(move || rx.recv());
        thread::sleep(Duration::from_millis(20));
        ch.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn send_to_closed_errors() {
        let ch = Channel::bounded(1);
        ch.close();
        assert_eq!(ch.send(1), Err(SendError));
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        let ch = Channel::bounded(16);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = ch.clone();
                thread::spawn(move || {
                    for i in 0..1000u64 {
                        tx.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let rx = ch.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(x) = rx.recv() {
                        got.push(x);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        ch.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all.len(), 4000);
        all.dedup();
        assert_eq!(all.len(), 4000, "duplicates detected");
    }

    #[test]
    fn stats_track_peak() {
        let ch = Channel::bounded(4);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        ch.send(3).unwrap();
        ch.recv();
        let (peak, pushed, popped) = ch.stats();
        assert_eq!(peak, 3);
        assert_eq!(pushed, 3);
        assert_eq!(popped, 1);
    }
}
