//! Read-only memory-mapped files without a `libc`/`memmap2` crate.
//!
//! The segmented binary edge format ([`crate::graph::binfmt`]) places
//! every segment at a computable offset, so a reader never needs to
//! copy segment bytes into a heap block — it can verify checksums and
//! decode records straight out of the page cache. This module supplies
//! the one OS primitive that enables that: a safe, owned wrapper over
//! `mmap(2)`.
//!
//! Std already links the platform C library on unix targets, so the
//! three syscalls we need (`mmap`, `munmap`, `madvise`) are declared
//! with `extern "C"` directly — no new dependency. The declarations
//! use LP64 types (`usize` length, `i64` offset), which match every
//! 64-bit unix this crate targets.
//!
//! # Safety model
//!
//! * [`Mmap::map_file`] maps the whole file `PROT_READ`/`MAP_PRIVATE`
//!   and advises `MADV_SEQUENTIAL` (the scan reads front to back);
//!   [`Mmap::map_file_advised`] lets callers pick a different
//!   [`Advice`] (`--madvise` on the CLI). Advice is always
//!   best-effort: a kernel that rejects it costs nothing but the
//!   syscall.
//! * The mapping is immutable for its lifetime, so [`Mmap`] is `Send`
//!   + `Sync` and hands out plain `&[u8]` slices; `Drop` unmaps.
//! * A zero-length file is represented without a syscall (`mmap` with
//!   `len == 0` is `EINVAL`); `as_slice` returns `&[]`.
//! * The one hazard mmap cannot remove: if another process truncates
//!   the file *after* mapping, touching the vanished pages faults.
//!   Callers defend against short files at open time by validating
//!   the header's claimed length against `as_slice().len()` (see
//!   `binfmt::parse_mapped`), which is why a short map is an
//!   `InvalidData` error and never a SIGBUS.
//!
//! On non-unix targets [`Mmap::map_file`] fails with
//! [`std::io::ErrorKind::Unsupported`] and [`supported`] returns
//! `false`; callers fall back to the buffered read path at compile
//! time (the fallback branch is ordinary safe code, always built).

use std::fs::File;
use std::io;

/// Whether this target has a real `mmap(2)` path. `false` means every
/// [`Mmap::map_file`] call returns `ErrorKind::Unsupported` and
/// callers should use the buffered reader instead.
pub fn supported() -> bool {
    cfg!(unix)
}

/// Page-cache advice applied to a fresh mapping (`--madvise` on the
/// CLI). Every variant is best-effort: the mapping is valid whether or
/// not the kernel honours the hint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Advice {
    /// `MADV_SEQUENTIAL`: aggressive read-ahead for a front-to-back
    /// scan. The default — it matches how every reader walks the
    /// segment table.
    #[default]
    Sequential,
    /// `MADV_HUGEPAGE`: back the mapping with transparent huge pages
    /// where the kernel supports them (Linux-only; elsewhere this
    /// degrades to no advice). Fewer TLB misses on maps much larger
    /// than the page-table reach.
    Huge,
    /// `MADV_WILLNEED`: fault the whole file into the page cache up
    /// front — useful when the file is cold and the scan would
    /// otherwise alternate compute with synchronous page-in.
    WillNeed,
    /// Skip the `madvise` call entirely (kernel default behaviour).
    None,
}

impl Advice {
    /// Parse the CLI spelling. `None` (the Option) means the string is
    /// not a recognised advice name.
    pub fn parse(s: &str) -> Option<Advice> {
        match s {
            "seq" => Some(Advice::Sequential),
            "huge" => Some(Advice::Huge),
            "willneed" => Some(Advice::WillNeed),
            "none" => Some(Advice::None),
            _ => Option::None,
        }
    }

    /// The CLI spelling, for stats footers.
    pub fn name(self) -> &'static str {
        match self {
            Advice::Sequential => "seq",
            Advice::Huge => "huge",
            Advice::WillNeed => "willneed",
            Advice::None => "none",
        }
    }
}

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    pub const MADV_SEQUENTIAL: c_int = 2;
    pub const MADV_WILLNEED: c_int = 3;
    /// `MADV_HUGEPAGE` is a Linux extension (value 14); other unixes
    /// have no equivalent, so requesting it degrades to no advice.
    pub const MADV_HUGEPAGE: Option<c_int> = if cfg!(target_os = "linux") {
        Some(14)
    } else {
        None
    };

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }
}

/// An owned, read-only mapping of an entire file.
#[cfg(unix)]
pub struct Mmap {
    /// Base address; null iff `len == 0` (no mapping exists).
    ptr: *mut std::os::raw::c_void,
    len: usize,
}

#[cfg(unix)]
// SAFETY: the mapping is PROT_READ and never mutated through this
// type, so shared references from any thread are fine.
unsafe impl Send for Mmap {}
#[cfg(unix)]
unsafe impl Sync for Mmap {}

#[cfg(unix)]
impl Mmap {
    /// Map `file` in its entirety, read-only, with sequential-access
    /// advice. The file handle may be closed afterwards; the mapping
    /// keeps the pages alive.
    pub fn map_file(file: &File) -> io::Result<Mmap> {
        Self::map_file_advised(file, Advice::Sequential)
    }

    /// [`Mmap::map_file`] with an explicit page-cache [`Advice`]. The
    /// advice is best-effort: `Advice::Huge` on a non-Linux unix (no
    /// `MADV_HUGEPAGE`) and any advice the kernel rejects both leave a
    /// perfectly usable mapping behind.
    pub fn map_file_advised(file: &File, advice: Advice) -> io::Result<Mmap> {
        use std::os::unix::io::AsRawFd;

        let len = file.metadata()?.len();
        let len = usize::try_from(len)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "file too large to map"))?;
        if len == 0 {
            return Ok(Mmap {
                ptr: std::ptr::null_mut(),
                len: 0,
            });
        }
        // SAFETY: fd is a valid open descriptor for `file`; we request
        // a fresh PROT_READ private mapping of `len` bytes and check
        // the MAP_FAILED sentinel before trusting the pointer.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(io::Error::last_os_error());
        }
        // Best-effort advice; failure is harmless.
        // SAFETY: `ptr..ptr+len` is the mapping established above.
        let hint = match advice {
            Advice::Sequential => Some(sys::MADV_SEQUENTIAL),
            Advice::WillNeed => Some(sys::MADV_WILLNEED),
            Advice::Huge => sys::MADV_HUGEPAGE,
            Advice::None => None,
        };
        if let Some(code) = hint {
            unsafe {
                let _ = sys::madvise(ptr, len, code);
            }
        }
        Ok(Mmap { ptr, len })
    }

    /// The mapped bytes. Empty slice for a zero-length file.
    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: `ptr` is a live PROT_READ mapping of exactly `len`
        // bytes, valid until `Drop`, and never written through.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }

    /// Mapped length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// `true` for a zero-length file (no mapping exists).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(unix)]
impl Drop for Mmap {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: `ptr`/`len` describe the mapping we own; after
            // munmap nothing dereferences it (self is being dropped).
            unsafe {
                let _ = sys::munmap(self.ptr, self.len);
            }
        }
    }
}

/// Non-unix stub: construction always fails with `Unsupported`, so
/// the methods below are unreachable but keep call sites compiling.
#[cfg(not(unix))]
pub struct Mmap {
    never: std::convert::Infallible,
}

#[cfg(not(unix))]
impl Mmap {
    pub fn map_file(_file: &File) -> io::Result<Mmap> {
        Self::map_file_advised(_file, Advice::Sequential)
    }

    pub fn map_file_advised(_file: &File, _advice: Advice) -> io::Result<Mmap> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "mmap is only available on unix targets; use the buffered reader",
        ))
    }

    pub fn as_slice(&self) -> &[u8] {
        match self.never {}
    }

    pub fn len(&self) -> usize {
        match self.never {}
    }

    pub fn is_empty(&self) -> bool {
        match self.never {}
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("pallas_mmap_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn maps_file_contents_byte_for_byte() {
        let path = tmp("bytes.bin");
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::File::create(&path)
            .unwrap()
            .write_all(&payload)
            .unwrap();

        let f = File::open(&path).unwrap();
        let map = Mmap::map_file(&f).unwrap();
        drop(f); // mapping outlives the descriptor
        assert_eq!(map.len(), payload.len());
        assert_eq!(map.as_slice(), &payload[..]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn zero_length_file_maps_to_empty_slice() {
        let path = tmp("empty.bin");
        std::fs::File::create(&path).unwrap();

        let f = File::open(&path).unwrap();
        let map = Mmap::map_file(&f).unwrap();
        assert!(map.is_empty());
        assert_eq!(map.as_slice(), &[] as &[u8]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn slices_are_shareable_across_threads() {
        let path = tmp("threads.bin");
        std::fs::File::create(&path)
            .unwrap()
            .write_all(&vec![7u8; 4096])
            .unwrap();

        let f = File::open(&path).unwrap();
        let map = std::sync::Arc::new(Mmap::map_file(&f).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = std::sync::Arc::clone(&map);
                std::thread::spawn(move || m.as_slice().iter().map(|&b| b as u64).sum::<u64>())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 7 * 4096);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn supported_reports_the_compile_time_truth() {
        assert!(supported());
    }

    #[test]
    fn advice_parses_the_cli_spellings_and_round_trips() {
        for (s, a) in [
            ("seq", Advice::Sequential),
            ("huge", Advice::Huge),
            ("willneed", Advice::WillNeed),
            ("none", Advice::None),
        ] {
            assert_eq!(Advice::parse(s), Some(a));
            assert_eq!(a.name(), s);
        }
        assert_eq!(Advice::parse("random"), Option::None);
        assert_eq!(Advice::default(), Advice::Sequential);
    }

    #[test]
    fn every_advice_still_maps_the_file_byte_for_byte() {
        // advice is best-effort by contract: whatever the kernel says,
        // the mapping must come back usable and exact
        let path = tmp("advice.bin");
        let payload: Vec<u8> = (0..=255u8).cycle().take(8192).collect();
        std::fs::File::create(&path)
            .unwrap()
            .write_all(&payload)
            .unwrap();
        for advice in [Advice::Sequential, Advice::Huge, Advice::WillNeed, Advice::None] {
            let f = File::open(&path).unwrap();
            let map = Mmap::map_file_advised(&f, advice).unwrap();
            assert_eq!(map.as_slice(), &payload[..], "{advice:?}");
        }
        let _ = std::fs::remove_file(&path);
    }
}
