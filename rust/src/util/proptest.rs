//! Tiny property-testing harness (the `proptest` crate is unavailable
//! offline).
//!
//! Provides the shape our invariant tests need: run a property over many
//! seeded random cases, and on failure *shrink* the failing case by
//! retrying with smaller size parameters, reporting the smallest
//! reproduction seed/size found.
//!
//! ```ignore
//! property("volumes conserved", 100, |rng, size| {
//!     let g = random_graph(rng, size);
//!     ...check...
//! });
//! ```

use super::rng::Xoshiro256;

/// Outcome of a single case.
pub type CaseResult = Result<(), String>;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Largest size parameter generated.
    pub max_size: usize,
    /// Meta-seed for case generation.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 100, max_size: 200, seed: 0xC0FFEE }
    }
}

/// Run `prop(rng, size)` for `config.cases` random `(seed, size)` pairs.
/// On failure, attempt to shrink `size` downwards and panic with the
/// smallest failing case.
pub fn check<F>(name: &str, config: Config, prop: F)
where
    F: Fn(&mut Xoshiro256, usize) -> CaseResult,
{
    let mut meta = Xoshiro256::new(config.seed);
    for case in 0..config.cases {
        let case_seed = meta.next_u64();
        // sizes sweep from small to max over the run so early failures
        // are already small
        let size = 1 + (config.max_size * (case + 1)) / config.cases;
        let mut rng = Xoshiro256::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // shrink: same seed, smaller sizes
            let mut best = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Xoshiro256::new(case_seed);
                match prop(&mut rng, s) {
                    Err(m) => {
                        best = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 shrunk size {}): {}",
                best.0, best.1
            );
        }
    }
}

/// Convenience: run with default config.
pub fn property<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Xoshiro256, usize) -> CaseResult,
{
    check(name, Config { cases, ..Config::default() }, prop);
}

/// Assert helper producing `CaseResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0usize);
        property("always true", 50, |_rng, _size| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails at size >= 3'")]
    fn failing_property_shrinks() {
        property("fails at size >= 3", 50, |_rng, size| {
            if size >= 3 {
                Err(format!("size {size} too big"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn rng_is_deterministic_per_case() {
        // same config → same sequence of cases
        let collect = |cfg: Config| {
            let v = std::cell::RefCell::new(Vec::new());
            check("det", cfg, |rng, size| {
                v.borrow_mut().push((rng.next_u64(), size));
                Ok(())
            });
            v.into_inner()
        };
        let a = collect(Config { cases: 10, ..Config::default() });
        let b = collect(Config { cases: 10, ..Config::default() });
        assert_eq!(a, b);
    }
}
