//! CLI application: subcommand dispatch for the `streamcom` binary.
//!
//! ```text
//! streamcom generate --preset amazon-s --scale 0.1 --out graph.bin
//! streamcom run --input graph.bin --vmax 64 [--parallel 4] [--out labels.txt]
//! streamcom run --preset amazon-s --scale 0.1 --vmax 64
//! streamcom sweep --preset dblp-s --scale 0.1 [--engine pjrt|native]
//! streamcom bench table1|table2|memory [--scale 0.1]
//! streamcom serve            # dynamic events on stdin, results on stdout
//! ```

use streamcom::bench::{memory, report, service as service_bench, table1, table2, workloads};
use streamcom::coordinator::algorithm::{StrConfig, StreamingClusterer};
use streamcom::coordinator::dynamic::{DynamicClusterer, Event};
use streamcom::coordinator::parallel::{run_parallel, ParallelConfig};
use streamcom::coordinator::selection::{select, NativeEngine, SelectionRule};
use streamcom::coordinator::sweep::MultiSweep;
use streamcom::graph::binfmt;
use streamcom::graph::edge::Edge;
use streamcom::graph::generators::presets;
use streamcom::graph::generators::sbm::{self, SbmConfig};
use streamcom::graph::generators::{lfr, GeneratedGraph};
use streamcom::graph::io;
use streamcom::metrics;
use streamcom::service::{
    ClusterService, CommitHorizon, RouteMode, ServiceConfig, ServiceError,
};
use streamcom::stream::meter::Meter;
use streamcom::stream::pscan::{DirectScan, ParallelScanner, ScanAbort, ScanStats};
use streamcom::stream::EdgeSource;
use streamcom::util::cli::Args;
use streamcom::util::mmap::Advice;

const USAGE: &str = "\
streamcom — streaming graph clustering (Hollocou et al. 2017 reproduction)

USAGE: streamcom <command> [options]

COMMANDS:
  generate   produce a SNAP-shaped workload (edge file + ground truth)
               --preset <name>      amazon-s dblp-s youtube-s livejournal-s orkut-s friendster-s
               --scale <f>          size multiplier [default 0.1]
               --seed <u64>         workload seed
               --out <path.bin>     binary edge output (also writes .cmty, .txt)
  run        one-pass streaming clustering
               --input <path>       .bin or .txt edge file (else --preset/--scale)
               --vmax <u64>         threshold parameter [default 64]
               --parallel <k>       sharded workers (0 = sequential)
               --refine             two-pass coarse-graph refinement (extension)
               --out <path>         write node<TAB>community labels
               --score              score against ground truth if available
  sweep      §2.5 multi-parameter run + sketch-only selection
               --preset/--scale/--input as above
               --base <u64>         ladder base [default 4]
               --engine <native|pjrt>  metric engine [default native]
  convert    translate an edge file between text and segmented binary
             (direction from the --out extension; always re-reads the
             written file and verifies the round trip before reporting)
               --input <path>       source (.bin = binary, else text;
                                    text ids are interned to dense u32)
               --out <path>         target (.bin = segmented binary v2,
                                    else SNAP-style text)
               --seg-records <k>    records per binary segment [default 65536]
               --mmap               read binary files through one read-only
                                    memory map (zero-copy; unix only, buffered
                                    fallback elsewhere)
               --madvise <a>        page-cache advice for mapped reads:
                                    seq [default] | huge | willneed | none
                                    (best-effort; huge is linux-only)
  bench      regenerate the paper's tables / service benchmarks
               table1|table2|memory|service  --scale <f>
               service prints the horizon sweep, the ingest-path
               microbench (shards × batch, pool hit/miss, router RMWs),
               the parallel-scan sweep (text/binary × readers
               {1,2,4}, partition checked against the in-memory
               baseline), the mmap-vs-buffered scan sweep AND the
               routing sweep (funnel vs direct dispatch × readers,
               labels checked each cell); --json writes all five to
               BENCH_service.json (--out <path> overrides the file name)
  serve      long-lived sharded clustering service: ingests the workload
             while answering queries on stdin
               --preset/--scale/--input as above, or --sbm <k>x<size>
               --vmax <u64>         threshold parameter [default 64]
               --shards <k>         shard workers [default 4]; any count works,
                                    powers of two take the router's shift
                                    fast path (recommended)
               --leaders <k>        leader partitions for the cross log's frozen
                                    decisions + the committed base (0 = one per
                                    shard); never changes results, only where
                                    committed state lives
               --drain-every <t>    edges between snapshot refreshes [default 65536, 0 = off]
               --horizon <edges>    commit horizon: drained cross edges this far behind
                                    the log head become final and their storage is freed,
                                    bounding memory (0 = unbounded, exact batch parity)
               --pace <e/s>         throttle ingest, edges/s (0 = full speed)
               --wal-dir <dir>      durability: append every edge to a
                                    write-ahead log under <dir> and checkpoint at
                                    epoch commits (off by default). Works on
                                    every route: the funnel logs per shard from
                                    its global stream, direct dispatch logs
                                    per-reader lanes keyed by the global seq
                                    index — both recover to the same seq cut
               --resume             recover from the latest checkpoint + WAL
                                    suffix in --wal-dir, then skip the already-
                                    ingested prefix of the workload
               --readers <k>        parallel source scan: k reader threads
                                    split --input (binary: segment-aligned,
                                    text: at newlines) and feed ingest in
                                    file order — the final partition is
                                    bit-identical to a single reader's
                                    (0 = in-memory path [default]; under
                                    --mmap, 0 auto-detects the machine's
                                    parallelism instead)
               --mmap               share one read-only memory map of a binary
                                    --input across all reader threads
                                    (zero-copy; unix only, buffered fallback
                                    elsewhere; text inputs keep buffered
                                    framing). Also seeds worker sketches from
                                    the header's n so they never grow
                                    mid-stream
               --route <mode>       how scanned edges reach the shard workers:
                                    auto [default] picks direct sharded
                                    dispatch (readers route, per-shard
                                    delivery in file order) for binary/mmap
                                    scans without --pace/--resume, funnel
                                    otherwise; direct requires it (fails fast
                                    when unsupported); funnel forces the
                                    ordered single-stream sequencer. Both
                                    modes yield bit-identical partitions,
                                    with or without --wal-dir
               --madvise <a>        page-cache advice for --mmap scans:
                                    seq [default] | huge | willneed | none
               queries: '? <node>' community, 'top <k>' largest, 'stats', 'q'
               --dynamic            legacy event mode ('+ u v' insert,
                                    '- u v' delete, '?' report on stdin)
  help       this text
";

/// Run the CLI with `argv` (without the program name); returns the exit code.
pub fn main_with_args(argv: Vec<String>) -> i32 {
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cmd = args.command.clone().unwrap_or_else(|| "help".into());
    let result = match cmd.as_str() {
        "generate" => cmd_generate(&args),
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "convert" => cmd_convert(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}; try `streamcom help`")),
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn load_workload(args: &Args) -> Result<GeneratedGraph, String> {
    if let Some(input) = args.get("input") {
        let edges = if input.ends_with(".bin") {
            io::read_binary_edges(input).map_err(|e| e.to_string())?
        } else {
            io::read_text_edges(input).map_err(|e| e.to_string())?.0
        };
        // look for ground truth next to the edges
        let gt_path = input
            .rsplit_once('.')
            .map(|(stem, _)| format!("{stem}.cmty"))
            .unwrap_or_else(|| format!("{input}.cmty"));
        let truth = io::read_ground_truth(&gt_path).unwrap_or_default();
        return Ok(GeneratedGraph { name: input.to_string(), edges, truth });
    }
    let preset_name = args.get_or("preset", "amazon-s");
    let preset = presets::find(preset_name)
        .ok_or_else(|| format!("unknown preset {preset_name:?}"))?;
    let scale = args.f64_or("scale", 0.1).map_err(|e| e.to_string())?;
    let seed = args.u64_or("seed", workloads::WORKLOAD_SEED).map_err(|e| e.to_string())?;
    Ok(lfr::generate(&preset.config(scale, seed)))
}

fn cmd_generate(args: &Args) -> Result<(), String> {
    let g = load_workload(args)?;
    let out = args.get_or("out", "workload.bin").to_string();
    io::write_binary_edges(&out, &g.edges).map_err(|e| e.to_string())?;
    let stem = out.rsplit_once('.').map(|(s, _)| s.to_string()).unwrap_or(out.clone());
    io::write_ground_truth(format!("{stem}.cmty"), &g.truth).map_err(|e| e.to_string())?;
    io::write_text_edges(format!("{stem}.txt"), &g.edges).map_err(|e| e.to_string())?;
    println!(
        "generated {}: n={} m={} communities={} → {out} / {stem}.cmty / {stem}.txt",
        g.name,
        g.n(),
        g.m(),
        g.truth.len()
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let g = load_workload(args)?;
    let v_max = args.u64_or("vmax", 64).map_err(|e| e.to_string())?;
    let shards = args.usize_or("parallel", 0).map_err(|e| e.to_string())?;

    let mut meter = Meter::start();
    let mut labels = if shards > 1 {
        let res = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(shards, v_max));
        meter.add_edges(res.state.edges_processed);
        res.labels()
    } else {
        let mut c = StreamingClusterer::new(g.n(), StrConfig::new(v_max));
        c.process_chunk(&g.edges.edges);
        meter.add_edges(c.stats.edges);
        c.labels()
    };
    if args.flag("refine") {
        // two-pass extension: cluster the coarse community graph
        labels = streamcom::coordinator::refine::refine_two_pass(&g.edges.edges, &labels, 7);
    }
    let r = meter.finish();
    let ncomm = metrics::labels_to_communities(&labels).len();
    println!(
        "{}: n={} m={} v_max={v_max} → {ncomm} communities in {:.3}s ({:.1} Medges/s)",
        g.name,
        g.n(),
        g.m(),
        r.elapsed.as_secs_f64(),
        r.edges_per_sec() / 1e6
    );
    if args.flag("score") && !g.truth.is_empty() {
        let truth = g.truth.to_labels(g.n());
        println!(
            "  F1={:.3} NMI={:.3} Q={:.3}",
            metrics::f1::average_f1_labels(&labels, &truth),
            metrics::nmi::nmi_labels(&labels, &truth),
            metrics::modularity::modularity(g.n(), &g.edges.edges, &labels),
        );
    }
    if let Some(out) = args.get("out") {
        io::write_labels(out, &labels).map_err(|e| e.to_string())?;
        println!("  labels → {out}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let g = load_workload(args)?;
    let base = args.u64_or("base", 4).map_err(|e| e.to_string())?;
    let ladder = MultiSweep::geometric_ladder(base, 8);
    let mut sweep = MultiSweep::new(g.n(), ladder.clone());
    let mut meter = Meter::start();
    sweep.process_chunk(&g.edges.edges);
    meter.add_edges(sweep.edges_processed);
    let r = meter.finish();

    let engine_name = args.get_or("engine", "native");
    let (winner, scores) = match engine_name {
        "native" => select(&sweep, &mut NativeEngine, SelectionRule::DensityScore),
        "pjrt" => {
            let mut engine = streamcom::runtime::PjrtEngine::load_default()
                .map_err(|e| format!("pjrt engine: {e}"))?;
            select(&sweep, &mut engine, SelectionRule::DensityScore)
        }
        other => return Err(format!("unknown engine {other:?}")),
    };

    let mut t = report::Table::new(
        &format!("sweep over {} ({} edges, {:.3}s, engine={engine_name})",
            g.name, g.m(), r.elapsed.as_secs_f64()),
        &["v_max", "H", "D", "balance", "ncomms", "score", "winner"],
    );
    for (a, &vm) in ladder.iter().enumerate() {
        let s = &scores[a];
        t.push_row(vec![
            vm.to_string(),
            format!("{:.3}", s.entropy),
            format!("{:.4}", s.density),
            format!("{:.4}", s.balance),
            format!("{:.0}", s.ncomms),
            format!("{:.4}", s.density_score),
            if a == winner { "*".into() } else { "".into() },
        ]);
    }
    println!("{}", t.render());
    if !g.truth.is_empty() {
        let truth = g.truth.to_labels(g.n());
        let labels = sweep.labels(winner);
        println!(
            "winner v_max={} → F1={:.3} NMI={:.3}",
            ladder[winner],
            metrics::f1::average_f1_labels(&labels, &truth),
            metrics::nmi::nmi_labels(&labels, &truth)
        );
    }
    Ok(())
}

/// `convert`: translate between SNAP text and the segmented binary
/// format, re-reading the written file to verify the round trip. Text
/// sources are interned to dense u32 ids (same as every other text
/// ingest path); a text *target* cannot represent isolated nodes, so
/// its node-count check is `≤` rather than `==`.
fn cmd_convert(args: &Args) -> Result<(), String> {
    let input = args.get("input").ok_or("convert needs --input <file>")?;
    let out = args.get("out").ok_or("convert needs --out <file>")?;
    let seg_records = args
        .u64_or("seg-records", binfmt::DEFAULT_SEG_RECORDS)
        .map_err(|e| e.to_string())?;
    // --mmap routes every binary read (source and the verify re-read)
    // through the zero-copy mapped path; same format, same errors.
    // --madvise tunes the mapping's page-cache advice (best-effort).
    let use_mmap = args.flag("mmap");
    let advice = parse_advice(args)?;
    let read_bin = |p: &str| {
        if use_mmap {
            io::read_binary_edges_mmap_with(p, advice)
        } else {
            io::read_binary_edges(p)
        }
    };
    let el = if input.ends_with(".bin") {
        read_bin(input).map_err(|e| format!("read {input}: {e}"))?
    } else {
        io::read_text_edges(input).map_err(|e| format!("read {input}: {e}"))?.0
    };
    if out.ends_with(".bin") {
        io::write_binary_edges_with(out, &el, seg_records)
            .map_err(|e| format!("write {out}: {e}"))?;
        let got = read_bin(out).map_err(|e| format!("verify {out}: {e}"))?;
        if got.n != el.n || got.edges != el.edges {
            return Err(format!("round-trip verification failed for {out}: re-read differs"));
        }
        let h = binfmt::SegHeader::new(el.n, el.edges.len() as u64, seg_records)
            .map_err(|e| e.to_string())?;
        println!(
            "convert: {input} → {out} (binary v{}, n={} m={}, {} segments of {seg_records}) — \
             round trip verified ({} reads)",
            binfmt::VERSION,
            el.n,
            el.m(),
            h.seg_count,
            if use_mmap { "mmap" } else { "buffered" }
        );
        if use_mmap {
            println!("convert: madvise={} applied to mapped reads", advice.name());
        }
    } else {
        io::write_text_edges(out, &el).map_err(|e| format!("write {out}: {e}"))?;
        // the text reader interns ids by first appearance, so the
        // re-read compares through its dense→original map
        let (got, back) = io::read_text_edges(out).map_err(|e| format!("verify {out}: {e}"))?;
        let same = got.m() == el.m()
            && got.n <= el.n
            && got.edges.iter().zip(&el.edges).all(|(g2, e1)| {
                back[g2.u as usize] == e1.u as u64 && back[g2.v as usize] == e1.v as u64
            });
        if !same {
            return Err(format!(
                "round-trip verification failed for {out}: re-read differs \
                 (self-loop edges cannot survive a text round trip)"
            ));
        }
        println!(
            "convert: {input} → {out} (text, n={} m={}) — round trip verified",
            el.n,
            el.m()
        );
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("table1");
    let scale = args.f64_or("scale", workloads::DEFAULT_SCALE).map_err(|e| e.to_string())?;
    match which {
        "table1" => {
            let cfg = table1::Table1Config { scale, ..Default::default() };
            let (t, rows) = table1::run(&cfg);
            println!("{}", t.render());
            for r in &rows {
                if let Some(s) = table1::speedup_vs_fastest_baseline(r) {
                    println!("{:<16} STR speedup vs fastest baseline: {s:.1}x", r.name);
                }
            }
        }
        "table2" => {
            let cfg = table2::Table2Config { scale, ..Default::default() };
            let (t, _) = table2::run(&cfg);
            println!("{}", t.render());
        }
        "memory" => {
            let graphs = workloads::load_all(scale, None, true);
            // service columns: what the sharded service additionally
            // retains for deferred cross-edge replay, with and without
            // a commit horizon (the horizon bounds it regardless of |E|)
            let shards = 4u64;
            let horizon = 1_000_000u64;
            let mut t = report::Table::new(
                &format!(
                    "Memory (§4.4, scale {scale}; x-log columns: {shards}-shard service)"
                ),
                &[
                    "dataset",
                    "|V|",
                    "|E|",
                    "edge list",
                    "STR sketch",
                    "ratio",
                    "x-log unbounded",
                    "x-log h=1M",
                ],
            );
            for g in &graphs {
                let el = memory::edge_list_bytes(g.m() as u64);
                let sk = memory::sketch_bytes(g.n() as u64);
                t.push_row(vec![
                    g.name.clone(),
                    g.n().to_string(),
                    g.m().to_string(),
                    memory::fmt_bytes(el),
                    memory::fmt_bytes(sk),
                    format!("{:.1}x", el as f64 / sk as f64),
                    memory::fmt_bytes(memory::cross_log_unbounded_bytes(
                        g.m() as u64,
                        shards,
                    )),
                    memory::fmt_bytes(memory::cross_log_bounded_bytes(
                        g.m() as u64,
                        shards,
                        horizon,
                    )),
                ]);
            }
            println!("{}", t.render());
        }
        "service" => {
            let cfg = service_bench::ServiceBenchConfig::scaled(scale);
            let (t, rows) = service_bench::run(&cfg);
            println!("{}", t.render());
            // the ingest-path microbench: shards × batch sweep with the
            // pool/RMW counters that pin the batch spine's amortization
            let (ti, ingest) = service_bench::run_ingest(&cfg);
            println!("{}", ti.render());
            // the parallel-scan microbench: format × reader-count sweep
            // through real files, partition checked against the
            // in-memory baseline
            let (tr, readers) = service_bench::run_readers(&cfg);
            println!("{}", tr.render());
            // the mmap-vs-buffered sweep: same binary file through both
            // scan transports at each reader count, labels checked
            // against the in-memory baseline
            let (tm, mmap_rows) = service_bench::run_mmap(&cfg);
            println!("{}", tm.render());
            // the routing sweep: funnel vs direct sharded dispatch at
            // each reader count, labels checked against the in-memory
            // baseline (CI hard-gates every cell's match)
            let (tq, routing_rows) = service_bench::run_routing(&cfg);
            println!("{}", tq.render());
            if args.flag("json") {
                let path = args.get_or("out", "BENCH_service.json");
                let json = service_bench::to_json(
                    &cfg,
                    &rows,
                    &ingest,
                    &readers,
                    &mmap_rows,
                    &routing_rows,
                );
                std::fs::write(path, json).map_err(|e| format!("write {path}: {e}"))?;
                println!("json → {path}");
            }
        }
        other => {
            return Err(format!(
                "unknown bench {other:?} (table1|table2|memory|service)"
            ))
        }
    }
    Ok(())
}

/// `serve` workload: explicit SBM spec, else the shared preset/input
/// loading (the SBM path is the paper's planted-partition stream and
/// the parity workload of `rust/tests/parallel_parity.rs`).
fn load_serve_workload(args: &Args) -> Result<GeneratedGraph, String> {
    if let Some(spec) = args.get("sbm") {
        let (k, size) = spec
            .split_once('x')
            .ok_or_else(|| format!("--sbm expects <communities>x<size>, got {spec:?}"))?;
        let k: usize = k.parse().map_err(|_| format!("bad community count {k:?}"))?;
        let size: usize = size.parse().map_err(|_| format!("bad community size {size:?}"))?;
        let seed = args.u64_or("seed", 42).map_err(|e| e.to_string())?;
        return Ok(sbm::generate(&SbmConfig::equal(k, size, 0.3, 0.002, seed)));
    }
    load_workload(args)
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    use std::io::BufRead;
    if args.flag("dynamic") {
        return cmd_serve_dynamic(args);
    }
    let v_max = args.u64_or("vmax", 64).map_err(|e| e.to_string())?;
    let shards = args.usize_or("shards", 4).map_err(|e| e.to_string())?;
    let pace = args.u64_or("pace", 0).map_err(|e| e.to_string())?;
    let readers_arg = args.usize_or("readers", 0).map_err(|e| e.to_string())?;
    let mmap = args.flag("mmap");
    if readers_arg > 0 && args.get("input").is_none() {
        return Err("--readers needs --input <file> (the parallel scan reads the file directly)"
            .to_string());
    }
    if mmap && args.get("input").is_none() {
        return Err("--mmap needs --input <file> (the mapped scan reads the file directly)"
            .to_string());
    }
    let route = {
        let s = args.get_or("route", "auto");
        RouteMode::parse(s)
            .ok_or_else(|| format!("--route expects auto|direct|funnel, got {s:?}"))?
    };
    let advice = parse_advice(args)?;
    let resume = args.flag("resume");
    // Direct sharded dispatch needs a coordination-free global sequence
    // index (segmented binary geometry) and has no single arrival
    // stream — the reasons it cannot serve an invocation, in the order
    // a user can fix them. `None` means direct is available.
    let funnel_because = if readers_arg == 0 && !mmap {
        Some("no file scan (in-memory ingest); add --readers/--mmap with a binary --input")
    } else if resume {
        Some("--resume slices the in-memory stream positionally")
    } else if !args.get("input").is_some_and(|p| p.ends_with(".bin")) {
        Some("text inputs have no fixed record geometry to sequence by")
    } else if args.u64_or("pace", 0).map_err(|e| e.to_string())? > 0 {
        Some("--pace throttles the funnel's global arrival stream")
    } else {
        None
    };
    let direct = match route {
        RouteMode::Funnel => false,
        RouteMode::Auto => funnel_because.is_none(),
        RouteMode::Direct => match funnel_because {
            None => true,
            Some(why) => {
                return Err(format!(
                    "--route direct is unsupported for this invocation: {why} \
                     (drop the conflicting flag or use --route funnel)"
                ))
            }
        },
    };
    // --mmap turns --readers 0 (the default) into auto-detection: one
    // reader per available core. Without --mmap, 0 keeps meaning the
    // in-memory path.
    let auto = mmap && readers_arg == 0;
    let readers = if auto {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        readers_arg
    };
    let mut g = load_serve_workload(args)?;
    let truth = if g.truth.is_empty() { None } else { Some(g.truth.to_labels(g.n())) };

    let mut config = ServiceConfig::new(shards, v_max);
    config.leaders = args.usize_or("leaders", 0).map_err(|e| e.to_string())?;
    config.drain_every = args.u64_or("drain-every", 65_536).map_err(|e| e.to_string())?;
    // Edges(0) is the CLI spelling of "unbounded"; the service
    // normalises it at start-up (covered by the CLI test-suite)
    config.horizon =
        CommitHorizon::Edges(args.u64_or("horizon", 0).map_err(|e| e.to_string())?);
    if let Some(dir) = args.get("wal-dir") {
        config.wal_dir = Some(std::path::PathBuf::from(dir));
    }
    // direct + durable: the readers write per-reader WAL lanes
    // themselves. Built from the same config (shared failpoint, same
    // segment geometry) before the service takes ownership of it; the
    // scan opens only after `start` has prepared the directory.
    let direct_wal = if direct { config.direct_wal_cfg() } else { None };
    // the file scan knows the final node count up front (the binary
    // header's n / the interned text id space): pre-size every worker
    // sketch so the per-chunk `ensure` never grows arrays mid-stream.
    // A perf knob only — unseen nodes label as singletons either way.
    if readers > 0 && !resume {
        config.initial_nodes = g.n();
    }
    let mut service = if resume {
        ClusterService::resume(config).map_err(|e| format!("resume: {e}"))?
    } else {
        ClusterService::start(config)
    };
    let queries = service.handle();
    println!(
        "serve: streaming {} (n={} m={}) across {shards} shards (v_max={v_max})",
        g.name,
        g.n(),
        g.m()
    );
    // a resumed service already holds a prefix of the stream — skip it
    let skip = if resume {
        let s = queries.stats();
        println!(
            "resume: recovered to t={} edges (checkpoint epoch {}, {} WAL edges replayed)",
            s.edges_ingested, s.recovered_epochs, s.wal_recovered_edges
        );
        s.edges_ingested as usize
    } else {
        0
    };
    println!("queries on stdin: '? <node>' community, 'top <k>' largest, 'stats', 'q' quit");

    // ingest runs in the background; this thread answers queries.
    // 'q' raises the stop flag so quitting doesn't wait out a paced
    // (potentially hours-long) stream
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop_ingest = std::sync::Arc::clone(&stop);
    let edges = std::mem::take(&mut g.edges.edges);
    let skip = skip.min(edges.len());
    // --readers: feed ingest from a parallel scan of the input file
    // instead of the preloaded copy. The scanner re-emits edges in
    // file order, so the final partition is bit-identical either way.
    // A resume skip needs positional slicing, so it keeps the
    // in-memory path.
    let mut scan_info: Option<(usize, bool, std::sync::Arc<ScanStats>)> = None;
    // 'q' on a direct scan must unblock the muxers, not just raise the
    // flag — the abort handle closes every routing queue
    let mut abort_scan: Option<ScanAbort> = None;
    let ingest = if direct && readers > 0 && skip == 0 {
        let input = args.get("input").expect("checked above").to_string();
        let durable = direct_wal.is_some();
        let mut dscan = if mmap {
            DirectScan::open_mmap_advised(&input, readers, 8_192, shards, direct_wal, advice)
        } else {
            DirectScan::open(&input, readers, 8_192, shards, direct_wal)
        }
        .map_err(|e| format!("direct scan {input}: {e}"))?;
        scan_info = Some((dscan.readers(), dscan.mmapped(), dscan.stats()));
        abort_scan = Some(dscan.abort_handle());
        if auto {
            println!("scan: --readers 0 auto-detected {readers} reader threads");
        }
        println!(
            "scan: {} reader threads over {input}{}, routing in the readers (direct dispatch)",
            dscan.readers(),
            if dscan.mmapped() { " (one shared mmap)" } else { "" }
        );
        if durable {
            println!(
                "wal: durable direct dispatch — {} readers append per-reader WAL lanes",
                dscan.readers()
            );
        }
        std::thread::spawn(move || {
            // reader failures and worker deaths surface as the
            // result's typed fault — checked after the join
            service.ingest_direct(&mut dscan);
            (service.finish(), None)
        })
    } else if readers > 0 && skip == 0 {
        let input = args.get("input").expect("checked above").to_string();
        if route == RouteMode::Auto {
            if let Some(why) = funnel_because {
                println!("note: --route auto picked the funnel ({why})");
            }
        }
        // --mmap on a binary input shares one read-only mapping across
        // all readers; text inputs (and non-unix builds) keep buffered
        // framing — open_mmap itself degrades on unsupported platforms
        let mut scanner = if mmap && input.ends_with(".bin") {
            ParallelScanner::open_mmap_advised(&input, readers, 8_192, advice)
        } else {
            ParallelScanner::open(&input, readers, 8_192)
        }
        .map_err(|e| format!("parallel scan {input}: {e}"))?;
        scan_info = Some((scanner.readers(), scanner.mmapped(), scanner.stats()));
        if auto {
            println!("scan: --readers 0 auto-detected {readers} reader threads");
        }
        println!(
            "scan: {} reader threads over {input}{}",
            scanner.readers(),
            if scanner.mmapped() { " (one shared mmap)" } else { "" }
        );
        std::thread::spawn(move || {
            let mut buf: Vec<Edge> = Vec::with_capacity(8_192);
            while scanner.next_batch(&mut buf) > 0 {
                if stop_ingest.load(std::sync::atomic::Ordering::Relaxed) {
                    break;
                }
                service.push_chunk(&buf);
                if pace > 0 && pace_sleep(buf.len(), pace, &stop_ingest) {
                    break;
                }
            }
            let scan_err = scanner.take_error();
            (service.finish(), scan_err)
        })
    } else {
        if readers > 0 {
            println!("note: resume skip > 0 — using the in-memory ingest path");
        }
        std::thread::spawn(move || {
            for chunk in edges[skip..].chunks(8_192) {
                if stop_ingest.load(std::sync::atomic::Ordering::Relaxed) {
                    break;
                }
                service.push_chunk(chunk);
                if pace > 0 && pace_sleep(chunk.len(), pace, &stop_ingest) {
                    break;
                }
            }
            (service.finish(), None)
        })
    };

    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line.map_err(|e| e.to_string())?;
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks.as_slice() {
            ["?", node] => {
                // a typo'd query must not kill the serving process
                let Ok(node) = node.parse::<u32>() else {
                    println!("! bad node id {node:?}");
                    continue;
                };
                let snap = queries.snapshot();
                println!(
                    "node {node} → community {} (snapshot at t={} edges)",
                    snap.community_of(node),
                    snap.edges()
                );
            }
            ["top", k] => {
                let Ok(k) = k.parse::<usize>() else {
                    println!("! bad count {k:?}");
                    continue;
                };
                let snap = queries.snapshot();
                println!(
                    "top {k} of {} communities at t={} edges:",
                    snap.community_count(),
                    snap.edges()
                );
                for c in snap.top_communities(k) {
                    println!(
                        "  community {:>9}  volume {:>9}  size {:>8}",
                        c.id, c.volume, c.size
                    );
                }
            }
            ["stats"] => {
                let s = queries.stats();
                let horizon = match s.horizon {
                    CommitHorizon::Unbounded => "unbounded".to_string(),
                    CommitHorizon::Edges(h) => h.to_string(),
                };
                let per_leader: Vec<String> = s
                    .per_leader
                    .iter()
                    .map(|l| {
                        format!(
                            "{}/{}/{}",
                            memory::fmt_bytes(l.retained_bytes),
                            memory::fmt_bytes(l.committed_bytes),
                            memory::fmt_bytes(l.freed_bytes)
                        )
                    })
                    .collect();
                println!(
                    "shards={} leaders={} horizon={horizon} ingested={} \
                     ({:.2} Medges/s) snapshot_lag={} \
                     drains={} replay_last={} replay_total={} \
                     delta_last={}B delta_total={}B \
                     cross drained/pending={}/{} \
                     x-log retained={} committed={} freed={} \
                     per-leader r/c/f=[{}] \
                     chunks={} pool hit/miss={}/{} recycled={} \
                     queues={:?} peaks={:?} sketch={} B ({:.1} B/node) \
                     wal={} ckpts={} ckpt_epoch={} recovered_epochs={} wal_replayed={}",
                    s.shards,
                    s.leaders,
                    s.edges_ingested,
                    s.edges_per_sec / 1e6,
                    s.edges_ingested.saturating_sub(s.snapshot_edges),
                    s.drains,
                    s.cross_replayed_last_drain,
                    s.cross_replayed_total,
                    s.delta_last_bytes,
                    s.delta_total_bytes,
                    s.cross_drained,
                    s.cross_pending,
                    s.cross_retained,
                    s.cross_committed,
                    memory::fmt_bytes(s.cross_freed_bytes),
                    per_leader.join(" "),
                    s.chunks_dispatched,
                    s.pool.hits,
                    s.pool.misses,
                    memory::fmt_bytes(s.pool.recycled_bytes),
                    s.queue_depths,
                    s.queue_peaks,
                    s.memory_bytes,
                    s.bytes_per_node(),
                    memory::fmt_bytes(s.wal_bytes),
                    s.checkpoints_written,
                    s.last_checkpoint_epoch,
                    s.recovered_epochs,
                    s.wal_recovered_edges,
                );
            }
            ["q"] | ["quit"] => {
                // explicit quit aborts the remainder of the stream;
                // plain EOF lets the ingest run to completion
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
                if let Some(a) = &abort_scan {
                    a.abort();
                }
                break;
            }
            [] => {}
            _ => println!("! unknown query {line:?} (try '? <node>', 'top <k>', 'stats', 'q')"),
        }
    }

    let (result, scan_err) = ingest.join().map_err(|_| "ingest thread panicked".to_string())?;
    // supervised failures end the run with one typed line and a
    // nonzero exit — on every route (reader, worker, or WAL lane
    // failures all funnel into these two)
    if let Some(detail) = scan_err {
        return Err(ServiceError::Reader { detail }.to_string());
    }
    if let Some(fault) = &result.fault {
        return Err(fault.to_string());
    }
    let labels = result.labels();
    let ncomm = metrics::labels_to_communities(&labels).len();
    println!(
        "final: {} edges ({} cross) → {ncomm} communities in {:.3}s ({:.2} Medges/s)",
        result.edges_ingested,
        result.cross_edges,
        result.elapsed.as_secs_f64(),
        result.edges_ingested as f64 / result.elapsed.as_secs_f64().max(1e-12) / 1e6
    );
    if let Some((nreaders, mapped, st)) = scan_info {
        println!(
            "scan: readers={nreaders} mmap={} bytes={} segments={} oversized={} malformed={} \
             route={} madvise={}",
            if mapped { "on" } else { "off" },
            memory::fmt_bytes(st.bytes_read()),
            st.segments_verified(),
            st.oversized_skipped(),
            st.malformed_skipped(),
            if direct { "direct" } else { "funnel" },
            if mapped { advice.name() } else { "off" }
        );
    }
    if let Some(truth) = truth {
        let full = result.snapshot.labels_padded(g.n());
        println!(
            "  F1={:.3} NMI={:.3}",
            metrics::f1::average_f1_labels(&full, &truth),
            metrics::nmi::nmi_labels(&full, &truth)
        );
    }
    Ok(())
}

fn cmd_serve_dynamic(args: &Args) -> Result<(), String> {
    use std::io::BufRead;
    let v_max = args.u64_or("vmax", 64).map_err(|e| e.to_string())?;
    let mut d = DynamicClusterer::new(0, StrConfig::new(v_max));
    // consecutive inserts batch through the same chunk spine the
    // sharded service routes to (`insert_batch` → `process_chunk`);
    // the pending run flushes before anything that reads or mutates
    // the sketch, so event semantics are unchanged
    let mut pending: Vec<Edge> = Vec::new();
    fn drain(d: &mut DynamicClusterer, pending: &mut Vec<Edge>) {
        if !pending.is_empty() {
            d.insert_batch(pending);
            pending.clear();
        }
    }
    let stdin = std::io::stdin();
    println!("streamcom serve: '+ u v' insert, '- u v' delete, '?' report, 'q' quit");
    for line in stdin.lock().lines() {
        let line = line.map_err(|e| e.to_string())?;
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks.as_slice() {
            ["+", u, v] => {
                let (u, v) = parse_pair(u, v)?;
                pending.push(Edge::new(u, v));
            }
            ["-", u, v] => {
                let (u, v) = parse_pair(u, v)?;
                drain(&mut d, &mut pending);
                if d.apply(Event::Delete(Edge::new(u, v))).is_err() {
                    println!("! unknown edge {u} {v}");
                }
            }
            ["?"] => {
                drain(&mut d, &mut pending);
                let labels = d.labels();
                let ncomm = metrics::labels_to_communities(&labels).len();
                println!(
                    "live_edges={} nodes={} communities={ncomm}",
                    d.live_edges(),
                    d.state().n()
                );
            }
            ["q"] | ["quit"] => break,
            [] => {}
            _ => println!("! parse error: {line:?}"),
        }
    }
    drain(&mut d, &mut pending);
    println!("bye: {} nodes, {} live edges", d.state().n(), d.live_edges());
    Ok(())
}

/// Parse `--madvise` (default `seq`): page-cache advice applied —
/// best-effort — to every memory-mapped read.
fn parse_advice(args: &Args) -> Result<Advice, String> {
    let s = args.get_or("madvise", "seq");
    Advice::parse(s)
        .ok_or_else(|| format!("--madvise expects seq|huge|willneed|none, got {s:?}"))
}

/// Sleep out `n_edges / pace` seconds in ≤ 100 ms slices so a raised
/// stop flag interrupts a slow pace promptly; true means "stopped".
fn pace_sleep(n_edges: usize, pace: u64, stop: &std::sync::atomic::AtomicBool) -> bool {
    let mut left = n_edges as f64 / pace as f64;
    while left > 0.0 {
        if stop.load(std::sync::atomic::Ordering::Relaxed) {
            return true;
        }
        let slice = left.min(0.1);
        std::thread::sleep(std::time::Duration::from_secs_f64(slice));
        left -= slice;
    }
    false
}

fn parse_pair(u: &str, v: &str) -> Result<(u32, u32), String> {
    Ok((
        u.parse().map_err(|_| format!("bad node id {u:?}"))?,
        v.parse().map_err(|_| format!("bad node id {v:?}"))?,
    ))
}
