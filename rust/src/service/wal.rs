//! Durability layer for the cluster service: append-only per-shard
//! write-ahead logs plus epoch-aligned checkpoints.
//!
//! The WAL is a set of fixed-width binary record files in one
//! directory. Every ingested edge is appended, before dispatch, to the
//! file set of its routing destination — `shard-{s}` for local edges,
//! `cross` for cross-shard edges — as a 24-byte little-endian record:
//!
//! ```text
//! [seq u64][u u32][v u32][check u64]
//! ```
//!
//! `seq` is the edge's global 0-based stream position and `check` is a
//! splitmix64-style mix of the other three fields, so replay can tell
//! a torn tail (trailing fragment shorter than one record — dropped
//! cleanly) from real corruption (a full-width record whose checksum
//! fails — a typed [`WalError::Corrupt`], never a wrong-but-valid
//! edge). Each file set rotates into a new segment file, named
//! `{prefix}.{first_seq:020}.wal`, every `wal_segment_records`
//! records; whole segments below a checkpoint cut are deleted, which
//! is how the log stays bounded.
//!
//! The funnel route appends through a single router-owned writer set
//! (`shard-{s}` / `cross` prefixes). Direct dispatch has no single
//! arrival stream, so each reader owns a private lane per destination
//! — `shard-{s}.r{k}` / `cross.r{k}` for reader `k` — and appends
//! every routed chunk *before* enqueueing it (flushed per chunk,
//! fsynced when the reader exits, which is the only checkpoint cut
//! the direct route reaches). Because `seq` is a global stream
//! position stamped by the readers themselves, the durable state of
//! the whole directory reduces to one number no matter how many lanes
//! exist: [`durable_cut`] — the largest S such that every sequence
//! number below S is present across all lanes. Recovery truncates
//! everything at or past the cut and replays the suffix in seq order
//! through the normal `Sharder` route, so the two write topologies
//! share one recovery path.
//!
//! Failure policy: transient I/O ([`WalError::Io`]) gets a bounded
//! retry with backoff before the disk is declared dead; corruption
//! ([`WalError::Corrupt`]) is never retried — a corrupt segment found
//! on resume is quarantined to `<name>.corrupt` and the checksum-valid
//! clean prefix is rewritten under the original name, so the durable
//! cut recovers everything before the damage.
//!
//! A checkpoint is a consistent cut of the whole service at stream
//! position `cut`: per-shard node-state arrays, the merger's fold
//! view, the cross-log's retained (uncommitted) epochs verbatim, and
//! the per-leader committed bases. It is written atomically —
//! `checkpoint.tmp`, fsync, rename over `checkpoint.bin` — so a crash
//! mid-write leaves the previous checkpoint intact. Recovery loads the
//! latest checkpoint and replays only the WAL suffix past its cut.
//!
//! Crash injection for the recovery harness goes through
//! [`FailPoint`], which models a dying *disk*: once tripped, every
//! later WAL or checkpoint write is silently dropped while the
//! in-memory service keeps running, so tests can then drop the service
//! (an abortive shutdown) and resume from whatever reached disk.

use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::state::StreamState;
use crate::graph::edge::Edge;
use crate::service::crosslog::{CrossLogExport, EpochExport};
use crate::service::snapshot::{BaseExport, MergerExport};

/// Bytes per WAL record: `[seq u64][u u32][v u32][check u64]`.
pub(crate) const RECORD_BYTES: usize = 24;

const WAL_SUFFIX: &str = ".wal";
const QUARANTINE_SUFFIX: &str = ".corrupt";
const CHECKPOINT_FILE: &str = "checkpoint.bin";
const CHECKPOINT_TMP: &str = "checkpoint.tmp";
const CKPT_MAGIC: [u8; 4] = *b"SCKP";
const CKPT_VERSION: u32 = 1;

/// splitmix64-style finalizer over the record fields; 24 bytes per
/// edge buys a per-record integrity check, which is what lets replay
/// distinguish a torn tail from silent corruption.
fn mix(seq: u64, u: u32, v: u32) -> u64 {
    let packed = ((u as u64) << 32) | v as u64;
    let mut z = seq ^ packed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn encode_record(buf: &mut Vec<u8>, seq: u64, e: Edge) {
    buf.extend_from_slice(&seq.to_le_bytes());
    buf.extend_from_slice(&e.u.to_le_bytes());
    buf.extend_from_slice(&e.v.to_le_bytes());
    buf.extend_from_slice(&mix(seq, e.u, e.v).to_le_bytes());
}

/// Errors surfaced by WAL replay and checkpoint recovery.
#[derive(Debug)]
pub enum WalError {
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// A durability file holds bytes that cannot be a valid prefix:
    /// a full-width WAL record with a failing checksum, a sequence
    /// regression within one file, or a checkpoint whose trailing
    /// checksum does not match its body.
    Corrupt {
        /// File holding the offending bytes.
        file: PathBuf,
        /// Byte offset of the first invalid record or field.
        offset: u64,
    },
    /// The durable state on disk does not fit the requested
    /// configuration (shard/leader/horizon fingerprint mismatch, or a
    /// resume without a WAL directory).
    Mismatch {
        /// Human-readable description of the mismatch.
        detail: String,
    },
}

impl std::fmt::Display for WalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WalError::Io(e) => write!(f, "wal io error: {e}"),
            WalError::Corrupt { file, offset } => {
                write!(f, "corrupt durability data in {} at byte {offset}", file.display())
            }
            WalError::Mismatch { detail } => write!(f, "durable state mismatch: {detail}"),
        }
    }
}

impl std::error::Error for WalError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WalError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WalError {
    fn from(e: std::io::Error) -> Self {
        WalError::Io(e)
    }
}

/// Where the simulated disk dies, for crash-injection tests.
#[derive(Debug, Clone)]
pub enum CrashPoint {
    /// The first `after_records` appended records reach the log
    /// intact; the next record is written as a torn fragment of
    /// `torn_bytes` bytes (less than one full record), everything
    /// buffered is flushed so the fragment is really on disk, and
    /// every durability write after that is silently dropped.
    WalAppend {
        /// Records written intact before the tear.
        after_records: u64,
        /// Bytes of the torn record that reach the log (capped below
        /// one full record).
        torn_bytes: usize,
    },
    /// The `nth` (0-based) checkpoint attempt writes only `keep_bytes`
    /// of its temporary file, never renames it into place, and every
    /// durability write after that is silently dropped — the previous
    /// `checkpoint.bin`, if any, stays intact.
    Checkpoint {
        /// 0-based index of the checkpoint attempt that dies.
        nth: u64,
        /// Bytes of the temporary checkpoint file that reach disk.
        keep_bytes: usize,
    },
    /// Direct-route hook: reader `reader`'s WAL lane writes its first
    /// `after_records` records intact, tears the next one to
    /// `torn_bytes` bytes, and the disk dies — every reader's later
    /// durability writes are dropped while the in-memory stream keeps
    /// flowing.
    ReaderWalAppend {
        /// 0-based reader index whose lane tears.
        reader: usize,
        /// Records that reader appends intact before the tear.
        after_records: u64,
        /// Bytes of the torn record that reach the lane (capped below
        /// one full record).
        torn_bytes: usize,
    },
    /// Direct-route hook: the process dies between reader `reader`'s
    /// WAL flush of its `after_chunks`-th chunk (0-based) and the
    /// queue push that would hand that chunk to the service — the
    /// chunk is durable but never ingested, and every later durability
    /// write is dropped.
    ReaderEnqueue {
        /// 0-based reader index that dies.
        reader: usize,
        /// Chunks that reader flushes *and* enqueues before the one
        /// that is flushed but never enqueued.
        after_chunks: u64,
    },
}

/// Shared crash-injection hook carried in the service configuration.
///
/// Models a dying disk rather than a dying process: once the armed
/// [`CrashPoint`] trips (or [`FailPoint::kill`] is called, or a real
/// I/O error occurs), all later WAL and checkpoint writes become
/// silent no-ops while the in-memory service keeps running. The
/// recovery harness then drops the service — an abortive shutdown —
/// and resumes a fresh one from whatever reached disk. Clones share
/// state, so the handle a test keeps observes the same trip.
#[derive(Debug, Clone, Default)]
pub struct FailPoint {
    inner: Arc<FailInner>,
}

#[derive(Debug, Default)]
struct FailInner {
    plan: Mutex<Option<CrashPoint>>,
    dead: AtomicBool,
    armed: AtomicBool,
    wal_records: AtomicU64,
    checkpoints: AtomicU64,
    reader_records: Mutex<Vec<u64>>,
    reader_chunks: Mutex<Vec<u64>>,
}

impl FailPoint {
    /// Arm the hook with a crash plan, replacing any previous plan.
    pub fn arm(&self, plan: CrashPoint) {
        *self.inner.plan.lock().unwrap() = Some(plan);
        self.inner.armed.store(true, Ordering::SeqCst);
    }

    /// True once the simulated disk has died.
    pub fn is_dead(&self) -> bool {
        self.inner.dead.load(Ordering::SeqCst)
    }

    /// Kill the simulated disk immediately: every later durability
    /// write is dropped.
    pub fn kill(&self) {
        self.inner.dead.store(true, Ordering::SeqCst);
    }

    /// Called once per live record append; returns `Some(torn_bytes)`
    /// when this append is the one the plan tears.
    fn wal_tear(&self) -> Option<usize> {
        let n = self.inner.wal_records.fetch_add(1, Ordering::SeqCst);
        let plan = self.inner.plan.lock().unwrap();
        match *plan {
            Some(CrashPoint::WalAppend { after_records, torn_bytes }) if n == after_records => {
                Some(torn_bytes)
            }
            _ => None,
        }
    }

    /// Called once per checkpoint attempt; returns `Some(keep_bytes)`
    /// when this attempt is the one the plan kills.
    fn checkpoint_tear(&self) -> Option<usize> {
        let n = self.inner.checkpoints.fetch_add(1, Ordering::SeqCst);
        let plan = self.inner.plan.lock().unwrap();
        match *plan {
            Some(CrashPoint::Checkpoint { nth, keep_bytes }) if n == nth => Some(keep_bytes),
            _ => None,
        }
    }

    /// Called once per live record a direct-route reader appends;
    /// returns `Some(torn_bytes)` when this append is the one a
    /// [`CrashPoint::ReaderWalAppend`] plan tears.
    fn reader_tear(&self, reader: usize) -> Option<usize> {
        if !self.inner.armed.load(Ordering::SeqCst) {
            return None;
        }
        let plan = self.inner.plan.lock().unwrap();
        match *plan {
            Some(CrashPoint::ReaderWalAppend { reader: r, after_records, torn_bytes })
                if r == reader =>
            {
                let mut counts = self.inner.reader_records.lock().unwrap();
                if counts.len() <= reader {
                    counts.resize(reader + 1, 0);
                }
                let n = counts[reader];
                counts[reader] += 1;
                (n == after_records).then_some(torn_bytes)
            }
            _ => None,
        }
    }

    /// Called once per chunk a direct-route reader flushes; `true`
    /// means a [`CrashPoint::ReaderEnqueue`] plan fires here — the
    /// chunk is on disk but must never reach the queue.
    fn reader_drop_chunk(&self, reader: usize) -> bool {
        if !self.inner.armed.load(Ordering::SeqCst) {
            return false;
        }
        let plan = self.inner.plan.lock().unwrap();
        match *plan {
            Some(CrashPoint::ReaderEnqueue { reader: r, after_chunks }) if r == reader => {
                let mut counts = self.inner.reader_chunks.lock().unwrap();
                if counts.len() <= reader {
                    counts.resize(reader + 1, 0);
                }
                let n = counts[reader];
                counts[reader] += 1;
                n == after_chunks
            }
            _ => false,
        }
    }
}

/// Attempts for a transient-I/O retry before the disk is declared
/// dead; delays back off 1 ms → 5 ms between attempts.
const IO_RETRIES: u32 = 3;

/// Run `op`, retrying transient I/O failures a bounded number of
/// times with backoff. Only `std::io::Error` is retried — corruption
/// never routes through here.
fn with_io_retry<T>(mut op: impl FnMut() -> std::io::Result<T>) -> std::io::Result<T> {
    let mut delay = std::time::Duration::from_millis(1);
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..IO_RETRIES {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => {
                last = Some(e);
                if attempt + 1 < IO_RETRIES {
                    std::thread::sleep(delay);
                    delay *= 5;
                }
            }
        }
    }
    Err(last.expect("retry loop ran at least once"))
}

/// Run `op`, retrying only [`WalError::Io`] with the same bounded
/// backoff as [`with_io_retry`]; `Corrupt` and `Mismatch` stay
/// fail-fast on the first occurrence.
pub(crate) fn retry_wal<T>(
    mut op: impl FnMut() -> Result<T, WalError>,
) -> Result<T, WalError> {
    let mut delay = std::time::Duration::from_millis(1);
    let mut last: Option<WalError> = None;
    for attempt in 0..IO_RETRIES {
        match op() {
            Ok(v) => return Ok(v),
            Err(WalError::Io(e)) => {
                last = Some(WalError::Io(e));
                if attempt + 1 < IO_RETRIES {
                    std::thread::sleep(delay);
                    delay *= 5;
                }
            }
            Err(e) => return Err(e),
        }
    }
    Err(last.expect("retry loop ran at least once"))
}

/// Buffered appender for one file set (`{prefix}.{first_seq:020}.wal`
/// segments in one directory).
struct WalWriter {
    dir: PathBuf,
    prefix: String,
    segment_records: u64,
    file: Option<File>,
    in_segment: u64,
    buf: Vec<u8>,
}

impl WalWriter {
    /// Open the file set, appending to the newest existing segment of
    /// this prefix (recovery already truncated it to whole records) or
    /// starting fresh when there is none.
    fn open(dir: &Path, prefix: String, segment_records: u64) -> std::io::Result<Self> {
        let mut newest: Option<(u64, PathBuf)> = None;
        for entry in fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            if let Some((p, first)) = parse_segment(&name.to_string_lossy()) {
                if p == prefix && newest.as_ref().map(|(f, _)| first > *f).unwrap_or(true) {
                    newest = Some((first, entry.path()));
                }
            }
        }
        let (file, in_segment) = match newest {
            Some((_, path)) => {
                let len = fs::metadata(&path)?.len();
                let f = OpenOptions::new().append(true).open(&path)?;
                (Some(f), len / RECORD_BYTES as u64)
            }
            None => (None, 0),
        };
        Ok(WalWriter { dir: dir.to_path_buf(), prefix, segment_records, file, in_segment, buf: Vec::new() })
    }

    fn segment_path(&self, first_seq: u64) -> PathBuf {
        self.dir.join(format!("{}.{first_seq:020}{WAL_SUFFIX}", self.prefix))
    }

    /// Rotate into a fresh segment when the current one is absent or
    /// full; the new segment is named by the sequence number of the
    /// record about to be appended.
    fn ensure_segment(&mut self, seq: u64) -> std::io::Result<()> {
        if self.file.is_none() || self.in_segment >= self.segment_records {
            self.flush()?;
            let path = self.segment_path(seq);
            let f = OpenOptions::new().create(true).append(true).open(path)?;
            self.file = Some(f);
            self.in_segment = 0;
        }
        Ok(())
    }

    fn append(&mut self, seq: u64, e: Edge) -> std::io::Result<()> {
        self.ensure_segment(seq)?;
        encode_record(&mut self.buf, seq, e);
        self.in_segment += 1;
        Ok(())
    }

    /// Append only the first `keep` bytes of the record — the torn
    /// fragment a dying disk leaves behind. Returns the bytes kept.
    fn append_torn(&mut self, seq: u64, e: Edge, keep: usize) -> std::io::Result<u64> {
        self.ensure_segment(seq)?;
        let mut rec = Vec::with_capacity(RECORD_BYTES);
        encode_record(&mut rec, seq, e);
        rec.truncate(keep.min(RECORD_BYTES - 1));
        let kept = rec.len() as u64;
        self.buf.extend_from_slice(&rec);
        Ok(kept)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        if let Some(f) = self.file.as_mut() {
            f.write_all(&self.buf)?;
        }
        self.buf.clear();
        Ok(())
    }

    fn sync(&mut self) -> std::io::Result<()> {
        self.flush()?;
        if let Some(f) = self.file.as_mut() {
            f.sync_data()?;
        }
        Ok(())
    }
}

/// The router-owned writer set: one file set per shard plus one for
/// cross-shard edges, sharing a single global sequence counter (the
/// stream position) and the crash-injection hook.
pub(crate) struct WalSet {
    locals: Vec<WalWriter>,
    cross: WalWriter,
    seq: u64,
    bytes: u64,
    failpoint: FailPoint,
    reported: bool,
}

impl WalSet {
    /// Open writers over `dir`, continuing the sequence at `next_seq`
    /// (0 for a fresh stream; the durable prefix after a resume).
    pub(crate) fn open(
        dir: &Path,
        shards: usize,
        segment_records: u64,
        failpoint: FailPoint,
        next_seq: u64,
    ) -> std::io::Result<Self> {
        let segment_records = segment_records.max(1);
        let locals = (0..shards)
            .map(|s| WalWriter::open(dir, format!("shard-{s}"), segment_records))
            .collect::<std::io::Result<Vec<_>>>()?;
        let cross = WalWriter::open(dir, "cross".to_string(), segment_records)?;
        Ok(WalSet { locals, cross, seq: next_seq, bytes: 0, failpoint, reported: false })
    }

    /// Total bytes appended to the log by this writer set.
    pub(crate) fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Append one edge to the file set of its routing destination
    /// (`Some(shard)` for local, `None` for cross). Always advances
    /// the sequence counter — it is the stream position — even when
    /// the simulated disk is dead and nothing is written.
    pub(crate) fn append(&mut self, shard: Option<usize>, e: Edge) {
        let seq = self.seq;
        self.seq += 1;
        if self.failpoint.is_dead() {
            return;
        }
        if let Some(torn) = self.failpoint.wal_tear() {
            let res = {
                let w = match shard {
                    Some(s) => &mut self.locals[s],
                    None => &mut self.cross,
                };
                w.append_torn(seq, e, torn)
            };
            match res {
                Ok(kept) => self.bytes += kept,
                Err(e) => self.report(e),
            }
            // land everything buffered — prior records and the torn
            // fragment — so the tear is really visible on disk
            if let Err(e) = self.flush_inner() {
                self.report(e);
            }
            self.failpoint.kill();
            return;
        }
        let res = match shard {
            Some(s) => self.locals[s].append(seq, e),
            None => self.cross.append(seq, e),
        };
        match res {
            Ok(()) => self.bytes += RECORD_BYTES as u64,
            Err(e) => self.report(e),
        }
    }

    /// Push buffered records to the files (no fsync). Transient I/O
    /// failures get the bounded retry before the disk is declared
    /// dead.
    pub(crate) fn flush(&mut self) {
        if self.failpoint.is_dead() {
            return;
        }
        let locals = &mut self.locals;
        let cross = &mut self.cross;
        if let Err(e) = with_io_retry(|| {
            for w in locals.iter_mut() {
                w.flush()?;
            }
            cross.flush()
        }) {
            self.report(e);
        }
    }

    /// Flush and fsync every file set — the checkpoint prerequisite: a
    /// checkpoint cut must never run ahead of the durable log.
    pub(crate) fn sync(&mut self) {
        if self.failpoint.is_dead() {
            return;
        }
        let locals = &mut self.locals;
        let cross = &mut self.cross;
        if let Err(e) = with_io_retry(|| {
            for w in locals.iter_mut() {
                w.sync()?;
            }
            cross.sync()
        }) {
            self.report(e);
        }
    }

    fn flush_inner(&mut self) -> std::io::Result<()> {
        for w in &mut self.locals {
            w.flush()?;
        }
        self.cross.flush()
    }

    /// A real I/O error is treated as the disk dying: report once,
    /// stop writing, keep serving from memory.
    fn report(&mut self, e: std::io::Error) {
        if !self.reported {
            eprintln!("wal: disabling durability after io error: {e}");
            self.reported = true;
        }
        self.failpoint.kill();
    }
}

/// Configuration for the direct-route reader lanes, handed to
/// `DirectScan` so each reader thread can open its own [`DirectWal`].
#[derive(Clone)]
pub struct DirectWalCfg {
    /// WAL directory (already prepared by the service).
    pub dir: PathBuf,
    /// Segment rotation threshold, in records.
    pub segment_records: u64,
    /// Shard count — one local lane per shard plus a cross lane.
    pub shards: usize,
    /// Shared crash-injection hook (the service's own).
    pub failpoint: FailPoint,
    /// Shared byte counter all readers add to, polled into
    /// `ServiceStats::wal_bytes` by the ingest loop.
    pub bytes: Arc<AtomicU64>,
}

/// Per-reader writer set for direct dispatch: one lane per routing
/// destination, prefixed `shard-{s}.r{k}` / `cross.r{k}` so every
/// file keeps the strictly-ascending per-file seq discipline the
/// scanner enforces. Records are appended before the owning chunk is
/// enqueued; [`DirectWal::flush_chunk`] lands the chunk and reports
/// whether the enqueue may proceed (the `ReaderEnqueue` crash point
/// fires between the two).
pub(crate) struct DirectWal {
    locals: Vec<WalWriter>,
    cross: WalWriter,
    reader: usize,
    failpoint: FailPoint,
    bytes: Arc<AtomicU64>,
    reported: bool,
}

impl DirectWal {
    /// Open reader `reader`'s lanes under the configured directory.
    pub(crate) fn open(cfg: &DirectWalCfg, reader: usize) -> std::io::Result<Self> {
        let segment_records = cfg.segment_records.max(1);
        let locals = (0..cfg.shards)
            .map(|s| WalWriter::open(&cfg.dir, format!("shard-{s}.r{reader}"), segment_records))
            .collect::<std::io::Result<Vec<_>>>()?;
        let cross = WalWriter::open(&cfg.dir, format!("cross.r{reader}"), segment_records)?;
        Ok(DirectWal {
            locals,
            cross,
            reader,
            failpoint: cfg.failpoint.clone(),
            bytes: Arc::clone(&cfg.bytes),
            reported: false,
        })
    }

    fn writer(&mut self, dest: Option<usize>) -> &mut WalWriter {
        match dest {
            Some(s) => &mut self.locals[s],
            None => &mut self.cross,
        }
    }

    /// Append one routed edge to its destination lane (`Some(shard)`
    /// local, `None` cross). Buffered until the chunk flush; a dead
    /// disk drops the write while the in-memory stream keeps flowing.
    pub(crate) fn append(&mut self, dest: Option<usize>, seq: u64, e: Edge) {
        if self.failpoint.is_dead() {
            return;
        }
        if let Some(torn) = self.failpoint.reader_tear(self.reader) {
            let res = {
                let w = self.writer(dest);
                w.append_torn(seq, e, torn)
            };
            match res {
                Ok(kept) => {
                    self.bytes.fetch_add(kept, Ordering::Relaxed);
                }
                Err(e) => self.report(e),
            }
            // land everything this reader buffered, torn fragment
            // included, so the tear is really visible on disk
            if let Err(e) = self.flush_all() {
                self.report(e);
            }
            self.failpoint.kill();
            return;
        }
        let res = {
            let w = self.writer(dest);
            with_io_retry(|| w.append(seq, e))
        };
        match res {
            Ok(()) => {
                self.bytes.fetch_add(RECORD_BYTES as u64, Ordering::Relaxed);
            }
            Err(e) => self.report(e),
        }
    }

    /// Flush the destination lane after its chunk filled. Returns
    /// `false` when the armed `ReaderEnqueue` crash point fires here:
    /// the chunk is durable but must never reach the queue, and the
    /// reader must stop as if the process died.
    #[must_use]
    pub(crate) fn flush_chunk(&mut self, dest: Option<usize>) -> bool {
        if self.failpoint.is_dead() {
            return true;
        }
        let res = {
            let w = self.writer(dest);
            with_io_retry(|| w.flush())
        };
        if let Err(e) = res {
            self.report(e);
            return true;
        }
        if self.failpoint.reader_drop_chunk(self.reader) {
            self.failpoint.kill();
            return false;
        }
        true
    }

    /// Flush and fsync every lane — called when the reader exits,
    /// which is the checkpoint cut the direct route rides.
    pub(crate) fn sync(&mut self) {
        if self.failpoint.is_dead() {
            return;
        }
        let locals = &mut self.locals;
        let cross = &mut self.cross;
        if let Err(e) = with_io_retry(|| {
            for w in locals.iter_mut() {
                w.sync()?;
            }
            cross.sync()
        }) {
            self.report(e);
        }
    }

    fn flush_all(&mut self) -> std::io::Result<()> {
        for w in &mut self.locals {
            w.flush()?;
        }
        self.cross.flush()
    }

    /// A persistent I/O error (after the bounded retry) is the disk
    /// dying: report once, stop writing, keep streaming from memory.
    fn report(&mut self, e: std::io::Error) {
        if !self.reported {
            eprintln!(
                "wal: reader {}: disabling durability after io error: {e}",
                self.reader
            );
            self.reported = true;
        }
        self.failpoint.kill();
    }
}

/// Prepare `dir` for a fresh stream: create it and remove previous
/// WAL segments, quarantined segments, and checkpoints (only files
/// matching our own naming).
pub(crate) fn init_fresh(dir: &Path) -> std::io::Result<()> {
    fs::create_dir_all(dir)?;
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.ends_with(WAL_SUFFIX)
            || name.ends_with(QUARANTINE_SUFFIX)
            || name == CHECKPOINT_FILE
            || name == CHECKPOINT_TMP
        {
            fs::remove_file(entry.path())?;
        }
    }
    Ok(())
}

/// Parse `{prefix}.{first_seq:020}.wal` into its parts.
fn parse_segment(name: &str) -> Option<(&str, u64)> {
    let stem = name.strip_suffix(WAL_SUFFIX)?;
    let (prefix, seq) = stem.rsplit_once('.')?;
    seq.parse::<u64>().ok().map(|first| (prefix, first))
}

/// One decoded WAL record.
#[derive(Debug, Clone, Copy)]
pub(crate) struct WalRecord {
    /// Global 0-based stream position.
    pub seq: u64,
    /// The edge itself, in arrival orientation.
    pub edge: Edge,
}

/// One scanned WAL file: its valid records and where validity ends.
pub(crate) struct ScannedFile {
    /// Path of the segment file.
    pub path: PathBuf,
    /// Checksum-verified records, in file order (strictly ascending
    /// sequence numbers).
    pub records: Vec<WalRecord>,
    /// Byte offset of the end of the last valid record; anything past
    /// it is a torn trailing fragment.
    pub valid_bytes: u64,
}

/// Scan every WAL segment under `dir`. A trailing fragment shorter
/// than one record is dropped cleanly; a full-width record with a bad
/// checksum, or a sequence regression within a file, is
/// [`WalError::Corrupt`].
pub(crate) fn scan_dir(dir: &Path) -> Result<Vec<ScannedFile>, WalError> {
    let mut paths: Vec<PathBuf> = Vec::new();
    if !dir.is_dir() {
        return Ok(Vec::new());
    }
    for entry in fs::read_dir(dir).map_err(WalError::Io)? {
        let entry = entry.map_err(WalError::Io)?;
        if parse_segment(&entry.file_name().to_string_lossy()).is_some() {
            paths.push(entry.path());
        }
    }
    paths.sort();
    paths.iter().map(|p| scan_file(p)).collect()
}

/// Quarantine every corrupt segment under `dir`: the offending file
/// is renamed to `<name>.corrupt` (preserved intact for forensics)
/// and its clean prefix — the checksum-valid whole records before the
/// corruption — is rewritten under the original name, so a following
/// [`scan_dir`] sees only valid data and the durable cut recovers
/// everything before the damage. Returns the quarantined paths.
pub(crate) fn quarantine_corrupt(dir: &Path) -> Result<Vec<PathBuf>, WalError> {
    let mut quarantined = Vec::new();
    if !dir.is_dir() {
        return Ok(quarantined);
    }
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in fs::read_dir(dir).map_err(WalError::Io)? {
        let entry = entry.map_err(WalError::Io)?;
        if parse_segment(&entry.file_name().to_string_lossy()).is_some() {
            paths.push(entry.path());
        }
    }
    paths.sort();
    for path in paths {
        match scan_file(&path) {
            Ok(_) => {}
            Err(WalError::Corrupt { offset, .. }) => {
                // `offset` is the start of the first invalid record,
                // so bytes below it are whole, checksum-valid records
                let keep = (offset as usize / RECORD_BYTES) * RECORD_BYTES;
                let data = fs::read(&path).map_err(WalError::Io)?;
                let mut quarantine = path.clone().into_os_string();
                quarantine.push(QUARANTINE_SUFFIX);
                let quarantine = PathBuf::from(quarantine);
                fs::rename(&path, &quarantine).map_err(WalError::Io)?;
                if keep > 0 {
                    fs::write(&path, &data[..keep]).map_err(WalError::Io)?;
                }
                quarantined.push(quarantine);
            }
            Err(e) => return Err(e),
        }
    }
    Ok(quarantined)
}

fn scan_file(path: &Path) -> Result<ScannedFile, WalError> {
    let data = fs::read(path).map_err(WalError::Io)?;
    let mut records = Vec::with_capacity(data.len() / RECORD_BYTES);
    let mut off = 0usize;
    let mut last_seq: Option<u64> = None;
    while off + RECORD_BYTES <= data.len() {
        let seq = u64::from_le_bytes(data[off..off + 8].try_into().unwrap());
        let u = u32::from_le_bytes(data[off + 8..off + 12].try_into().unwrap());
        let v = u32::from_le_bytes(data[off + 12..off + 16].try_into().unwrap());
        let check = u64::from_le_bytes(data[off + 16..off + 24].try_into().unwrap());
        if check != mix(seq, u, v) || last_seq.map(|p| seq <= p).unwrap_or(false) {
            return Err(WalError::Corrupt { file: path.to_path_buf(), offset: off as u64 });
        }
        last_seq = Some(seq);
        records.push(WalRecord { seq, edge: Edge::new(u, v) });
        off += RECORD_BYTES;
    }
    Ok(ScannedFile { path: path.to_path_buf(), records, valid_bytes: off as u64 })
}

/// The durable seq cut of a scanned WAL directory: the largest S such
/// that every sequence number in `[cut, S)` is present somewhere
/// across the scanned files — equivalently, the first sequence number
/// at or past `cut` missing from the union of all lanes. Computed
/// from the per-file sorted-run structure both write topologies
/// produce (the funnel's per-destination sets, direct dispatch's
/// per-reader-per-destination lanes). Everything below S was logged
/// contiguously; records at or past S (written after a gap a dying
/// disk left) are unusable.
pub(crate) fn durable_cut(files: &[ScannedFile], cut: u64) -> u64 {
    let mut seqs: Vec<u64> = files
        .iter()
        .flat_map(|f| f.records.iter().map(|r| r.seq))
        .filter(|&s| s >= cut)
        .collect();
    seqs.sort_unstable();
    seqs.dedup();
    let mut p = cut;
    for s in seqs {
        if s == p {
            p += 1;
        } else if s > p {
            break;
        }
    }
    p
}

/// All records with `cut ≤ seq < limit`, in global stream order.
pub(crate) fn suffix(files: &[ScannedFile], cut: u64, limit: u64) -> Vec<WalRecord> {
    let mut recs: Vec<WalRecord> = files
        .iter()
        .flat_map(|f| f.records.iter().copied())
        .filter(|r| r.seq >= cut && r.seq < limit)
        .collect();
    recs.sort_unstable_by_key(|r| r.seq);
    recs
}

/// Physically truncate every scanned file at its first record with
/// `seq ≥ limit`, dropping torn trailing fragments with it, so appends
/// after a resume (which restart at `limit`) can never produce
/// duplicate sequence numbers. Files left empty are removed.
pub(crate) fn truncate_beyond(files: &[ScannedFile], limit: u64) -> std::io::Result<()> {
    for f in files {
        let keep = f.records.iter().take_while(|r| r.seq < limit).count();
        let end = (keep * RECORD_BYTES) as u64;
        let on_disk = fs::metadata(&f.path)?.len();
        if end == 0 {
            fs::remove_file(&f.path)?;
        } else if on_disk > end {
            let file = OpenOptions::new().write(true).open(&f.path)?;
            file.set_len(end)?;
            file.sync_data()?;
        }
    }
    Ok(())
}

/// Delete whole WAL segments made redundant by a checkpoint at
/// `cutoff`: a segment can go once a newer segment of the same prefix
/// starts at or below `cutoff`, because every record in the older one
/// is then below the cut the checkpoint already covers. The newest
/// segment of each prefix is always kept (it is the append target).
/// Returns the bytes freed.
pub(crate) fn truncate_segments(dir: &Path, cutoff: u64) -> std::io::Result<u64> {
    let mut by_prefix: BTreeMap<String, Vec<(u64, PathBuf)>> = BTreeMap::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        if let Some((prefix, first)) = parse_segment(&name.to_string_lossy()) {
            by_prefix.entry(prefix.to_string()).or_default().push((first, entry.path()));
        }
    }
    let mut freed = 0u64;
    for segs in by_prefix.values_mut() {
        segs.sort();
        for i in 0..segs.len().saturating_sub(1) {
            if segs[i + 1].0 <= cutoff {
                freed += fs::metadata(&segs[i].1).map(|m| m.len()).unwrap_or(0);
                fs::remove_file(&segs[i].1)?;
            }
        }
    }
    Ok(freed)
}

/// Everything a checkpoint persists: a consistent cut of the whole
/// service at stream position `cut`, plus the configuration
/// fingerprint recovery validates against.
pub(crate) struct CheckpointData {
    /// Shard count the state was built under.
    pub shards: u32,
    /// Leader partition count.
    pub leaders: u32,
    /// Volume threshold `v_max`.
    pub v_max: u64,
    /// Commit horizon in edges; 0 encodes unbounded.
    pub horizon: u64,
    /// Cross-log epoch length derived from the horizon.
    pub epoch_len: u64,
    /// Stream position of the cut: edges `[0, cut)` are covered.
    pub cut: u64,
    /// Per-shard node-state arrays.
    pub states: Vec<StreamState>,
    /// The merger's fold view and drain cursors.
    pub merger: MergerExport,
    /// The cross-log counters and retained (uncommitted) epochs,
    /// verbatim — frozen decisions included, so recovery never has to
    /// reconstruct replay order.
    pub crosslog: CrossLogExport,
    /// Per-leader committed base slices.
    pub bases: Vec<BaseExport>,
}

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }
    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn len(&mut self, n: usize) {
        self.u64(n as u64);
    }
    fn u32s(&mut self, v: &[u32]) {
        self.len(v.len());
        for &x in v {
            self.u32(x);
        }
    }
    fn u64s(&mut self, v: &[u64]) {
        self.len(v.len());
        for &x in v {
            self.u64(x);
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
    path: &'a Path,
}

impl<'a> Dec<'a> {
    fn corrupt(&self) -> WalError {
        WalError::Corrupt { file: self.path.to_path_buf(), offset: self.pos as u64 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], WalError> {
        if self.pos + n > self.buf.len() {
            return Err(self.corrupt());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, WalError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, WalError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, WalError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    /// Length prefix, sanity-bounded so a corrupt length can never
    /// trigger a huge allocation before the bounds check trips.
    fn len(&mut self, elem_bytes: usize) -> Result<usize, WalError> {
        let n = self.u64()? as usize;
        match n.checked_mul(elem_bytes) {
            Some(total) if self.pos + total <= self.buf.len() => Ok(n),
            _ => Err(self.corrupt()),
        }
    }
    fn u32s(&mut self) -> Result<Vec<u32>, WalError> {
        let n = self.len(4)?;
        (0..n).map(|_| self.u32()).collect()
    }
    fn u64s(&mut self) -> Result<Vec<u64>, WalError> {
        let n = self.len(8)?;
        (0..n).map(|_| self.u64()).collect()
    }
}

fn fnv1a(data: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn encode_checkpoint(d: &CheckpointData) -> Vec<u8> {
    let mut e = Enc { buf: Vec::new() };
    e.buf.extend_from_slice(&CKPT_MAGIC);
    e.u32(CKPT_VERSION);
    e.u32(d.shards);
    e.u32(d.leaders);
    e.u64(d.v_max);
    e.u64(d.horizon);
    e.u64(d.epoch_len);
    e.u64(d.cut);
    e.len(d.states.len());
    for s in &d.states {
        e.u64(s.edges_processed);
        e.u32s(&s.degree);
        e.u32s(&s.community);
        e.u64s(&s.volume);
    }
    e.u32s(&d.merger.fold_degree);
    e.u32s(&d.merger.cross_community);
    e.u64(d.merger.drained);
    e.u64(d.merger.drained_m);
    let c = &d.crosslog;
    e.u64(c.committed);
    e.u64(c.appended);
    e.u64(c.epochs_sealed);
    e.u64(c.epochs_committed);
    e.u64(c.freed_bytes);
    e.u64s(&c.appended_per_leader);
    e.u64s(&c.committed_per_leader);
    e.u64s(&c.frozen_retained_per_leader);
    e.u64s(&c.freed_bytes_per_leader);
    e.len(c.epochs.len());
    for ep in &c.epochs {
        e.u64(ep.start);
        e.u8(ep.sealed as u8);
        e.len(ep.edges.len());
        for edge in &ep.edges {
            e.u32(edge.u);
            e.u32(edge.v);
        }
        e.len(ep.frozen.len());
        for lane in &ep.frozen {
            e.len(lane.len());
            for &(node, comm) in lane {
                e.u32(node);
                e.u32(comm);
            }
        }
    }
    e.len(d.bases.len());
    for b in &d.bases {
        e.u64(b.records);
        e.u32s(&b.degree);
        e.u32s(&b.community);
    }
    let sum = fnv1a(&e.buf);
    e.u64(sum);
    e.buf
}

fn decode_checkpoint(path: &Path, data: &[u8]) -> Result<CheckpointData, WalError> {
    let corrupt = |offset: u64| WalError::Corrupt { file: path.to_path_buf(), offset };
    if data.len() < CKPT_MAGIC.len() + 4 + 8 {
        return Err(corrupt(data.len() as u64));
    }
    let (body, tail) = data.split_at(data.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().unwrap());
    if fnv1a(body) != want {
        return Err(corrupt(body.len() as u64));
    }
    let mut d = Dec { buf: body, pos: 0, path };
    if d.take(4)? != CKPT_MAGIC {
        return Err(corrupt(0));
    }
    let version = d.u32()?;
    if version != CKPT_VERSION {
        return Err(WalError::Mismatch {
            detail: format!("checkpoint version {version}, this build reads {CKPT_VERSION}"),
        });
    }
    let shards = d.u32()?;
    let leaders = d.u32()?;
    let v_max = d.u64()?;
    let horizon = d.u64()?;
    let epoch_len = d.u64()?;
    let cut = d.u64()?;
    let n_states = d.len(8)?;
    let mut states = Vec::with_capacity(n_states);
    for _ in 0..n_states {
        let edges_processed = d.u64()?;
        let degree = d.u32s()?;
        let community = d.u32s()?;
        let volume = d.u64s()?;
        states.push(StreamState { degree, community, volume, edges_processed });
    }
    let merger = MergerExport {
        fold_degree: d.u32s()?,
        cross_community: d.u32s()?,
        drained: d.u64()?,
        drained_m: d.u64()?,
    };
    let committed = d.u64()?;
    let appended = d.u64()?;
    let epochs_sealed = d.u64()?;
    let epochs_committed = d.u64()?;
    let freed_bytes = d.u64()?;
    let appended_per_leader = d.u64s()?;
    let committed_per_leader = d.u64s()?;
    let frozen_retained_per_leader = d.u64s()?;
    let freed_bytes_per_leader = d.u64s()?;
    let n_epochs = d.len(17)?;
    let mut epochs = Vec::with_capacity(n_epochs);
    for _ in 0..n_epochs {
        let start = d.u64()?;
        let sealed = d.u8()? != 0;
        let n_edges = d.len(8)?;
        let mut edges = Vec::with_capacity(n_edges);
        for _ in 0..n_edges {
            let u = d.u32()?;
            let v = d.u32()?;
            edges.push(Edge::new(u, v));
        }
        let n_lanes = d.len(8)?;
        let mut frozen = Vec::with_capacity(n_lanes);
        for _ in 0..n_lanes {
            let n_recs = d.len(8)?;
            let mut lane = Vec::with_capacity(n_recs);
            for _ in 0..n_recs {
                let node = d.u32()?;
                let comm = d.u32()?;
                lane.push((node, comm));
            }
            frozen.push(lane);
        }
        epochs.push(EpochExport { start, sealed, edges, frozen });
    }
    let crosslog = CrossLogExport {
        committed,
        appended,
        epochs_sealed,
        epochs_committed,
        freed_bytes,
        appended_per_leader,
        committed_per_leader,
        frozen_retained_per_leader,
        freed_bytes_per_leader,
        epochs,
    };
    let n_bases = d.len(8)?;
    let mut bases = Vec::with_capacity(n_bases);
    for _ in 0..n_bases {
        let records = d.u64()?;
        let degree = d.u32s()?;
        let community = d.u32s()?;
        bases.push(BaseExport { degree, community, records });
    }
    Ok(CheckpointData {
        shards,
        leaders,
        v_max,
        horizon,
        epoch_len,
        cut,
        states,
        merger,
        crosslog,
        bases,
    })
}

/// Atomically write a checkpoint: encode, write `checkpoint.tmp`,
/// fsync, rename over `checkpoint.bin`, best-effort directory fsync.
/// Returns `Ok(true)` when the checkpoint landed, `Ok(false)` when the
/// simulated disk is (or just became) dead.
pub(crate) fn write_checkpoint(
    dir: &Path,
    data: &CheckpointData,
    fp: &FailPoint,
) -> std::io::Result<bool> {
    if fp.is_dead() {
        return Ok(false);
    }
    let bytes = encode_checkpoint(data);
    let tmp = dir.join(CHECKPOINT_TMP);
    if let Some(keep) = fp.checkpoint_tear() {
        let _ = fs::write(&tmp, &bytes[..keep.min(bytes.len())]);
        fp.kill();
        return Ok(false);
    }
    let mut f = File::create(&tmp)?;
    f.write_all(&bytes)?;
    f.sync_data()?;
    drop(f);
    fs::rename(&tmp, dir.join(CHECKPOINT_FILE))?;
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_data();
    }
    Ok(true)
}

/// Read the latest checkpoint under `dir`. `Ok(None)` when none was
/// ever completed; a stale `checkpoint.tmp` from an interrupted write
/// is removed and ignored.
pub(crate) fn read_checkpoint(dir: &Path) -> Result<Option<CheckpointData>, WalError> {
    let _ = fs::remove_file(dir.join(CHECKPOINT_TMP));
    let path = dir.join(CHECKPOINT_FILE);
    let data = match fs::read(&path) {
        Ok(d) => d,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    decode_checkpoint(&path, &data).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    fn scratch(tag: &str) -> PathBuf {
        static NEXT: AtomicU32 = AtomicU32::new(0);
        let id = NEXT.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "streamcom-wal-{}-{tag}-{id}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        init_fresh(&dir).unwrap();
        dir
    }

    fn edge(u: u32, v: u32) -> Edge {
        Edge::new(u, v)
    }

    #[test]
    fn append_scan_roundtrip_across_segments_and_destinations() {
        let dir = scratch("roundtrip");
        let mut wal = WalSet::open(&dir, 2, 3, FailPoint::default(), 0).unwrap();
        for i in 0..10u32 {
            let dest = match i % 3 {
                0 => Some(0),
                1 => Some(1),
                _ => None,
            };
            wal.append(dest, edge(i, i + 1));
        }
        wal.sync();
        assert_eq!(wal.bytes(), 10 * RECORD_BYTES as u64);

        let files = scan_dir(&dir).unwrap();
        let recs = suffix(&files, 0, u64::MAX);
        assert_eq!(recs.len(), 10);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
            assert_eq!((r.edge.u, r.edge.v), (i as u32, i as u32 + 1));
        }
        assert_eq!(durable_cut(&files, 0), 10);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_dropped_and_full_corruption_is_typed() {
        let dir = scratch("torn");
        let mut wal = WalSet::open(&dir, 1, 1024, FailPoint::default(), 0).unwrap();
        for i in 0..4u32 {
            wal.append(Some(0), edge(i, i + 1));
        }
        wal.sync();
        let files = scan_dir(&dir).unwrap();
        assert_eq!(files.len(), 1);
        let path = files[0].path.clone();
        let full = fs::read(&path).unwrap();

        // every proper-prefix truncation of the last record drops it
        // cleanly and keeps the first three
        for keep in 0..RECORD_BYTES {
            let cut = full.len() - RECORD_BYTES + keep;
            fs::write(&path, &full[..cut]).unwrap();
            let scanned = scan_file(&path).unwrap();
            assert_eq!(scanned.records.len(), 3, "keep={keep}");
            assert_eq!(scanned.valid_bytes, (3 * RECORD_BYTES) as u64);
        }

        // a flipped byte inside a full-width record is a typed error
        let mut bad = full.clone();
        bad[RECORD_BYTES + 9] ^= 0x40;
        fs::write(&path, &bad).unwrap();
        match scan_file(&path) {
            Err(WalError::Corrupt { offset, .. }) => {
                assert_eq!(offset, RECORD_BYTES as u64)
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn failpoint_tears_the_planned_record_then_goes_dark() {
        let dir = scratch("failpoint");
        let fp = FailPoint::default();
        fp.arm(CrashPoint::WalAppend { after_records: 5, torn_bytes: 7 });
        let mut wal = WalSet::open(&dir, 2, 1024, fp.clone(), 0).unwrap();
        for i in 0..20u32 {
            wal.append(Some((i % 2) as usize), edge(i, i + 1));
        }
        wal.sync();
        assert!(fp.is_dead());
        assert_eq!(wal.bytes(), 5 * RECORD_BYTES as u64 + 7);

        let files = scan_dir(&dir).unwrap();
        assert_eq!(durable_cut(&files, 0), 5);
        assert_eq!(suffix(&files, 0, 5).len(), 5);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn durable_cut_stops_at_the_first_gap() {
        let files = vec![ScannedFile {
            path: PathBuf::from("x"),
            records: [0u64, 1, 2, 4, 5]
                .iter()
                .map(|&seq| WalRecord { seq, edge: edge(0, 1) })
                .collect(),
            valid_bytes: 0,
        }];
        assert_eq!(durable_cut(&files, 0), 3);
        assert_eq!(durable_cut(&files, 4), 6);
        assert_eq!(suffix(&files, 0, 3).len(), 3);
    }

    #[test]
    fn truncate_beyond_cuts_files_at_the_limit() {
        let dir = scratch("beyond");
        let mut wal = WalSet::open(&dir, 1, 1024, FailPoint::default(), 0).unwrap();
        for i in 0..6u32 {
            wal.append(Some(0), edge(i, i + 1));
        }
        wal.sync();
        let files = scan_dir(&dir).unwrap();
        truncate_beyond(&files, 4).unwrap();
        let files = scan_dir(&dir).unwrap();
        let recs = suffix(&files, 0, u64::MAX);
        assert_eq!(recs.len(), 4);
        assert!(recs.iter().all(|r| r.seq < 4));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn segment_gc_keeps_everything_at_or_past_the_cutoff() {
        let dir = scratch("gc");
        let mut wal = WalSet::open(&dir, 1, 2, FailPoint::default(), 0).unwrap();
        for i in 0..9u32 {
            wal.append(Some(0), edge(i, i + 1));
        }
        wal.sync();
        // segments: [0,1] [2,3] [4,5] [6,7] [8]
        let freed = truncate_segments(&dir, 5).unwrap();
        assert_eq!(freed, 2 * 2 * RECORD_BYTES as u64);
        let files = scan_dir(&dir).unwrap();
        let recs = suffix(&files, 0, u64::MAX);
        // records ≥ 4 all survive (segment [4,5] starts below the
        // cutoff's successor, so it must be kept)
        assert!(recs.iter().all(|r| r.seq >= 4));
        assert_eq!(recs.len(), 5);
        assert_eq!(durable_cut(&files, 5), 9);
        let _ = fs::remove_dir_all(&dir);
    }

    fn sample_checkpoint() -> CheckpointData {
        CheckpointData {
            shards: 2,
            leaders: 1,
            v_max: 64,
            horizon: 32,
            epoch_len: 8,
            cut: 40,
            states: vec![StreamState {
                degree: vec![1, 2],
                community: vec![0, 0],
                volume: vec![3, 4],
                edges_processed: 5,
            }],
            merger: MergerExport {
                fold_degree: vec![7, 8],
                cross_community: vec![0, 1],
                drained: 9,
                drained_m: 10,
            },
            crosslog: CrossLogExport {
                committed: 8,
                appended: 12,
                epochs_sealed: 1,
                epochs_committed: 1,
                freed_bytes: 64,
                appended_per_leader: vec![12],
                committed_per_leader: vec![8],
                frozen_retained_per_leader: vec![8],
                freed_bytes_per_leader: vec![64],
                epochs: vec![EpochExport {
                    start: 8,
                    sealed: false,
                    edges: vec![edge(1, 9), edge(2, 8)],
                    frozen: vec![vec![(1, 1), (9, 1), (2, 2), (8, 2)]],
                }],
            },
            bases: vec![BaseExport {
                degree: vec![2, 2],
                community: vec![1, 1],
                records: 4,
            }],
        }
    }

    #[test]
    fn checkpoint_roundtrips_and_detects_corruption() {
        let dir = scratch("ckpt");
        assert!(read_checkpoint(&dir).unwrap().is_none());
        let data = sample_checkpoint();
        assert!(write_checkpoint(&dir, &data, &FailPoint::default()).unwrap());
        let back = read_checkpoint(&dir).unwrap().expect("checkpoint present");
        assert_eq!(back.cut, 40);
        assert_eq!(back.states[0].volume, vec![3, 4]);
        assert_eq!(back.crosslog.epochs[0].edges.len(), 2);
        assert_eq!(back.crosslog.epochs[0].frozen[0].len(), 4);
        assert_eq!(back.bases[0].records, 4);

        // flip one byte: typed corruption, never a bogus checkpoint
        let path = dir.join(CHECKPOINT_FILE);
        let mut raw = fs::read(&path).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 1;
        fs::write(&path, &raw).unwrap();
        assert!(matches!(read_checkpoint(&dir), Err(WalError::Corrupt { .. })));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_failpoint_leaves_previous_checkpoint_intact() {
        let dir = scratch("ckpt-fp");
        let first = sample_checkpoint();
        assert!(write_checkpoint(&dir, &first, &FailPoint::default()).unwrap());

        let fp = FailPoint::default();
        fp.arm(CrashPoint::Checkpoint { nth: 0, keep_bytes: 10 });
        let mut second = sample_checkpoint();
        second.cut = 80;
        assert!(!write_checkpoint(&dir, &second, &fp).unwrap());
        assert!(fp.is_dead());

        // the torn tmp is ignored and the previous checkpoint survives
        let back = read_checkpoint(&dir).unwrap().expect("previous checkpoint");
        assert_eq!(back.cut, 40);
        let _ = fs::remove_dir_all(&dir);
    }

    fn direct_cfg(dir: &Path, shards: usize, fp: FailPoint) -> DirectWalCfg {
        DirectWalCfg {
            dir: dir.to_path_buf(),
            segment_records: 4,
            shards,
            failpoint: fp,
            bytes: Arc::new(AtomicU64::new(0)),
        }
    }

    #[test]
    fn direct_lanes_interleave_into_one_durable_cut() {
        // two readers striping the seq space across two shards plus
        // the cross lane: the per-file runs stay sorted, the union is
        // contiguous, and the cut sees through the lane structure
        let dir = scratch("direct-lanes");
        let cfg = direct_cfg(&dir, 2, FailPoint::default());
        let mut r0 = DirectWal::open(&cfg, 0).unwrap();
        let mut r1 = DirectWal::open(&cfg, 1).unwrap();
        for seq in 0..20u64 {
            let w = if seq % 2 == 0 { &mut r0 } else { &mut r1 };
            let dest = match seq % 3 {
                0 => Some(0),
                1 => Some(1),
                _ => None,
            };
            w.append(dest, seq, edge(seq as u32, seq as u32 + 1));
        }
        r0.sync();
        r1.sync();
        assert_eq!(cfg.bytes.load(Ordering::Relaxed), 20 * RECORD_BYTES as u64);

        let files = scan_dir(&dir).unwrap();
        assert_eq!(durable_cut(&files, 0), 20);
        let recs = suffix(&files, 0, u64::MAX);
        assert_eq!(recs.len(), 20);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
            assert_eq!((r.edge.u, r.edge.v), (i as u32, i as u32 + 1));
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reader_tear_fires_on_the_planned_lane_then_goes_dark() {
        let dir = scratch("reader-tear");
        let fp = FailPoint::default();
        fp.arm(CrashPoint::ReaderWalAppend { reader: 1, after_records: 3, torn_bytes: 9 });
        let cfg = direct_cfg(&dir, 1, fp.clone());
        let mut r0 = DirectWal::open(&cfg, 0).unwrap();
        let mut r1 = DirectWal::open(&cfg, 1).unwrap();
        // reader 0 logs even seqs, reader 1 odd seqs, chunk size 1 so
        // every record is flushed as it lands; reader 1's 4th record
        // (seq 7) tears, killing the disk for both readers
        for seq in 0..12u64 {
            let w = if seq % 2 == 0 { &mut r0 } else { &mut r1 };
            w.append(Some(0), seq, edge(seq as u32, seq as u32 + 1));
            let _ = w.flush_chunk(Some(0));
        }
        assert!(fp.is_dead());
        r0.sync();
        r1.sync();
        assert_eq!(cfg.bytes.load(Ordering::Relaxed), 7 * RECORD_BYTES as u64 + 9);

        // seqs 0..=6 survive; the torn fragment of 7 is dropped
        // cleanly and everything after the death never reached disk
        let files = scan_dir(&dir).unwrap();
        assert_eq!(durable_cut(&files, 0), 7);
        assert_eq!(suffix(&files, 0, u64::MAX).len(), 7);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reader_enqueue_crash_is_durable_but_stops_the_reader() {
        let dir = scratch("reader-enqueue");
        let fp = FailPoint::default();
        fp.arm(CrashPoint::ReaderEnqueue { reader: 0, after_chunks: 2 });
        let cfg = direct_cfg(&dir, 1, fp.clone());
        let mut r0 = DirectWal::open(&cfg, 0).unwrap();
        let mut allowed = Vec::new();
        for chunk in 0..4u64 {
            for i in 0..3u64 {
                let seq = chunk * 3 + i;
                r0.append(Some(0), seq, edge(seq as u32, seq as u32 + 1));
            }
            allowed.push(r0.flush_chunk(Some(0)));
        }
        // chunk 2 is flushed but must not be enqueued; chunk 3's
        // appends hit the dead disk, so its flush is a visible no-op
        assert_eq!(allowed, vec![true, true, false, true]);
        assert!(fp.is_dead());

        // the dropped chunk itself is durable: replay covers it
        let files = scan_dir(&dir).unwrap();
        assert_eq!(durable_cut(&files, 0), 9);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn quarantine_recovers_the_clean_prefix_of_a_corrupt_segment() {
        let dir = scratch("quarantine");
        let mut wal = WalSet::open(&dir, 1, 1024, FailPoint::default(), 0).unwrap();
        for i in 0..8u32 {
            wal.append(Some(0), edge(i, i + 1));
        }
        wal.sync();
        let files = scan_dir(&dir).unwrap();
        let path = files[0].path.clone();
        let mut raw = fs::read(&path).unwrap();
        raw[5 * RECORD_BYTES + 3] ^= 0x10; // corrupt record 5 in place
        fs::write(&path, &raw).unwrap();
        assert!(matches!(scan_dir(&dir), Err(WalError::Corrupt { .. })));

        let quarantined = quarantine_corrupt(&dir).unwrap();
        assert_eq!(quarantined.len(), 1);
        assert!(quarantined[0].to_string_lossy().ends_with(QUARANTINE_SUFFIX));
        // the damaged bytes are preserved verbatim in quarantine...
        assert_eq!(fs::read(&quarantined[0]).unwrap(), raw);
        // ...while the clean prefix is recovered under the original
        // name and the scan goes back to typed-clean
        let files = scan_dir(&dir).unwrap();
        assert_eq!(durable_cut(&files, 0), 5);
        assert_eq!(suffix(&files, 0, u64::MAX).len(), 5);
        // idempotent: a second pass finds nothing left to quarantine
        assert!(quarantine_corrupt(&dir).unwrap().is_empty());
        let _ = fs::remove_dir_all(&dir);
    }
}
