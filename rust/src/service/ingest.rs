//! The service core: shard workers, bounded mailboxes, and the drains.
//!
//! Mirrors the sneldb-style shard-worker design on top of the existing
//! stream substrate:
//!
//! * **Router** — `super::router::Router`, the single routing core
//!   (also the batch path's core via `coordinator::parallel`):
//!   each ingest batch is partitioned in one pass (pow2 shard counts
//!   take a shift fast path), intra-shard edges batch into
//!   pool-recycled per-shard chunks (`super::bufpool` — the workers
//!   return spent chunks, so steady-state dispatch allocates
//!   nothing), cross-shard edges append to the epoch-structured cross
//!   log (`super::crosslog`), which seals epochs on the router's
//!   chunk boundaries. This is the **funnel** path — one routing
//!   thread sees the global arrival stream, which pacing requires.
//!   For segmented binary scans, [`ClusterService::ingest_direct`]
//!   bypasses it: the scan's reader threads route ([`DirectScan`]),
//!   thin per-shard muxers forward file-ordered sub-chunks into the
//!   same mailboxes, and the cross lane reaches the same log in the
//!   same arrival order — same partition, no single-thread funnel.
//!   With durability on, the readers append their routed chunks to
//!   per-reader WAL lanes before enqueueing them and the durable
//!   prefix is the **seq cut** over all lanes (`wal::durable_cut`),
//!   so the fast path and the WAL compose; checkpoints on this path
//!   fire at the end-of-stream quiesce, where the cut equals the
//!   ingested count (mid-stream, concurrent muxers have no
//!   consistent cut).
//! * **Supervised degradation** — reader and worker deaths no longer
//!   panic the ingest thread: the first failure is recorded as a
//!   typed [`ServiceError`] (`Shared::fault`), the remaining feeds
//!   quiesce and drain, checkpoints stop, and the caller observes
//!   the fault via [`ClusterService::take_fault`] or
//!   [`ServiceResult::fault`].
//! * **Shard worker** — long-lived thread owning one
//!   [`StreamingClusterer`] behind a mutex; drains its bounded mailbox
//!   chunk by chunk. Workers never share nodes (hash-sharding), so they
//!   run the exact sequential algorithm on their slice of the node
//!   space.
//! * **Backpressure** — each mailbox is a bounded [`Channel`]; when a
//!   hot shard falls behind, `push` **blocks** on that mailbox until the
//!   worker catches up. Edges are never dropped, and cold shards are
//!   unaffected.
//! * **Drains (the delta protocol)** — every `drain_every` pushed edges
//!   the thin `Merger` folds its commit-invariant view (total drained
//!   cross degree + frozen communities) over a fresh shard merge and
//!   replays **only the cross edges that arrived since the previous
//!   drain** — `O(n + new cross)` per drain, each cross edge replayed
//!   exactly once by the snapshot path. Under a bounded
//!   [`CommitHorizon`](super::config::CommitHorizon) the drain then
//!   ships each newly-finalized epoch's frozen-record slices to their
//!   `LeaderShard` partitions, which fold them into their
//!   committed-base slices locally — and the epoch's storage is
//!   **freed**. The bytes exchanged per drain (replayed suffix in,
//!   frozen records + per-epoch commit headers out) are the **delta
//!   payload**, tracked in `delta_last_bytes`/`delta_total_bytes`:
//!   `O(new epoch deltas)`, never `O(committed base)` — the committed
//!   base is not read, written, or shipped by a mid-stream drain.
//! * **Terminal replay** — [`ClusterService::finish`] merges the final
//!   shard sketches *and* (once) the K committed-base slices, then
//!   replays the retained (uncommitted) cross tail in arrival order.
//!   With the default `CommitHorizon::Unbounded` every base slice is
//!   empty and the tail is the whole history — the batch leader's pass,
//!   which is why the final partition is then bit-identical to
//!   `run_parallel` and independent of the drain cadence. With
//!   `CommitHorizon::Edges(h)` memory stays bounded instead, and
//!   committed decisions are final.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::algorithm::StreamingClusterer;
use crate::coordinator::state::StreamState;
use crate::graph::edge::Edge;
use crate::stream::meter::Meter;
use crate::stream::pscan::DirectScan;
use crate::stream::shard::{Route, Sharder};
use crate::stream::source::EdgeSource;
use crate::util::channel::Channel;

use super::bufpool::BufPool;
use super::config::{CommitHorizon, ServiceConfig};
use super::crosslog::{
    CrossLog, BYTES_PER_EDGE, BYTES_PER_FROZEN_ENTRY, EPOCH_COMMIT_HEADER_BYTES,
};
use super::query::QueryHandle;
use super::router::Router;
use super::snapshot::{merge_committed_bases, CommittedBase, LeaderShard, Merger, Snapshot};
use super::wal::{self, CheckpointData, WalError, WalSet};

/// A supervised ingest failure: the typed, survivable form of what
/// used to be a panic. Recorded once (first failure wins) in
/// `Shared::fault`; the ingest paths then quiesce-and-drain instead of
/// unwinding, and the caller picks the fault up via
/// [`ClusterService::take_fault`] or [`ServiceResult::fault`] — the
/// CLI maps it to a one-line `error:` and a nonzero exit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// A shard worker thread died (panicked) mid-stream; its sketch
    /// slice is incomplete, so the run's results are unreliable.
    Worker {
        /// Index of the dead shard worker.
        shard: usize,
    },
    /// A direct-scan reader failed (decode or I/O); `detail` is the
    /// scan's uniform `reader {i}/{n} (...): {cause}` message.
    Reader {
        /// The reader's own error line.
        detail: String,
    },
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Worker { shard } => {
                write!(f, "shard worker {shard} died mid-stream; results are incomplete")
            }
            ServiceError::Reader { detail } => write!(f, "scan failed: {detail}"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// State shared between the router, the shard workers, and every
/// [`QueryHandle`].
///
/// Lock order (where two or more are held together):
/// `merger` → `crosslog` → `leaders[i]` (ascending `i`). The stats path
/// takes `crosslog` and each `leaders[i]` one at a time, never nested
/// under anything else. The chunk pool's shelf lock (`bufpool`) is a
/// leaf: checkout/return never hold any other lock.
pub(crate) struct Shared {
    pub(crate) config: ServiceConfig,
    pub(crate) mailboxes: Vec<Channel<Vec<Edge>>>,
    /// Chunk-buffer pool: the router checks buffers out on dispatch,
    /// the workers return them after processing — steady-state chunk
    /// dispatch performs zero heap allocations (see `super::bufpool`).
    pub(crate) bufpool: BufPool,
    pub(crate) states: Vec<Mutex<StreamingClusterer>>,
    /// The epoch-structured cross-edge log (arrival order; the merger's
    /// cursor marks the drained prefix, the commit horizon bounds what
    /// stays resident, frozen records are partitioned per leader).
    pub(crate) crosslog: Mutex<CrossLog>,
    /// The thin drain merger (commit-invariant fold + cursor).
    pub(crate) merger: Mutex<Merger>,
    /// The leader partitions: one committed-base slice per node range.
    pub(crate) leaders: Vec<Mutex<LeaderShard>>,
    /// Edges accepted by `push` (including cross and self-loops).
    pub(crate) ingested: AtomicU64,
    /// Local edges handed to mailboxes.
    pub(crate) dispatched: AtomicU64,
    /// Local edges the workers have finished processing.
    pub(crate) processed: AtomicU64,
    /// Snapshot drains performed so far.
    pub(crate) drains: AtomicU64,
    /// Cross edges replayed by the most recent drain.
    pub(crate) replayed_last: AtomicU64,
    /// Σ cross edges replayed across all snapshot drains (stays equal
    /// to the drained cursor: each cross edge is replayed exactly once).
    pub(crate) replayed_total: AtomicU64,
    /// Cross edges integrated into the published snapshot.
    pub(crate) cross_drained: AtomicU64,
    /// Cross edges accepted by the router but still in its local
    /// pending batch (not yet appended to the cross log). Published on
    /// every batch so `stats()` counts them without a `flush()`.
    pub(crate) cross_buffered: AtomicU64,
    /// Delta payload of the most recent drain: replayed suffix bytes +
    /// frozen-record bytes + per-epoch commit headers. O(new deltas),
    /// independent of the committed-base size (asserted by tests).
    pub(crate) delta_last_bytes: AtomicU64,
    /// Σ delta payload across all drains.
    pub(crate) delta_total_bytes: AtomicU64,
    /// Bytes appended to the write-ahead log by this process (0 when
    /// durability is off; published by the router after each batch).
    pub(crate) wal_bytes: AtomicU64,
    /// Checkpoints successfully written by this process.
    pub(crate) checkpoints_written: AtomicU64,
    /// Cross-log epochs covered by the latest durable checkpoint — the
    /// checkpoint trigger fires when the live commit count passes it.
    pub(crate) last_checkpoint_epoch: AtomicU64,
    /// Epochs already committed in the checkpoint this service resumed
    /// from (0 for a fresh start — proves recovery started from the
    /// checkpoint, not from an empty service).
    pub(crate) recovered_epochs: AtomicU64,
    /// WAL records replayed past the checkpoint cut during resume —
    /// proves recovery replayed only the suffix.
    pub(crate) wal_recovered_edges: AtomicU64,
    /// Set by `finish`: the published snapshot is the terminal replay
    /// and must never be overwritten by a late mid-stream drain.
    pub(crate) finished: AtomicBool,
    /// First supervised failure (worker/reader death); see
    /// [`record_fault`]. Checked cheaply through `faulted`.
    pub(crate) fault: Mutex<Option<ServiceError>>,
    /// Lock-free "a fault has been recorded" flag — gates checkpoints
    /// and lets hot paths skip the `fault` mutex.
    pub(crate) faulted: AtomicBool,
    /// Latest copy-on-read snapshot (swap-on-drain).
    pub(crate) snapshot: RwLock<Arc<Snapshot>>,
    /// Ingest throughput meter (fed at chunk granularity).
    pub(crate) meter: Mutex<Meter>,
}

/// Record a supervised failure: the first fault wins (later ones are
/// usually cascades of the first), and the `faulted` flag flips so the
/// checkpoint gate and the drain paths see it without taking the lock.
pub(crate) fn record_fault(shared: &Shared, err: ServiceError) {
    let mut slot = shared.fault.lock().unwrap();
    if slot.is_none() {
        eprintln!("service: {err}");
        *slot = Some(err);
    }
    shared.faulted.store(true, Ordering::SeqCst);
}

/// Publish a snapshot into the shared slot. Mid-stream drains respect
/// both monotonicity (concurrent rebuilds may finish out of order —
/// never let the published snapshot go backwards in time) and the
/// `finished` flag (never clobber the terminal replay); the terminal
/// replay itself writes unconditionally.
pub(crate) fn publish_snapshot(shared: &Shared, snap: &Arc<Snapshot>, is_final: bool) {
    let mut slot = shared.snapshot.write().unwrap();
    if is_final
        || (!shared.finished.load(Ordering::SeqCst) && snap.edges() >= slot.edges())
    {
        *slot = Arc::clone(snap);
    }
}

/// Incremental snapshot drain — the delta protocol. Under the merger
/// lock: clone the shard sketches, slice the cross log at the drained
/// cursor, and let the thin `Merger` replay only the new suffix. Under
/// a bounded commit horizon the replayed decisions are recorded back
/// into their epochs' per-leader slices, and every epoch that fell
/// behind the horizon ships its slices to the leader partitions (which
/// fold them into their committed-base slices) and is freed. The bytes
/// exchanged — suffix + frozen records + commit headers — are the delta
/// payload; the committed base itself is never touched. Publishes and
/// returns the resulting snapshot. After `finish` this is a no-op that
/// returns the terminal snapshot.
pub(crate) fn rebuild_snapshot(shared: &Shared) -> Arc<Snapshot> {
    if shared.finished.load(Ordering::SeqCst) {
        return Arc::clone(&shared.snapshot.read().unwrap());
    }
    let mut merger = shared.merger.lock().unwrap();
    let states: Vec<StreamState> = shared
        .states
        .iter()
        .map(|m| m.lock().unwrap().state.clone())
        .collect();
    let replay_start = merger.drained();
    let (new_cross, want_frozen) = {
        let log = shared.crosslog.lock().unwrap();
        (log.suffix_from(replay_start), log.wants_frozen())
    };
    let mut frozen = want_frozen.then(|| Vec::with_capacity(new_cross.len() * 2));
    let snap = Arc::new(merger.drain(
        &shared.config.str_config,
        &states,
        &new_cross,
        frozen.as_mut(),
    ));
    // the delta payload a cross-process drain would ship: the replayed
    // suffix in, the frozen decisions back out, one header per epoch
    // commit — and NO term that scales with the committed base
    let mut payload = new_cross.len() as u64 * BYTES_PER_EDGE;
    if let Some(frozen) = frozen {
        payload += frozen.len() as u64 * BYTES_PER_FROZEN_ENTRY;
        // hand the frozen decisions to their epochs' per-leader slices,
        // then finalize every epoch the horizon has passed: each leader
        // partition folds its slice into its committed base, and the
        // epoch's storage is freed when `committable` drops
        let mut log = shared.crosslog.lock().unwrap();
        log.record_frozen(replay_start, &frozen);
        let committable = log.take_committable(merger.drained());
        payload += committable.len() as u64 * EPOCH_COMMIT_HEADER_BYTES;
        for epoch in &committable {
            for (l, slice) in epoch.frozen_slices().iter().enumerate() {
                if !slice.is_empty() {
                    shared.leaders[l].lock().unwrap().commit(slice);
                }
            }
        }
        debug_assert_eq!(
            shared
                .leaders
                .iter()
                .map(|l| l.lock().unwrap().committed_records())
                .sum::<u64>()
                / 2,
            log.committed_edges(),
            "committed accounting diverged between leader shards and cross log"
        );
    }
    shared.drains.fetch_add(1, Ordering::Relaxed);
    shared.replayed_last.store(new_cross.len() as u64, Ordering::Relaxed);
    shared
        .replayed_total
        .fetch_add(new_cross.len() as u64, Ordering::Relaxed);
    shared.cross_drained.store(merger.drained_m(), Ordering::Relaxed);
    shared.delta_last_bytes.store(payload, Ordering::Relaxed);
    shared.delta_total_bytes.fetch_add(payload, Ordering::Relaxed);
    drop(merger);
    publish_snapshot(shared, &snap, false);
    snap
}

fn worker_loop(shared: &Shared, w: usize) {
    // close the mailbox on the way out — including on panic — so a dead
    // worker turns the router's blocked sends into errors instead of a
    // permanent hang; finish() then surfaces the panic via join()
    struct CloseOnExit<'a>(&'a Channel<Vec<Edge>>);
    impl Drop for CloseOnExit<'_> {
        fn drop(&mut self) {
            self.0.close();
        }
    }
    let mailbox = &shared.mailboxes[w];
    let _guard = CloseOnExit(mailbox);
    while let Some(chunk) = mailbox.recv() {
        {
            let mut clusterer = shared.states[w].lock().unwrap();
            clusterer.process_chunk(&chunk);
        }
        shared.processed.fetch_add(chunk.len() as u64, Ordering::SeqCst);
        // close the zero-allocation cycle: the spent chunk goes back to
        // the pool for the router's next dispatch
        shared.bufpool.give_back(chunk);
    }
}

/// Final outcome of a service run (after [`ClusterService::finish`]).
#[derive(Debug)]
pub struct ServiceResult {
    /// The final partition: all local edges processed and the retained
    /// cross tail replayed in arrival order over the merged committed
    /// base. Under `CommitHorizon::Unbounded` (the default) the base is
    /// empty and the tail is the full cross history, so this is
    /// identical to what the batch coordinator produces for the same
    /// stream and configuration, whatever the drain cadence was. Under
    /// a bounded horizon, committed mid-stream decisions are final and
    /// the result may differ from batch by a bounded quality margin.
    pub snapshot: Arc<Snapshot>,
    /// Total edges pushed over the service's lifetime.
    pub edges_ingested: u64,
    /// Cross-shard edges resolved by deferred replay.
    pub cross_edges: u64,
    /// Wall-clock ingest time.
    pub elapsed: Duration,
    /// First supervised failure recorded during the run (worker or
    /// reader death), if any — `Some` means the snapshot covers only
    /// what survived, and callers should treat the run as failed.
    pub fault: Option<ServiceError>,
}

impl ServiceResult {
    /// Final community labels (unseen nodes as singletons).
    pub fn labels(&self) -> Vec<u32> {
        self.snapshot.labels()
    }

    /// The final merged sketch.
    pub fn state(&self) -> &StreamState {
        self.snapshot.state()
    }
}

/// A long-lived sharded clustering service.
///
/// Owns `shards` worker threads; `push` routes edges to them with
/// blocking backpressure, queries are served from copy-on-read
/// snapshots via [`QueryHandle`]s. See the [module docs](self) and
/// `docs/ARCHITECTURE.md` for the dataflow.
pub struct ClusterService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// The write-side routing core (shared with the batch path, which
    /// is a preset over this service).
    router: Router,
}

/// Clamp and resolve the configuration conventions shared by every way
/// a service comes up (`start` and `resume` must agree on these, or a
/// resumed service would checkpoint under a different fingerprint than
/// it validated).
fn normalize(mut config: ServiceConfig) -> ServiceConfig {
    config.shards = config.shards.max(1);
    config.mailbox_depth = config.mailbox_depth.max(1);
    config.chunk_size = config.chunk_size.max(1);
    config.wal_segment_records = config.wal_segment_records.max(1);
    if config.drain_every == 0 {
        // match the CLI's "0 = disabled" convention — a drain after
        // every edge would collapse throughput
        config.drain_every = u64::MAX;
    }
    config.horizon = config.horizon.normalized();
    // 0 = one leader partition per shard worker, so each worker's
    // node range owns exactly its slice of the committed base
    if config.leaders == 0 {
        config.leaders = config.shards;
    }
    config
}

impl ClusterService {
    /// Spawn the shard workers and return the router handle.
    ///
    /// With `config.wal_dir` set this **begins a fresh durable
    /// stream**: previous WAL segments and checkpoints under the
    /// directory are removed. Use [`resume`](Self::resume) to continue
    /// an interrupted stream instead.
    pub fn start(config: ServiceConfig) -> Self {
        let config = normalize(config);
        if let Some(dir) = config.wal_dir.as_deref() {
            wal::init_fresh(dir).expect("initialise WAL directory");
        }
        let states = (0..config.shards)
            .map(|_| StreamingClusterer::new(config.initial_nodes, config.str_config.clone()))
            .collect();
        let crosslog = CrossLog::new(config.horizon, config.leaders);
        let leaders = (0..config.leaders)
            .map(|l| LeaderShard::new(l, config.leaders))
            .collect();
        Self::boot(config, states, crosslog, Merger::new(), leaders, 0, 0)
            .expect("open write-ahead log")
    }

    /// Resume an interrupted durable stream from `config.wal_dir`: load
    /// the latest checkpoint (none ⇒ an empty service), validate its
    /// configuration fingerprint, replay the WAL suffix past its cut —
    /// truncated to the longest contiguous durable prefix, with any
    /// torn trailing fragment dropped — and come up ready to ingest the
    /// rest of the stream. `ServiceStats::edges_ingested` then reports
    /// the recovered stream position, i.e. how many leading edges of
    /// the stream the caller should skip.
    ///
    /// Only the post-checkpoint suffix is re-ingested
    /// (`ServiceStats::wal_recovered_edges` counts it;
    /// `recovered_epochs` proves the committed history came from the
    /// checkpoint). Under [`CommitHorizon::Unbounded`] no epoch ever
    /// commits, so no checkpoint is ever written and recovery replays
    /// the whole WAL — exactness without bounds; a bounded horizon
    /// keeps both the log and the replay bounded. Resume-exactness
    /// caveat: a `TieBreak::Random` configuration reseeds its RNG here,
    /// so recovered runs are only bit-identical under deterministic
    /// tie-breaking (the default).
    pub fn resume(config: ServiceConfig) -> Result<Self, WalError> {
        let config = normalize(config);
        let Some(dir) = config.wal_dir.clone() else {
            return Err(WalError::Mismatch {
                detail: "resume requires a WAL directory (config.wal_dir)".to_string(),
            });
        };
        let horizon_edges = match config.horizon {
            CommitHorizon::Unbounded => 0,
            CommitHorizon::Edges(h) => h,
        };
        let (mut states, mut crosslog, merger, leaders, cut, recovered_epochs) =
            match wal::read_checkpoint(&dir)? {
                Some(c) => {
                    if c.shards as usize != config.shards
                        || c.leaders as usize != config.leaders
                        || c.v_max != config.str_config.v_max
                        || c.horizon != horizon_edges
                    {
                        return Err(WalError::Mismatch {
                            detail: format!(
                                "checkpoint written under shards={} leaders={} v_max={} \
                                 horizon={}, resume asked for shards={} leaders={} v_max={} \
                                 horizon={}",
                                c.shards,
                                c.leaders,
                                c.v_max,
                                c.horizon,
                                config.shards,
                                config.leaders,
                                config.str_config.v_max,
                                horizon_edges
                            ),
                        });
                    }
                    let states: Vec<StreamingClusterer> = c
                        .states
                        .into_iter()
                        .map(|st| StreamingClusterer::with_state(st, config.str_config.clone()))
                        .collect();
                    let epochs = c.crosslog.epochs_committed;
                    let crosslog = CrossLog::resume(config.horizon, config.leaders, c.crosslog);
                    let leaders: Vec<LeaderShard> = c
                        .bases
                        .into_iter()
                        .enumerate()
                        .map(|(l, b)| {
                            LeaderShard::restore(l, config.leaders, CommittedBase::from_parts(b))
                        })
                        .collect();
                    (states, crosslog, Merger::resume(c.merger), leaders, c.cut, epochs)
                }
                None => {
                    // no checkpoint ever completed — recover the whole
                    // stream from the WAL over an empty service
                    let states = (0..config.shards)
                        .map(|_| {
                            StreamingClusterer::new(config.initial_nodes, config.str_config.clone())
                        })
                        .collect();
                    let leaders = (0..config.leaders)
                        .map(|l| LeaderShard::new(l, config.leaders))
                        .collect();
                    let crosslog = CrossLog::new(config.horizon, config.leaders);
                    (states, crosslog, Merger::new(), leaders, 0, 0)
                }
            };

        // quarantine first: a segment whose tail fails its checksum is
        // renamed to `<name>.corrupt` (evidence preserved) and its
        // clean prefix of whole records is recovered under the
        // original name — resume then proceeds over intact files only.
        // Transient I/O gets the bounded retry; Corrupt stays
        // fail-fast inside the scan itself.
        for q in wal::retry_wal(|| wal::quarantine_corrupt(&dir))? {
            eprintln!("wal: quarantined corrupt segment to {}", q.display());
        }
        // the durable suffix: everything contiguously logged past the
        // cut. The cut is seq-first (`durable_cut` walks the union of
        // every lane's sorted runs — funnel shard/cross files and
        // per-reader direct lanes alike), and the files are truncated
        // there so post-resume appends (restarting at the cut) can
        // never duplicate a sequence.
        let files = wal::retry_wal(|| wal::scan_dir(&dir))?;
        let prefix = wal::durable_cut(&files, cut);
        wal::retry_wal(|| wal::truncate_beyond(&files, prefix).map_err(WalError::from))?;
        let suffix = wal::suffix(&files, cut, prefix);
        let recovered_edges = suffix.len() as u64;

        // replay before any worker exists, routing exactly as the
        // router would have: per-shard order and cross arrival order
        // are reproduced, and epoch sealing is count-based, so one
        // bulk append recreates the same epoch structure
        let sharder = Sharder::new(config.shards);
        let mut cross: Vec<Edge> = Vec::new();
        for rec in &suffix {
            match sharder.route(rec.edge) {
                Route::Local(w) => {
                    states[w].process_chunk(std::slice::from_ref(&rec.edge));
                }
                Route::Cross => cross.push(rec.edge),
            }
        }
        if !cross.is_empty() {
            crosslog.append(&mut cross);
        }

        let svc = Self::boot(
            config,
            states,
            crosslog,
            merger,
            leaders,
            prefix,
            recovered_edges,
        )?;
        svc.shared
            .recovered_epochs
            .store(recovered_epochs, Ordering::SeqCst);
        Ok(svc)
    }

    /// Shared bring-up for `start` and `resume`: wrap the (fresh or
    /// restored) components in `Shared`, spawn the shard workers, and
    /// open the WAL writers at stream position `ingested`. `config`
    /// must already be normalized.
    fn boot(
        config: ServiceConfig,
        states: Vec<StreamingClusterer>,
        crosslog: CrossLog,
        merger: Merger,
        leaders: Vec<LeaderShard>,
        ingested: u64,
        recovered_edges: u64,
    ) -> Result<Self, WalError> {
        let shards = config.shards;
        // per shard, at most: the pending buffer, `mailbox_depth`
        // queued chunks, one in the worker's hands, and one in transit
        // during the dispatch swap (checkout happens before the spent
        // buffer returns) — the in-flight bound. Sizing the shelf to it
        // and prewarming below means checkout can never find the shelf
        // empty: steady state starts at zero misses.
        let pool_cap = shards * (config.mailbox_depth + 3);
        // every recovered edge is either in a shard state or in the
        // cross log, so the local done-count is derivable — it must be,
        // for later quiesced-cut checks (`dispatched + cross appended
        // == ingested`) to keep holding
        let local_done = ingested - crosslog.appended();
        let checkpoint_epoch = crosslog.epochs_committed();

        let shared = Arc::new(Shared {
            mailboxes: (0..shards)
                .map(|_| Channel::bounded(config.mailbox_depth))
                .collect(),
            bufpool: BufPool::new(pool_cap),
            states: states.into_iter().map(Mutex::new).collect(),
            crosslog: Mutex::new(crosslog),
            merger: Mutex::new(merger),
            leaders: leaders.into_iter().map(Mutex::new).collect(),
            ingested: AtomicU64::new(ingested),
            dispatched: AtomicU64::new(local_done),
            processed: AtomicU64::new(local_done),
            drains: AtomicU64::new(0),
            replayed_last: AtomicU64::new(0),
            replayed_total: AtomicU64::new(0),
            cross_drained: AtomicU64::new(0),
            cross_buffered: AtomicU64::new(0),
            delta_last_bytes: AtomicU64::new(0),
            delta_total_bytes: AtomicU64::new(0),
            wal_bytes: AtomicU64::new(0),
            checkpoints_written: AtomicU64::new(0),
            last_checkpoint_epoch: AtomicU64::new(checkpoint_epoch),
            recovered_epochs: AtomicU64::new(0),
            wal_recovered_edges: AtomicU64::new(recovered_edges),
            finished: AtomicBool::new(false),
            fault: Mutex::new(None),
            faulted: AtomicBool::new(false),
            snapshot: RwLock::new(Arc::new(Snapshot::empty())),
            meter: Mutex::new(Meter::start()),
            config,
        });

        // fill the shelf to the in-flight bound before the router's
        // first checkout (Router::new takes one pending buffer per
        // shard) — the warm-up miss ramp becomes hits from edge one
        shared.bufpool.prewarm(pool_cap, shared.config.chunk_size);

        let workers = (0..shards)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("shard-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawn shard worker")
            })
            .collect();

        let wal = match shared.config.wal_dir.as_deref() {
            Some(dir) => Some(WalSet::open(
                dir,
                shards,
                shared.config.wal_segment_records,
                shared.config.failpoint.clone(),
                ingested,
            )?),
            None => None,
        };
        let router = Router::new(Arc::clone(&shared), wal);
        Ok(Self { shared, workers, router })
    }

    /// A cloneable query handle sharing this service's state. Handles
    /// stay valid after [`finish`](Self::finish) and keep serving the
    /// final snapshot.
    pub fn handle(&self) -> QueryHandle {
        QueryHandle::new(Arc::clone(&self.shared))
    }

    /// Route one edge. Blocks when the target shard's mailbox is full
    /// (backpressure); triggers an automatic incremental drain every
    /// `config.drain_every` edges.
    pub fn push(&mut self, e: Edge) {
        if self.router.push(e) {
            self.refresh();
        }
    }

    /// Route a chunk of edges as **one batch** through
    /// `Router::push_batch`: a single routing pass, per-batch (not
    /// per-edge) counter/meter/drain-clock bookkeeping. The automatic
    /// drain clock is therefore batch-granular — a drain fires at the
    /// first chunk boundary at or past `config.drain_every` edges
    /// since the previous drain (the final partition is
    /// drain-cadence-independent under the default unbounded horizon,
    /// so only mid-stream snapshot freshness sees the difference).
    pub fn push_chunk(&mut self, chunk: &[Edge]) {
        if self.router.push_batch(chunk) {
            self.refresh();
        }
    }

    /// Drain an entire [`EdgeSource`] through the service; returns the
    /// number of edges ingested from it.
    pub fn ingest<S: EdgeSource>(&mut self, source: &mut S, batch: usize) -> u64 {
        let mut buf = Vec::with_capacity(batch.max(1));
        let mut total = 0u64;
        while source.next_batch(&mut buf) > 0 {
            total += buf.len() as u64;
            self.push_chunk(&buf);
        }
        total
    }

    /// Drain a [`DirectScan`] into the shard workers without the
    /// routing funnel: the scan's reader threads already partitioned
    /// the stream, so this spawns one thin **muxer** per shard that
    /// forwards its [`DestFeed`](crate::stream::pscan::DestFeed)'s
    /// sub-chunks — in file order — straight into the shard's mailbox,
    /// while the calling thread consumes the cross lane and appends it
    /// to the cross log in global-sequence order. Per-shard edge order
    /// and cross arrival order are exactly what the funnel
    /// ([`ingest`](Self::ingest) over a
    /// [`ParallelScanner`](crate::stream::pscan::ParallelScanner))
    /// produces, and epoch sealing is count-based, so the final
    /// partition is bit-identical at any reader count — the
    /// routing-mode property suite pins it.
    ///
    /// The automatic drain clock is **seq-keyed** here: a cross chunk
    /// whose span reaches a multiple of `config.drain_every` (global
    /// stream position, not cross count) triggers a snapshot rebuild.
    /// Reader-count-invariant because sequence indices are; cadence is
    /// approximate — streams with few cross edges drain rarely, which
    /// only affects mid-stream snapshot freshness, never the final
    /// partition (unbounded horizon).
    ///
    /// With durability on (`config.wal_dir` set), open the scan with
    /// [`ServiceConfig::direct_wal_cfg`] so the readers append their
    /// routed chunks to per-reader WAL lanes before enqueueing; this
    /// method then publishes the scan's WAL byte counter into the
    /// service stats and runs an end-of-stream quiesce — the one point
    /// where the seq cut, the ingested count, and every lane's fsync
    /// line up, so it doubles as the direct path's checkpoint
    /// opportunity (mid-stream, concurrent muxers have no consistent
    /// cut to export).
    ///
    /// Returns the number of edges ingested. Worker deaths and reader
    /// failures do not panic: the first one is recorded as a
    /// [`ServiceError`] (see [`take_fault`](Self::take_fault)), the
    /// affected feeds drain, and the count reflects what was actually
    /// dispatched. Panics only if the scan was routed for a different
    /// shard count — a wiring bug, not a runtime failure.
    pub fn ingest_direct(&mut self, scan: &mut DirectScan) -> u64 {
        assert_eq!(
            scan.shards(),
            self.shared.config.shards,
            "DirectScan routed for a different shard count than the service runs"
        );
        let (shard_feeds, mut cross_feed) = scan.feeds();
        let muxers: Vec<JoinHandle<u64>> = shard_feeds
            .into_iter()
            .enumerate()
            .map(|(w, mut feed)| {
                let shared = Arc::clone(&self.shared);
                std::thread::Builder::new()
                    .name(format!("mux-{w}"))
                    .spawn(move || {
                        let mut total = 0u64;
                        while let Some(chunk) = feed.recv() {
                            let len = chunk.edges.len() as u64;
                            // a closed mailbox mid-run means the worker
                            // died: record the fault and keep draining
                            // the feed so the readers never block on a
                            // full queue behind a dead shard
                            if shared.mailboxes[w].send(chunk.edges).is_err() {
                                record_fault(&shared, ServiceError::Worker { shard: w });
                                while feed.recv().is_some() {}
                                break;
                            }
                            shared.ingested.fetch_add(len, Ordering::Relaxed);
                            shared.meter.lock().unwrap().add_edges(len);
                            shared.dispatched.fetch_add(len, Ordering::SeqCst);
                            total += len;
                        }
                        total
                    })
                    .expect("spawn direct-dispatch muxer")
            })
            .collect();

        let drain_every = self.shared.config.drain_every;
        let mut next_drain = drain_every;
        let mut total = 0u64;
        while let Some(mut chunk) = cross_feed.recv() {
            let len = chunk.edges.len() as u64;
            let last_seq = chunk.last_seq;
            self.shared.ingested.fetch_add(len, Ordering::Relaxed);
            self.shared.meter.lock().unwrap().add_edges(len);
            {
                // scoped: rebuild_snapshot below takes merger →
                // crosslog, so the log lock must be released first
                let mut log = self.shared.crosslog.lock().unwrap();
                log.append(&mut chunk.edges);
            }
            total += len;
            if let Some(b) = scan.wal_bytes() {
                self.shared.wal_bytes.store(b, Ordering::Relaxed);
            }
            if drain_every != u64::MAX && last_seq + 1 >= next_drain {
                rebuild_snapshot(&self.shared);
                next_drain = ((last_seq + 1) / drain_every + 1) * drain_every;
            }
        }
        for (w, h) in muxers.into_iter().enumerate() {
            match h.join() {
                Ok(n) => total += n,
                Err(_) => record_fault(&self.shared, ServiceError::Worker { shard: w }),
            }
        }
        if let Some(b) = scan.wal_bytes() {
            self.shared.wal_bytes.store(b, Ordering::Relaxed);
        }
        if let Some(detail) = scan.take_error() {
            record_fault(&self.shared, ServiceError::Reader { detail });
        }
        // end-of-stream quiesce: every reader synced its lanes on
        // exit, nothing is in flight, and — only when the scan
        // delivered the whole file — the delivered seqs are exactly
        // [0, total), so `ingested` is a valid seq cut for the
        // checkpoint. A partial delivery (abort, fault) has seq gaps
        // and must not checkpoint; its WAL lanes still recover to the
        // durable cut on resume.
        let complete = scan.len_hint().is_some_and(|m| m as u64 == total);
        if complete
            && self.shared.config.wal_dir.is_some()
            && !self.shared.faulted.load(Ordering::SeqCst)
        {
            self.quiesce();
        }
        total
    }

    /// Dispatch all partially-filled router buffers (local and cross).
    pub fn flush(&mut self) {
        self.router.flush();
    }

    /// Flush and rebuild the copy-on-read snapshot *now* (without
    /// waiting for the workers to drain their mailboxes — the snapshot
    /// covers whatever they have processed so far, plus all buffered
    /// cross edges).
    ///
    /// With durability on (`config.wal_dir` set) this upgrades to a
    /// full [`quiesce`](Self::quiesce): checkpoints need quiesced cuts
    /// — a stream position where nothing is in flight — so every drain
    /// point becomes a checkpoint opportunity.
    pub fn refresh(&mut self) -> Arc<Snapshot> {
        if self.shared.config.wal_dir.is_some() {
            return self.quiesce();
        }
        self.flush();
        self.router.reset_drain_clock();
        rebuild_snapshot(&self.shared)
    }

    /// Flush, wait until the workers have processed every dispatched
    /// edge, then rebuild the snapshot. The result covers *exactly* the
    /// edges pushed so far — the strongest mid-stream consistency the
    /// service offers.
    pub fn quiesce(&mut self) -> Arc<Snapshot> {
        self.flush();
        let mut spins = 0u32;
        while self.shared.processed.load(Ordering::SeqCst)
            < self.shared.dispatched.load(Ordering::SeqCst)
        {
            // a mailbox only closes mid-run when its worker died — a
            // recv'd-but-unprocessed chunk would make this wait
            // eternal, so record the fault and snapshot what we have
            if let Some(w) = self.shared.mailboxes.iter().position(|m| m.is_closed()) {
                record_fault(&self.shared, ServiceError::Worker { shard: w });
                break;
            }
            // short yield phase for the common fast drain, then back off
            // to sleeps so a long wait doesn't burn a core
            spins += 1;
            if spins < 64 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        self.router.reset_drain_clock();
        let snap = rebuild_snapshot(&self.shared);
        self.maybe_checkpoint();
        snap
    }

    /// Write an epoch-aligned checkpoint if one is due: durability on,
    /// nothing in flight (the workers have processed every dispatched
    /// edge, so `ingested` is a consistent cut), and the cross log has
    /// committed at least one epoch since the last checkpoint. Called
    /// from every quiesced drain. Under `CommitHorizon::Unbounded`
    /// epochs never commit, so this never fires — recovery then
    /// replays the whole WAL, trading recovery time for exactness.
    fn maybe_checkpoint(&mut self) {
        let Some(dir) = self.shared.config.wal_dir.clone() else {
            return;
        };
        // a faulted run has no trustworthy cut: a dead worker's slice
        // is incomplete even when the counters happen to line up
        if self.shared.faulted.load(Ordering::SeqCst) {
            return;
        }
        let ingested = self.shared.ingested.load(Ordering::SeqCst);
        let dispatched = self.shared.dispatched.load(Ordering::SeqCst);
        let processed = self.shared.processed.load(Ordering::SeqCst);
        let (appended, epochs_committed) = {
            let log = self.shared.crosslog.lock().unwrap();
            (log.appended(), log.epochs_committed())
        };
        // a valid cut: every ingested edge is either fully processed by
        // its shard worker or resident in the cross log
        if dispatched != processed || dispatched + appended != ingested {
            return;
        }
        if epochs_committed <= self.shared.last_checkpoint_epoch.load(Ordering::SeqCst) {
            return;
        }
        // durability barrier: the checkpoint claims edges [0, cut) are
        // on disk, so the log must be fsynced up to the cut first
        self.router.wal_sync();
        let data = {
            // hold the merger lock across the whole export so a racing
            // handle-driven drain cannot commit epochs between the
            // pieces (lock order merger → crosslog → leaders)
            let merger = self.shared.merger.lock().unwrap();
            let states: Vec<StreamState> = self
                .shared
                .states
                .iter()
                .map(|m| m.lock().unwrap().state.clone())
                .collect();
            let (crosslog, epoch_len) = {
                let log = self.shared.crosslog.lock().unwrap();
                (log.export(), log.epoch_len())
            };
            let bases = self
                .shared
                .leaders
                .iter()
                .map(|l| l.lock().unwrap().base().export())
                .collect();
            let cfg = &self.shared.config;
            CheckpointData {
                shards: cfg.shards as u32,
                leaders: cfg.leaders as u32,
                v_max: cfg.str_config.v_max,
                horizon: match cfg.horizon {
                    CommitHorizon::Unbounded => 0,
                    CommitHorizon::Edges(h) => h,
                },
                epoch_len,
                cut: ingested,
                states,
                merger: merger.export(),
                crosslog,
                bases,
            }
        };
        let covered = data.crosslog.epochs_committed;
        match wal::write_checkpoint(&dir, &data, &self.shared.config.failpoint) {
            Ok(true) => {
                self.shared.checkpoints_written.fetch_add(1, Ordering::SeqCst);
                self.shared
                    .last_checkpoint_epoch
                    .store(covered, Ordering::SeqCst);
                // whole segments below the cut are now redundant
                if let Err(e) = wal::truncate_segments(&dir, data.cut) {
                    eprintln!("wal: segment gc failed: {e}");
                }
            }
            // simulated (or real, already-reported) disk death — keep
            // serving from memory, like every other durable write
            Ok(false) => {}
            Err(e) => {
                eprintln!("wal: disabling durability after checkpoint error: {e}");
                self.shared.config.failpoint.kill();
            }
        }
    }

    /// End of stream: flush, close the mailboxes, join the workers, and
    /// run the terminal replay — merge the final shard sketches, fold
    /// the **merged** committed-base slices over them (the one moment
    /// the K slices are read as a whole), and replay the retained
    /// (uncommitted) cross tail in arrival order with a fresh tail
    /// merger. Under `CommitHorizon::Unbounded` every slice is empty
    /// and the tail is the whole cross history — the batch
    /// coordinator's own final pass, so the result is bit-identical to
    /// `run_parallel` on the same stream and independent of how many
    /// incremental drains happened mid-stream. Under
    /// `CommitHorizon::Edges(h)` the freed history stays final instead.
    pub fn finish(mut self) -> ServiceResult {
        self.router.flush();
        // make the full stream durable before tearing down — a resume
        // after a clean finish replays to the exact end of stream
        self.router.wal_sync();
        for mb in &self.shared.mailboxes {
            mb.close();
        }
        for (w, h) in std::mem::take(&mut self.workers).into_iter().enumerate() {
            if h.join().is_err() {
                record_fault(&self.shared, ServiceError::Worker { shard: w });
            }
        }
        let states: Vec<StreamState> = self
            .shared
            .states
            .iter()
            .map(|m| m.lock().unwrap().state.clone())
            .collect();
        let (base, tail, cross_total) = {
            // hold the merger lock so a racing mid-stream drain cannot
            // commit epochs between the tail read and the slice reads
            // (which would double-count them); lock order merger →
            // crosslog → leaders[i]
            let _merger = self.shared.merger.lock().unwrap();
            let log = self.shared.crosslog.lock().unwrap();
            let tail = log.suffix_from(log.committed_edges());
            let cross_total = log.appended();
            let slices: Vec<CommittedBase> = self
                .shared
                .leaders
                .iter()
                .map(|l| l.lock().unwrap().base().clone())
                .collect();
            (merge_committed_bases(&slices), tail, cross_total)
        };
        // raise the flag first so a racing mid-stream drain cannot
        // overwrite the terminal snapshot we are about to publish
        self.shared.finished.store(true, Ordering::SeqCst);
        let snapshot = Arc::new(Snapshot::build_over(
            &self.shared.config.str_config,
            base,
            &states,
            &tail,
        ));
        publish_snapshot(&self.shared, &snapshot, true);
        let report = self.shared.meter.lock().unwrap().snapshot();
        let fault = self.shared.fault.lock().unwrap().take();
        ServiceResult {
            snapshot,
            edges_ingested: self.shared.ingested.load(Ordering::Relaxed),
            cross_edges: cross_total,
            elapsed: report.elapsed,
            fault,
        }
    }

    /// Take the first supervised failure recorded so far, if any —
    /// `None` means the service is healthy. Faults are recorded (not
    /// panicked) by the muxers, the quiesce wait, and the worker
    /// joins; once taken, subsequent calls return `None`.
    pub fn take_fault(&self) -> Option<ServiceError> {
        if !self.shared.faulted.load(Ordering::SeqCst) {
            return None;
        }
        self.shared.fault.lock().unwrap().take()
    }
}

impl Drop for ClusterService {
    /// Abort semantics: close mailboxes (workers drain what was already
    /// dispatched and exit) and join. Router-buffered edges are
    /// discarded — call [`finish`](Self::finish) for a clean shutdown.
    fn drop(&mut self) {
        for mb in &self.shared.mailboxes {
            mb.close();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::parallel::{run_parallel, ParallelConfig};
    use crate::graph::generators::sbm::{self, SbmConfig};

    fn small_config(shards: usize, v_max: u64) -> ServiceConfig {
        let mut cfg = ServiceConfig::new(shards, v_max);
        cfg.chunk_size = 64;
        cfg.drain_every = u64::MAX;
        cfg
    }

    #[test]
    fn every_pushed_edge_reaches_the_final_partition() {
        let g = sbm::generate(&SbmConfig::equal(6, 30, 0.4, 0.01, 5));
        let mut svc = ClusterService::start(small_config(3, 64));
        for &e in &g.edges.edges {
            svc.push(e);
        }
        let res = svc.finish();
        assert_eq!(res.edges_ingested, g.m() as u64);
        assert_eq!(res.snapshot.edges(), g.m() as u64);
        assert_eq!(res.snapshot.local_edges + res.snapshot.cross_edges, g.m() as u64);
        assert_eq!(res.state().total_volume(), 2 * g.m() as u64);
    }

    #[test]
    fn final_partition_identical_to_batch_parallel_coordinator() {
        // the batch path IS this service in the batch preset, so this
        // pins the preset wiring: same hash-sharding, same per-shard
        // order, same terminal replay → bit-identical labels
        let g = sbm::generate(&SbmConfig::equal(8, 40, 0.3, 0.01, 9));
        let shards = 4;
        let v_max = 64;

        let par = run_parallel(g.n(), &g.edges.edges, &ParallelConfig::new(shards, v_max));
        let par_labels = par.labels();

        let mut svc = ClusterService::start(small_config(shards, v_max));
        svc.push_chunk(&g.edges.edges);
        let svc_labels = svc.finish().labels();

        // the service sizes its sketch to the max touched id; the batch
        // wrapper pads to n — compare on the service's node range
        assert!(svc_labels.len() <= par_labels.len());
        assert_eq!(svc_labels[..], par_labels[..svc_labels.len()]);
    }

    #[test]
    fn leader_partition_count_is_semantics_free() {
        // K is a deployment-shape knob: the final partition must be
        // bit-identical whatever the leader count (here under the
        // default unbounded horizon; the sharded_leader suite covers
        // the bounded deterministic case at the unit level)
        let g = sbm::generate(&SbmConfig::equal(8, 40, 0.3, 0.01, 41));
        let mut reference: Option<Vec<u32>> = None;
        for leaders in [1usize, 2, 5] {
            let mut cfg = small_config(3, 64);
            cfg.leaders = leaders;
            cfg.drain_every = 200;
            let mut svc = ClusterService::start(cfg);
            svc.push_chunk(&g.edges.edges);
            let labels = svc.finish().snapshot.labels_padded(g.n());
            match &reference {
                None => reference = Some(labels),
                Some(r) => assert_eq!(&labels, r, "leaders={leaders} diverged"),
            }
        }
    }

    #[test]
    fn snapshot_during_ingest_is_a_valid_partition() {
        let g = sbm::generate(&SbmConfig::equal(8, 40, 0.35, 0.005, 11));
        let half = g.m() / 2;
        let mut svc = ClusterService::start(small_config(4, 64));

        svc.push_chunk(&g.edges.edges[..half]);
        let snap = svc.quiesce();
        // exactly the pushed prefix, with all stream-end invariants
        assert_eq!(snap.edges(), half as u64);
        assert_eq!(snap.state().total_volume(), 2 * half as u64);
        let n = snap.state().n();
        assert!(snap.labels().iter().all(|&l| (l as usize) < n));

        // ingest continues unaffected after the snapshot
        svc.push_chunk(&g.edges.edges[half..]);
        let res = svc.finish();
        assert_eq!(res.snapshot.edges(), g.m() as u64);
        assert_eq!(res.state().total_volume(), 2 * g.m() as u64);
    }

    #[test]
    fn backpressure_blocks_rather_than_drops() {
        use std::sync::atomic::AtomicUsize;

        let mut cfg = ServiceConfig::new(1, 8);
        cfg.chunk_size = 1;
        cfg.mailbox_depth = 1;
        cfg.drain_every = u64::MAX;
        let mut svc = ClusterService::start(cfg);
        let shared = Arc::clone(&svc.shared);

        // stall the single worker by holding its state lock
        let stall = shared.states[0].lock().unwrap();

        let progress = Arc::new(AtomicUsize::new(0));
        let progress2 = Arc::clone(&progress);
        let pusher = std::thread::spawn(move || {
            for i in 0..6u32 {
                svc.push(Edge::new(2 * i, 2 * i + 1));
                progress2.store(i as usize + 1, Ordering::SeqCst);
            }
            svc.finish()
        });

        // with depth 1 and the worker stalled, at most ~3 pushes can
        // complete (one chunk in the worker's hands, one queued, one
        // blocked in send); the pusher must NOT finish all 6
        std::thread::sleep(Duration::from_millis(150));
        let made = progress.load(Ordering::SeqCst);
        assert!(made < 6, "pusher should be blocked, got {made}/6 pushes");

        drop(stall); // release the worker → everything drains
        let res = pusher.join().expect("pusher panicked");
        assert_eq!(res.edges_ingested, 6, "blocked edges must not be dropped");
        assert_eq!(res.snapshot.edges(), 6);
    }

    #[test]
    fn automatic_drains_keep_snapshot_fresh() {
        let g = sbm::generate(&SbmConfig::equal(5, 30, 0.4, 0.01, 13));
        let mut cfg = ServiceConfig::new(2, 64);
        cfg.chunk_size = 32;
        cfg.drain_every = 100; // force many automatic drains
        let mut svc = ClusterService::start(cfg);
        let handle = svc.handle();

        assert_eq!(handle.snapshot().edges(), 0);
        svc.push_chunk(&g.edges.edges);
        // at least one drain fired, so the cached snapshot is non-empty
        assert!(handle.snapshot().edges() > 0);
        let res = svc.finish();
        assert_eq!(res.snapshot.edges(), g.m() as u64);
        // the handle now serves the final snapshot
        assert_eq!(handle.snapshot().edges(), g.m() as u64);
    }

    #[test]
    fn direct_ingest_matches_the_funneled_partition_and_accounting() {
        use crate::graph::io::write_binary_edges_with;

        let g = sbm::generate(&SbmConfig::equal(6, 30, 0.4, 0.01, 21));
        let mut path = std::env::temp_dir();
        path.push(format!("streamcom_ingest_direct_{}.bin", std::process::id()));
        write_binary_edges_with(&path, &g.edges, 64).unwrap();

        let mut cfg = small_config(3, 64);
        cfg.initial_nodes = g.n();
        let mut funnel = ClusterService::start(cfg.clone());
        funnel.push_chunk(&g.edges.edges);
        let want = funnel.finish().snapshot.labels_padded(g.n());

        for readers in [1usize, 2, 4] {
            let mut scan = DirectScan::open(&path, readers, 64, 3, None).unwrap();
            let mut svc = ClusterService::start(cfg.clone());
            let ingested = svc.ingest_direct(&mut scan);
            assert_eq!(ingested, g.m() as u64, "readers={readers}");
            assert!(scan.take_error().is_none());
            let res = svc.finish();
            assert_eq!(res.edges_ingested, g.m() as u64);
            // bit-identical to the funneled run at every reader count
            assert_eq!(
                res.snapshot.labels_padded(g.n()),
                want,
                "direct route diverged at readers={readers}"
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn refresh_after_finish_serves_the_terminal_snapshot() {
        let g = sbm::generate(&SbmConfig::equal(4, 25, 0.4, 0.01, 15));
        let mut cfg = ServiceConfig::new(2, 64);
        cfg.drain_every = 50;
        let mut svc = ClusterService::start(cfg);
        let handle = svc.handle();
        svc.push_chunk(&g.edges.edges);
        let res = svc.finish();
        // a late refresh must not clobber (or diverge from) the final
        let snap = handle.refresh();
        assert_eq!(snap.labels(), res.snapshot.labels());
        assert_eq!(handle.snapshot().labels(), res.snapshot.labels());
    }
}
