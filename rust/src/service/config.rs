//! Service configuration.
//!
//! The knobs mirror [`crate::coordinator::parallel::ParallelConfig`]
//! (the batch twin) plus the service-only drain cadence. Defaults are
//! tuned for "ingest a few million edges/s on a laptop while staying
//! queryable": deep enough mailboxes to ride out query-induced stalls,
//! a drain interval short enough that mid-stream answers lag the stream
//! by well under a second.

use crate::coordinator::algorithm::StrConfig;

/// Configuration for a [`crate::service::ClusterService`].
///
/// ```
/// use streamcom::service::ServiceConfig;
///
/// let mut cfg = ServiceConfig::new(4, 64);
/// cfg.chunk_size = 1024; // smaller dispatch batches, lower latency
/// assert_eq!(cfg.shards, 4);
/// assert_eq!(cfg.str_config.v_max, 64);
/// ```
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Number of shard workers (clamped to ≥ 1 at start-up).
    pub shards: usize,
    /// Per-worker streaming configuration (the paper's `v_max` etc.).
    pub str_config: StrConfig,
    /// Bounded mailbox depth per shard, in chunks. When a shard's
    /// mailbox is full, `push` **blocks** — backpressure, never drops.
    pub mailbox_depth: usize,
    /// Edges per dispatched chunk (router-side batching).
    pub chunk_size: usize,
    /// Edges between automatic cross-edge drains: every `drain_every`
    /// pushed edges the service rebuilds its copy-on-read snapshot so
    /// queries see fresh assignments mid-stream. `0` or `u64::MAX`
    /// disables automatic drains (snapshots then only refresh on
    /// demand).
    pub drain_every: u64,
}

impl ServiceConfig {
    /// Service over `shards` workers with the paper's `v_max` threshold
    /// and default batching/drain cadence.
    pub fn new(shards: usize, v_max: u64) -> Self {
        Self {
            shards: shards.max(1),
            str_config: StrConfig::new(v_max),
            mailbox_depth: 8,
            chunk_size: 4_096,
            drain_every: 262_144,
        }
    }

    /// Batch preset: automatic drains disabled, so the terminal replay
    /// in `ClusterService::finish` is the only merge — exactly the
    /// one-shot semantics of `coordinator::parallel::run_parallel`,
    /// which is implemented as this preset over the service.
    pub fn batch(shards: usize, v_max: u64) -> Self {
        let mut cfg = Self::new(shards, v_max);
        cfg.drain_every = 0; // 0 = disabled (normalised at start-up)
        cfg
    }
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self::new(4, 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let cfg = ServiceConfig::default();
        assert!(cfg.shards >= 1);
        assert!(cfg.mailbox_depth >= 1);
        assert!(cfg.chunk_size >= 1);
        assert!(cfg.drain_every > cfg.chunk_size as u64);
    }

    #[test]
    fn zero_shards_clamped() {
        assert_eq!(ServiceConfig::new(0, 8).shards, 1);
    }

    #[test]
    fn batch_preset_disables_automatic_drains() {
        let cfg = ServiceConfig::batch(4, 64);
        assert_eq!(cfg.drain_every, 0);
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.str_config.v_max, 64);
    }
}
