//! Service configuration.
//!
//! The knobs mirror [`crate::coordinator::parallel::ParallelConfig`]
//! (the batch twin) plus the service-only drain cadence. Defaults are
//! tuned for "ingest a few million edges/s on a laptop while staying
//! queryable": deep enough mailboxes to ride out query-induced stalls,
//! a drain interval short enough that mid-stream answers lag the stream
//! by well under a second.

use std::path::PathBuf;

use crate::coordinator::algorithm::StrConfig;
use crate::service::wal::FailPoint;

/// Finality policy for the service's epoch-structured cross-edge log
/// (`service::crosslog`).
///
/// Cross-shard edges are buffered for deferred replay. The horizon
/// decides how long they stay resident:
///
/// * [`Unbounded`](CommitHorizon::Unbounded) — every cross edge is
///   retained until [`finish`](crate::service::ClusterService::finish),
///   whose terminal replay re-decides the **whole** history against the
///   final shard sketches. The final partition is bit-identical to the
///   batch coordinator and independent of the drain cadence — exactly
///   the semantics the golden and property suites pin. Memory grows
///   with the cross fraction of the stream.
/// * [`Edges(h)`](CommitHorizon::Edges) — once a sealed epoch of the
///   cross log falls more than `h` cross edges behind the head *and*
///   its edges have been drained, its replay decisions become **final**:
///   their frozen degree/community effects are folded into the leader's
///   persistent committed base and the epoch's storage is freed.
///   Retained cross-edge memory is then bounded by `h` plus one epoch,
///   at the cost of exact batch parity: `finish` replays only the
///   uncommitted tail over the committed base, so the final partition
///   can differ (bounded in practice — the golden-stream suite asserts
///   modularity within 2% of the unbounded run). Mid-stream decisions
///   depend on when drains happen, so a bounded horizon is also not
///   drain-cadence independent.
///
/// `Edges(0)` is normalised to `Unbounded` at service start-up
/// (mirroring the CLI's `0 = disabled` convention for `--horizon`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CommitHorizon {
    /// Retain all cross edges; terminal replay covers the full history
    /// (bit-identical to batch, drain-cadence independent). The default.
    #[default]
    Unbounded,
    /// Cross edges more than this many cross edges behind the log head
    /// become final once drained; their storage is freed.
    Edges(u64),
}

impl CommitHorizon {
    /// True when no cross edge is ever committed early.
    pub fn is_unbounded(&self) -> bool {
        matches!(self, CommitHorizon::Unbounded)
    }

    /// Map the CLI convention `Edges(0)` onto `Unbounded`.
    pub(crate) fn normalized(self) -> Self {
        match self {
            CommitHorizon::Edges(0) => CommitHorizon::Unbounded,
            other => other,
        }
    }
}

/// How scanned edges travel from reader threads into shard workers
/// (`--route` on the CLI; resolved by the serve command, not stored in
/// [`ServiceConfig`] — it is a property of the ingest path, not of the
/// service state).
///
/// * `Auto` — direct dispatch whenever the input supports it
///   (segmented binary or mmap scan, no pacing, no `--resume`);
///   funnel otherwise, with a printed note. The default. `--wal-dir`
///   no longer forces the funnel: direct readers write their own
///   per-reader WAL lanes ([`crate::service::DirectWalCfg`]).
/// * `Direct` — require direct dispatch
///   ([`crate::stream::pscan::DirectScan`] +
///   [`crate::service::ClusterService::ingest_direct`]); the CLI
///   fails fast when the input cannot support it (text input,
///   pacing, resume's positional slicing).
/// * `Funnel` — always use the ordered single-stream sequencer
///   ([`crate::stream::pscan::ParallelScanner`]), the only mode that
///   yields a global arrival stream for pacing and resume.
///
/// Both modes produce bit-identical final partitions in the exactness
/// domains — the routing-mode property suite pins it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouteMode {
    /// Pick direct when the input supports it, funnel otherwise.
    #[default]
    Auto,
    /// Require reader-side routing; fail fast when unsupported.
    Direct,
    /// Always funnel through the ordered single-stream sequencer.
    Funnel,
}

impl RouteMode {
    /// Parse the CLI spelling (`auto`, `direct`, `funnel`).
    pub fn parse(s: &str) -> Option<RouteMode> {
        match s {
            "auto" => Some(RouteMode::Auto),
            "direct" => Some(RouteMode::Direct),
            "funnel" => Some(RouteMode::Funnel),
            _ => None,
        }
    }

    /// The CLI spelling, for stats footers.
    pub fn name(self) -> &'static str {
        match self {
            RouteMode::Auto => "auto",
            RouteMode::Direct => "direct",
            RouteMode::Funnel => "funnel",
        }
    }
}

/// Configuration for a [`crate::service::ClusterService`].
///
/// ```
/// use streamcom::service::ServiceConfig;
///
/// let mut cfg = ServiceConfig::new(4, 64);
/// cfg.chunk_size = 1024; // smaller dispatch batches, lower latency
/// assert_eq!(cfg.shards, 4);
/// assert_eq!(cfg.str_config.v_max, 64);
/// ```
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Number of shard workers (clamped to ≥ 1 at start-up).
    pub shards: usize,
    /// Number of leader partitions the cross log's frozen decisions and
    /// the committed base are sharded across (node-range ownership via
    /// `stream::shard::shard_of(node, leaders)`). `0` — the default —
    /// means **one leader partition per shard worker**, so each
    /// worker's node range owns exactly its own slice of the committed
    /// base; normalised at start-up. The partition count never changes
    /// results (only where committed state lives — property-tested), so
    /// this is a deployment-shape knob, not a semantics knob.
    pub leaders: usize,
    /// Per-worker streaming configuration (the paper's `v_max` etc.).
    pub str_config: StrConfig,
    /// Bounded mailbox depth per shard, in chunks. When a shard's
    /// mailbox is full, `push` **blocks** — backpressure, never drops.
    pub mailbox_depth: usize,
    /// Edges per dispatched chunk (router-side batching).
    pub chunk_size: usize,
    /// Edges between automatic cross-edge drains: every `drain_every`
    /// pushed edges the service rebuilds its copy-on-read snapshot so
    /// queries see fresh assignments mid-stream. `0` or `u64::MAX`
    /// disables automatic drains (snapshots then only refresh on
    /// demand).
    pub drain_every: u64,
    /// Finality policy for the cross-edge log: how far behind the log
    /// head a drained epoch may fall before its decisions are committed
    /// and its edge storage freed. See [`CommitHorizon`].
    pub horizon: CommitHorizon,
    /// Durability directory. `None` — the default — keeps the service
    /// purely in memory, bit-identical to every pre-durability
    /// behaviour. `Some(dir)` appends every ingested edge to a
    /// per-shard write-ahead log under `dir` before dispatch, writes an
    /// epoch-aligned checkpoint whenever the cross log commits an epoch
    /// at a quiesced cut, and lets `ClusterService::resume` restart
    /// from the latest checkpoint plus the WAL suffix past it.
    pub wal_dir: Option<PathBuf>,
    /// Records per WAL segment file. Whole segments below a checkpoint
    /// cut are deleted, so smaller segments reclaim disk sooner at the
    /// cost of more files (clamped to ≥ 1 at start-up).
    pub wal_segment_records: u64,
    /// Crash-injection hook for the recovery harness; the default is
    /// never armed and costs one atomic load per durable write. Clones
    /// of the config share the hook.
    pub failpoint: FailPoint,
    /// Pre-size every worker sketch to this many nodes at start-up
    /// (`0` — the default — starts empty and grows on demand). File
    /// ingest sets it from the binary header's `n`, so workers never
    /// grow their degree/community/volume arrays mid-stream: the
    /// per-chunk `ensure` becomes a no-op branch for the whole scan.
    /// A perf knob, not a semantics knob — unseen nodes label as
    /// singletons either way, so the partition is unchanged; only the
    /// label-vector *length* reflects the pre-size (compare via
    /// `Snapshot::labels_padded` when mixing seeded/unseeded runs).
    pub initial_nodes: usize,
}

impl ServiceConfig {
    /// Service over `shards` workers with the paper's `v_max` threshold
    /// and default batching/drain cadence.
    pub fn new(shards: usize, v_max: u64) -> Self {
        Self {
            shards: shards.max(1),
            leaders: 0, // 0 = one leader partition per shard
            str_config: StrConfig::new(v_max),
            mailbox_depth: 8,
            chunk_size: 4_096,
            drain_every: 262_144,
            horizon: CommitHorizon::Unbounded,
            wal_dir: None,
            wal_segment_records: 65_536,
            failpoint: FailPoint::default(),
            initial_nodes: 0,
        }
    }

    /// The direct-route durability wiring, when `wal_dir` is set: the
    /// [`DirectWalCfg`](crate::service::DirectWalCfg) handed to
    /// [`DirectScan::open`](crate::stream::pscan::DirectScan::open) so
    /// each reader thread writes its own per-reader WAL lanes. Carries
    /// a fresh shared byte counter;
    /// [`ingest_direct`](crate::service::ClusterService::ingest_direct)
    /// polls it into the service stats. Call on the **same** config the
    /// service runs with (shared `failpoint`), after
    /// `ClusterService::start` (which prepares the directory).
    pub fn direct_wal_cfg(&self) -> Option<crate::service::wal::DirectWalCfg> {
        self.wal_dir.as_ref().map(|dir| crate::service::wal::DirectWalCfg {
            dir: dir.clone(),
            segment_records: self.wal_segment_records.max(1),
            shards: self.shards.max(1),
            failpoint: self.failpoint.clone(),
            bytes: std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0)),
        })
    }

    /// Batch preset: automatic drains disabled, so the terminal replay
    /// in `ClusterService::finish` is the only merge — exactly the
    /// one-shot semantics of `coordinator::parallel::run_parallel`,
    /// which is implemented as this preset over the service. The
    /// horizon is pinned to [`CommitHorizon::Unbounded`]: batch
    /// semantics *are* the full-history terminal replay, so a bounded
    /// horizon would change what `run_parallel` means.
    pub fn batch(shards: usize, v_max: u64) -> Self {
        let mut cfg = Self::new(shards, v_max);
        cfg.drain_every = 0; // 0 = disabled (normalised at start-up)
        cfg.horizon = CommitHorizon::Unbounded;
        cfg
    }
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self::new(4, 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let cfg = ServiceConfig::default();
        assert!(cfg.shards >= 1);
        assert!(cfg.mailbox_depth >= 1);
        assert!(cfg.chunk_size >= 1);
        assert!(cfg.drain_every > cfg.chunk_size as u64);
    }

    #[test]
    fn zero_shards_clamped() {
        assert_eq!(ServiceConfig::new(0, 8).shards, 1);
    }

    #[test]
    fn batch_preset_disables_automatic_drains() {
        let cfg = ServiceConfig::batch(4, 64);
        assert_eq!(cfg.drain_every, 0);
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.str_config.v_max, 64);
    }

    #[test]
    fn batch_preset_pins_unbounded_horizon() {
        // batch ≡ full-history terminal replay; a bounded horizon would
        // silently change run_parallel's semantics
        assert!(ServiceConfig::batch(4, 64).horizon.is_unbounded());
        assert!(ServiceConfig::default().horizon.is_unbounded());
    }

    #[test]
    fn leaders_default_to_follow_shards() {
        // 0 = "one leader partition per shard", resolved at start-up so
        // changing `shards` after construction still tracks
        assert_eq!(ServiceConfig::new(4, 64).leaders, 0);
        assert_eq!(ServiceConfig::batch(4, 64).leaders, 0);
    }

    #[test]
    fn sketches_start_empty_unless_seeded() {
        // initial_nodes is the file-ingest fast path; the in-memory
        // default must stay grow-on-demand so label-vector lengths of
        // existing callers are unchanged
        assert_eq!(ServiceConfig::new(4, 64).initial_nodes, 0);
        assert_eq!(ServiceConfig::batch(4, 64).initial_nodes, 0);
    }

    #[test]
    fn route_mode_parses_the_cli_spellings_and_round_trips() {
        for (s, m) in [
            ("auto", RouteMode::Auto),
            ("direct", RouteMode::Direct),
            ("funnel", RouteMode::Funnel),
        ] {
            assert_eq!(RouteMode::parse(s), Some(m));
            assert_eq!(m.name(), s);
        }
        assert_eq!(RouteMode::parse("express"), None);
        assert_eq!(RouteMode::default(), RouteMode::Auto);
    }

    #[test]
    fn zero_edge_horizon_normalises_to_unbounded() {
        assert_eq!(
            CommitHorizon::Edges(0).normalized(),
            CommitHorizon::Unbounded
        );
        assert_eq!(
            CommitHorizon::Edges(7).normalized(),
            CommitHorizon::Edges(7)
        );
        assert!(!CommitHorizon::Edges(7).is_unbounded());
    }
}
