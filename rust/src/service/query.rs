//! Read path: point lookups, top-k summaries, and the stats endpoint.
//!
//! A [`QueryHandle`] is a cheap cloneable reference into the service's
//! shared state. Queries read the latest copy-on-read [`Snapshot`] —
//! they never touch the shard workers' hot loop, so read traffic cannot
//! slow ingestion (the only shared-state contact is one `RwLock` read
//! of an `Arc`). Stats follow the same rule: memory figures come from
//! the published snapshot, queue depths from the mailbox channels,
//! throughput from the `stream::meter` instance the router feeds, the
//! drain and delta-payload counters from atomics the drain path
//! maintains, the cross-log occupancy (retained/committed/freed, global
//! and per leader partition) from one brief lock of the log's own
//! mutex, and each leader shard's committed bytes from one brief lock
//! of that shard alone — never from the workers' own state locks, and
//! never nested.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use super::bufpool::PoolStats;
use super::config::CommitHorizon;
use super::ingest::{rebuild_snapshot, Shared};
use super::snapshot::{CommunitySummary, Snapshot};

/// Cloneable read handle onto a running (or finished) service.
#[derive(Clone)]
pub struct QueryHandle {
    shared: Arc<Shared>,
}

/// Byte accounting for one leader partition (node-range slice of the
/// cross log + committed base). Makes the sharded-leader claim
/// observable: drains move bytes from `retained` into `committed` +
/// `freed` without the per-drain payload ever scaling with `committed`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeaderStats {
    /// Resident cross-log bytes owned by this partition (retained edges
    /// attributed to its node range + its frozen record slices).
    pub retained_bytes: u64,
    /// Committed-base bytes this partition carries (frozen decision
    /// records folded into its base slice — what a fresh replica would
    /// fetch to adopt the slice).
    pub committed_bytes: u64,
    /// Bytes this partition's commits have released.
    pub freed_bytes: u64,
}

/// Point-in-time operational statistics.
#[derive(Debug, Clone)]
pub struct ServiceStats {
    /// Shard worker count.
    pub shards: usize,
    /// Leader partition count (committed base + frozen records are
    /// sharded across these by node range).
    pub leaders: usize,
    /// The service's commit horizon, post-normalisation (`Edges(0)` at
    /// start-up reads back as `Unbounded`).
    pub horizon: CommitHorizon,
    /// Edges accepted by the router so far.
    pub edges_ingested: u64,
    /// Cross-shard edges accepted over the service's lifetime —
    /// includes the router's still-buffered partial batch, so a stats
    /// read between batches never undercounts accepted edges.
    pub cross_total: u64,
    /// Cross edges not yet integrated into the published snapshot:
    /// logged-but-undrained plus the router's buffered partial batch
    /// (`cross_buffered`).
    pub cross_pending: u64,
    /// Cross edges accepted by the router but not yet appended to the
    /// cross log (its local partial batch — what `stats()` before a
    /// `flush()` used to omit entirely).
    pub cross_buffered: u64,
    /// Cross edges the drains have integrated so far (the merger's
    /// cursor into the cross log).
    pub cross_drained: u64,
    /// Cross edges currently resident in the epoch log. Bounded by
    /// `horizon + cross_epoch_len` under `CommitHorizon::Edges`
    /// (asserted by the boundedness suite); grows with the stream under
    /// `Unbounded`.
    pub cross_retained: u64,
    /// Cross edges whose decisions became final: folded into the
    /// leaders' committed-base slices, their storage freed.
    pub cross_committed: u64,
    /// Resident bytes of the cross log (edges + frozen decision
    /// records).
    pub cross_log_bytes: u64,
    /// Bytes released by committed (freed) epochs so far.
    pub cross_freed_bytes: u64,
    /// Edges per cross-log epoch (the `+ one epoch` slack in the
    /// retention bound).
    pub cross_epoch_len: u64,
    /// Cross-log epochs sealed so far.
    pub epochs_sealed: u64,
    /// Cross-log epochs committed (finalized and freed) so far.
    pub epochs_committed: u64,
    /// Per-leader-partition byte accounting
    /// (retained/committed/freed); entries sum to the corresponding
    /// globals.
    pub per_leader: Vec<LeaderStats>,
    /// Snapshot drains performed so far.
    pub drains: u64,
    /// Cross edges replayed by the most recent drain — with the
    /// incremental merger this is only what arrived since the previous
    /// drain, not the whole buffer.
    pub cross_replayed_last_drain: u64,
    /// Σ cross edges replayed across all snapshot drains. The
    /// incremental-replay guarantee is `cross_replayed_total ==
    /// cross_drained`: every cross edge is replayed exactly once by the
    /// snapshot path, however many drains happen (asserted by the
    /// service test-suite).
    pub cross_replayed_total: u64,
    /// Delta payload of the most recent drain: the bytes a
    /// cross-process drain would ship (replayed suffix + frozen
    /// records + per-epoch commit headers). O(new epoch deltas) by
    /// construction — independent of the committed-base size, which is
    /// the sharded-leader scaling claim (asserted by the
    /// sharded-leader suite).
    pub delta_last_bytes: u64,
    /// Σ delta payload across all drains.
    pub delta_total_bytes: u64,
    /// Ingest throughput over the service lifetime (edges/s).
    pub edges_per_sec: f64,
    /// Time since the service started.
    pub uptime: Duration,
    /// Current chunks queued per shard mailbox.
    pub queue_depths: Vec<usize>,
    /// High-water mark of each shard mailbox (backpressure indicator).
    pub queue_peaks: Vec<usize>,
    /// Chunks handed to shard mailboxes over the service's lifetime
    /// (with the batch spine, router-side atomic RMWs are one per
    /// ingest batch plus one per dispatched chunk — not per edge).
    pub chunks_dispatched: u64,
    /// Chunk-buffer pool counters: the shelf is prewarmed to the
    /// in-flight bound at boot, so steady-state zero-allocation ingest
    /// shows up as `misses == 0` while `hits` keeps growing (asserted
    /// by the service integration suite).
    pub pool: PoolStats,
    /// Bytes appended to the write-ahead log by this process (0 when
    /// durability is off). After a resume this restarts at 0 — it
    /// measures what *this* process wrote, which together with
    /// `wal_recovered_edges` proves recovery did not re-log the
    /// checkpointed prefix.
    pub wal_bytes: u64,
    /// Checkpoints written by this process (epoch-aligned, at quiesced
    /// cuts; 0 under `CommitHorizon::Unbounded`, where no epoch ever
    /// commits).
    pub checkpoints_written: u64,
    /// Cross-log epochs covered by the latest durable checkpoint.
    pub last_checkpoint_epoch: u64,
    /// Epochs already committed in the checkpoint this service resumed
    /// from (0 for a fresh start) — proves recovery adopted the
    /// checkpointed history instead of recomputing it.
    pub recovered_epochs: u64,
    /// WAL records replayed past the checkpoint cut during resume —
    /// proves recovery replayed only the suffix, not the full stream.
    pub wal_recovered_edges: u64,
    /// Edges covered by the currently-published snapshot (query lag =
    /// `edges_ingested - snapshot_edges`).
    pub snapshot_edges: u64,
    /// Sketch bytes of the published snapshot's merged state (the live
    /// shard states hold roughly the same again, split across workers).
    pub memory_bytes: usize,
    /// Node-id space size of the published snapshot.
    pub nodes: usize,
}

impl ServiceStats {
    /// Snapshot sketch bytes per node of id space (the paper's "three
    /// integers per node": 16 B).
    pub fn bytes_per_node(&self) -> f64 {
        if self.nodes == 0 {
            0.0
        } else {
            self.memory_bytes as f64 / self.nodes as f64
        }
    }

    /// Committed-base bytes summed across the leader partitions.
    pub fn committed_bytes_total(&self) -> u64 {
        self.per_leader.iter().map(|l| l.committed_bytes).sum()
    }
}

impl QueryHandle {
    pub(crate) fn new(shared: Arc<Shared>) -> Self {
        Self { shared }
    }

    /// The latest published snapshot (copy-on-read: an `Arc` clone, no
    /// data copy, no contact with the ingest path).
    pub fn snapshot(&self) -> Arc<Snapshot> {
        Arc::clone(&self.shared.snapshot.read().unwrap())
    }

    /// Force an incremental drain from the live shard states. Unlike
    /// `ClusterService::refresh`, this cannot flush the router's batch
    /// buffers (it has no access to them), so it covers dispatched
    /// edges only. After `finish` it simply returns the terminal
    /// snapshot.
    pub fn refresh(&self) -> Arc<Snapshot> {
        rebuild_snapshot(&self.shared)
    }

    /// Community of `node` in the latest snapshot.
    pub fn community_of(&self, node: u32) -> u32 {
        self.snapshot().community_of(node)
    }

    /// The `k` largest communities in the latest snapshot.
    pub fn top_communities(&self, k: usize) -> Vec<CommunitySummary> {
        self.snapshot().top_communities(k)
    }

    /// Sample the service's operational stats.
    pub fn stats(&self) -> ServiceStats {
        let report = self.shared.meter.lock().unwrap().snapshot();
        let snap = self.snapshot();
        let queue_depths: Vec<usize> =
            self.shared.mailboxes.iter().map(|m| m.len()).collect();
        let mut queue_peaks = Vec::with_capacity(self.shared.mailboxes.len());
        let mut chunks_dispatched = 0u64;
        for m in &self.shared.mailboxes {
            let (peak, pushed, _) = m.stats();
            queue_peaks.push(peak);
            chunks_dispatched += pushed;
        }
        // memory comes from the published snapshot, not the live shard
        // states — stats must never contend with the workers' hot loop
        let memory_bytes = snap.memory_bytes();
        let nodes = snap.state().n();
        let (
            cross_total,
            cross_retained,
            cross_committed,
            cross_log_bytes,
            cross_freed_bytes,
            cross_epoch_len,
            epochs_sealed,
            epochs_committed,
            retained_per_leader,
            freed_per_leader,
        ) = {
            let log = self.shared.crosslog.lock().unwrap();
            (
                log.appended(),
                log.retained_edges(),
                log.committed_edges(),
                log.retained_bytes(),
                log.freed_bytes(),
                log.epoch_len(),
                log.epochs_sealed(),
                log.epochs_committed(),
                log.retained_bytes_per_leader(),
                log.freed_bytes_per_leader(),
            )
        };
        // one brief lock per leader shard, never nested under the log
        let per_leader: Vec<LeaderStats> = retained_per_leader
            .into_iter()
            .zip(freed_per_leader)
            .zip(&self.shared.leaders)
            .map(|((retained_bytes, freed_bytes), shard)| LeaderStats {
                retained_bytes,
                committed_bytes: shard.lock().unwrap().committed_bytes(),
                freed_bytes,
            })
            .collect();
        let cross_drained = self.shared.cross_drained.load(Ordering::Relaxed);
        // fold the router's still-buffered partial batch in: a stats
        // read between batches must count every accepted cross edge,
        // not just the flushed ones (the PR 9 footgun)
        let cross_buffered = self.shared.cross_buffered.load(Ordering::Relaxed);
        let cross_total = cross_total + cross_buffered;
        ServiceStats {
            shards: self.shared.config.shards,
            leaders: self.shared.config.leaders,
            horizon: self.shared.config.horizon,
            edges_ingested: self.shared.ingested.load(Ordering::Relaxed),
            cross_total,
            cross_pending: cross_total.saturating_sub(cross_drained),
            cross_buffered,
            cross_drained,
            cross_retained,
            cross_committed,
            cross_log_bytes,
            cross_freed_bytes,
            cross_epoch_len,
            epochs_sealed,
            epochs_committed,
            per_leader,
            drains: self.shared.drains.load(Ordering::Relaxed),
            cross_replayed_last_drain: self.shared.replayed_last.load(Ordering::Relaxed),
            cross_replayed_total: self.shared.replayed_total.load(Ordering::Relaxed),
            delta_last_bytes: self.shared.delta_last_bytes.load(Ordering::Relaxed),
            delta_total_bytes: self.shared.delta_total_bytes.load(Ordering::Relaxed),
            edges_per_sec: report.edges_per_sec(),
            uptime: report.elapsed,
            queue_depths,
            queue_peaks,
            chunks_dispatched,
            pool: self.shared.bufpool.stats(),
            wal_bytes: self.shared.wal_bytes.load(Ordering::Relaxed),
            checkpoints_written: self.shared.checkpoints_written.load(Ordering::Relaxed),
            last_checkpoint_epoch: self.shared.last_checkpoint_epoch.load(Ordering::Relaxed),
            recovered_epochs: self.shared.recovered_epochs.load(Ordering::Relaxed),
            wal_recovered_edges: self.shared.wal_recovered_edges.load(Ordering::Relaxed),
            snapshot_edges: snap.edges(),
            memory_bytes,
            nodes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::{CommitHorizon, ServiceConfig};
    use super::super::ingest::ClusterService;
    use crate::graph::generators::sbm::{self, SbmConfig};

    #[test]
    fn stats_reflect_ingest_and_queues() {
        let g = sbm::generate(&SbmConfig::equal(6, 30, 0.4, 0.01, 17));
        let mut cfg = ServiceConfig::new(3, 64);
        cfg.chunk_size = 64;
        cfg.drain_every = u64::MAX;
        let mut svc = ClusterService::start(cfg);
        let handle = svc.handle();

        svc.push_chunk(&g.edges.edges);
        svc.quiesce();
        let s = handle.stats();
        assert_eq!(s.shards, 3);
        assert_eq!(s.leaders, 3, "leaders=0 must resolve to one per shard");
        assert_eq!(s.per_leader.len(), 3);
        assert!(s.horizon.is_unbounded());
        assert_eq!(s.edges_ingested, g.m() as u64);
        assert_eq!(s.queue_depths.len(), 3);
        assert_eq!(s.snapshot_edges, g.m() as u64);
        // the quiesce drained everything that was buffered
        assert_eq!(s.cross_pending, 0);
        assert_eq!(s.cross_drained, s.cross_total);
        // unbounded horizon: the whole log stays resident, nothing is
        // ever committed or freed — globally and per leader partition
        assert_eq!(s.cross_retained, s.cross_total);
        assert_eq!(s.cross_committed, 0);
        assert_eq!(s.cross_freed_bytes, 0);
        assert_eq!(s.epochs_committed, 0);
        assert_eq!(s.committed_bytes_total(), 0);
        assert_eq!(
            s.per_leader.iter().map(|l| l.retained_bytes).sum::<u64>(),
            s.cross_log_bytes,
            "per-leader retained bytes must partition the log"
        );
        // the drain shipped the replayed suffix as its delta payload
        assert!(s.drains >= 1);
        assert_eq!(s.delta_total_bytes, s.cross_replayed_total * 8);
        // durability off: every WAL/checkpoint counter stays zero
        assert_eq!(s.wal_bytes, 0);
        assert_eq!(s.checkpoints_written, 0);
        assert_eq!(s.last_checkpoint_epoch, 0);
        assert_eq!(s.recovered_epochs, 0);
        assert_eq!(s.wal_recovered_edges, 0);
        assert!(s.memory_bytes > 0);
        assert!(s.bytes_per_node() >= 16.0, "{}", s.bytes_per_node());
        assert!(s.uptime.as_nanos() > 0);
        svc.finish();
    }

    #[test]
    fn explicit_leader_count_and_zero_horizon_normalisation_show_in_stats() {
        // Edges(0) is the CLI's "unbounded" spelling; start-up must
        // normalise it, and the leaders knob must be taken as given
        let mut cfg = ServiceConfig::new(2, 64);
        cfg.leaders = 5;
        cfg.horizon = CommitHorizon::Edges(0);
        let svc = ClusterService::start(cfg);
        let s = svc.handle().stats();
        assert_eq!(s.leaders, 5);
        assert_eq!(s.per_leader.len(), 5);
        assert!(s.horizon.is_unbounded());
        assert_eq!(s.horizon, CommitHorizon::Unbounded);
    }

    #[test]
    fn community_of_matches_snapshot_labels() {
        let g = sbm::generate(&SbmConfig::equal(5, 30, 0.4, 0.01, 19));
        let mut cfg = ServiceConfig::new(2, 64);
        cfg.chunk_size = 32;
        let mut svc = ClusterService::start(cfg);
        let handle = svc.handle();
        svc.push_chunk(&g.edges.edges);
        svc.quiesce();
        let snap = handle.snapshot();
        let labels = snap.labels();
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(handle.community_of(i as u32), l, "node {i}");
        }
        // unseen ids beyond the sketch are singletons
        let big = (labels.len() as u32) + 1000;
        assert_eq!(handle.community_of(big), big);
        svc.finish();
    }

    #[test]
    fn handles_survive_finish() {
        let g = sbm::generate(&SbmConfig::equal(4, 25, 0.4, 0.01, 23));
        let mut svc = ClusterService::start(ServiceConfig::new(2, 64));
        let handle = svc.handle();
        svc.push_chunk(&g.edges.edges);
        let res = svc.finish();
        assert_eq!(handle.snapshot().edges(), res.snapshot.edges());
        assert_eq!(handle.stats().edges_ingested, g.m() as u64);
    }
}
