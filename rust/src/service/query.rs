//! Read path: point lookups, top-k summaries, and the stats endpoint.
//!
//! A [`QueryHandle`] is a cheap cloneable reference into the service's
//! shared state. Queries read the latest copy-on-read [`Snapshot`] —
//! they never touch the shard workers' hot loop, so read traffic cannot
//! slow ingestion (the only shared-state contact is one `RwLock` read
//! of an `Arc`). Stats follow the same rule: memory figures come from
//! the published snapshot, queue depths from the mailbox channels,
//! throughput from the `stream::meter` instance the router feeds, the
//! drain counters from atomics the drain path maintains, and the
//! cross-log occupancy (retained/committed/freed) from one brief lock
//! of the log's own mutex — never from the workers' own state locks.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use super::ingest::{rebuild_snapshot, Shared};
use super::snapshot::{CommunitySummary, Snapshot};

/// Cloneable read handle onto a running (or finished) service.
#[derive(Clone)]
pub struct QueryHandle {
    shared: Arc<Shared>,
}

/// Point-in-time operational statistics.
#[derive(Debug, Clone)]
pub struct ServiceStats {
    /// Shard worker count.
    pub shards: usize,
    /// Edges accepted by the router so far.
    pub edges_ingested: u64,
    /// Cross-shard edges logged over the service's lifetime.
    pub cross_total: u64,
    /// Cross edges not yet integrated into the published snapshot
    /// (awaiting the next incremental drain).
    pub cross_pending: u64,
    /// Cross edges the drains have integrated so far (the persistent
    /// leader's cursor into the cross log).
    pub cross_drained: u64,
    /// Cross edges currently resident in the epoch log. Bounded by
    /// `horizon + cross_epoch_len` under `CommitHorizon::Edges`
    /// (asserted by the boundedness suite); grows with the stream under
    /// `Unbounded`.
    pub cross_retained: u64,
    /// Cross edges whose decisions became final: folded into the
    /// committed base, their storage freed.
    pub cross_committed: u64,
    /// Resident bytes of the cross log (edges + frozen decision
    /// records).
    pub cross_log_bytes: u64,
    /// Bytes released by committed (freed) epochs so far.
    pub cross_freed_bytes: u64,
    /// Edges per cross-log epoch (the `+ one epoch` slack in the
    /// retention bound).
    pub cross_epoch_len: u64,
    /// Cross-log epochs sealed so far.
    pub epochs_sealed: u64,
    /// Cross-log epochs committed (finalized and freed) so far.
    pub epochs_committed: u64,
    /// Snapshot drains performed so far.
    pub drains: u64,
    /// Cross edges replayed by the most recent drain — with the
    /// incremental leader this is only what arrived since the previous
    /// drain, not the whole buffer.
    pub cross_replayed_last_drain: u64,
    /// Σ cross edges replayed across all snapshot drains. The
    /// incremental-replay guarantee is `cross_replayed_total ==
    /// cross_drained`: every cross edge is replayed exactly once by the
    /// snapshot path, however many drains happen (asserted by the
    /// service test-suite).
    pub cross_replayed_total: u64,
    /// Ingest throughput over the service lifetime (edges/s).
    pub edges_per_sec: f64,
    /// Time since the service started.
    pub uptime: Duration,
    /// Current chunks queued per shard mailbox.
    pub queue_depths: Vec<usize>,
    /// High-water mark of each shard mailbox (backpressure indicator).
    pub queue_peaks: Vec<usize>,
    /// Edges covered by the currently-published snapshot (query lag =
    /// `edges_ingested - snapshot_edges`).
    pub snapshot_edges: u64,
    /// Sketch bytes of the published snapshot's merged state (the live
    /// shard states hold roughly the same again, split across workers).
    pub memory_bytes: usize,
    /// Node-id space size of the published snapshot.
    pub nodes: usize,
}

impl ServiceStats {
    /// Snapshot sketch bytes per node of id space (the paper's "three
    /// integers per node": 16 B).
    pub fn bytes_per_node(&self) -> f64 {
        if self.nodes == 0 {
            0.0
        } else {
            self.memory_bytes as f64 / self.nodes as f64
        }
    }
}

impl QueryHandle {
    pub(crate) fn new(shared: Arc<Shared>) -> Self {
        Self { shared }
    }

    /// The latest published snapshot (copy-on-read: an `Arc` clone, no
    /// data copy, no contact with the ingest path).
    pub fn snapshot(&self) -> Arc<Snapshot> {
        Arc::clone(&self.shared.snapshot.read().unwrap())
    }

    /// Force an incremental drain from the live shard states. Unlike
    /// `ClusterService::refresh`, this cannot flush the router's batch
    /// buffers (it has no access to them), so it covers dispatched
    /// edges only. After `finish` it simply returns the terminal
    /// snapshot.
    pub fn refresh(&self) -> Arc<Snapshot> {
        rebuild_snapshot(&self.shared)
    }

    /// Community of `node` in the latest snapshot.
    pub fn community_of(&self, node: u32) -> u32 {
        self.snapshot().community_of(node)
    }

    /// The `k` largest communities in the latest snapshot.
    pub fn top_communities(&self, k: usize) -> Vec<CommunitySummary> {
        self.snapshot().top_communities(k)
    }

    /// Sample the service's operational stats.
    pub fn stats(&self) -> ServiceStats {
        let report = self.shared.meter.lock().unwrap().snapshot();
        let snap = self.snapshot();
        let queue_depths: Vec<usize> =
            self.shared.mailboxes.iter().map(|m| m.len()).collect();
        let queue_peaks: Vec<usize> =
            self.shared.mailboxes.iter().map(|m| m.stats().0).collect();
        // memory comes from the published snapshot, not the live shard
        // states — stats must never contend with the workers' hot loop
        let memory_bytes = snap.memory_bytes();
        let nodes = snap.state().n();
        let (
            cross_total,
            cross_retained,
            cross_committed,
            cross_log_bytes,
            cross_freed_bytes,
            cross_epoch_len,
            epochs_sealed,
            epochs_committed,
        ) = {
            let log = self.shared.crosslog.lock().unwrap();
            (
                log.appended(),
                log.retained_edges(),
                log.committed_edges(),
                log.retained_bytes(),
                log.freed_bytes(),
                log.epoch_len(),
                log.epochs_sealed(),
                log.epochs_committed(),
            )
        };
        let cross_drained = self.shared.cross_drained.load(Ordering::Relaxed);
        ServiceStats {
            shards: self.shared.config.shards,
            edges_ingested: self.shared.ingested.load(Ordering::Relaxed),
            cross_total,
            cross_pending: cross_total.saturating_sub(cross_drained),
            cross_drained,
            cross_retained,
            cross_committed,
            cross_log_bytes,
            cross_freed_bytes,
            cross_epoch_len,
            epochs_sealed,
            epochs_committed,
            drains: self.shared.drains.load(Ordering::Relaxed),
            cross_replayed_last_drain: self.shared.replayed_last.load(Ordering::Relaxed),
            cross_replayed_total: self.shared.replayed_total.load(Ordering::Relaxed),
            edges_per_sec: report.edges_per_sec(),
            uptime: report.elapsed,
            queue_depths,
            queue_peaks,
            snapshot_edges: snap.edges(),
            memory_bytes,
            nodes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::ServiceConfig;
    use super::super::ingest::ClusterService;
    use crate::graph::generators::sbm::{self, SbmConfig};

    #[test]
    fn stats_reflect_ingest_and_queues() {
        let g = sbm::generate(&SbmConfig::equal(6, 30, 0.4, 0.01, 17));
        let mut cfg = ServiceConfig::new(3, 64);
        cfg.chunk_size = 64;
        cfg.drain_every = u64::MAX;
        let mut svc = ClusterService::start(cfg);
        let handle = svc.handle();

        svc.push_chunk(&g.edges.edges);
        svc.quiesce();
        let s = handle.stats();
        assert_eq!(s.shards, 3);
        assert_eq!(s.edges_ingested, g.m() as u64);
        assert_eq!(s.queue_depths.len(), 3);
        assert_eq!(s.snapshot_edges, g.m() as u64);
        // the quiesce drained everything that was buffered
        assert_eq!(s.cross_pending, 0);
        assert_eq!(s.cross_drained, s.cross_total);
        // unbounded horizon: the whole log stays resident, nothing is
        // ever committed or freed
        assert_eq!(s.cross_retained, s.cross_total);
        assert_eq!(s.cross_committed, 0);
        assert_eq!(s.cross_freed_bytes, 0);
        assert_eq!(s.epochs_committed, 0);
        assert!(s.drains >= 1);
        assert!(s.memory_bytes > 0);
        assert!(s.bytes_per_node() >= 16.0, "{}", s.bytes_per_node());
        assert!(s.uptime.as_nanos() > 0);
        svc.finish();
    }

    #[test]
    fn community_of_matches_snapshot_labels() {
        let g = sbm::generate(&SbmConfig::equal(5, 30, 0.4, 0.01, 19));
        let mut cfg = ServiceConfig::new(2, 64);
        cfg.chunk_size = 32;
        let mut svc = ClusterService::start(cfg);
        let handle = svc.handle();
        svc.push_chunk(&g.edges.edges);
        svc.quiesce();
        let snap = handle.snapshot();
        let labels = snap.labels();
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(handle.community_of(i as u32), l, "node {i}");
        }
        // unseen ids beyond the sketch are singletons
        let big = (labels.len() as u32) + 1000;
        assert_eq!(handle.community_of(big), big);
        svc.finish();
    }

    #[test]
    fn handles_survive_finish() {
        let g = sbm::generate(&SbmConfig::equal(4, 25, 0.4, 0.01, 23));
        let mut svc = ClusterService::start(ServiceConfig::new(2, 64));
        let handle = svc.handle();
        svc.push_chunk(&g.edges.edges);
        let res = svc.finish();
        assert_eq!(handle.snapshot().edges(), res.snapshot.edges());
        assert_eq!(handle.stats().edges_ingested, g.m() as u64);
    }
}
