//! The epoch-structured cross-edge log, partitioned across leaders.
//!
//! Cross-shard edges cannot be decided when they arrive (their decision
//! needs both shards' community state), so the router defers them. This
//! module is where they wait — and, under a bounded
//! [`CommitHorizon`], where they *stop* waiting:
//!
//! * Edges append to the **open epoch**; when the open epoch reaches
//!   `epoch_len` edges it is **sealed** and a fresh open epoch starts.
//!   Sealing is exact count-based, inside `append` (a chunk that
//!   overfills the open epoch is split at the boundary) — so epoch
//!   boundaries depend only on the cross **arrival sequence**, never
//!   on who appends or in what chunk sizes. That is what lets the
//!   direct dispatch path (`stream::pscan::DirectScan` +
//!   `ClusterService::ingest_direct`) reproduce the funnel's epoch
//!   structure bit-for-bit at any reader count: it delivers the same
//!   cross subsequence in the same order, and the boundaries follow.
//! * Drains replay the suffix past the merger's cursor and (under a
//!   bounded horizon) record each replayed edge's **frozen decision**
//!   — `(endpoint, post-decision community)` pairs — back into the
//!   owning epoch, routed into the **leader partition** that owns the
//!   endpoint's node range (`shard_of(endpoint, leaders)`).
//! * An epoch whose end is more than `horizon` cross edges behind the
//!   log head, and whose edges have all been drained, is **committable**:
//!   each leader partition folds *its slice* of the epoch's frozen
//!   decisions into its own committed-base slice
//!   (`snapshot::LeaderShard::commit`) and the epoch — edges and frozen
//!   records — is dropped, freeing its memory.
//!
//! Lifecycle of one epoch:
//!
//! ```text
//! open ──(epoch_len edges)──▶ sealed ──(drain replays; decisions
//!      frozen per leader)──▶ drained ──(head moves ≥ horizon past
//!      end)──▶ committed: each leader folds its slice into its
//!      committed base, FREE
//! ```
//!
//! The **spine** of the log — arrival order, epoch boundaries, the edge
//! storage itself — stays global: the replay that decides cross edges
//! is a sequential pass in arrival order, and splitting the edge stream
//! would force a k-way merge on every drain for zero semantic gain.
//! What *is* partitioned is everything a leader owns per node range:
//! the frozen decision slices, the committed base, and the byte
//! accounting (retained/committed/freed per leader). An edge's own
//! storage is attributed to the leader owning its first endpoint.
//!
//! With [`CommitHorizon::Unbounded`] nothing is ever committed and no
//! frozen records are kept: the log is the old retained buffer, split
//! into epochs, and `finish` replays all of it — bit-identical to the
//! batch coordinator. With [`CommitHorizon::Edges(h)`](CommitHorizon::Edges)
//! retained memory is bounded by `h + epoch_len` edges (each retained
//! edge costs [`BYTES_PER_EDGE`], plus [`BYTES_PER_FROZEN_ENTRY`] per
//! endpoint once drained), independent of the stream length.

use std::collections::VecDeque;

use crate::graph::edge::Edge;
use crate::stream::shard::shard_of;

use super::config::CommitHorizon;

/// Bytes per retained cross edge (two dense `u32` node ids).
pub(crate) const BYTES_PER_EDGE: u64 = std::mem::size_of::<Edge>() as u64;
/// Bytes per frozen decision record (endpoint id + community id); two
/// records per drained edge, kept only under a bounded horizon.
pub(crate) const BYTES_PER_FROZEN_ENTRY: u64 = 8;
/// Per-epoch counter overhead a commit delta ships alongside the frozen
/// records (epoch start, edge count, record count — three `u64`s). Part
/// of the drain-payload accounting: the payload must be O(epoch deltas)
/// and this is the "per-epoch counters" term.
pub(crate) const EPOCH_COMMIT_HEADER_BYTES: u64 = 24;

/// A frozen replay decision: `(endpoint, post-decision community)`.
/// `UNSEEN` as the community marks a skipped (self-loop) slot.
pub(crate) type FrozenDecision = (u32, u32);

/// Epoch length for a horizon: fine enough that the `h + epoch_len`
/// retention bound stays close to `h`, coarse enough that commits are
/// amortised. Unbounded logs use a fixed coarse epoch (they only need
/// epochs for accounting — nothing ever commits).
pub(crate) fn epoch_len_for(horizon: CommitHorizon) -> u64 {
    const UNBOUNDED_EPOCH_LEN: u64 = 65_536;
    match horizon {
        CommitHorizon::Unbounded => UNBOUNDED_EPOCH_LEN,
        CommitHorizon::Edges(h) => (h / 4).clamp(1, UNBOUNDED_EPOCH_LEN),
    }
}

/// One epoch of the log. Fields are read by the leaders at commit time.
pub(crate) struct Epoch {
    /// Global index (in the append-ordered cross stream) of this
    /// epoch's first edge.
    start: u64,
    /// The epoch's edges, in arrival order.
    edges: Vec<Edge>,
    /// Sealed epochs accept no more edges.
    sealed: bool,
    /// Frozen decisions partitioned by owning leader
    /// (`shard_of(endpoint, leaders)`), each slice in replay order.
    /// Populated only under a bounded horizon.
    frozen: Vec<Vec<FrozenDecision>>,
    /// Total frozen records attached (across all leader slices) — the
    /// completeness counter for the commit-time assertion.
    frozen_count: usize,
}

impl Epoch {
    fn new(start: u64, leaders: usize) -> Self {
        Self {
            start,
            edges: Vec::new(),
            sealed: false,
            frozen: vec![Vec::new(); leaders],
            frozen_count: 0,
        }
    }

    /// Global index one past this epoch's last edge.
    fn end(&self) -> u64 {
        self.start + self.edges.len() as u64
    }

    /// Frozen decision slices, one per leader partition — the commit
    /// delta each leader folds into its committed-base slice.
    pub(crate) fn frozen_slices(&self) -> &[Vec<FrozenDecision>] {
        &self.frozen
    }

    /// Total frozen records attached (all leader slices).
    pub(crate) fn frozen_count(&self) -> usize {
        self.frozen_count
    }

    fn bytes(&self) -> u64 {
        self.edges.len() as u64 * BYTES_PER_EDGE
            + self.frozen_count as u64 * BYTES_PER_FROZEN_ENTRY
    }
}

/// One retained epoch flattened for checkpointing — edges and frozen
/// decision slices verbatim, so recovery never has to reconstruct the
/// replay order of partially drained epochs.
#[derive(Debug, Clone)]
pub(crate) struct EpochExport {
    /// Global index of the epoch's first edge.
    pub start: u64,
    /// Whether the epoch was sealed.
    pub sealed: bool,
    /// The epoch's edges, in arrival order.
    pub edges: Vec<Edge>,
    /// Frozen decisions per leader partition, each in replay order.
    pub frozen: Vec<Vec<FrozenDecision>>,
}

/// The cross log's durable image for checkpointing: every counter plus
/// the retained (uncommitted) epochs verbatim.
#[derive(Debug, Clone)]
pub(crate) struct CrossLogExport {
    /// Global index of the first retained edge.
    pub committed: u64,
    /// Total cross edges ever appended (the log head).
    pub appended: u64,
    /// Epochs sealed so far.
    pub epochs_sealed: u64,
    /// Epochs committed (and freed) so far.
    pub epochs_committed: u64,
    /// Bytes released by committed epochs.
    pub freed_bytes: u64,
    /// Edges ever appended, per leader partition.
    pub appended_per_leader: Vec<u64>,
    /// Edges committed, per leader partition.
    pub committed_per_leader: Vec<u64>,
    /// Frozen records currently resident, per leader partition.
    pub frozen_retained_per_leader: Vec<u64>,
    /// Bytes released by commits, per leader partition.
    pub freed_bytes_per_leader: Vec<u64>,
    /// Retained epochs, oldest first (the last one is the open epoch).
    pub epochs: Vec<EpochExport>,
}

/// The log: a deque of epochs (committed ones are gone, the last one is
/// open) plus the commit cursor and byte accounting — global and per
/// leader partition. Lives in the service's shared state behind a
/// mutex; the lock order everywhere is merger → crosslog → leader
/// shards (ascending index).
pub(crate) struct CrossLog {
    horizon: CommitHorizon,
    epoch_len: u64,
    /// Leader partition count (node-range owner =
    /// `shard_of(node, leaders)`).
    leaders: usize,
    /// Uncommitted epochs, oldest first; the last is the open epoch.
    epochs: VecDeque<Epoch>,
    /// Global index of the first retained edge: everything before it
    /// has been folded into the committed base slices and freed.
    committed: u64,
    /// Total cross edges ever appended (the log head).
    appended: u64,
    epochs_sealed: u64,
    epochs_committed: u64,
    /// Bytes released by committed epochs (edges + frozen records).
    freed_bytes: u64,
    /// Edges ever appended, attributed per leader (owner of `e.u`).
    appended_per_leader: Vec<u64>,
    /// Edges committed (freed), attributed per leader (owner of `e.u`).
    committed_per_leader: Vec<u64>,
    /// Frozen records currently resident, per leader partition.
    frozen_retained_per_leader: Vec<u64>,
    /// Bytes released by commits, per leader partition.
    freed_bytes_per_leader: Vec<u64>,
}

impl CrossLog {
    pub(crate) fn new(horizon: CommitHorizon, leaders: usize) -> Self {
        let horizon = horizon.normalized();
        let leaders = leaders.max(1);
        let mut epochs = VecDeque::new();
        epochs.push_back(Epoch::new(0, leaders));
        Self {
            horizon,
            epoch_len: epoch_len_for(horizon),
            leaders,
            epochs,
            committed: 0,
            appended: 0,
            epochs_sealed: 0,
            epochs_committed: 0,
            freed_bytes: 0,
            appended_per_leader: vec![0; leaders],
            committed_per_leader: vec![0; leaders],
            frozen_retained_per_leader: vec![0; leaders],
            freed_bytes_per_leader: vec![0; leaders],
        }
    }

    /// Append a router chunk, sealing the open epoch at `epoch_len`
    /// boundaries. Drains (and clears) `batch`.
    pub(crate) fn append(&mut self, batch: &mut Vec<Edge>) {
        let mut rest: &[Edge] = batch;
        while !rest.is_empty() {
            let take = {
                let open = self.epochs.back_mut().expect("open epoch");
                debug_assert!(!open.sealed, "appending into a sealed epoch");
                let room = (self.epoch_len as usize)
                    .saturating_sub(open.edges.len())
                    .min(rest.len());
                open.edges.extend_from_slice(&rest[..room]);
                room
            };
            for e in &rest[..take] {
                self.appended_per_leader[shard_of(e.u, self.leaders)] += 1;
            }
            self.appended += take as u64;
            rest = &rest[take..];
            if self.epochs.back().expect("open epoch").edges.len() as u64 >= self.epoch_len {
                self.epochs.back_mut().expect("open epoch").sealed = true;
                self.epochs_sealed += 1;
                let head = self.appended;
                let leaders = self.leaders;
                self.epochs.push_back(Epoch::new(head, leaders));
            }
        }
        batch.clear();
    }

    /// Copy of the retained suffix `[cursor, head)` in arrival order
    /// (the drain and terminal-replay input). `cursor` must not point
    /// into committed (freed) territory.
    pub(crate) fn suffix_from(&self, cursor: u64) -> Vec<Edge> {
        debug_assert!(
            cursor >= self.committed,
            "cursor {cursor} points into committed prefix {}",
            self.committed
        );
        let mut out = Vec::with_capacity(self.appended.saturating_sub(cursor) as usize);
        for ep in &self.epochs {
            if ep.end() <= cursor {
                continue;
            }
            let skip = cursor.saturating_sub(ep.start) as usize;
            out.extend_from_slice(&ep.edges[skip..]);
        }
        out
    }

    /// True when drains must hand frozen decision records back to the
    /// log (bounded horizon only — an unbounded log never commits, so
    /// recording would be pure overhead).
    pub(crate) fn wants_frozen(&self) -> bool {
        !self.horizon.is_unbounded()
    }

    /// Attach frozen decisions for the just-replayed edges
    /// `[start, start + records.len()/2)` to their owning epochs,
    /// routing each record into the leader partition that owns its
    /// endpoint. `records` holds exactly two entries per edge, in
    /// replay order (the per-partition slices preserve that order).
    pub(crate) fn record_frozen(&mut self, start: u64, records: &[FrozenDecision]) {
        if !self.wants_frozen() || records.is_empty() {
            return;
        }
        debug_assert_eq!(records.len() % 2, 0, "two frozen records per edge");
        let leaders = self.leaders;
        let mut cursor = start;
        let mut rest = records;
        for ep in self.epochs.iter_mut() {
            if rest.is_empty() {
                break;
            }
            if ep.end() <= cursor {
                continue;
            }
            debug_assert!(
                cursor >= ep.start,
                "frozen records skipped an epoch: cursor {cursor} < start {}",
                ep.start
            );
            let edges_here = ((ep.end() - cursor) as usize).min(rest.len() / 2);
            for &(node, comm) in &rest[..edges_here * 2] {
                let owner = shard_of(node, leaders);
                ep.frozen[owner].push((node, comm));
                self.frozen_retained_per_leader[owner] += 1;
            }
            ep.frozen_count += edges_here * 2;
            rest = &rest[edges_here * 2..];
            cursor += edges_here as u64;
        }
        debug_assert!(rest.is_empty(), "frozen records past the log head");
    }

    /// Pop every epoch whose decisions are final: sealed, fully drained
    /// (`drained` = the merger's replay cursor), and at least `horizon`
    /// cross edges behind the head. The caller hands each returned
    /// epoch's frozen slices to their leader shards, then drops the
    /// epoch — that drop is the memory bound. Always empty under
    /// [`CommitHorizon::Unbounded`].
    pub(crate) fn take_committable(&mut self, drained: u64) -> Vec<Epoch> {
        let CommitHorizon::Edges(h) = self.horizon else {
            return Vec::new();
        };
        let mut out = Vec::new();
        while let Some(ep) = self.epochs.front() {
            let behind_horizon = self.appended - ep.end() >= h;
            if !(ep.sealed && ep.end() <= drained && behind_horizon) {
                break;
            }
            let ep = self.epochs.pop_front().expect("front epoch");
            debug_assert_eq!(
                ep.frozen_count,
                ep.edges.len() * 2,
                "committing an epoch with incomplete frozen records"
            );
            self.committed = ep.end();
            self.epochs_committed += 1;
            self.freed_bytes += ep.bytes();
            for e in &ep.edges {
                let owner = shard_of(e.u, self.leaders);
                self.committed_per_leader[owner] += 1;
                self.freed_bytes_per_leader[owner] += BYTES_PER_EDGE;
            }
            for (l, slice) in ep.frozen.iter().enumerate() {
                self.frozen_retained_per_leader[l] -= slice.len() as u64;
                self.freed_bytes_per_leader[l] +=
                    slice.len() as u64 * BYTES_PER_FROZEN_ENTRY;
            }
            out.push(ep);
        }
        out
    }

    /// Total cross edges ever appended (the log head).
    pub(crate) fn appended(&self) -> u64 {
        self.appended
    }

    /// Edges committed (folded into the base slices and freed). Because
    /// the committed region is a prefix, this is also the global index
    /// of the first retained edge.
    pub(crate) fn committed_edges(&self) -> u64 {
        self.committed
    }

    /// Edges currently resident in the log.
    pub(crate) fn retained_edges(&self) -> u64 {
        self.appended - self.committed
    }

    /// Resident bytes: retained edges plus their frozen records.
    pub(crate) fn retained_bytes(&self) -> u64 {
        self.epochs.iter().map(Epoch::bytes).sum()
    }

    /// Bytes released by committed epochs so far.
    pub(crate) fn freed_bytes(&self) -> u64 {
        self.freed_bytes
    }

    /// Resident bytes attributed to each leader partition: retained
    /// edges owned by its node range (via `e.u`) plus its resident
    /// frozen record slices. Sums to [`retained_bytes`](Self::retained_bytes).
    pub(crate) fn retained_bytes_per_leader(&self) -> Vec<u64> {
        (0..self.leaders)
            .map(|l| {
                (self.appended_per_leader[l] - self.committed_per_leader[l])
                    * BYTES_PER_EDGE
                    + self.frozen_retained_per_leader[l] * BYTES_PER_FROZEN_ENTRY
            })
            .collect()
    }

    /// Bytes each leader partition's commits have released. Sums to
    /// [`freed_bytes`](Self::freed_bytes).
    pub(crate) fn freed_bytes_per_leader(&self) -> Vec<u64> {
        self.freed_bytes_per_leader.clone()
    }

    /// Edges per epoch (the `+ one epoch` term of the retention bound).
    pub(crate) fn epoch_len(&self) -> u64 {
        self.epoch_len
    }

    /// Epochs sealed so far.
    pub(crate) fn epochs_sealed(&self) -> u64 {
        self.epochs_sealed
    }

    /// Epochs committed (and freed) so far.
    pub(crate) fn epochs_committed(&self) -> u64 {
        self.epochs_committed
    }

    /// Flatten the whole log — counters and retained epochs verbatim —
    /// for checkpointing.
    pub(crate) fn export(&self) -> CrossLogExport {
        CrossLogExport {
            committed: self.committed,
            appended: self.appended,
            epochs_sealed: self.epochs_sealed,
            epochs_committed: self.epochs_committed,
            freed_bytes: self.freed_bytes,
            appended_per_leader: self.appended_per_leader.clone(),
            committed_per_leader: self.committed_per_leader.clone(),
            frozen_retained_per_leader: self.frozen_retained_per_leader.clone(),
            freed_bytes_per_leader: self.freed_bytes_per_leader.clone(),
            epochs: self
                .epochs
                .iter()
                .map(|ep| EpochExport {
                    start: ep.start,
                    sealed: ep.sealed,
                    edges: ep.edges.clone(),
                    frozen: ep.frozen.clone(),
                })
                .collect(),
        }
    }

    /// Rebuild a log from a checkpoint image. The deque invariant —
    /// it always ends with an open epoch — is restored even from an
    /// image whose last epoch was sealed on an exact boundary.
    pub(crate) fn resume(horizon: CommitHorizon, leaders: usize, e: CrossLogExport) -> Self {
        let mut log = Self::new(horizon, leaders);
        log.committed = e.committed;
        log.appended = e.appended;
        log.epochs_sealed = e.epochs_sealed;
        log.epochs_committed = e.epochs_committed;
        log.freed_bytes = e.freed_bytes;
        log.appended_per_leader = e.appended_per_leader;
        log.committed_per_leader = e.committed_per_leader;
        log.frozen_retained_per_leader = e.frozen_retained_per_leader;
        log.freed_bytes_per_leader = e.freed_bytes_per_leader;
        log.epochs.clear();
        for ep in e.epochs {
            let mut epoch = Epoch::new(ep.start, log.leaders);
            epoch.sealed = ep.sealed;
            epoch.edges = ep.edges;
            epoch.frozen_count = ep.frozen.iter().map(Vec::len).sum();
            epoch.frozen = ep.frozen;
            log.epochs.push_back(epoch);
        }
        if log.epochs.back().map(|ep| ep.sealed).unwrap_or(true) {
            let head = log.appended;
            let leaders = log.leaders;
            log.epochs.push_back(Epoch::new(head, leaders));
        }
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn edges(range: std::ops::Range<u32>) -> Vec<Edge> {
        range.map(|i| Edge::new(i, i + 1)).collect()
    }

    fn frozen_total(ep: &Epoch) -> usize {
        ep.frozen_slices().iter().map(Vec::len).sum()
    }

    #[test]
    fn appends_seal_epochs_on_chunk_boundaries() {
        // horizon 8 → epoch_len 2
        let mut log = CrossLog::new(CommitHorizon::Edges(8), 1);
        assert_eq!(log.epoch_len(), 2);
        let mut batch = edges(0..5);
        log.append(&mut batch);
        assert!(batch.is_empty(), "append must drain the chunk");
        assert_eq!(log.appended(), 5);
        assert_eq!(log.epochs_sealed(), 2); // [0,2) and [2,4) sealed; [4,..) open
        assert_eq!(log.retained_edges(), 5);
        assert_eq!(log.suffix_from(0), edges(0..5));
        assert_eq!(log.suffix_from(3), edges(3..5));
    }

    #[test]
    fn unbounded_log_never_commits_and_keeps_no_frozen_records() {
        let mut log = CrossLog::new(CommitHorizon::Unbounded, 2);
        log.append(&mut edges(0..100));
        assert!(!log.wants_frozen());
        log.record_frozen(0, &[(0, 0); 200]); // must be a no-op
        assert!(log.take_committable(100).is_empty());
        assert_eq!(log.retained_edges(), 100);
        assert_eq!(log.committed_edges(), 0);
        assert_eq!(log.freed_bytes(), 0);
        assert_eq!(log.retained_bytes(), 100 * BYTES_PER_EDGE);
        // per-leader views partition the totals even when idle
        assert_eq!(
            log.retained_bytes_per_leader().iter().sum::<u64>(),
            log.retained_bytes()
        );
        assert_eq!(log.freed_bytes_per_leader(), vec![0, 0]);
    }

    #[test]
    fn zero_horizon_is_unbounded() {
        let log = CrossLog::new(CommitHorizon::Edges(0), 1);
        assert!(!log.wants_frozen());
    }

    #[test]
    fn commit_requires_sealed_drained_and_behind_horizon() {
        // epoch_len 2, horizon 8
        let mut log = CrossLog::new(CommitHorizon::Edges(8), 1);
        log.append(&mut edges(0..4)); // epochs [0,2) and [2,4) sealed

        // drained but not behind the horizon → nothing commits
        let frozen: Vec<FrozenDecision> = (0..4).flat_map(|i| [(i, 0), (i + 1, 0)]).collect();
        log.record_frozen(0, &frozen);
        assert!(log.take_committable(4).is_empty());

        // move the head 8 past epoch [0,2)'s end, drain everything
        log.append(&mut edges(4..10)); // head = 10; 10 - 2 = 8 ≥ h
        let frozen: Vec<FrozenDecision> = (4..10).flat_map(|i| [(i, 0), (i + 1, 0)]).collect();
        log.record_frozen(4, &frozen);
        let committed = log.take_committable(10);
        assert_eq!(committed.len(), 1, "exactly epoch [0,2) is behind the horizon");
        assert_eq!(committed[0].frozen_count(), 4);
        assert_eq!(frozen_total(&committed[0]), 4);
        assert_eq!(log.committed_edges(), 2);
        assert_eq!(log.retained_edges(), 8);
        assert_eq!(
            log.freed_bytes(),
            2 * BYTES_PER_EDGE + 4 * BYTES_PER_FROZEN_ENTRY
        );
        assert_eq!(log.epochs_committed(), 1);
        // the suffix past the commit point is intact
        assert_eq!(log.suffix_from(2), edges(2..10));
    }

    #[test]
    fn undrained_epochs_never_commit() {
        let mut log = CrossLog::new(CommitHorizon::Edges(4), 1); // epoch_len 1
        log.append(&mut edges(0..10));
        // head is far past every early epoch, but nothing was drained
        assert!(log.take_committable(0).is_empty());
        // drain only the first 3 edges → only epochs ending ≤ 3 AND
        // ≥ 4 behind the head (end ≤ 6) qualify → epochs [0,1),[1,2),[2,3)
        let frozen: Vec<FrozenDecision> = (0..3).flat_map(|i| [(i, 0), (i + 1, 0)]).collect();
        log.record_frozen(0, &frozen);
        assert_eq!(log.take_committable(3).len(), 3);
        assert_eq!(log.committed_edges(), 3);
    }

    #[test]
    fn frozen_records_split_across_epochs() {
        let mut log = CrossLog::new(CommitHorizon::Edges(8), 1); // epoch_len 2
        log.append(&mut edges(0..6));
        // one drain covering edges [1, 5) spans epochs [0,2), [2,4), [4,6)
        let frozen: Vec<FrozenDecision> = (1..5).flat_map(|i| [(i, 7), (i + 1, 7)]).collect();
        // first drain covered [0, 1)
        log.record_frozen(0, &[(0, 7), (1, 7)]);
        log.record_frozen(1, &frozen);
        log.append(&mut edges(6..20)); // push the head far past everything
        let frozen: Vec<FrozenDecision> = (5..20).flat_map(|i| [(i, 7), (i + 1, 7)]).collect();
        log.record_frozen(5, &frozen);
        let committed = log.take_committable(20);
        // every sealed epoch with end ≤ 20 - 8 = 12 commits: [0,2)…[10,12)
        assert_eq!(committed.len(), 6);
        for ep in &committed {
            assert_eq!(ep.frozen_count(), ep.edges.len() * 2);
        }
    }

    #[test]
    fn frozen_records_route_to_their_owning_leader_partition() {
        let leaders = 4usize;
        let mut log = CrossLog::new(CommitHorizon::Edges(8), leaders); // epoch_len 2
        log.append(&mut edges(0..2)); // one sealed epoch [0,2)
        let frozen: Vec<FrozenDecision> = (0..2).flat_map(|i| [(i, 9), (i + 1, 9)]).collect();
        log.record_frozen(0, &frozen);
        log.append(&mut edges(2..12)); // head far past [0,2)
        let tail: Vec<FrozenDecision> = (2..12).flat_map(|i| [(i, 9), (i + 1, 9)]).collect();
        log.record_frozen(2, &tail);
        let committed = log.take_committable(12);
        assert!(!committed.is_empty());
        for ep in &committed {
            assert_eq!(ep.frozen_slices().len(), leaders);
            for (l, slice) in ep.frozen_slices().iter().enumerate() {
                for &(node, _) in slice {
                    assert_eq!(
                        shard_of(node, leaders),
                        l,
                        "record for node {node} filed under partition {l}"
                    );
                }
            }
            assert_eq!(frozen_total(ep), ep.frozen_count());
        }
        // per-leader accounting partitions the totals exactly
        assert_eq!(
            log.retained_bytes_per_leader().iter().sum::<u64>(),
            log.retained_bytes()
        );
        assert_eq!(
            log.freed_bytes_per_leader().iter().sum::<u64>(),
            log.freed_bytes()
        );
    }

    #[test]
    fn export_resume_roundtrips_counters_epochs_and_suffixes() {
        let horizon = CommitHorizon::Edges(8); // epoch_len 2
        let mut log = CrossLog::new(horizon, 2);
        log.append(&mut edges(0..2));
        let frozen: Vec<FrozenDecision> = (0..2).flat_map(|i| [(i, 9), (i + 1, 9)]).collect();
        log.record_frozen(0, &frozen);
        log.append(&mut edges(2..13)); // head far past [0,2)
        let tail: Vec<FrozenDecision> = (2..13).flat_map(|i| [(i, 9), (i + 1, 9)]).collect();
        log.record_frozen(2, &tail);
        assert!(!log.take_committable(13).is_empty());

        let back = CrossLog::resume(horizon, 2, log.export());
        assert_eq!(back.appended(), log.appended());
        assert_eq!(back.committed_edges(), log.committed_edges());
        assert_eq!(back.retained_edges(), log.retained_edges());
        assert_eq!(back.retained_bytes(), log.retained_bytes());
        assert_eq!(back.freed_bytes(), log.freed_bytes());
        assert_eq!(back.epochs_sealed(), log.epochs_sealed());
        assert_eq!(back.epochs_committed(), log.epochs_committed());
        assert_eq!(
            back.retained_bytes_per_leader(),
            log.retained_bytes_per_leader()
        );
        assert_eq!(back.freed_bytes_per_leader(), log.freed_bytes_per_leader());
        assert_eq!(
            back.suffix_from(back.committed_edges()),
            log.suffix_from(log.committed_edges())
        );
        assert!(
            !back.epochs.back().expect("open epoch").sealed,
            "resume must leave an open epoch at the tail"
        );
    }

    #[test]
    fn retention_bound_holds_when_drains_keep_pace() {
        let h = 16u64;
        let mut log = CrossLog::new(CommitHorizon::Edges(h), 2);
        let mut next = 0u32;
        for _ in 0..50 {
            let lo = next;
            next += 7;
            log.append(&mut edges(lo..next));
            // drain to the head, then commit
            let frozen: Vec<FrozenDecision> =
                (lo..next).flat_map(|i| [(i, 0), (i + 1, 0)]).collect();
            // records for just-appended edges (prior ones already recorded)
            log.record_frozen(lo as u64, &frozen);
            let _ = log.take_committable(log.appended());
            assert!(
                log.retained_edges() <= h + log.epoch_len(),
                "retained {} > h {} + epoch {}",
                log.retained_edges(),
                h,
                log.epoch_len()
            );
        }
        assert!(log.epochs_committed() > 0);
        assert!(log.freed_bytes() > 0);
    }
}
