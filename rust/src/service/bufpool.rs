//! Pooled chunk buffers — the zero-allocation ingest cycle.
//!
//! Every dispatched chunk used to be a freshly allocated `Vec<Edge>`
//! that the shard worker dropped after processing: one heap
//! allocation and one free per `chunk_size` edges, on the hottest path
//! in the process. The pool closes that cycle into a loop of owned
//! buffers:
//!
//! ```text
//! router pending[w] ──send──► mailbox[w] ──recv──► shard worker w
//!       ▲                                              │ process_chunk
//!       │ checkout() (hit = recycled)                  ▼
//!       └───────────────── BufPool ◄───── give_back() ─┘
//! ```
//!
//! In steady state every `checkout` is a **hit** (a recycled buffer
//! with its capacity intact), so chunk dispatch performs no heap
//! allocation at all. The cycle used to *warm up* through misses —
//! bounded by the number of buffers that can be in flight at once
//! (per shard: the pending buffer, `mailbox_depth` queued chunks, one
//! in the worker's hands, and one in transit during the dispatch
//! swap). [`prewarm`](BufPool::prewarm) removes even that ramp: the
//! service boot fills the shelf to the in-flight bound before the
//! router checks out its first pending buffer, so steady state starts
//! at **zero misses** — which is exactly what the zero-allocation
//! integration test asserts via [`PoolStats`].
//!
//! The idle shelf is capped (`max_idle`): buffers beyond the cap are
//! dropped on return, so a transient burst cannot pin memory forever.

use std::mem::size_of;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::graph::edge::Edge;

/// Counters of the chunk-buffer pool, surfaced in
/// [`ServiceStats::pool`](super::ServiceStats::pool) and the `serve`
/// stats line. `hits + misses` is the total number of checkouts;
/// with the boot-time [`prewarm`](BufPool::prewarm) steady-state
/// zero-allocation ingest shows up as `misses == 0` while `hits`
/// keeps growing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Checkouts served by a recycled buffer (no allocation).
    pub hits: u64,
    /// Checkouts that had to allocate a fresh buffer (cold cycle).
    pub misses: u64,
    /// Buffer-capacity bytes returned to the pool over its lifetime.
    pub recycled_bytes: u64,
}

/// The pool itself: a mutex-held shelf of empty, capacity-bearing
/// chunk buffers plus lifetime counters. One per service, shared by
/// the router (checkout on dispatch) and every shard worker (return
/// after processing). The shelf lock is taken once per *chunk*, never
/// per edge, so it adds nothing to the per-edge cost.
pub(crate) struct BufPool {
    free: Mutex<Vec<Vec<Edge>>>,
    /// Idle buffers kept at most; returns beyond this are dropped.
    max_idle: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    recycled_bytes: AtomicU64,
}

impl BufPool {
    /// A pool that shelves at most `max_idle` idle buffers.
    pub(crate) fn new(max_idle: usize) -> Self {
        Self {
            free: Mutex::new(Vec::with_capacity(max_idle.min(64))),
            max_idle: max_idle.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            recycled_bytes: AtomicU64::new(0),
        }
    }

    /// Fill the shelf with ready buffers of `cap` capacity, up to
    /// `count` (clamped to `max_idle`). Called once at service boot
    /// with the in-flight bound, *before* the router's first checkout,
    /// so the recycle loop starts full: the pre-allocated buffers are
    /// deliberately not counted as hits, misses, or recycled bytes —
    /// they are capacity planning, not cycle traffic — which is what
    /// lets the integration test pin `misses == 0` after warmup.
    pub(crate) fn prewarm(&self, count: usize, cap: usize) {
        let want = count.min(self.max_idle);
        let mut free = self.free.lock().unwrap();
        while free.len() < want {
            free.push(Vec::with_capacity(cap));
        }
    }

    /// An empty buffer with at least `cap` capacity: recycled when the
    /// shelf has one (hit), freshly allocated otherwise (miss).
    pub(crate) fn checkout(&self, cap: usize) -> Vec<Edge> {
        let recycled = self.free.lock().unwrap().pop();
        match recycled {
            Some(mut buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                // buffers come back cleared; reserve only if a config
                // change outgrew the recycled capacity
                if buf.capacity() < cap {
                    buf.reserve(cap);
                }
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(cap)
            }
        }
    }

    /// Return a spent chunk to the shelf: cleared, its capacity counted
    /// as recycled; dropped instead when the shelf is at `max_idle` or
    /// the buffer never held capacity worth keeping.
    pub(crate) fn give_back(&self, mut buf: Vec<Edge>) {
        buf.clear();
        if buf.capacity() == 0 {
            return;
        }
        let bytes = (buf.capacity() * size_of::<Edge>()) as u64;
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_idle {
            free.push(buf);
            drop(free);
            self.recycled_bytes.fetch_add(bytes, Ordering::Relaxed);
        }
    }

    /// Lifetime counters (lock-free reads).
    pub(crate) fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            recycled_bytes: self.recycled_bytes.load(Ordering::Relaxed),
        }
    }

    /// Buffers currently shelved (tests/observability).
    #[cfg(test)]
    pub(crate) fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_miss_then_hit_cycle() {
        let pool = BufPool::new(4);
        let buf = pool.checkout(16);
        let cap = buf.capacity();
        assert!(cap >= 16);
        assert_eq!(pool.stats(), PoolStats { hits: 0, misses: 1, recycled_bytes: 0 });

        pool.give_back(buf);
        assert_eq!(pool.idle(), 1);
        assert_eq!(pool.stats().recycled_bytes, (cap * size_of::<Edge>()) as u64);

        let again = pool.checkout(16);
        assert_eq!(again.capacity(), cap, "recycled capacity must survive");
        assert!(again.is_empty(), "recycled buffers must come back cleared");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn give_back_clears_contents() {
        let pool = BufPool::new(2);
        let mut buf = pool.checkout(8);
        buf.push(Edge::new(1, 2));
        buf.push(Edge::new(3, 4));
        pool.give_back(buf);
        let buf = pool.checkout(8);
        assert!(buf.is_empty(), "a stale edge in a recycled chunk would be re-processed");
    }

    #[test]
    fn idle_shelf_is_capped() {
        let pool = BufPool::new(2);
        let bufs: Vec<_> = (0..5).map(|_| pool.checkout(8)).collect();
        let caps: u64 = bufs[..2].iter().map(|b| b.capacity() as u64).sum();
        for b in bufs {
            pool.give_back(b);
        }
        assert_eq!(pool.idle(), 2, "returns beyond max_idle must be dropped");
        // only the shelved capacity counts as recycled
        assert_eq!(pool.stats().recycled_bytes, caps * size_of::<Edge>() as u64);
    }

    #[test]
    fn checkout_regrows_undersized_recycled_buffers() {
        let pool = BufPool::new(2);
        pool.give_back({
            let mut v = Vec::with_capacity(4);
            v.push(Edge::new(0, 1));
            v
        });
        let buf = pool.checkout(32);
        assert!(buf.capacity() >= 32);
        assert!(buf.is_empty());
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn zero_capacity_buffers_are_not_shelved() {
        let pool = BufPool::new(2);
        pool.give_back(Vec::new());
        assert_eq!(pool.idle(), 0);
        assert_eq!(pool.stats().recycled_bytes, 0);
    }

    #[test]
    fn prewarm_fills_the_shelf_without_counting_as_traffic() {
        let pool = BufPool::new(8);
        pool.prewarm(4, 16);
        assert_eq!(pool.idle(), 4);
        assert_eq!(pool.stats(), PoolStats::default(), "prewarm is not cycle traffic");

        // every checkout up to the prewarmed depth is a hit — no ramp
        let bufs: Vec<_> = (0..4).map(|_| pool.checkout(16)).collect();
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (4, 0));
        for b in &bufs {
            assert!(b.capacity() >= 16);
        }

        // prewarm is idempotent and respects max_idle
        pool.prewarm(100, 16);
        assert_eq!(pool.idle(), 8, "clamped to max_idle");
        pool.prewarm(2, 16);
        assert_eq!(pool.idle(), 8, "never drains the shelf");
    }
}
