//! The single routing/merge core behind every execution path.
//!
//! Until this module existed the repo carried **two** copies of the
//! route/batch/merge/replay pipeline: one inside the batch dispatcher
//! (`coordinator::parallel::run_parallel`) and one inside the service's
//! ingest path — bit-identical in behaviour, duplicated in code. Both
//! now go through here:
//!
//! * `Router` — the write-side core: classify each edge with
//!   `stream::shard::route`, batch same-shard edges into per-shard
//!   chunks bound for the workers' bounded mailboxes (blocking
//!   backpressure, never drops), and append cross-shard edges to the
//!   epoch-structured cross log (`super::crosslog`) — epochs seal on
//!   these chunk boundaries, and they are also the unit the sharded
//!   drain leader ships: a drain exchanges only the epoch deltas the
//!   router created here, never the committed base they eventually
//!   fold into. `ClusterService` owns one; `run_parallel` is a thin
//!   batch preset over `ClusterService` and therefore uses the same
//!   instance type, the same code, the same semantics.
//! * [`merge_disjoint_states`] — the merge half of the core: the
//!   conflict-free array union of shard sketches that every drain and
//!   the terminal replay build on.
//!
//! One core means one place where the paper's "every edge exactly once"
//! accounting lives, and one place the golden/property suites have to
//! pin down.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::coordinator::state::{StreamState, UNSEEN};
use crate::graph::edge::Edge;
use crate::stream::shard::{route, Route};

use super::ingest::Shared;

/// Merge shard-disjoint worker states into one sketch (disjoint array
/// union).
///
/// Hash-sharding guarantees no two workers ever touch the same node, so
/// degrees and communities copy over and volumes add. The result is
/// sized to `max(n, largest worker state)` — workers that grew on
/// demand beyond the pre-sized `n` (the service starts them at 0) are
/// handled transparently. Shared by every snapshot drain and by the
/// terminal replay in `ClusterService::finish` (and therefore by the
/// batch path, `coordinator::parallel::run_parallel`).
///
/// Debug builds assert the disjointness invariant; a violation means
/// the caller routed one node's edges to two different workers.
pub fn merge_disjoint_states(n: usize, states: &[StreamState]) -> StreamState {
    let n = states.iter().map(|st| st.n()).fold(n, usize::max);
    let mut merged = StreamState::new(n);
    for st in states {
        for i in 0..st.n() {
            if st.degree[i] > 0 || st.community[i] != UNSEEN {
                debug_assert_eq!(merged.degree[i], 0, "shard overlap at node {i}");
                merged.degree[i] = st.degree[i];
                merged.community[i] = st.community[i];
            }
            if st.volume[i] > 0 {
                merged.volume[i] += st.volume[i];
            }
        }
        merged.edges_processed += st.edges_processed;
    }
    merged
}

/// The write-side routing core: per-shard batch buffers plus the
/// deferred cross-edge batch, all draining into the `Shared` service
/// state. Owned by `ClusterService`; not thread-safe by itself (one
/// router per ingest thread, backed by thread-safe `Shared`).
pub(crate) struct Router {
    shared: Arc<Shared>,
    /// Per-shard batch buffers (not yet dispatched to mailboxes).
    pending: Vec<Vec<Edge>>,
    /// Cross-edge batch (flushed to the shared cross log in chunks —
    /// one lock per chunk instead of one per edge).
    cross_pending: Vec<Edge>,
    /// Edges routed since the last snapshot drain.
    since_drain: u64,
    /// Edges (local *and* cross) not yet reported to the shared meter.
    unmetered: u64,
}

impl Router {
    pub(crate) fn new(shared: Arc<Shared>) -> Self {
        let shards = shared.config.shards;
        Self {
            shared,
            pending: (0..shards).map(|_| Vec::new()).collect(),
            cross_pending: Vec::new(),
            since_drain: 0,
            unmetered: 0,
        }
    }

    /// Route one edge. Blocks when the target shard's mailbox is full
    /// (backpressure). Returns `true` when `config.drain_every` edges
    /// have accumulated since the last drain — the caller owns the
    /// drain itself (and must call [`reset_drain_clock`](Self::reset_drain_clock)
    /// when it drains for any other reason).
    pub(crate) fn push(&mut self, e: Edge) -> bool {
        match route(e, self.shared.config.shards) {
            Route::Local(w) => {
                self.pending[w].push(e);
                if self.pending[w].len() >= self.shared.config.chunk_size {
                    self.dispatch(w);
                }
            }
            Route::Cross => {
                self.cross_pending.push(e);
                if self.cross_pending.len() >= self.shared.config.chunk_size {
                    self.flush_cross();
                }
            }
        }
        self.shared.ingested.fetch_add(1, Ordering::Relaxed);
        self.unmetered += 1;
        if self.unmetered >= 1024 {
            self.meter_flush();
        }
        self.since_drain += 1;
        self.since_drain >= self.shared.config.drain_every
    }

    /// Restart the automatic-drain countdown (called after any drain).
    pub(crate) fn reset_drain_clock(&mut self) {
        self.since_drain = 0;
    }

    /// Send shard `w`'s pending batch to its mailbox (blocking when the
    /// mailbox is full — that *is* the backpressure).
    fn dispatch(&mut self, w: usize) {
        if self.pending[w].is_empty() {
            return;
        }
        let batch = std::mem::take(&mut self.pending[w]);
        let len = batch.len() as u64;
        // a mailbox only closes mid-run when its worker died; fail fast
        // rather than silently discarding this shard's edges for the
        // rest of a long-lived run ("edges are never dropped")
        match self.shared.mailboxes[w].send(batch) {
            Ok(()) => {
                self.shared.dispatched.fetch_add(len, Ordering::SeqCst);
            }
            Err(_) => panic!("shard worker {w} died; its mailbox is closed mid-stream"),
        }
    }

    /// Append the router-local cross batch to the shared cross log —
    /// one lock per chunk, not per edge. The log seals epochs on these
    /// boundaries.
    fn flush_cross(&mut self) {
        if self.cross_pending.is_empty() {
            return;
        }
        self.shared.crosslog.lock().unwrap().append(&mut self.cross_pending);
    }

    /// Report batched edge counts (local and cross) to the throughput
    /// meter behind `QueryHandle::stats`.
    fn meter_flush(&mut self) {
        if self.unmetered > 0 {
            self.shared.meter.lock().unwrap().add_edges(self.unmetered);
            self.unmetered = 0;
        }
    }

    /// Dispatch all partially-filled buffers (local and cross) and
    /// flush the meter.
    pub(crate) fn flush(&mut self) {
        for w in 0..self.pending.len() {
            self.dispatch(w);
        }
        self.flush_cross();
        self.meter_flush();
    }
}
