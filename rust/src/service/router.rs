//! The single routing/merge core behind every execution path.
//!
//! Until this module existed the repo carried **two** copies of the
//! route/batch/merge/replay pipeline: one inside the batch dispatcher
//! (`coordinator::parallel::run_parallel`) and one inside the service's
//! ingest path — bit-identical in behaviour, duplicated in code. Both
//! now go through here:
//!
//! * `Router` — the write-side core: partition each ingest **batch**
//!   in one pass with a precomputed `stream::shard::Sharder` (shift
//!   fast path for power-of-two shard counts), batch same-shard edges
//!   into pool-recycled per-shard chunks bound for the workers'
//!   bounded mailboxes (blocking backpressure, never drops), and
//!   append cross-shard edges to the
//!   epoch-structured cross log (`super::crosslog`) — epochs seal on
//!   these chunk boundaries, and they are also the unit the sharded
//!   drain leader ships: a drain exchanges only the epoch deltas the
//!   router created here, never the committed base they eventually
//!   fold into. `ClusterService` owns one; `run_parallel` is a thin
//!   batch preset over `ClusterService` and therefore uses the same
//!   instance type, the same code, the same semantics.
//! * [`merge_disjoint_states`] — the merge half of the core: the
//!   conflict-free array union of shard sketches that every drain and
//!   the terminal replay build on.
//!
//! One core means one place where the paper's "every edge exactly once"
//! accounting lives, and one place the golden/property suites have to
//! pin down.
//!
//! In routing-mode terms (`--route` on the CLI) this is the **funnel**:
//! one thread sees the global arrival stream, which is exactly what
//! pacing needs. Segmented binary scans can skip it —
//! `stream::pscan::DirectScan` routes in the reader threads and
//! `ClusterService::ingest_direct` muxes the pre-routed sub-chunks
//! into the same mailboxes and cross log, in the same order; with
//! durability on the readers write per-reader WAL lanes themselves
//! (`wal::DirectWal`), so the funnel's arrival-stream WAL here is one
//! of two equivalent producers of the same seq-keyed durable cut.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::coordinator::state::{StreamState, UNSEEN};
use crate::graph::edge::Edge;
use crate::stream::shard::{Route, Sharder};

use super::ingest::{record_fault, ServiceError, Shared};
use super::wal::WalSet;

/// Unreported edges accumulated before the throughput meter's mutex is
/// taken (once per ~this many edges, or at most once per batch).
const METER_FLUSH_EVERY: u64 = 1024;

/// Merge shard-disjoint worker states into one sketch (disjoint array
/// union).
///
/// Hash-sharding guarantees no two workers ever touch the same node, so
/// degrees and communities copy over and volumes add. The result is
/// sized to `max(n, largest worker state)` — workers that grew on
/// demand beyond the pre-sized `n` (the service starts them at 0) are
/// handled transparently. Shared by every snapshot drain and by the
/// terminal replay in `ClusterService::finish` (and therefore by the
/// batch path, `coordinator::parallel::run_parallel`).
///
/// Debug builds assert the disjointness invariant; a violation means
/// the caller routed one node's edges to two different workers.
pub fn merge_disjoint_states(n: usize, states: &[StreamState]) -> StreamState {
    let n = states.iter().map(|st| st.n()).fold(n, usize::max);
    let mut merged = StreamState::new(n);
    for st in states {
        for i in 0..st.n() {
            if st.degree[i] > 0 || st.community[i] != UNSEEN {
                debug_assert_eq!(merged.degree[i], 0, "shard overlap at node {i}");
                merged.degree[i] = st.degree[i];
                merged.community[i] = st.community[i];
            }
            if st.volume[i] > 0 {
                merged.volume[i] += st.volume[i];
            }
        }
        merged.edges_processed += st.edges_processed;
    }
    merged
}

/// The write-side routing core: per-shard batch buffers plus the
/// deferred cross-edge batch, all draining into the `Shared` service
/// state. Owned by `ClusterService`; not thread-safe by itself (one
/// router per ingest thread, backed by thread-safe `Shared`).
///
/// §Perf: the core is **batch-granular**. [`push_batch`](Self::push_batch)
/// is the primary entry point — one pass partitions the batch into
/// per-shard runs and the cross run through a precomputed [`Sharder`]
/// (shift fast path when `shards` is a power of two), and all
/// bookkeeping that used to run per edge (`ingested` atomic RMW, meter
/// check, drain-clock arithmetic) runs once per batch. Chunk buffers
/// come from the shared [`BufPool`](super::bufpool::BufPool) and are
/// returned by the workers, so steady-state dispatch allocates
/// nothing. [`push`](Self::push) survives as a one-edge batch for the
/// dynamic/event path.
pub(crate) struct Router {
    shared: Arc<Shared>,
    /// Precomputed shard router (pow2 shift fast path when possible).
    sharder: Sharder,
    /// Per-shard batch buffers (not yet dispatched to mailboxes);
    /// pool-backed — dispatch swaps in a recycled buffer.
    pending: Vec<Vec<Edge>>,
    /// Cross-edge batch (flushed to the shared cross log in chunks —
    /// one lock per chunk instead of one per edge). Drained in place,
    /// so its capacity is reused for the whole run.
    cross_pending: Vec<Edge>,
    /// Edges routed since the last snapshot drain.
    since_drain: u64,
    /// Edges (local *and* cross) not yet reported to the shared meter.
    unmetered: u64,
    /// Durability sink: when the service runs with a WAL directory,
    /// every routed edge is appended here — to the same per-shard /
    /// cross destination the router chose — **before** it is pushed to
    /// a pending buffer, so the log is always a superset of what the
    /// in-memory pipeline has seen. `None` on the default in-memory
    /// path (zero cost there).
    wal: Option<WalSet>,
}

impl Router {
    pub(crate) fn new(shared: Arc<Shared>, wal: Option<WalSet>) -> Self {
        let shards = shared.config.shards;
        let chunk = shared.config.chunk_size;
        Self {
            sharder: Sharder::new(shards),
            pending: (0..shards).map(|_| shared.bufpool.checkout(chunk)).collect(),
            cross_pending: Vec::with_capacity(chunk),
            since_drain: 0,
            unmetered: 0,
            wal,
            shared,
        }
    }

    /// Route one edge — a one-edge [`push_batch`](Self::push_batch),
    /// kept for the dynamic/event path. Blocks when the target shard's
    /// mailbox is full (backpressure).
    pub(crate) fn push(&mut self, e: Edge) -> bool {
        self.push_batch(std::slice::from_ref(&e))
    }

    /// Route a batch of edges — the primary ingest entry point. One
    /// pass partitions the batch into per-shard runs (dispatched as
    /// chunks whenever a pending buffer fills) and the cross run;
    /// the `ingested` counter, the meter check, and the drain clock
    /// are each touched **once per batch**, not per edge. Blocks when
    /// a target shard's mailbox is full (backpressure). Returns `true`
    /// when at least `config.drain_every` edges have accumulated since
    /// the last drain — the drain clock is batch-granular: the caller
    /// (who owns the drain) learns at the first batch boundary at or
    /// past the cadence, and must call
    /// [`reset_drain_clock`](Self::reset_drain_clock) when it drains
    /// for any other reason.
    pub(crate) fn push_batch(&mut self, batch: &[Edge]) -> bool {
        if batch.is_empty() {
            return false;
        }
        let chunk_size = self.shared.config.chunk_size;
        for &e in batch {
            match self.sharder.route(e) {
                Route::Local(w) => {
                    if let Some(wal) = self.wal.as_mut() {
                        wal.append(Some(w), e);
                    }
                    self.pending[w].push(e);
                    if self.pending[w].len() >= chunk_size {
                        self.dispatch(w);
                    }
                }
                Route::Cross => {
                    if let Some(wal) = self.wal.as_mut() {
                        wal.append(None, e);
                    }
                    self.cross_pending.push(e);
                    if self.cross_pending.len() >= chunk_size {
                        self.flush_cross();
                    }
                }
            }
        }
        if let Some(wal) = self.wal.as_mut() {
            // flush to the OS once per batch (fsync waits for the next
            // checkpoint); publish the running byte count for stats
            wal.flush();
            self.shared.wal_bytes.store(wal.bytes(), Ordering::Relaxed);
        }
        // publish the router-local cross batch size so a stats read
        // between batches sees every accepted cross edge, flushed or
        // not (the PR 9 footgun: stats before flush() undercounted)
        self.shared
            .cross_buffered
            .store(self.cross_pending.len() as u64, Ordering::Relaxed);
        let k = batch.len() as u64;
        self.shared.ingested.fetch_add(k, Ordering::Relaxed);
        self.unmetered += k;
        if self.unmetered >= METER_FLUSH_EVERY {
            self.meter_flush();
        }
        self.since_drain += k;
        self.since_drain >= self.shared.config.drain_every
    }

    /// Restart the automatic-drain countdown (called after any drain).
    pub(crate) fn reset_drain_clock(&mut self) {
        self.since_drain = 0;
    }

    /// Send shard `w`'s pending batch to its mailbox (blocking when the
    /// mailbox is full — that *is* the backpressure). The replacement
    /// pending buffer comes from the pool: in steady state it is one
    /// the worker already processed and returned, so no allocation
    /// happens here.
    fn dispatch(&mut self, w: usize) {
        if self.pending[w].is_empty() {
            return;
        }
        let fresh = self.shared.bufpool.checkout(self.shared.config.chunk_size);
        let batch = std::mem::replace(&mut self.pending[w], fresh);
        let len = batch.len() as u64;
        // a mailbox only closes mid-run when its worker died; record
        // the typed fault (first failure wins) instead of panicking —
        // the `ingested`/`dispatched` gap it leaves blocks every later
        // checkpoint, and the caller surfaces the fault as an error
        match self.shared.mailboxes[w].send(batch) {
            Ok(()) => {
                self.shared.dispatched.fetch_add(len, Ordering::SeqCst);
            }
            Err(_) => record_fault(&self.shared, ServiceError::Worker { shard: w }),
        }
    }

    /// Append the router-local cross batch to the shared cross log —
    /// one lock per chunk, not per edge. The log seals epochs on these
    /// boundaries.
    fn flush_cross(&mut self) {
        if self.cross_pending.is_empty() {
            return;
        }
        self.shared.crosslog.lock().unwrap().append(&mut self.cross_pending);
        self.shared.cross_buffered.store(0, Ordering::Relaxed);
    }

    /// Report batched edge counts (local and cross) to the throughput
    /// meter behind `QueryHandle::stats`.
    fn meter_flush(&mut self) {
        if self.unmetered > 0 {
            self.shared.meter.lock().unwrap().add_edges(self.unmetered);
            self.unmetered = 0;
        }
    }

    /// Dispatch all partially-filled buffers (local and cross) and
    /// flush the meter.
    pub(crate) fn flush(&mut self) {
        for w in 0..self.pending.len() {
            self.dispatch(w);
        }
        self.flush_cross();
        self.meter_flush();
    }

    /// Fsync every WAL destination — the durability barrier a
    /// checkpoint needs before it may claim its cut is on disk. No-op
    /// on the in-memory path.
    pub(crate) fn wal_sync(&mut self) {
        if let Some(wal) = self.wal.as_mut() {
            wal.sync();
        }
    }
}
