//! Copy-on-read snapshots and the persistent drain leader.
//!
//! The service needs valid partitions *while* the stream is still
//! flowing. Originally every drain rebuilt the queryable partition from
//! scratch: clone the shard sketches, merge, and replay the **entire**
//! cross-edge buffer — cost `O(all cross edges)`, growing with the
//! cross fraction `≈ 1 − 1/shards` of everything ever streamed. A
//! service that drains often would spend its life re-deciding old cross
//! edges.
//!
//! `LeaderState` replaces that with an **incremental** drain. It
//! persists two facts between drains:
//!
//! * per-node cross *degree* — how much degree node `i` has accumulated
//!   from already-drained cross edges (split between the committed base
//!   and the live tail), and
//! * `cross_community[i]` — the community the last drained cross-edge
//!   decision left node `i` in (its decisions are *frozen*: a drained
//!   cross edge is never re-decided mid-stream).
//!
//! A drain then costs `O(n)` to fold those frozen effects over a fresh
//! merge of the shard sketches — volumes are *derived* in one pass via
//! [`StreamState::recompute_volumes`], which is sound because
//! `v_k = Σ_{i∈k} d_i` is an invariant of the decision rule — plus
//! `O(new cross edges)` to replay only what arrived since the previous
//! drain. Amortised over a run, every cross edge is replayed **exactly
//! once** by the snapshot path (asserted via the drain counters in
//! `QueryHandle::stats`).
//!
//! Since the commit-horizon refactor the frozen state is **split in
//! two** (see `service::crosslog` for the epoch log that drives it):
//!
//! * the **committed base** ([`CommittedBase`]) — the effects of cross
//!   edges whose epochs fell behind the commit horizon. These are
//!   *final*: their edge storage has been freed, so they can never be
//!   re-replayed. The terminal replay starts from this base.
//! * the **live tail fold** (`tail_degree` + the union community view)
//!   — the effects of drained-but-uncommitted cross edges. These are
//!   frozen for mid-stream views but still provisional: `finish`
//!   discards the fold and re-replays the retained tail against the
//!   final shard sketches.
//!
//! Consistency notes, all pinned by tests:
//!
//! * Under [`CommitHorizon::Unbounded`](super::config::CommitHorizon)
//!   the committed base stays empty, so a fresh leader draining the
//!   whole log is *exactly* the old full-buffer rebuild —
//!   `Snapshot::build` is implemented that way, and it is what
//!   `ClusterService::finish` runs as the terminal replay. The
//!   **final** partition therefore never depends on how many mid-stream
//!   drains happened (golden + property suites).
//! * Under a bounded horizon the terminal replay covers only the
//!   uncommitted tail over the committed base: memory is bounded, and
//!   the final partition may differ from batch by whatever the
//!   committed mid-stream decisions pinned (golden-stream modularity
//!   within 2% of the unbounded run, asserted).
//! * Mid-stream snapshots keep every stream-end invariant (volume
//!   conservation `Σ v_k = 2t`, labels in node-id space), but between
//!   drains the frozen decisions may differ from what a from-scratch
//!   replay would decide against the newer shard volumes — the view is
//!   cheap because history is not re-litigated.

use crate::coordinator::algorithm::{StrConfig, StreamingClusterer};
use crate::coordinator::state::{StreamState, UNSEEN};
use crate::graph::edge::Edge;

use super::crosslog::FrozenDecision;
use super::router::merge_disjoint_states;

/// One row of a top-k community report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommunitySummary {
    /// Community id (lives in the node-id space).
    pub id: u32,
    /// Community volume `v_k` (sum of member degrees).
    pub volume: u64,
    /// Member count.
    pub size: u32,
}

/// The *final* effects of committed cross edges: degree contributed per
/// node, the community each node's last committed decision chose, and
/// the committed edge count. Once an epoch's decisions land here its
/// edges are gone — this base is the only trace they leave, and it is
/// what the terminal replay (and every drain) builds on.
#[derive(Debug, Clone, Default)]
pub(crate) struct CommittedBase {
    degree: Vec<u32>,
    community: Vec<u32>,
    m: u64,
}

impl CommittedBase {
    fn ensure(&mut self, i: usize) {
        if self.degree.len() <= i {
            self.degree.resize(i + 1, 0);
            self.community.resize(i + 1, UNSEEN);
        }
    }
}

/// The persistent drain leader, split along the commit horizon:
///
/// * [`CommittedBase`] — final effects of committed epochs (their edges
///   are freed; these decisions can never be re-replayed);
/// * the live tail fold — `tail_degree` plus the union community view
///   `cross_community`, covering drained-but-uncommitted cross edges
///   (provisional: `finish` discards the fold and re-replays the tail);
/// * the cursor into the cross log (global edge index).
///
/// Lives in the service's shared state behind a mutex; a fresh instance
/// draining a full log reproduces the from-scratch rebuild bit for bit.
pub(crate) struct LeaderState {
    /// Final effects of committed epochs.
    committed: CommittedBase,
    /// Degree contributed by drained-but-uncommitted cross edges.
    tail_degree: Vec<u32>,
    /// Community each node was left in by its last drained cross-edge
    /// decision — committed or tail, whichever came later (`UNSEEN` =
    /// no cross edge has touched this node). The union view folded
    /// into mid-stream snapshots.
    cross_community: Vec<u32>,
    /// Cursor into the cross log: edges `[0, drained)` (global indices)
    /// have been replayed by some earlier drain.
    drained: u64,
    /// Drained *uncommitted* cross edges that entered `edges_processed`
    /// (self-loops never route cross, so committed + tail equals
    /// `drained` in practice; kept separate so the accounting cannot
    /// drift if that ever changes).
    tail_m: u64,
}

impl LeaderState {
    pub(crate) fn new() -> Self {
        Self::over(CommittedBase::default())
    }

    /// Leader resuming from a committed base with an empty tail — the
    /// terminal replay's starting point (and, with an empty base, the
    /// from-scratch rebuild).
    pub(crate) fn over(committed: CommittedBase) -> Self {
        Self {
            tail_degree: vec![0; committed.degree.len()],
            cross_community: committed.community.clone(),
            committed,
            drained: 0,
            tail_m: 0,
        }
    }

    /// Log positions already replayed (the caller slices the cross log
    /// at this cursor before draining).
    pub(crate) fn drained(&self) -> u64 {
        self.drained
    }

    /// Drained cross edges counted into snapshot coverage (committed
    /// base + live tail).
    pub(crate) fn drained_m(&self) -> u64 {
        self.committed.m + self.tail_m
    }

    /// Cross edges whose decisions are final (committed base only).
    pub(crate) fn committed_m(&self) -> u64 {
        self.committed.m
    }

    /// Clone of the committed base — what `finish` replays the
    /// uncommitted tail over.
    pub(crate) fn committed_base(&self) -> CommittedBase {
        self.committed.clone()
    }

    /// Incremental drain: fold the frozen cross effects (committed base
    /// + live tail) over a fresh merge of `shard_states`, derive the
    /// volumes, then replay only `new_cross` (the log suffix past
    /// [`drained`](Self::drained)). When `frozen_log` is given (bounded
    /// horizon), two `(endpoint, post-decision community)` records per
    /// replayed edge are appended to it for the cross log's epochs.
    pub(crate) fn drain(
        &mut self,
        config: &StrConfig,
        shard_states: &[StreamState],
        new_cross: &[Edge],
        mut frozen_log: Option<&mut Vec<FrozenDecision>>,
    ) -> Snapshot {
        let mut base = merge_disjoint_states(0, shard_states);
        let local_edges = base.edges_processed;
        let hi = self.committed.degree.len().max(self.tail_degree.len());
        if hi > 0 {
            // frozen effects may reference ids no shard has seen yet
            base.ensure((hi - 1) as u32);
            for (i, &d) in self.committed.degree.iter().enumerate() {
                base.degree[i] += d;
            }
            for (i, &d) in self.tail_degree.iter().enumerate() {
                base.degree[i] += d;
            }
            for (i, &c) in self.cross_community.iter().enumerate() {
                if c != UNSEEN {
                    base.community[i] = c;
                }
            }
        }
        base.edges_processed += self.drained_m();
        base.recompute_volumes();

        let mut leader = StreamingClusterer::with_state(base, config.clone());
        for &e in new_cross {
            debug_assert!(!e.is_self_loop(), "self-loops must never route cross");
            if e.is_self_loop() {
                // keep the two-records-per-edge alignment; UNSEEN marks
                // the slot as carrying no decision
                if let Some(log) = frozen_log.as_deref_mut() {
                    log.push((e.u, UNSEEN));
                    log.push((e.v, UNSEEN));
                }
                continue;
            }
            leader.process_edge(e);
            self.freeze(e, &leader.state, frozen_log.as_deref_mut());
            self.tail_m += 1;
        }
        self.drained += new_cross.len() as u64;

        Snapshot {
            state: leader.state,
            local_edges,
            cross_edges: self.drained_m(),
        }
    }

    /// Freeze the outcome of one replayed cross edge: its degree
    /// contribution and the communities it left its endpoints in. A
    /// later cross edge touching the same node simply overwrites the
    /// community (last decision wins — exactly replay order).
    fn freeze(
        &mut self,
        e: Edge,
        state: &StreamState,
        frozen_log: Option<&mut Vec<FrozenDecision>>,
    ) {
        let hi = e.u.max(e.v) as usize;
        if self.tail_degree.len() <= hi {
            self.tail_degree.resize(hi + 1, 0);
            self.cross_community.resize(hi + 1, UNSEEN);
        }
        self.tail_degree[e.u as usize] += 1;
        self.tail_degree[e.v as usize] += 1;
        let cu = state.community[e.u as usize];
        let cv = state.community[e.v as usize];
        self.cross_community[e.u as usize] = cu;
        self.cross_community[e.v as usize] = cv;
        if let Some(log) = frozen_log {
            log.push((e.u, cu));
            log.push((e.v, cv));
        }
    }

    /// Fold one finalized epoch's frozen decisions into the committed
    /// base, moving their degree contribution out of the live tail.
    /// Epochs must be committed in log order (the cross log guarantees
    /// it), so overwriting the committed community per record preserves
    /// last-decision-wins. The union view (`cross_community`) already
    /// holds each node's globally-last drained decision and is
    /// untouched.
    pub(crate) fn commit_epoch(&mut self, frozen: &[FrozenDecision]) {
        let mut moved = 0u64;
        for &(node, comm) in frozen {
            if comm == UNSEEN {
                continue; // skipped slot (self-loop) — carries no decision
            }
            let i = node as usize;
            self.committed.ensure(i);
            self.committed.degree[i] += 1;
            self.committed.community[i] = comm;
            debug_assert!(
                self.tail_degree[i] > 0,
                "committing node {i} with no tail degree to move"
            );
            self.tail_degree[i] -= 1;
            moved += 1;
        }
        debug_assert_eq!(moved % 2, 0, "frozen records come in endpoint pairs");
        self.committed.m += moved / 2;
        self.tail_m -= moved / 2;
    }
}

/// An immutable, point-in-time partition of the ingested stream.
#[derive(Debug, Clone)]
pub struct Snapshot {
    state: StreamState,
    /// Intra-shard edges covered by this snapshot.
    pub local_edges: u64,
    /// Cross-shard edges replayed into this snapshot.
    pub cross_edges: u64,
}

impl Snapshot {
    /// The before-any-edges snapshot: every node is its own singleton.
    pub(crate) fn empty() -> Self {
        Self { state: StreamState::new(0), local_edges: 0, cross_edges: 0 }
    }

    /// Full-history rebuild: merge shard sketches and replay the whole
    /// cross log in arrival order. Implemented as
    /// [`build_over`](Self::build_over) with an empty committed base —
    /// the incremental path with no history is the full rebuild, so
    /// there is exactly one merge/replay implementation to trust. This
    /// is the terminal replay `ClusterService::finish` runs under
    /// `CommitHorizon::Unbounded` (and therefore the batch
    /// `run_parallel` semantics).
    pub(crate) fn build(
        config: &StrConfig,
        shard_states: &[StreamState],
        cross: &[Edge],
    ) -> Self {
        Self::build_over(config, CommittedBase::default(), shard_states, cross)
    }

    /// Terminal replay over a committed base: fold the base's final
    /// cross effects over the merged shard sketches, then replay only
    /// `tail` — the retained (uncommitted) cross edges — in arrival
    /// order with a fresh tail leader. With an empty base this *is*
    /// [`build`](Self::build); with a bounded horizon it is how
    /// `finish` avoids needing the freed history back.
    pub(crate) fn build_over(
        config: &StrConfig,
        committed: CommittedBase,
        shard_states: &[StreamState],
        tail: &[Edge],
    ) -> Self {
        LeaderState::over(committed).drain(config, shard_states, tail, None)
    }

    /// The merged sketch behind this snapshot.
    pub fn state(&self) -> &StreamState {
        &self.state
    }

    /// Edges covered by this snapshot (`t` in the paper).
    pub fn edges(&self) -> u64 {
        self.state.edges_processed
    }

    /// Current community of `node`. Nodes the stream has not mentioned
    /// yet (including ids beyond the sketch) are their own singleton.
    pub fn community_of(&self, node: u32) -> u32 {
        let i = node as usize;
        if i >= self.state.n() {
            return node;
        }
        let c = self.state.community[i];
        if c == UNSEEN {
            node
        } else {
            c
        }
    }

    /// Full label vector (unseen nodes as singletons).
    pub fn labels(&self) -> Vec<u32> {
        self.state.labels()
    }

    /// Label vector padded to `n` entries: the sketch only grows to the
    /// largest streamed id, so trailing never-seen nodes are filled in
    /// as their own singletons (for scoring against ground truth of a
    /// known node count).
    pub fn labels_padded(&self, n: usize) -> Vec<u32> {
        let mut labels = self.state.labels();
        while labels.len() < n {
            labels.push(labels.len() as u32);
        }
        labels
    }

    /// Number of non-empty communities.
    pub fn community_count(&self) -> usize {
        self.state.community_count()
    }

    /// The `k` largest communities by volume.
    pub fn top_communities(&self, k: usize) -> Vec<CommunitySummary> {
        self.state
            .community_volumes()
            .into_iter()
            .take(k)
            .map(|(id, volume, size)| CommunitySummary { id, volume, size })
            .collect()
    }

    /// Sketch bytes held by this snapshot (16 bytes/node).
    pub fn memory_bytes(&self) -> usize {
        self.state.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_all_singletons() {
        let s = Snapshot::empty();
        assert_eq!(s.edges(), 0);
        assert_eq!(s.community_of(0), 0);
        assert_eq!(s.community_of(12345), 12345);
        assert!(s.top_communities(4).is_empty());
        assert_eq!(s.community_count(), 0);
    }

    #[test]
    fn build_merges_disjoint_shards_and_replays_cross() {
        let cfg = StrConfig::new(8);
        // shard 0 owns nodes {0, 1}, shard 1 owns {5, 6}
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let cross = vec![Edge::new(1, 5)];
        let snap = Snapshot::build(&cfg, &[a.state.clone(), b.state.clone()], &cross);

        assert_eq!(snap.local_edges, 2);
        assert_eq!(snap.cross_edges, 1);
        assert_eq!(snap.edges(), 3);
        // stream-end invariant holds mid-stream
        assert_eq!(snap.state().total_volume(), 2 * snap.edges());
        // intra-shard joins survive the merge
        assert_eq!(snap.community_of(0), snap.community_of(1));
        assert_eq!(snap.community_of(5), snap.community_of(6));
    }

    #[test]
    fn incremental_drains_cover_the_same_edges_as_one_full_drain() {
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let states = [a.state.clone(), b.state.clone()];
        let cross = vec![Edge::new(1, 5), Edge::new(0, 6), Edge::new(1, 6)];

        // one edge per drain, shard states fixed between drains
        let mut leader = LeaderState::new();
        let s1 = leader.drain(&cfg, &states, &cross[..1], None);
        assert_eq!((s1.edges(), leader.drained()), (3, 1));
        let s2 = leader.drain(&cfg, &states, &cross[1..2], None);
        assert_eq!((s2.edges(), leader.drained()), (4, 2));
        let s3 = leader.drain(&cfg, &states, &cross[2..], None);
        assert_eq!((s3.edges(), leader.drained()), (5, 3));
        assert_eq!(s3.state().total_volume(), 2 * s3.edges());

        // with shard states unchanged between drains there is nothing to
        // re-decide, so the incremental result IS the full rebuild
        let full = Snapshot::build(&cfg, &states, &cross);
        assert_eq!(s3.labels(), full.labels());
        assert_eq!(s3.state().volume, full.state().volume);
        assert_eq!(s3.state().degree, full.state().degree);
    }

    #[test]
    fn leader_freezes_cross_only_nodes_beyond_every_shard() {
        // node 900 exists only in cross edges; the leader must carry it
        // across drains even though no shard sketch will ever mention it
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let states = [a.state.clone()];

        let mut leader = LeaderState::new();
        let s1 = leader.drain(&cfg, &states, &[Edge::new(0, 900)], None);
        let c900 = s1.community_of(900);
        assert!(s1.state().n() > 900);

        let s2 = leader.drain(&cfg, &states, &[], None);
        assert_eq!(s2.community_of(900), c900, "frozen decision lost");
        assert_eq!(s2.edges(), s1.edges());
        assert_eq!(s2.state().total_volume(), 2 * s2.edges());
    }

    #[test]
    fn committing_an_epoch_leaves_mid_stream_drains_unchanged() {
        // the commit fold moves effects from the tail to the committed
        // base; with shard states fixed, a drain after the commit must
        // see the exact same partition as one before it
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let states = [a.state.clone(), b.state.clone()];
        let cross = vec![Edge::new(1, 5), Edge::new(0, 6), Edge::new(1, 6)];

        let mut leader = LeaderState::new();
        let mut frozen = Vec::new();
        let before = leader.drain(&cfg, &states, &cross, Some(&mut frozen));
        assert_eq!(frozen.len(), 2 * cross.len());

        // commit the first two edges' decisions (one "epoch")
        leader.commit_epoch(&frozen[..4]);
        assert_eq!(leader.committed_m(), 2);
        assert_eq!(leader.drained_m(), 3, "commit must not change coverage");

        let after = leader.drain(&cfg, &states, &[], None);
        assert_eq!(after.labels(), before.labels());
        assert_eq!(after.state().volume, before.state().volume);
        assert_eq!(after.state().degree, before.state().degree);
        assert_eq!(after.edges(), before.edges());
    }

    #[test]
    fn build_over_committed_base_covers_base_plus_tail() {
        // drain everything, commit a prefix, then rebuild from the
        // committed base + the retained tail: coverage and invariants
        // must match the full rebuild (with static shard states the
        // partition is identical too, since nothing gets re-decided
        // against different volumes)
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let states = [a.state.clone(), b.state.clone()];
        let cross = vec![Edge::new(1, 5), Edge::new(0, 6), Edge::new(1, 6)];

        let mut leader = LeaderState::new();
        let mut frozen = Vec::new();
        leader.drain(&cfg, &states, &cross, Some(&mut frozen));
        leader.commit_epoch(&frozen[..2]); // commit the first edge

        let full = Snapshot::build(&cfg, &states, &cross);
        let over = Snapshot::build_over(
            &cfg,
            leader.committed_base(),
            &states,
            &cross[1..],
        );
        assert_eq!(over.edges(), full.edges());
        assert_eq!(over.cross_edges, full.cross_edges);
        assert_eq!(over.state().total_volume(), 2 * over.edges());
        assert_eq!(over.labels(), full.labels());
    }

    #[test]
    fn top_communities_sorted_by_volume() {
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        // triangle on {0,1,2} (volume 6) vs single edge {4,5} (volume 2)
        for e in [Edge::new(0, 1), Edge::new(1, 2), Edge::new(0, 2), Edge::new(4, 5)] {
            a.process_edge(e);
        }
        let snap = Snapshot::build(&cfg, &[a.state.clone()], &[]);
        let top = snap.top_communities(10);
        assert!(!top.is_empty());
        for w in top.windows(2) {
            assert!(w[0].volume >= w[1].volume, "{top:?}");
        }
        let total: u64 = top.iter().map(|c| c.volume).sum();
        assert_eq!(total, 2 * snap.edges());
    }
}
