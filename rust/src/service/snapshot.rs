//! Copy-on-read snapshots and the persistent drain leader.
//!
//! The service needs valid partitions *while* the stream is still
//! flowing. Originally every drain rebuilt the queryable partition from
//! scratch: clone the shard sketches, merge, and replay the **entire**
//! cross-edge buffer — cost `O(all cross edges)`, growing with the
//! cross fraction `≈ 1 − 1/shards` of everything ever streamed. A
//! service that drains often would spend its life re-deciding old cross
//! edges.
//!
//! `LeaderState` replaces that with an **incremental** drain. It
//! persists two facts between drains:
//!
//! * `cross_degree[i]` — how much degree node `i` has accumulated from
//!   already-drained cross edges, and
//! * `cross_community[i]` — the community the last drained cross-edge
//!   decision left node `i` in (its decisions are *frozen*: a drained
//!   cross edge is never re-decided).
//!
//! A drain then costs `O(n)` to fold those frozen effects over a fresh
//! merge of the shard sketches — volumes are *derived* in one pass via
//! [`StreamState::recompute_volumes`], which is sound because
//! `v_k = Σ_{i∈k} d_i` is an invariant of the decision rule — plus
//! `O(new cross edges)` to replay only what arrived since the previous
//! drain. Amortised over a run, every cross edge is replayed **exactly
//! once** by the snapshot path (asserted via the drain counters in
//! `QueryHandle::stats`).
//!
//! Two consistency notes, both pinned by tests:
//!
//! * A fresh leader draining the whole buffer is *exactly* the old
//!   full-buffer rebuild — `Snapshot::build` is implemented that way,
//!   and it is what `ClusterService::finish` runs as the terminal
//!   replay. The **final** partition therefore never depends on how
//!   many mid-stream drains happened (golden + property suites).
//! * Mid-stream snapshots keep every stream-end invariant (volume
//!   conservation `Σ v_k = 2t`, labels in node-id space), but between
//!   drains the frozen decisions may differ from what a from-scratch
//!   replay would decide against the newer shard volumes — the view is
//!   cheap because history is not re-litigated.

use crate::coordinator::algorithm::{StrConfig, StreamingClusterer};
use crate::coordinator::state::{StreamState, UNSEEN};
use crate::graph::edge::Edge;

use super::router::merge_disjoint_states;

/// One row of a top-k community report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommunitySummary {
    /// Community id (lives in the node-id space).
    pub id: u32,
    /// Community volume `v_k` (sum of member degrees).
    pub volume: u64,
    /// Member count.
    pub size: u32,
}

/// The persistent drain leader: the frozen effects of every
/// already-drained cross edge, plus the cursor into the retained
/// cross-edge buffer. Lives in the service's shared state behind a
/// mutex; a fresh instance draining a full buffer reproduces the
/// from-scratch rebuild bit for bit.
pub(crate) struct LeaderState {
    /// Degree contributed to each node by drained cross edges.
    cross_degree: Vec<u32>,
    /// Community each node was left in by its last drained cross-edge
    /// decision (`UNSEEN` = no cross edge has touched this node).
    cross_community: Vec<u32>,
    /// Cursor into the retained cross buffer: edges `[0, drained)` have
    /// been replayed by some earlier drain.
    drained: usize,
    /// Drained cross edges that entered `edges_processed` (self-loops
    /// never route cross, so this equals `drained` in practice; kept
    /// separate so the accounting cannot drift if that ever changes).
    drained_m: u64,
}

impl LeaderState {
    pub(crate) fn new() -> Self {
        Self {
            cross_degree: Vec::new(),
            cross_community: Vec::new(),
            drained: 0,
            drained_m: 0,
        }
    }

    /// Buffer positions already replayed (the caller slices the shared
    /// cross buffer at this cursor before draining).
    pub(crate) fn drained(&self) -> usize {
        self.drained
    }

    /// Drained cross edges counted into snapshot coverage.
    pub(crate) fn drained_m(&self) -> u64 {
        self.drained_m
    }

    /// Incremental drain: fold the frozen cross effects over a fresh
    /// merge of `shard_states`, derive the volumes, then replay only
    /// `new_cross` (the buffer suffix past [`drained`](Self::drained)).
    pub(crate) fn drain(
        &mut self,
        config: &StrConfig,
        shard_states: &[StreamState],
        new_cross: &[Edge],
    ) -> Snapshot {
        let mut base = merge_disjoint_states(0, shard_states);
        let local_edges = base.edges_processed;
        if !self.cross_degree.is_empty() {
            // frozen effects may reference ids no shard has seen yet
            base.ensure((self.cross_degree.len() - 1) as u32);
            for i in 0..self.cross_degree.len() {
                base.degree[i] += self.cross_degree[i];
                let c = self.cross_community[i];
                if c != UNSEEN {
                    base.community[i] = c;
                }
            }
        }
        base.edges_processed += self.drained_m;
        base.recompute_volumes();

        let mut leader = StreamingClusterer::with_state(base, config.clone());
        for &e in new_cross {
            debug_assert!(!e.is_self_loop(), "self-loops must never route cross");
            if e.is_self_loop() {
                continue;
            }
            leader.process_edge(e);
            self.freeze(e, &leader.state);
            self.drained_m += 1;
        }
        self.drained += new_cross.len();

        Snapshot {
            state: leader.state,
            local_edges,
            cross_edges: self.drained_m,
        }
    }

    /// Freeze the outcome of one replayed cross edge: its degree
    /// contribution and the communities it left its endpoints in. A
    /// later cross edge touching the same node simply overwrites the
    /// community (last decision wins — exactly replay order).
    fn freeze(&mut self, e: Edge, state: &StreamState) {
        let hi = e.u.max(e.v) as usize;
        if self.cross_degree.len() <= hi {
            self.cross_degree.resize(hi + 1, 0);
            self.cross_community.resize(hi + 1, UNSEEN);
        }
        self.cross_degree[e.u as usize] += 1;
        self.cross_degree[e.v as usize] += 1;
        self.cross_community[e.u as usize] = state.community[e.u as usize];
        self.cross_community[e.v as usize] = state.community[e.v as usize];
    }
}

/// An immutable, point-in-time partition of the ingested stream.
#[derive(Debug, Clone)]
pub struct Snapshot {
    state: StreamState,
    /// Intra-shard edges covered by this snapshot.
    pub local_edges: u64,
    /// Cross-shard edges replayed into this snapshot.
    pub cross_edges: u64,
}

impl Snapshot {
    /// The before-any-edges snapshot: every node is its own singleton.
    pub(crate) fn empty() -> Self {
        Self { state: StreamState::new(0), local_edges: 0, cross_edges: 0 }
    }

    /// Full-buffer rebuild: merge shard sketches and replay the whole
    /// cross buffer in arrival order. Implemented as a *fresh*
    /// `LeaderState` draining everything — the incremental path with
    /// no history is the full rebuild, so there is exactly one
    /// merge/replay implementation to trust. This is the terminal
    /// replay `ClusterService::finish` runs (and therefore the batch
    /// `run_parallel` semantics).
    pub(crate) fn build(
        config: &StrConfig,
        shard_states: &[StreamState],
        cross: &[Edge],
    ) -> Self {
        LeaderState::new().drain(config, shard_states, cross)
    }

    /// The merged sketch behind this snapshot.
    pub fn state(&self) -> &StreamState {
        &self.state
    }

    /// Edges covered by this snapshot (`t` in the paper).
    pub fn edges(&self) -> u64 {
        self.state.edges_processed
    }

    /// Current community of `node`. Nodes the stream has not mentioned
    /// yet (including ids beyond the sketch) are their own singleton.
    pub fn community_of(&self, node: u32) -> u32 {
        let i = node as usize;
        if i >= self.state.n() {
            return node;
        }
        let c = self.state.community[i];
        if c == UNSEEN {
            node
        } else {
            c
        }
    }

    /// Full label vector (unseen nodes as singletons).
    pub fn labels(&self) -> Vec<u32> {
        self.state.labels()
    }

    /// Label vector padded to `n` entries: the sketch only grows to the
    /// largest streamed id, so trailing never-seen nodes are filled in
    /// as their own singletons (for scoring against ground truth of a
    /// known node count).
    pub fn labels_padded(&self, n: usize) -> Vec<u32> {
        let mut labels = self.state.labels();
        while labels.len() < n {
            labels.push(labels.len() as u32);
        }
        labels
    }

    /// Number of non-empty communities.
    pub fn community_count(&self) -> usize {
        self.state.community_count()
    }

    /// The `k` largest communities by volume.
    pub fn top_communities(&self, k: usize) -> Vec<CommunitySummary> {
        self.state
            .community_volumes()
            .into_iter()
            .take(k)
            .map(|(id, volume, size)| CommunitySummary { id, volume, size })
            .collect()
    }

    /// Sketch bytes held by this snapshot (16 bytes/node).
    pub fn memory_bytes(&self) -> usize {
        self.state.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_all_singletons() {
        let s = Snapshot::empty();
        assert_eq!(s.edges(), 0);
        assert_eq!(s.community_of(0), 0);
        assert_eq!(s.community_of(12345), 12345);
        assert!(s.top_communities(4).is_empty());
        assert_eq!(s.community_count(), 0);
    }

    #[test]
    fn build_merges_disjoint_shards_and_replays_cross() {
        let cfg = StrConfig::new(8);
        // shard 0 owns nodes {0, 1}, shard 1 owns {5, 6}
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let cross = vec![Edge::new(1, 5)];
        let snap = Snapshot::build(&cfg, &[a.state.clone(), b.state.clone()], &cross);

        assert_eq!(snap.local_edges, 2);
        assert_eq!(snap.cross_edges, 1);
        assert_eq!(snap.edges(), 3);
        // stream-end invariant holds mid-stream
        assert_eq!(snap.state().total_volume(), 2 * snap.edges());
        // intra-shard joins survive the merge
        assert_eq!(snap.community_of(0), snap.community_of(1));
        assert_eq!(snap.community_of(5), snap.community_of(6));
    }

    #[test]
    fn incremental_drains_cover_the_same_edges_as_one_full_drain() {
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let states = [a.state.clone(), b.state.clone()];
        let cross = vec![Edge::new(1, 5), Edge::new(0, 6), Edge::new(1, 6)];

        // one edge per drain, shard states fixed between drains
        let mut leader = LeaderState::new();
        let s1 = leader.drain(&cfg, &states, &cross[..1]);
        assert_eq!((s1.edges(), leader.drained()), (3, 1));
        let s2 = leader.drain(&cfg, &states, &cross[1..2]);
        assert_eq!((s2.edges(), leader.drained()), (4, 2));
        let s3 = leader.drain(&cfg, &states, &cross[2..]);
        assert_eq!((s3.edges(), leader.drained()), (5, 3));
        assert_eq!(s3.state().total_volume(), 2 * s3.edges());

        // with shard states unchanged between drains there is nothing to
        // re-decide, so the incremental result IS the full rebuild
        let full = Snapshot::build(&cfg, &states, &cross);
        assert_eq!(s3.labels(), full.labels());
        assert_eq!(s3.state().volume, full.state().volume);
        assert_eq!(s3.state().degree, full.state().degree);
    }

    #[test]
    fn leader_freezes_cross_only_nodes_beyond_every_shard() {
        // node 900 exists only in cross edges; the leader must carry it
        // across drains even though no shard sketch will ever mention it
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let states = [a.state.clone()];

        let mut leader = LeaderState::new();
        let s1 = leader.drain(&cfg, &states, &[Edge::new(0, 900)]);
        let c900 = s1.community_of(900);
        assert!(s1.state().n() > 900);

        let s2 = leader.drain(&cfg, &states, &[]);
        assert_eq!(s2.community_of(900), c900, "frozen decision lost");
        assert_eq!(s2.edges(), s1.edges());
        assert_eq!(s2.state().total_volume(), 2 * s2.edges());
    }

    #[test]
    fn top_communities_sorted_by_volume() {
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        // triangle on {0,1,2} (volume 6) vs single edge {4,5} (volume 2)
        for e in [Edge::new(0, 1), Edge::new(1, 2), Edge::new(0, 2), Edge::new(4, 5)] {
            a.process_edge(e);
        }
        let snap = Snapshot::build(&cfg, &[a.state.clone()], &[]);
        let top = snap.top_communities(10);
        assert!(!top.is_empty());
        for w in top.windows(2) {
            assert!(w[0].volume >= w[1].volume, "{top:?}");
        }
        let total: u64 = top.iter().map(|c| c.volume).sum();
        assert_eq!(total, 2 * snap.edges());
    }
}
