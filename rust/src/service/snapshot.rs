//! Copy-on-read snapshots, the thin drain merger, and the sharded
//! committed-base leaders.
//!
//! The service needs valid partitions *while* the stream is still
//! flowing. Originally every drain rebuilt the queryable partition from
//! scratch: clone the shard sketches, merge, and replay the **entire**
//! cross-edge buffer — cost `O(all cross edges)`. The persistent leader
//! (PR 2) made drains incremental; the commit horizon (PR 3) made the
//! retained log bounded. This revision splits the leader itself so a
//! drain no longer touches the committed base at all:
//!
//! * `Merger` — the thin merger. Persists, per node, the **total**
//!   cross degree contributed by already-drained cross edges
//!   (`fold_degree`) and the community the last drained decision left
//!   the node in (`cross_community` — frozen: a drained cross edge is
//!   never re-decided mid-stream). Because a commit only *moves* a
//!   record from the live tail into a committed-base slice — the
//!   per-node degree sum and the last-decision community are invariant
//!   under that move — the merger's fold needs no update when epochs
//!   commit. A mid-stream drain therefore reads **only** the merger
//!   fold (`O(n)`) and the cross edges that arrived since the previous
//!   drain; the committed base, however large, is never re-read and
//!   never re-shipped.
//! * `LeaderShard` — one per leader partition. Owns the
//!   `CommittedBase` **slice** for its node range
//!   (`shard_of(node, leaders)`): the final effects of committed
//!   epochs. Commits arrive as per-epoch frozen-record slices (the
//!   epoch delta) and fold in locally — no cross-partition
//!   coordination, no merger involvement.
//! * `merge_committed_bases` — the disjoint-node-range merge rule:
//!   each node's committed records all live in exactly one slice
//!   (its owner's), so the merge is a conflict-free array union, and
//!   "per node, last committed epoch wins" is preserved because each
//!   slice receives its records in global commit order. Run **once**,
//!   at `finish`, to assemble the base the terminal replay starts from
//!   — the only moment the base slices are read as a whole.
//!
//! A drain costs `O(n)` to fold the merger state over a fresh merge of
//! the shard sketches — volumes are *derived* in one pass via
//! [`StreamState::recompute_volumes`], which is sound because
//! `v_k = Σ_{i∈k} d_i` is an invariant of the decision rule — plus
//! `O(new cross edges)` to replay only what arrived since the previous
//! drain. Amortised over a run, every cross edge is replayed **exactly
//! once** by the snapshot path (asserted via the drain counters in
//! `QueryHandle::stats`).
//!
//! Consistency notes, all pinned by tests:
//!
//! * Under [`CommitHorizon::Unbounded`](super::config::CommitHorizon)
//!   nothing commits, every base slice stays empty, and a fresh merger
//!   draining the whole log is *exactly* the old full-buffer rebuild —
//!   `Snapshot::build` is implemented that way, and it is what
//!   `ClusterService::finish` runs as the terminal replay. The
//!   **final** partition therefore never depends on how many mid-stream
//!   drains happened (golden + property suites).
//! * The leader partition count never changes results — only where
//!   committed state lives. Merging K base slices reproduces the
//!   single-leader base bit for bit (property-tested below across
//!   partition counts × horizons).
//! * Under a bounded horizon the terminal replay covers only the
//!   uncommitted tail over the merged base: memory is bounded, and the
//!   final partition may differ from batch by whatever the committed
//!   mid-stream decisions pinned (golden-stream modularity within 2%
//!   of the unbounded run, asserted).
//! * Mid-stream snapshots keep every stream-end invariant (volume
//!   conservation `Σ v_k = 2t`, labels in node-id space), but between
//!   drains the frozen decisions may differ from what a from-scratch
//!   replay would decide against the newer shard volumes — the view is
//!   cheap because history is not re-litigated.

use crate::coordinator::algorithm::{StrConfig, StreamingClusterer};
use crate::coordinator::state::{StreamState, UNSEEN};
use crate::graph::edge::Edge;
use crate::stream::shard::shard_of;

use super::crosslog::{FrozenDecision, BYTES_PER_FROZEN_ENTRY};
use super::router::merge_disjoint_states;

/// One row of a top-k community report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommunitySummary {
    /// Community id (lives in the node-id space).
    pub id: u32,
    /// Community volume `v_k` (sum of member degrees).
    pub volume: u64,
    /// Member count.
    pub size: u32,
}

/// The *final* effects of committed cross edges: degree contributed per
/// node, the community each node's last committed decision chose, and
/// the committed record count. Once an epoch's decisions land here its
/// edges are gone — this base is the only trace they leave. Each
/// [`LeaderShard`] owns one **slice** (only nodes in its range are ever
/// populated); [`merge_committed_bases`] assembles the whole for the
/// terminal replay.
#[derive(Debug, Clone, Default)]
pub(crate) struct CommittedBase {
    degree: Vec<u32>,
    community: Vec<u32>,
    /// Committed endpoint records folded in (two per committed cross
    /// edge — a slice may hold an odd count when an edge's endpoints
    /// have different owners, so this counts half-edges, not edges).
    records: u64,
}

/// A committed-base slice flattened for checkpointing (field-for-field
/// image of [`CommittedBase`]).
#[derive(Debug, Clone)]
pub(crate) struct BaseExport {
    /// Per-node committed cross degree.
    pub degree: Vec<u32>,
    /// Per-node last committed community (`UNSEEN` = untouched).
    pub community: Vec<u32>,
    /// Committed endpoint records folded in.
    pub records: u64,
}

/// The merger's durable image for checkpointing: the fold arrays plus
/// both drain cursors.
#[derive(Debug, Clone)]
pub(crate) struct MergerExport {
    /// Per-node degree from drained cross edges.
    pub fold_degree: Vec<u32>,
    /// Per-node last drained community (`UNSEEN` = untouched).
    pub cross_community: Vec<u32>,
    /// Cross-log positions already replayed.
    pub drained: u64,
    /// Drained cross edges counted into coverage.
    pub drained_m: u64,
}

impl CommittedBase {
    fn ensure(&mut self, i: usize) {
        if self.degree.len() <= i {
            self.degree.resize(i + 1, 0);
            self.community.resize(i + 1, UNSEEN);
        }
    }

    /// Flatten for checkpointing.
    pub(crate) fn export(&self) -> BaseExport {
        BaseExport {
            degree: self.degree.clone(),
            community: self.community.clone(),
            records: self.records,
        }
    }

    /// Rebuild from a checkpoint image.
    pub(crate) fn from_parts(e: BaseExport) -> Self {
        Self { degree: e.degree, community: e.community, records: e.records }
    }

    /// Committed cross edges covered (meaningful on a merged base or a
    /// single-partition slice, where both endpoints of every committed
    /// edge are present).
    pub(crate) fn m(&self) -> u64 {
        self.records / 2
    }

    /// Committed endpoint records folded into this slice.
    pub(crate) fn records(&self) -> u64 {
        self.records
    }
}

/// Merge disjoint committed-base slices into the whole base.
///
/// The merge rule for disjoint node ranges: every node's committed
/// records were all routed to its owning partition, so at most one
/// slice has data for any node — degrees and communities copy over
/// (debug-asserted disjoint) and record counts add. "Per node, last
/// committed epoch wins" needs no tie-break here: it was already
/// enforced inside the owning slice, which received the node's records
/// in global commit order.
pub(crate) fn merge_committed_bases(slices: &[CommittedBase]) -> CommittedBase {
    let n = slices.iter().map(|b| b.degree.len()).max().unwrap_or(0);
    let mut out = CommittedBase::default();
    if n > 0 {
        out.ensure(n - 1);
    }
    for b in slices {
        for i in 0..b.degree.len() {
            if b.degree[i] > 0 || b.community[i] != UNSEEN {
                debug_assert!(
                    out.degree[i] == 0 && out.community[i] == UNSEEN,
                    "leader base slices overlap at node {i}"
                );
                out.degree[i] = b.degree[i];
                out.community[i] = b.community[i];
            }
        }
        out.records += b.records;
    }
    out
}

/// One leader partition: the committed-base slice for its node range.
/// Commits fold epoch-delta frozen records in locally; nothing else
/// ever writes here, and mid-stream drains never read it — the slices
/// are only assembled (once) by the terminal replay.
pub(crate) struct LeaderShard {
    /// Partition index (owner of node `i` ⇔ `shard_of(i, of) == id`).
    id: usize,
    /// Partition count.
    of: usize,
    base: CommittedBase,
}

impl LeaderShard {
    pub(crate) fn new(id: usize, of: usize) -> Self {
        debug_assert!(id < of.max(1));
        Self { id, of: of.max(1), base: CommittedBase::default() }
    }

    /// Fold one epoch's frozen-record slice for this partition into the
    /// committed base slice. Records arrive in global commit order
    /// (epochs commit oldest-first, slices preserve replay order), so
    /// overwriting the community per record is last-decision-wins.
    pub(crate) fn commit(&mut self, frozen: &[FrozenDecision]) {
        for &(node, comm) in frozen {
            if comm == UNSEEN {
                continue; // skipped slot (self-loop) — carries no decision
            }
            debug_assert_eq!(
                shard_of(node, self.of),
                self.id,
                "record for node {node} shipped to the wrong leader partition"
            );
            let i = node as usize;
            self.base.ensure(i);
            self.base.degree[i] += 1;
            self.base.community[i] = comm;
            self.base.records += 1;
        }
    }

    /// Rebuild a partition from a checkpointed base slice.
    pub(crate) fn restore(id: usize, of: usize, base: CommittedBase) -> Self {
        debug_assert!(id < of.max(1));
        Self { id, of: of.max(1), base }
    }

    /// This partition's committed-base slice.
    pub(crate) fn base(&self) -> &CommittedBase {
        &self.base
    }

    /// Committed endpoint records held by this slice.
    pub(crate) fn committed_records(&self) -> u64 {
        self.base.records
    }

    /// Logical bytes of committed decision state this slice carries
    /// (the payload a fresh replica would have to fetch to adopt it).
    pub(crate) fn committed_bytes(&self) -> u64 {
        self.base.records * BYTES_PER_FROZEN_ENTRY
    }
}

/// The thin drain merger: the only state a mid-stream drain needs.
///
/// * `fold_degree[i]` — total degree node `i` accumulated from **all**
///   drained cross edges, committed or not. Commits move records
///   between the tail and a base slice without changing this sum, so
///   the merger is commit-invariant by construction.
/// * `cross_community[i]` — the community the last drained cross-edge
///   decision left node `i` in (`UNSEEN` = untouched). Also
///   commit-invariant: the union view already reflects the globally
///   last decision.
/// * the cursor into the cross log and the drained-edge count.
///
/// Lives in the service's shared state behind a mutex; a fresh instance
/// draining a full log reproduces the from-scratch rebuild bit for bit.
pub(crate) struct Merger {
    /// Per-node degree from drained cross edges (committed + tail).
    fold_degree: Vec<u32>,
    /// Community each node was left in by its last drained cross-edge
    /// decision (`UNSEEN` = no cross edge has touched this node).
    cross_community: Vec<u32>,
    /// Cursor into the cross log: edges `[0, drained)` (global indices)
    /// have been replayed by some earlier drain.
    drained: u64,
    /// Drained cross edges that entered `edges_processed` (self-loops
    /// never route cross, so this equals `drained` in practice; kept
    /// separate so the accounting cannot drift if that ever changes).
    drained_m: u64,
}

impl Merger {
    pub(crate) fn new() -> Self {
        Self::over(CommittedBase::default())
    }

    /// Merger resuming from a (merged) committed base with an empty
    /// tail — the terminal replay's starting point (and, with an empty
    /// base, the from-scratch rebuild).
    pub(crate) fn over(base: CommittedBase) -> Self {
        Self {
            drained_m: base.m(),
            fold_degree: base.degree,
            cross_community: base.community,
            drained: 0,
        }
    }

    /// Flatten for checkpointing.
    pub(crate) fn export(&self) -> MergerExport {
        MergerExport {
            fold_degree: self.fold_degree.clone(),
            cross_community: self.cross_community.clone(),
            drained: self.drained,
            drained_m: self.drained_m,
        }
    }

    /// Rebuild from a checkpoint image — unlike [`over`](Self::over),
    /// this restores the drain cursors verbatim, so the next drain
    /// resumes exactly where the checkpointed one left off.
    pub(crate) fn resume(e: MergerExport) -> Self {
        Self {
            fold_degree: e.fold_degree,
            cross_community: e.cross_community,
            drained: e.drained,
            drained_m: e.drained_m,
        }
    }

    /// Log positions already replayed (the caller slices the cross log
    /// at this cursor before draining).
    pub(crate) fn drained(&self) -> u64 {
        self.drained
    }

    /// Drained cross edges counted into snapshot coverage.
    pub(crate) fn drained_m(&self) -> u64 {
        self.drained_m
    }

    /// Incremental drain: fold the frozen cross effects over a fresh
    /// merge of `shard_states`, derive the volumes, then replay only
    /// `new_cross` (the log suffix past [`drained`](Self::drained)).
    /// When `frozen_log` is given (bounded horizon), two
    /// `(endpoint, post-decision community)` records per replayed edge
    /// are appended to it for the cross log's epochs.
    pub(crate) fn drain(
        &mut self,
        config: &StrConfig,
        shard_states: &[StreamState],
        new_cross: &[Edge],
        mut frozen_log: Option<&mut Vec<FrozenDecision>>,
    ) -> Snapshot {
        let mut base = merge_disjoint_states(0, shard_states);
        let local_edges = base.edges_processed;
        let hi = self.fold_degree.len();
        if hi > 0 {
            // frozen effects may reference ids no shard has seen yet
            base.ensure((hi - 1) as u32);
            for (i, &d) in self.fold_degree.iter().enumerate() {
                base.degree[i] += d;
            }
            for (i, &c) in self.cross_community.iter().enumerate() {
                if c != UNSEEN {
                    base.community[i] = c;
                }
            }
        }
        base.edges_processed += self.drained_m;
        base.recompute_volumes();

        let mut leader = StreamingClusterer::with_state(base, config.clone());
        for &e in new_cross {
            debug_assert!(!e.is_self_loop(), "self-loops must never route cross");
            if e.is_self_loop() {
                // keep the two-records-per-edge alignment; UNSEEN marks
                // the slot as carrying no decision
                if let Some(log) = frozen_log.as_deref_mut() {
                    log.push((e.u, UNSEEN));
                    log.push((e.v, UNSEEN));
                }
                continue;
            }
            leader.process_edge(e);
            self.freeze(e, &leader.state, frozen_log.as_deref_mut());
            self.drained_m += 1;
        }
        self.drained += new_cross.len() as u64;

        Snapshot {
            state: leader.state,
            local_edges,
            cross_edges: self.drained_m,
        }
    }

    /// Freeze the outcome of one replayed cross edge: its degree
    /// contribution and the communities it left its endpoints in. A
    /// later cross edge touching the same node simply overwrites the
    /// community (last decision wins — exactly replay order).
    fn freeze(
        &mut self,
        e: Edge,
        state: &StreamState,
        frozen_log: Option<&mut Vec<FrozenDecision>>,
    ) {
        let hi = e.u.max(e.v) as usize;
        if self.fold_degree.len() <= hi {
            self.fold_degree.resize(hi + 1, 0);
            self.cross_community.resize(hi + 1, UNSEEN);
        }
        self.fold_degree[e.u as usize] += 1;
        self.fold_degree[e.v as usize] += 1;
        let cu = state.community[e.u as usize];
        let cv = state.community[e.v as usize];
        self.cross_community[e.u as usize] = cu;
        self.cross_community[e.v as usize] = cv;
        if let Some(log) = frozen_log {
            log.push((e.u, cu));
            log.push((e.v, cv));
        }
    }
}

/// An immutable, point-in-time partition of the ingested stream.
#[derive(Debug, Clone)]
pub struct Snapshot {
    state: StreamState,
    /// Intra-shard edges covered by this snapshot.
    pub local_edges: u64,
    /// Cross-shard edges replayed into this snapshot.
    pub cross_edges: u64,
}

impl Snapshot {
    /// The before-any-edges snapshot: every node is its own singleton.
    pub(crate) fn empty() -> Self {
        Self { state: StreamState::new(0), local_edges: 0, cross_edges: 0 }
    }

    /// Full-history rebuild: merge shard sketches and replay the whole
    /// cross log in arrival order. Implemented as
    /// [`build_over`](Self::build_over) with an empty committed base —
    /// the incremental path with no history is the full rebuild, so
    /// there is exactly one merge/replay implementation to trust. This
    /// is the terminal replay `ClusterService::finish` runs under
    /// `CommitHorizon::Unbounded` (and therefore the batch
    /// `run_parallel` semantics).
    pub(crate) fn build(
        config: &StrConfig,
        shard_states: &[StreamState],
        cross: &[Edge],
    ) -> Self {
        Self::build_over(config, CommittedBase::default(), shard_states, cross)
    }

    /// Terminal replay over a (merged) committed base: fold the base's
    /// final cross effects over the merged shard sketches, then replay
    /// only `tail` — the retained (uncommitted) cross edges — in
    /// arrival order with a fresh tail merger. With an empty base this
    /// *is* [`build`](Self::build); with a bounded horizon it is how
    /// `finish` avoids needing the freed history back.
    pub(crate) fn build_over(
        config: &StrConfig,
        committed: CommittedBase,
        shard_states: &[StreamState],
        tail: &[Edge],
    ) -> Self {
        Merger::over(committed).drain(config, shard_states, tail, None)
    }

    /// The merged sketch behind this snapshot.
    pub fn state(&self) -> &StreamState {
        &self.state
    }

    /// Edges covered by this snapshot (`t` in the paper).
    pub fn edges(&self) -> u64 {
        self.state.edges_processed
    }

    /// Current community of `node`. Nodes the stream has not mentioned
    /// yet (including ids beyond the sketch) are their own singleton.
    pub fn community_of(&self, node: u32) -> u32 {
        let i = node as usize;
        if i >= self.state.n() {
            return node;
        }
        let c = self.state.community[i];
        if c == UNSEEN {
            node
        } else {
            c
        }
    }

    /// Full label vector (unseen nodes as singletons).
    pub fn labels(&self) -> Vec<u32> {
        self.state.labels()
    }

    /// Label vector padded to `n` entries: the sketch only grows to the
    /// largest streamed id, so trailing never-seen nodes are filled in
    /// as their own singletons (for scoring against ground truth of a
    /// known node count).
    pub fn labels_padded(&self, n: usize) -> Vec<u32> {
        let mut labels = self.state.labels();
        while labels.len() < n {
            labels.push(labels.len() as u32);
        }
        labels
    }

    /// Number of non-empty communities.
    pub fn community_count(&self) -> usize {
        self.state.community_count()
    }

    /// The `k` largest communities by volume.
    pub fn top_communities(&self, k: usize) -> Vec<CommunitySummary> {
        self.state
            .community_volumes()
            .into_iter()
            .take(k)
            .map(|(id, volume, size)| CommunitySummary { id, volume, size })
            .collect()
    }

    /// Sketch bytes held by this snapshot (16 bytes/node).
    pub fn memory_bytes(&self) -> usize {
        self.state.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::CommitHorizon;
    use super::super::crosslog::CrossLog;
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn empty_snapshot_is_all_singletons() {
        let s = Snapshot::empty();
        assert_eq!(s.edges(), 0);
        assert_eq!(s.community_of(0), 0);
        assert_eq!(s.community_of(12345), 12345);
        assert!(s.top_communities(4).is_empty());
        assert_eq!(s.community_count(), 0);
    }

    #[test]
    fn build_merges_disjoint_shards_and_replays_cross() {
        let cfg = StrConfig::new(8);
        // shard 0 owns nodes {0, 1}, shard 1 owns {5, 6}
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let cross = vec![Edge::new(1, 5)];
        let snap = Snapshot::build(&cfg, &[a.state.clone(), b.state.clone()], &cross);

        assert_eq!(snap.local_edges, 2);
        assert_eq!(snap.cross_edges, 1);
        assert_eq!(snap.edges(), 3);
        // stream-end invariant holds mid-stream
        assert_eq!(snap.state().total_volume(), 2 * snap.edges());
        // intra-shard joins survive the merge
        assert_eq!(snap.community_of(0), snap.community_of(1));
        assert_eq!(snap.community_of(5), snap.community_of(6));
    }

    #[test]
    fn incremental_drains_cover_the_same_edges_as_one_full_drain() {
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let states = [a.state.clone(), b.state.clone()];
        let cross = vec![Edge::new(1, 5), Edge::new(0, 6), Edge::new(1, 6)];

        // one edge per drain, shard states fixed between drains
        let mut merger = Merger::new();
        let s1 = merger.drain(&cfg, &states, &cross[..1], None);
        assert_eq!((s1.edges(), merger.drained()), (3, 1));
        let s2 = merger.drain(&cfg, &states, &cross[1..2], None);
        assert_eq!((s2.edges(), merger.drained()), (4, 2));
        let s3 = merger.drain(&cfg, &states, &cross[2..], None);
        assert_eq!((s3.edges(), merger.drained()), (5, 3));
        assert_eq!(s3.state().total_volume(), 2 * s3.edges());

        // with shard states unchanged between drains there is nothing to
        // re-decide, so the incremental result IS the full rebuild
        let full = Snapshot::build(&cfg, &states, &cross);
        assert_eq!(s3.labels(), full.labels());
        assert_eq!(s3.state().volume, full.state().volume);
        assert_eq!(s3.state().degree, full.state().degree);
    }

    #[test]
    fn merger_freezes_cross_only_nodes_beyond_every_shard() {
        // node 900 exists only in cross edges; the merger must carry it
        // across drains even though no shard sketch will ever mention it
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let states = [a.state.clone()];

        let mut merger = Merger::new();
        let s1 = merger.drain(&cfg, &states, &[Edge::new(0, 900)], None);
        let c900 = s1.community_of(900);
        assert!(s1.state().n() > 900);

        let s2 = merger.drain(&cfg, &states, &[], None);
        assert_eq!(s2.community_of(900), c900, "frozen decision lost");
        assert_eq!(s2.edges(), s1.edges());
        assert_eq!(s2.state().total_volume(), 2 * s2.edges());
    }

    #[test]
    fn committing_an_epoch_leaves_mid_stream_drains_unchanged() {
        // a commit only moves frozen records into a leader's base slice;
        // the merger fold is invariant under it, so a drain after the
        // commit must see the exact same partition as one before it
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let states = [a.state.clone(), b.state.clone()];
        let cross = vec![Edge::new(1, 5), Edge::new(0, 6), Edge::new(1, 6)];

        let mut merger = Merger::new();
        let mut frozen = Vec::new();
        let before = merger.drain(&cfg, &states, &cross, Some(&mut frozen));
        assert_eq!(frozen.len(), 2 * cross.len());

        // commit the first two edges' decisions (one "epoch") into a
        // single-partition leader
        let mut shard = LeaderShard::new(0, 1);
        shard.commit(&frozen[..4]);
        assert_eq!(shard.base().m(), 2);
        assert_eq!(merger.drained_m(), 3, "commit must not change coverage");

        let after = merger.drain(&cfg, &states, &[], None);
        assert_eq!(after.labels(), before.labels());
        assert_eq!(after.state().volume, before.state().volume);
        assert_eq!(after.state().degree, before.state().degree);
        assert_eq!(after.edges(), before.edges());
    }

    #[test]
    fn build_over_committed_base_covers_base_plus_tail() {
        // drain everything, commit a prefix, then rebuild from the
        // committed base + the retained tail: coverage and invariants
        // must match the full rebuild (with static shard states the
        // partition is identical too, since nothing gets re-decided
        // against different volumes)
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let states = [a.state.clone(), b.state.clone()];
        let cross = vec![Edge::new(1, 5), Edge::new(0, 6), Edge::new(1, 6)];

        let mut merger = Merger::new();
        let mut frozen = Vec::new();
        merger.drain(&cfg, &states, &cross, Some(&mut frozen));
        let mut shard = LeaderShard::new(0, 1);
        shard.commit(&frozen[..2]); // commit the first edge

        let full = Snapshot::build(&cfg, &states, &cross);
        let over = Snapshot::build_over(
            &cfg,
            merge_committed_bases(&[shard.base().clone()]),
            &states,
            &cross[1..],
        );
        assert_eq!(over.edges(), full.edges());
        assert_eq!(over.cross_edges, full.cross_edges);
        assert_eq!(over.state().total_volume(), 2 * over.edges());
        assert_eq!(over.labels(), full.labels());
    }

    #[test]
    fn merge_routes_each_node_to_exactly_one_slice() {
        // three partitions, records hand-routed exactly as the cross log
        // does it: owner = shard_of(node, 3)
        let of = 3usize;
        let mut shards: Vec<LeaderShard> =
            (0..of).map(|l| LeaderShard::new(l, of)).collect();
        let records: Vec<FrozenDecision> =
            (0..40u32).flat_map(|i| [(i, i % 5), (i + 1, i % 5)]).collect();
        for &(node, comm) in &records {
            shards[shard_of(node, of)].commit(&[(node, comm)]);
        }
        let merged =
            merge_committed_bases(&shards.iter().map(|s| s.base().clone()).collect::<Vec<_>>());
        // vs the single-partition fold of the same record stream
        let mut single = LeaderShard::new(0, 1);
        single.commit(&records);
        assert_eq!(merged.degree, single.base().degree);
        assert_eq!(merged.community, single.base().community);
        assert_eq!(merged.records(), single.base().records());
        assert_eq!(merged.m(), 40);
    }

    /// The sharded-base merge rule, end to end and deterministic: drive
    /// the cross log + merger + K leader shards by hand (no threads, so
    /// drain points are identical across K) and check that merging the
    /// K per-partition base slices reproduces the single-leader base —
    /// and the same terminal partition — for the same committed epochs,
    /// across partition counts {1, 2, 4} × horizons.
    #[test]
    fn sharded_base_merge_equals_single_leader_across_horizons() {
        property("sharded base merge ≡ single leader", 12, |rng, size| {
            let n = size.max(4);
            let cfg = StrConfig::new(1 + rng.next_below(100));
            // fixed shard sketch over a few local edges
            let mut a = StreamingClusterer::new(0, cfg.clone());
            for _ in 0..size {
                let u = rng.range(0, n) as u32;
                let v = rng.range(0, n) as u32;
                if u != v {
                    a.process_edge(Edge::new(u, v));
                }
            }
            let states = [a.state.clone()];

            // a random cross stream and a fixed chunking of it
            let m = size * 3 + 8;
            let cross: Vec<Edge> = (0..m)
                .map(|_| {
                    let u = rng.range(0, n) as u32;
                    let mut v = rng.range(0, n) as u32;
                    if u == v {
                        v = (v + 1) % n as u32;
                    }
                    Edge::new(u, v)
                })
                .collect();
            let chunk = 1 + rng.next_below(6) as usize;
            let h = 1 + rng.next_below(24);

            for horizon in [CommitHorizon::Edges(h), CommitHorizon::Edges(2 * h)] {
                let mut reference: Option<(CommittedBase, Vec<u32>)> = None;
                for leaders in [1usize, 2, 4] {
                    let mut log = CrossLog::new(horizon, leaders);
                    let mut merger = Merger::new();
                    let mut shards: Vec<LeaderShard> =
                        (0..leaders).map(|l| LeaderShard::new(l, leaders)).collect();

                    for part in cross.chunks(chunk) {
                        log.append(&mut part.to_vec());
                        let start = merger.drained();
                        let suffix = log.suffix_from(start);
                        let mut frozen = Vec::with_capacity(suffix.len() * 2);
                        merger.drain(&cfg, &states, &suffix, Some(&mut frozen));
                        log.record_frozen(start, &frozen);
                        for ep in log.take_committable(merger.drained()) {
                            for (l, slice) in ep.frozen_slices().iter().enumerate() {
                                shards[l].commit(slice);
                            }
                        }
                    }

                    let merged = merge_committed_bases(
                        &shards.iter().map(|s| s.base().clone()).collect::<Vec<_>>(),
                    );
                    let tail = log.suffix_from(log.committed_edges());
                    let snap =
                        Snapshot::build_over(&cfg, merged.clone(), &states, &tail);
                    if merged.m() != log.committed_edges() {
                        return Err(format!(
                            "leaders={leaders}: merged base covers {} edges, \
                             log committed {}",
                            merged.m(),
                            log.committed_edges()
                        ));
                    }
                    match &reference {
                        None => reference = Some((merged, snap.labels())),
                        Some((base1, labels1)) => {
                            if merged.degree != base1.degree
                                || merged.community != base1.community
                                || merged.records() != base1.records()
                            {
                                return Err(format!(
                                    "leaders={leaders}: merged base slices diverged \
                                     from the single-leader base (h={h})"
                                ));
                            }
                            if snap.labels() != *labels1 {
                                return Err(format!(
                                    "leaders={leaders}: terminal partition diverged \
                                     (h={h})"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn top_communities_sorted_by_volume() {
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        // triangle on {0,1,2} (volume 6) vs single edge {4,5} (volume 2)
        for e in [Edge::new(0, 1), Edge::new(1, 2), Edge::new(0, 2), Edge::new(4, 5)] {
            a.process_edge(e);
        }
        let snap = Snapshot::build(&cfg, &[a.state.clone()], &[]);
        let top = snap.top_communities(10);
        assert!(!top.is_empty());
        for w in top.windows(2) {
            assert!(w[0].volume >= w[1].volume, "{top:?}");
        }
        let total: u64 = top.iter().map(|c| c.volume).sum();
        assert_eq!(total, 2 * snap.edges());
    }
}
