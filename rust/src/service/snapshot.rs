//! Copy-on-read snapshots: consistent, queryable partitions mid-stream.
//!
//! The batch parallel coordinator only materialises a partition after a
//! final barrier (workers drain → merge → cross-edge replay). The
//! service needs answers *while* the stream is still flowing, so it
//! periodically builds a [`Snapshot`]: clone each shard's sketch under
//! its lock (three flat arrays — cheap), merge the disjoint clones with
//! [`merge_disjoint_states`], and replay the cross-edge buffer through
//! the merged clone exactly as the batch leader would. The live shard
//! states are never blocked for longer than one `memcpy`, and the
//! snapshot is immutable afterwards — readers share it via `Arc` with
//! no further coordination.
//!
//! A snapshot is therefore *exactly* the partition the batch coordinator
//! would have produced had the stream ended at the drain point: every
//! invariant that holds at a stream end (volume conservation
//! `Σ v_k = 2t`, labels in node-id space) holds for every snapshot.

use crate::coordinator::algorithm::{StrConfig, StreamingClusterer};
use crate::coordinator::parallel::merge_disjoint_states;
use crate::coordinator::state::{StreamState, UNSEEN};
use crate::graph::edge::Edge;

/// One row of a top-k community report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommunitySummary {
    /// Community id (lives in the node-id space).
    pub id: u32,
    /// Community volume `v_k` (sum of member degrees).
    pub volume: u64,
    /// Member count.
    pub size: u32,
}

/// An immutable, point-in-time partition of the ingested stream.
#[derive(Debug, Clone)]
pub struct Snapshot {
    state: StreamState,
    /// Intra-shard edges covered by this snapshot.
    pub local_edges: u64,
    /// Cross-shard edges replayed into this snapshot.
    pub cross_edges: u64,
}

impl Snapshot {
    /// The before-any-edges snapshot: every node is its own singleton.
    pub(crate) fn empty() -> Self {
        Self { state: StreamState::new(0), local_edges: 0, cross_edges: 0 }
    }

    /// Merge shard sketches and replay the pending cross edges, exactly
    /// the batch leader's final step (`coordinator::parallel`).
    pub(crate) fn build(
        config: &StrConfig,
        shard_states: &[StreamState],
        cross: &[Edge],
    ) -> Self {
        let merged = merge_disjoint_states(0, shard_states);
        let local_edges = merged.edges_processed;
        let mut leader = StreamingClusterer::new(0, config.clone());
        leader.state = merged;
        leader.process_chunk(cross);
        Self { state: leader.state, local_edges, cross_edges: cross.len() as u64 }
    }

    /// The merged sketch behind this snapshot.
    pub fn state(&self) -> &StreamState {
        &self.state
    }

    /// Edges covered by this snapshot (`t` in the paper).
    pub fn edges(&self) -> u64 {
        self.state.edges_processed
    }

    /// Current community of `node`. Nodes the stream has not mentioned
    /// yet (including ids beyond the sketch) are their own singleton.
    pub fn community_of(&self, node: u32) -> u32 {
        let i = node as usize;
        if i >= self.state.n() {
            return node;
        }
        let c = self.state.community[i];
        if c == UNSEEN {
            node
        } else {
            c
        }
    }

    /// Full label vector (unseen nodes as singletons).
    pub fn labels(&self) -> Vec<u32> {
        self.state.labels()
    }

    /// Label vector padded to `n` entries: the sketch only grows to the
    /// largest streamed id, so trailing never-seen nodes are filled in
    /// as their own singletons (for scoring against ground truth of a
    /// known node count).
    pub fn labels_padded(&self, n: usize) -> Vec<u32> {
        let mut labels = self.state.labels();
        while labels.len() < n {
            labels.push(labels.len() as u32);
        }
        labels
    }

    /// Number of non-empty communities.
    pub fn community_count(&self) -> usize {
        self.state.community_count()
    }

    /// The `k` largest communities by volume.
    pub fn top_communities(&self, k: usize) -> Vec<CommunitySummary> {
        self.state
            .community_volumes()
            .into_iter()
            .take(k)
            .map(|(id, volume, size)| CommunitySummary { id, volume, size })
            .collect()
    }

    /// Sketch bytes held by this snapshot (16 bytes/node).
    pub fn memory_bytes(&self) -> usize {
        self.state.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_all_singletons() {
        let s = Snapshot::empty();
        assert_eq!(s.edges(), 0);
        assert_eq!(s.community_of(0), 0);
        assert_eq!(s.community_of(12345), 12345);
        assert!(s.top_communities(4).is_empty());
        assert_eq!(s.community_count(), 0);
    }

    #[test]
    fn build_merges_disjoint_shards_and_replays_cross() {
        let cfg = StrConfig::new(8);
        // shard 0 owns nodes {0, 1}, shard 1 owns {5, 6}
        let mut a = StreamingClusterer::new(0, cfg.clone());
        a.process_edge(Edge::new(0, 1));
        let mut b = StreamingClusterer::new(0, cfg.clone());
        b.process_edge(Edge::new(5, 6));
        let cross = vec![Edge::new(1, 5)];
        let snap = Snapshot::build(&cfg, &[a.state.clone(), b.state.clone()], &cross);

        assert_eq!(snap.local_edges, 2);
        assert_eq!(snap.cross_edges, 1);
        assert_eq!(snap.edges(), 3);
        // stream-end invariant holds mid-stream
        assert_eq!(snap.state().total_volume(), 2 * snap.edges());
        // intra-shard joins survive the merge
        assert_eq!(snap.community_of(0), snap.community_of(1));
        assert_eq!(snap.community_of(5), snap.community_of(6));
    }

    #[test]
    fn top_communities_sorted_by_volume() {
        let cfg = StrConfig::new(64);
        let mut a = StreamingClusterer::new(0, cfg.clone());
        // triangle on {0,1,2} (volume 6) vs single edge {4,5} (volume 2)
        for e in [Edge::new(0, 1), Edge::new(1, 2), Edge::new(0, 2), Edge::new(4, 5)] {
            a.process_edge(e);
        }
        let snap = Snapshot::build(&cfg, &[a.state.clone()], &[]);
        let top = snap.top_communities(10);
        assert!(!top.is_empty());
        for w in top.windows(2) {
            assert!(w[0].volume >= w[1].volume, "{top:?}");
        }
        let total: u64 = top.iter().map(|c| c.volume).sum();
        assert_eq!(total, 2 * snap.edges());
    }
}
