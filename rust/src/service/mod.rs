//! Long-lived sharded clustering service.
//!
//! The paper's algorithm stores three integers per node and touches
//! each edge once — the ideal shape for an *ingestion service*, not
//! just a batch CLI. This module promotes the batch parallel
//! coordinator into exactly that:
//!
//! * [`ingest`] — N shard workers behind bounded mailboxes (sneldb-style
//!   shard/mailbox/backpressure design) fed by a router built on
//!   `stream::shard`; `push` blocks when a shard lags, never drops.
//! * [`snapshot`] — copy-on-read [`Snapshot`]s: merge the disjoint
//!   shard sketches and replay buffered cross edges, producing a valid
//!   partition *mid-stream* (periodic drains keep it fresh).
//! * [`query`] — cloneable [`QueryHandle`]s serving `community_of`
//!   point lookups, top-k community summaries, and an operational
//!   stats endpoint (edges/s, queue depths, memory per node).
//! * [`config`] — [`ServiceConfig`] knobs (shards, `v_max`, mailbox
//!   depth, chunk size, drain cadence).
//!
//! The final partition after [`ClusterService::finish`] is
//! **bit-identical** to `coordinator::parallel::run_parallel` on the
//! same stream — the service is the online form of the same
//! deferred-cross-edge design. See `docs/ARCHITECTURE.md` for the full
//! dataflow and invariants.
//!
//! ```
//! use streamcom::graph::edge::Edge;
//! use streamcom::service::{ClusterService, ServiceConfig};
//!
//! let mut service = ClusterService::start(ServiceConfig::new(2, 8));
//! let queries = service.handle();
//!
//! // a triangle arrives on the stream...
//! service.push_chunk(&[Edge::new(0, 1), Edge::new(1, 2), Edge::new(0, 2)]);
//! // ...and is queryable mid-stream after a drain
//! let snap = service.quiesce();
//! assert_eq!(snap.edges(), 3);
//! assert_eq!(queries.community_of(0), queries.community_of(1));
//!
//! let result = service.finish();
//! assert_eq!(result.edges_ingested, 3);
//! ```

pub mod config;
pub mod ingest;
pub mod query;
pub mod snapshot;

pub use config::ServiceConfig;
pub use ingest::{ClusterService, ServiceResult};
pub use query::{QueryHandle, ServiceStats};
pub use snapshot::{CommunitySummary, Snapshot};
