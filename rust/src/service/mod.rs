//! Long-lived sharded clustering service — and the one routing core.
//!
//! The paper's algorithm stores three integers per node and touches
//! each edge once — the ideal shape for an *ingestion service*, not
//! just a batch CLI. This module is that service, and since the batch
//! coordinator (`coordinator::parallel::run_parallel`) is now a thin
//! preset over it, it is also the **only** route/batch/merge/replay
//! pipeline in the repo:
//!
//! * [`router`] — the single routing/merge core: one-pass per-batch
//!   partitioning (pow2 shard counts take a shift fast path) with
//!   blocking backpressure, cross-edge deferral into the epoch
//!   log, and the disjoint shard-sketch merge.
//! * [`bufpool`] — the chunk-buffer pool closing the router → mailbox
//!   → worker cycle: spent chunks come back for the next dispatch, so
//!   steady-state ingest performs zero heap allocations (hit/miss/
//!   recycled-bytes counters in [`ServiceStats`]).
//! * `crosslog` — the epoch-structured cross-edge log: cross edges
//!   live in sealed epochs; under a bounded [`CommitHorizon`] an epoch
//!   that falls behind the horizon ships its frozen decisions — as
//!   per-leader-partition **epoch deltas** — into the sharded
//!   committed base and its storage is **freed**, which bounds
//!   resident cross-edge memory by `horizon + one epoch`.
//! * [`ingest`] — N shard workers behind bounded mailboxes (sneldb-style
//!   shard/mailbox/backpressure design); `push` blocks when a shard
//!   lags, never drops. For segmented binary scans,
//!   [`ClusterService::ingest_direct`] consumes reader-routed
//!   per-shard sub-chunks (`stream::pscan::DirectScan`) without the
//!   single-threaded routing funnel — same per-shard order, same
//!   partition ([`RouteMode`] picks the path on the CLI).
//! * [`snapshot`] — copy-on-read [`Snapshot`]s plus the sharded drain
//!   leader: a thin commit-invariant `Merger` (each drain folds it over
//!   a fresh shard merge and replays **only the cross edges that
//!   arrived since the last drain** — `O(n + new cross)` instead of
//!   `O(all cross)`) and K per-node-range `LeaderShard` partitions
//!   owning disjoint committed-base slices, merged once at `finish` —
//!   so a mid-stream drain ships epoch deltas only, never the base.
//! * [`query`] — cloneable [`QueryHandle`]s serving `community_of`
//!   point lookups, top-k community summaries, and an operational
//!   stats endpoint (edges/s, queue depths, drain/replay counters,
//!   per-drain delta payload, cross-log retained/committed/freed
//!   occupancy — global and per leader partition — memory per node).
//! * [`config`] — [`ServiceConfig`] knobs (shards, leader partitions,
//!   `v_max`, mailbox depth, chunk size, drain cadence,
//!   [`CommitHorizon`], WAL directory) plus the
//!   [`batch`](ServiceConfig::batch) preset.
//! * [`wal`] — the durability layer: per-destination write-ahead logs
//!   of fixed-width checksummed records plus epoch-aligned checkpoints
//!   written at quiesced cuts, so a crashed service resumes from the
//!   latest checkpoint and replays only the WAL suffix past it. The
//!   durable prefix is **seq-keyed** (`wal::durable_cut` over every
//!   lane's sorted runs), which lets the direct route write
//!   per-reader lanes ([`DirectWalCfg`]) instead of forcing the
//!   funnel; corrupt segments found on resume are quarantined to
//!   `<name>.corrupt` with their clean prefix recovered, and
//!   transient WAL I/O gets a bounded retry. Off by default
//!   (`wal_dir: None`) — the in-memory path is untouched.
//!
//! Failures degrade instead of panicking: reader and worker deaths
//! are recorded as typed [`ServiceError`]s, the remaining feeds drain,
//! and callers observe the fault via `ClusterService::take_fault` or
//! `ServiceResult::fault`.
//!
//! With the default [`CommitHorizon::Unbounded`], the final partition
//! after [`ClusterService::finish`] is **bit-identical** to
//! `coordinator::parallel::run_parallel` on the same stream — by
//! construction, since both are the same code — and independent of the
//! drain cadence, because `finish` then runs the terminal full replay
//! of the whole cross log. [`CommitHorizon::Edges(h)`](CommitHorizon::Edges)
//! trades that exactness for `O(h)` cross-edge memory: old epochs'
//! decisions become final and `finish` replays only the uncommitted
//! tail over the committed base. See `docs/ARCHITECTURE.md` for the
//! full dataflow and invariants.
//!
//! ```
//! use streamcom::graph::edge::Edge;
//! use streamcom::service::{ClusterService, ServiceConfig};
//!
//! let mut service = ClusterService::start(ServiceConfig::new(2, 8));
//! let queries = service.handle();
//!
//! // a triangle arrives on the stream...
//! service.push_chunk(&[Edge::new(0, 1), Edge::new(1, 2), Edge::new(0, 2)]);
//! // ...and is queryable mid-stream after a drain
//! let snap = service.quiesce();
//! assert_eq!(snap.edges(), 3);
//! assert_eq!(queries.community_of(0), queries.community_of(1));
//!
//! let result = service.finish();
//! assert_eq!(result.edges_ingested, 3);
//! ```

pub mod bufpool;
pub mod config;
pub(crate) mod crosslog;
pub mod ingest;
pub mod query;
pub mod router;
pub mod snapshot;
pub mod wal;

pub use bufpool::PoolStats;
pub use config::{CommitHorizon, RouteMode, ServiceConfig};
pub use ingest::{ClusterService, ServiceError, ServiceResult};
pub use query::{LeaderStats, QueryHandle, ServiceStats};
pub use router::merge_disjoint_states;
pub use snapshot::{CommunitySummary, Snapshot};
pub use wal::{CrashPoint, DirectWalCfg, FailPoint, WalError};
